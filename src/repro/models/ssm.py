"""Mamba-2 SSD (state-space duality) mixer — chunked train/prefill form +
O(1)-state decode step.

The chunked algorithm (Dao & Gu, 2024): within a chunk of length Q the
output is a masked quadratic form (matmul-friendly — this is what the MXU
wants); across chunks a tiny (H, N, P) state is carried by an associative
scan. Decode keeps only that state plus a (K-1)-deep conv ring: cache size
is independent of sequence length, which is why the `long_500k` shape is
runnable for SSM/hybrid archs only.

Shapes: B batch, S seq, H ssm heads, P head dim, N state dim, G groups
(B/C shared across H/G heads), Q chunk length.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Params, _dense_init, init_rmsnorm, rmsnorm


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    return d_inner, heads


def init_ssd(key, cfg) -> Params:
    d = cfg.d_model
    n, g, k = cfg.ssm_state, cfg.ssm_groups, cfg.ssm_conv
    d_inner, h = ssm_dims(cfg)
    dt = cfg.param_dtype
    conv_dim = d_inner + 2 * g * n
    ks = jax.random.split(key, 4)
    return {
        # in_proj packs [z, x, B, C, dt].
        "in_proj": _dense_init(ks[0], (d, 2 * d_inner + 2 * g * n + h), dt),
        "conv_w": _dense_init(ks[1], (k, conv_dim), dt, scale=k**-0.5),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "a_log": jnp.zeros((h,), jnp.float32),  # A = -exp(a_log) = -1 at init
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": init_rmsnorm(d_inner, dt),
        "out_proj": _dense_init(ks[2], (d_inner, d), dt, scale=d_inner**-0.5),
    }


def _split_proj(p: Params, x: jax.Array, cfg):
    d_inner, h = ssm_dims(cfg)
    g, n = cfg.ssm_groups, cfg.ssm_state
    proj = x @ p["in_proj"].astype(x.dtype)
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * g * n], axis=-1)
    return z, xbc, dt  # (..., d_inner), (..., conv_dim), (..., H)


def _causal_conv(p: Params, xbc: jax.Array) -> jax.Array:
    """Depthwise causal conv along S. xbc (B, S, C)."""
    k = p["conv_w"].shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1]] * p["conv_w"][i].astype(xbc.dtype)
        for i in range(k)
    )
    return jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))


def ssd(
    p: Params,
    x_in: jax.Array,
    cfg,
    return_final_state: bool = False,
):
    """Chunked SSD forward. x_in (B, S, d_model) -> (B, S, d_model)
    [+ (state (B,H,N,P), conv_tail (B,K-1,conv_dim)) if requested]."""
    cdt = cfg.compute_dtype
    b, orig_s, _ = x_in.shape
    g, n, q = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_chunk
    d_inner, h = ssm_dims(cfg)
    pdim = cfg.ssm_head_dim
    # Pad S to a chunk multiple; padded steps get dt = 0 (identity state
    # transition, zero input) so outputs and the final state are exact.
    pad = (-orig_s) % q
    x_in = jnp.pad(x_in, ((0, 0), (0, pad), (0, 0))) if pad else x_in
    s = orig_s + pad
    nc = s // q

    z, xbc_raw, dt_raw = _split_proj(p, x_in.astype(cdt), cfg)
    xbc = _causal_conv(p, xbc_raw)
    x, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    if pad:
        valid = (jnp.arange(s) < orig_s)[None, :, None]
        dt = jnp.where(valid, dt, 0.0)
    a = -jnp.exp(p["a_log"])  # (H,)
    da = dt * a  # (B,S,H) log-decay per step

    # chunk reshapes
    xh = x.reshape(b, nc, q, h, pdim)
    bm = bmat.reshape(b, nc, q, g, n)
    cm = cmat.reshape(b, nc, q, g, n)
    dtc = dt.reshape(b, nc, q, h)
    dac = da.reshape(b, nc, q, h)

    ca = jnp.cumsum(dac, axis=2)  # (B,Nc,Q,H) inclusive cumsum of log decay
    xdt = xh * dtc[..., None].astype(cdt)

    heads_per_group = h // g
    # intra-chunk: M[i,j] = (C_i . B_j) * exp(ca_i - ca_j) for j <= i
    cb = jnp.einsum("bcqgn,bckgn->bcgqk", cm, bm)  # (B,Nc,G,Q,Q)
    cb = jnp.repeat(cb, heads_per_group, axis=2)  # (B,Nc,H,Q,Q)
    decay = ca[..., :, None, :] - ca[..., None, :, :]  # (B,Nc,Q,Q,H) i,j
    decay = jnp.moveaxis(decay, -1, 2)  # (B,Nc,H,Q,Q)
    causal = jnp.tril(jnp.ones((q, q), bool))
    m = jnp.where(causal, cb.astype(jnp.float32) * jnp.exp(decay), 0.0).astype(cdt)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", m, xdt)

    # chunk states: S_c = sum_j exp(ca_last - ca_j) B_j (dt_j x_j)^T
    tail = jnp.exp(ca[..., -1:, :] - ca)  # (B,Nc,Q,H)
    bx = jnp.einsum(
        "bcqhn,bcqhp->bchnp",
        jnp.repeat(bm, heads_per_group, axis=3),
        xdt * tail[..., None].astype(cdt),
    )
    gamma = jnp.exp(ca[:, :, -1, :])  # (B,Nc,H) chunk total decay

    # inter-chunk associative scan: h_after_c = gamma_c * h_before_c + S_c
    def combine(left, right):
        gl, sl = left
        gr, sr = right
        return gl * gr, sr + sl * gr[..., None, None].astype(sl.dtype)

    g_scan, s_scan = jax.lax.associative_scan(
        combine, (gamma.astype(jnp.float32), bx.astype(jnp.float32)), axis=1
    )
    # state *before* chunk c = state after c-1; before chunk 0 = 0.
    h_before = jnp.concatenate(
        [jnp.zeros_like(s_scan[:, :1]), s_scan[:, :-1]], axis=1
    ).astype(cdt)

    y_inter = jnp.einsum(
        "bcqhn,bchnp->bcqhp", jnp.repeat(cm, heads_per_group, axis=3), h_before
    ) * jnp.exp(ca)[..., None].astype(cdt)

    y = (y_intra + y_inter + xh * p["d_skip"].astype(cdt)[..., None]).reshape(
        b, s, d_inner
    )
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = (y @ p["out_proj"].astype(cdt))[:, :orig_s]

    if not return_final_state:
        return out
    final_state = s_scan[:, -1].astype(cdt)  # (B,H,N,P) exact: padded dt = 0
    k = cfg.ssm_conv
    conv_tail = xbc_raw[:, orig_s - (k - 1) : orig_s, :]  # pre-conv activations
    return out, (final_state, conv_tail)


def init_ssd_cache(cfg, batch: int, dtype):
    d_inner, h = ssm_dims(cfg)
    conv_dim = d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "state": jnp.zeros((batch, h, cfg.ssm_state, cfg.ssm_head_dim), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def ssd_decode(p: Params, x_in: jax.Array, cache: Params, cfg):
    """Single-token SSD step. x_in (B, 1, d_model) -> (B, 1, d_model), cache'.

    State update: h <- exp(dt*A) h + B (dt*x)^T ; y = C.h + D*x.
    """
    cdt = cfg.compute_dtype
    b = x_in.shape[0]
    g, n = cfg.ssm_groups, cfg.ssm_state
    d_inner, h = ssm_dims(cfg)
    pdim = cfg.ssm_head_dim
    hpg = h // g

    z, xbc_raw, dt_raw = _split_proj(p, x_in.astype(cdt), cfg)
    # conv over ring of the last K-1 raw inputs + current
    hist = jnp.concatenate([cache["conv"].astype(cdt), xbc_raw], axis=1)  # (B,K,conv)
    k = cfg.ssm_conv
    conv_out = sum(hist[:, i] * p["conv_w"][i].astype(cdt) for i in range(k))
    xbc = jax.nn.silu(conv_out + p["conv_b"].astype(cdt))[:, None, :]  # (B,1,conv)
    x, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    decay = jnp.exp(dt * -jnp.exp(p["a_log"]))  # (B,H)
    xh = x[:, 0].reshape(b, h, pdim)
    bmg = jnp.repeat(bmat[:, 0].reshape(b, g, n), hpg, axis=1)  # (B,H,N)
    cmg = jnp.repeat(cmat[:, 0].reshape(b, g, n), hpg, axis=1)

    xdt = xh * dt[..., None].astype(cdt)
    new_state = cache["state"].astype(cdt) * decay[..., None, None].astype(cdt) + (
        bmg[..., :, None] * xdt[..., None, :]
    )  # (B,H,N,P)
    y = jnp.einsum("bhn,bhnp->bhp", cmg, new_state) + xh * p["d_skip"].astype(cdt)[
        ..., None
    ]
    y = y.reshape(b, 1, d_inner)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(cdt)
    new_cache = {
        "state": new_state.astype(cache["state"].dtype),
        "conv": hist[:, 1:].astype(cache["conv"].dtype),
    }
    return out, new_cache
