"""Model-zoo building blocks: norm, RoPE, GQA attention (causal / sliding /
cross), gated MLP, top-k MoE (capacity-based dispatch), all as pure functions
over param pytrees (dicts of jnp arrays). No framework dependency.

Conventions:
* params are stored in ``cfg.param_dtype`` (f32 by default) and cast to
  ``cfg.compute_dtype`` (bf16) at use — the production mixed-precision recipe.
* every init fn takes an ``ArchConfig``-like cfg (duck-typed fields).
* attention caches: full causal layers use a (B, S_max, KV, hd) buffer
  indexed by absolute position; sliding-window layers use a ring buffer of
  the window size (position mod W).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


# -- initializers -------------------------------------------------------------


def _dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# -- normalization ------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


# -- rotary embeddings ---------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, hd), positions (..., S) -> rotated x."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- attention ----------------------------------------------------------------


def init_attention(key, cfg, cross: bool = False) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.q_heads, cfg.kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    ks = jax.random.split(key, 6)
    p = {
        "wq": _dense_init(ks[0], (d, h, hd), dt),
        "wk": _dense_init(ks[1], (d, kv, hd), dt),
        "wv": _dense_init(ks[2], (d, kv, hd), dt),
        "wo": _dense_init(ks[3], (h, hd, d), dt, scale=(h * hd) ** -0.5),
    }
    if getattr(cfg, "qk_norm", False):
        p["q_norm"] = init_rmsnorm(hd, dt)
        p["k_norm"] = init_rmsnorm(hd, dt)
    return p


def _attn_scores_mask(
    q_pos: jax.Array, k_pos: jax.Array, causal: bool, window: int | None
) -> jax.Array:
    """(..., S_q) x (..., S_k) -> (..., S_q, S_k) additive mask in f32."""
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(dq.shape, dk.shape), dtype=bool)
    if causal:
        ok &= dk <= dq
    if window is not None:
        ok &= dk > dq - window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attention(
    p: Params,
    x: jax.Array,
    *,
    cfg,
    positions: jax.Array,
    kv_x: jax.Array | None = None,
    kv_positions: jax.Array | None = None,
    causal: bool = True,
    window: int | None = None,
    use_rope: bool = True,
) -> jax.Array:
    """Full (prefill/train) attention. x (B, S, d) -> (B, S, d).

    ``kv_x`` switches to cross-attention (no mask, no rope on kv side unless
    kv_positions given)."""
    cdt = cfg.compute_dtype
    xq = x.astype(cdt)
    xkv = (kv_x if kv_x is not None else x).astype(cdt)
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(cdt))
    k = jnp.einsum("btd,dhk->bthk", xkv, p["wk"].astype(cdt))
    v = jnp.einsum("btd,dhk->bthk", xkv, p["wv"].astype(cdt))
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        if kv_x is None:
            k = rope(k, positions, cfg.rope_theta)
        elif kv_positions is not None:
            k = rope(k, kv_positions, cfg.rope_theta)

    b, s, h, hd = q.shape
    kvh = k.shape[2]

    if (
        kv_x is None
        and window is not None
        and getattr(cfg, "block_local_attn", False)
        and s > window
    ):
        out = _block_local_attention(q, k, v, window, cdt)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))

    if getattr(cfg, "gqa_repeat_kv", False) and kvh < h:
        # Repeat KV to the q-head count so the score einsum keeps a single
        # head dim sharded over `model` (the (kv, g) reshape below defeats
        # the SPMD partitioner's head sharding for kv % mesh != 0).
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
        scores *= hd**-0.5
        if kv_x is None:
            mask = _attn_scores_mask(positions, positions, causal, window)
            scores = scores + mask[:, None, :, :]
        w = jax.nn.softmax(scores, axis=-1).astype(cdt)
        out = jnp.einsum("bhst,bthd->bshd", w, v)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))

    g = h // kvh
    q = q.reshape(b, s, kvh, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    scores *= hd**-0.5
    if kv_x is None:  # self-attention: causal / sliding mask
        kpos = positions
        mask = _attn_scores_mask(positions, kpos, causal, window)
        scores = scores + mask[:, None, None, :, :]
    w = jax.nn.softmax(scores, axis=-1).astype(cdt)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v).reshape(b, s, h, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))


def _block_local_attention(q, k, v, window: int, cdt):
    """Banded sliding-window attention: O(S * 2W) instead of O(S^2).

    Queries are split into blocks of W; block i attends to key blocks i-1
    and i, which covers every key in (pos-W, pos]. With W == block size the
    static relative mask is: j > i' (window) and j <= i' + W (causal), for
    key column j in [0, 2W) and query row i' in [0, W); block 0 additionally
    masks its (nonexistent) previous block.
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    w = window
    pad = (-s) % w
    if pad:
        cfgpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, cfgpad), jnp.pad(k, cfgpad), jnp.pad(v, cfgpad)
    sp = s + pad
    nb = sp // w

    qb = q.reshape(b, nb, w, kvh, g, hd)
    kb = k.reshape(b, nb, w, kvh, hd)
    vb = v.reshape(b, nb, w, kvh, hd)
    # NOTE (§Perf hymba iteration 3, refuted): replacing these concats with
    # sliced einsums + pad + scatter-add *increased* bytes by 38% — the
    # out-of-place pad/add copies cost more than the 2W-wide K/V views.
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kb], axis=2)  # (b, nb, 2w, kvh, hd)
    v2 = jnp.concatenate([vprev, vb], axis=2)

    scores = jnp.einsum("bnikgd,bnjkd->bnkgij", qb, k2).astype(jnp.float32)
    scores *= hd**-0.5
    qi = jnp.arange(w)[:, None]
    kj = jnp.arange(2 * w)[None, :]
    ok = (kj > qi) & (kj <= qi + w)  # (w, 2w) window+causal band
    block0_ok = kj >= w  # no previous block for block 0
    mask = jnp.where(ok[None], 0.0, -1e30) + jnp.where(
        (jnp.arange(nb)[:, None, None] > 0) | block0_ok[None], 0.0, -1e30
    )
    scores = scores + mask[None, :, None, None, :, :]
    wts = jax.nn.softmax(scores, axis=-1).astype(cdt)
    out = jnp.einsum("bnkgij,bnjkd->bnikgd", wts, v2)
    out = out.reshape(b, sp, h, hd)
    return out[:, :s]


def init_attn_cache(cfg, batch: int, max_len: int, window: int | None, dtype):
    w = min(window, max_len) if window else max_len
    shape = (batch, w, cfg.kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def decode_attention(
    p: Params,
    x: jax.Array,
    cache: Params,
    pos: jax.Array,
    *,
    cfg,
    window: int | None = None,
    use_rope: bool = True,
) -> tuple[jax.Array, Params]:
    """One-token decode. x (B, 1, d), pos scalar int32 -> (B, 1, d), cache'.

    Full layers write at ``pos``; sliding layers write at ``pos mod W`` (ring)
    and mask out slots older than the window.
    """
    cdt = cfg.compute_dtype
    xq = x.astype(cdt)
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", xq, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", xq, p["wv"].astype(cdt))
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    posv = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
    if use_rope:
        q = rope(q, posv, cfg.rope_theta)
        k = rope(k, posv, cfg.rope_theta)

    buf_len = cache["k"].shape[1]
    slot = pos % buf_len if window else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)

    b, _, h, hd = q.shape
    kvh = ck.shape[2]
    g = h // kvh
    qr = q.reshape(b, 1, kvh, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qr, ck.astype(cdt)).astype(jnp.float32)
    scores *= hd**-0.5

    slots = jnp.arange(buf_len)
    if window:
        # Ring buffer: valid iff the slot holds a position in (pos-W, pos].
        age = (slot - slots) % buf_len  # 0 = current token
        valid = (age <= pos) & (age < buf_len)
    else:
        valid = slots <= pos
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(cdt)
    out = jnp.einsum("bkgst,btkd->bskgd", w, cv.astype(cdt)).reshape(b, 1, h, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))
    return y, {"k": ck, "v": cv}


def flash_decode_attention(
    p: Params,
    x: jax.Array,
    cache: Params,
    pos: int,
    *,
    cfg,
    mesh,
    batch_axes=("data",),
    seq_axis: str = "model",
) -> tuple[jax.Array, Params]:
    """Flash-decode: one-token attention against a sequence-sharded KV cache
    WITHOUT gathering it (the baseline pjit lowering all-gathers K and V per
    layer — see EXPERIMENTS.md §Perf, llama-3.2-vision-90b decode_32k).

    shard_map over (batch_axes x seq_axis): each seq shard computes partial
    (max, exp-sum, weighted-V) statistics over its KV slice; a 3-term
    psum/pmax combine reconstructs the exact softmax. The cache write lands
    on the one shard owning ``pos`` (static at trace time).

    Requires ``pos`` static and no sliding window (ring caches are small and
    stay on the plain path).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    cdt = cfg.compute_dtype
    b, _, d = x.shape
    s_total = cache["k"].shape[1]
    n_seq = mesh.shape[seq_axis]
    shard_len = s_total // n_seq
    owner = pos // shard_len
    local_slot = pos % shard_len

    xq = x.astype(cdt)
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(cdt))
    k_new = jnp.einsum("bsd,dhk->bshk", xq, p["wk"].astype(cdt))
    v_new = jnp.einsum("bsd,dhk->bshk", xq, p["wv"].astype(cdt))
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q)
        k_new = rmsnorm(p["k_norm"], k_new)
    posv = jnp.full((b, 1), pos, dtype=jnp.int32)
    q = rope(q, posv, cfg.rope_theta)
    k_new = rope(k_new, posv, cfg.rope_theta)

    ba = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    qspec = P(ba, None, None, None)
    cspec = P(ba, seq_axis, None, None)

    def kernel(q_l, kn_l, vn_l, ck_l, cv_l):
        idx = jax.lax.axis_index(seq_axis)

        def write(buf, new):
            return jax.lax.dynamic_update_slice_in_dim(
                buf, new.astype(buf.dtype), local_slot, axis=1
            )

        ck_l = jax.lax.cond(idx == owner, lambda: write(ck_l, kn_l), lambda: ck_l)
        cv_l = jax.lax.cond(idx == owner, lambda: write(cv_l, vn_l), lambda: cv_l)

        bl, _, h, hd = q_l.shape
        kvh = ck_l.shape[2]
        g = h // kvh
        qr = q_l.reshape(bl, 1, kvh, g, hd)
        sc = jnp.einsum("bskgd,btkd->bkgst", qr, ck_l.astype(cdt)).astype(jnp.float32)
        sc = sc * hd**-0.5
        kpos = idx * shard_len + jnp.arange(shard_len)
        sc = jnp.where(kpos[None, None, None, None, :] <= pos, sc, -1e30)

        m_loc = jnp.max(sc, axis=-1, keepdims=True)  # (b,kv,g,1,1)
        m_glob = jax.lax.pmax(m_loc, seq_axis)
        w = jnp.exp(sc - m_glob)
        den = jax.lax.psum(jnp.sum(w, axis=-1), seq_axis)  # (b,kv,g,1)
        num = jnp.einsum("bkgst,btkd->bskgd", w.astype(cdt), cv_l.astype(cdt))
        num = jax.lax.psum(num, seq_axis)  # (b,1,kv,g,hd)
        # den (b,kv,g,s=1) -> (b,1,kv,g,1) to broadcast against num.
        den_r = den.transpose(0, 3, 1, 2)[..., None]
        out = num / jnp.maximum(den_r, 1e-30)
        return out.reshape(bl, 1, h, hd).astype(cdt), ck_l, cv_l

    f = shard_map(
        kernel,
        mesh=mesh,
        in_specs=(qspec, qspec, qspec, cspec, cspec),
        out_specs=(qspec, cspec, cspec),
        check_rep=False,
    )
    out, ck, cv = f(q, k_new, v_new, cache["k"], cache["v"])
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))
    return y, {"k": ck, "v": cv}


# -- gated MLP ----------------------------------------------------------------


def init_mlp(key, cfg, d_ff: int | None = None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.param_dtype
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d, ff), dt),
        "w_in": _dense_init(ks[1], (d, ff), dt),
        "w_out": _dense_init(ks[2], (ff, d), dt, scale=ff**-0.5),
    }


def mlp(p: Params, x: jax.Array, cfg) -> jax.Array:
    cdt = cfg.compute_dtype
    x = x.astype(cdt)
    g = jax.nn.silu(x @ p["w_gate"].astype(cdt))
    u = x @ p["w_in"].astype(cdt)
    return (g * u) @ p["w_out"].astype(cdt)


# -- mixture of experts ---------------------------------------------------------


def init_moe(key, cfg) -> Params:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = cfg.param_dtype
    ks = jax.random.split(key, 4)
    p = {
        "router": _dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": _dense_init(ks[1], (e, d, ff), dt),
        "w_in": _dense_init(ks[2], (e, d, ff), dt),
        "w_out": _dense_init(ks[3], (e, ff, d), dt, scale=ff**-0.5),
    }
    if getattr(cfg, "moe_shared_ff", 0):
        p["shared"] = init_mlp(jax.random.fold_in(key, 7), cfg, cfg.moe_shared_ff)
    return p


def moe(p: Params, x: jax.Array, cfg, capacity_factor: float | None = None) -> jax.Array:
    """Top-k MoE with capacity-based scatter dispatch (MaxText-style
    'dropping' implementation): tokens beyond an expert's capacity are
    dropped (contribute zero), which keeps every shape static and makes the
    expert matmuls dense (E, C, d) x (E, d, f) einsums — the production
    expert-parallel formulation (experts sharded over the `model` axis).

    Capacity policy: small token counts (decode steps) get a drop-free
    capacity (= T, worst case all tokens on one expert — the buffer is tiny
    there); large token counts (training/prefill) use the standard
    capacity-factor dropping.
    """
    cdt = cfg.compute_dtype
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.moe_top_k
    if capacity_factor is None:
        cap = t if t <= 256 else max(1, int(k * t / e * 1.25))
    else:
        cap = max(1, int(k * t / e * capacity_factor))

    xt = x.reshape(t, d).astype(cdt)
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, choice) within its expert's capacity buffer.
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)  # (T, k, E)
    flat_oh = onehot.reshape(t * k, e)
    if getattr(cfg, "moe_scan_dispatch", False):
        # Hierarchical log-depth prefix sum (== cumsum, cheaper twice over):
        # 1. XLA lowers jnp.cumsum to an O(n^2) reduce-window whose cost
        #    model poisons the roofline (kimi §Perf iteration 1);
        # 2. a flat scan over the token axis spans the data shards, costing
        #    all-to-alls (iteration 2). Blocking by the DP degree keeps each
        #    scan shard-local; only the (blocks, E) totals cross shards.
        nb = 16 if (t * k) % 16 == 0 else 1
        r = flat_oh.reshape(nb, (t * k) // nb, e)
        local = jax.lax.associative_scan(jnp.add, r, axis=1)
        totals = local[:, -1, :]  # (nb, E)
        offsets = jnp.cumsum(totals, axis=0) - totals  # exclusive, tiny
        csum = (local + offsets[:, None, :]).reshape(t * k, e)
    else:
        csum = jnp.cumsum(flat_oh, axis=0)
    pos_in_expert = (csum - flat_oh).reshape(t, k, e)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)  # (T, k)
    keep = pos < cap

    # Scatter tokens into (E, C, d); dropped tokens go to a trash row.
    buf = jnp.zeros((e, cap + 1, d), cdt)
    slot = jnp.where(keep, pos, cap)
    buf = buf.at[expert_idx.reshape(-1), slot.reshape(-1)].add(
        jnp.repeat(xt, k, axis=0)
    )
    buf = buf[:, :cap]  # (E, C, d)

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(cdt)))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_in"].astype(cdt))
    out_buf = jnp.einsum("ecf,efd->ecd", g * u, p["w_out"].astype(cdt))  # (E, C, d)

    # Gather back with gate weighting.
    gathered = out_buf[expert_idx.reshape(-1), jnp.minimum(slot, cap - 1).reshape(-1)]
    gathered = gathered.reshape(t, k, d) * (gate_vals * keep)[..., None].astype(cdt)
    y = gathered.sum(axis=1)

    if "shared" in p:
        y = y + mlp(p["shared"], xt, cfg)
    return y.reshape(b, s, d)


def moe_aux_loss(p: Params, x: jax.Array, cfg) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style): E * sum_e f_e * p_e."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    frac = jnp.mean(jax.nn.one_hot(idx, cfg.num_experts, dtype=jnp.float32), axis=(0, 1))
    imp = jnp.mean(probs, axis=0)
    return cfg.num_experts * jnp.sum(frac * imp)
