"""Sharding rules: param pytree -> PartitionSpec pytree for a given mesh.

TP over the ``model`` axis (attention heads / ffn / experts / vocab), DP over
``data`` (+ ``pod``), optional FSDP (large param dims additionally sharded
over ``data``, ZeRO-3 style — XLA inserts the per-layer all-gathers).

Rules are (path, shape) driven: the key path disambiguates e.g. a dense MLP
``w_gate`` (d, ff) from an expert ``w_gate`` (E, d, ff), and any extra
leading dims are scan stacks (replicated). Dims that don't divide the mesh
axis fall back to replication (e.g. 8 KV heads on a 16-way model axis).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

MODEL_AXIS = "model"
DATA_AXIS = "data"


def _rule(names: tuple[str, ...], shape: tuple[int, ...], cfg: ArchConfig, mesh_shape: dict) -> P:
    name = names[-1]
    in_moe = "moe" in names[:-1]
    msize = mesh_shape.get(MODEL_AXIS, 1)
    dsize = mesh_shape.get(DATA_AXIS, 1)

    def m(dim: int):  # model axis if divisible
        return MODEL_AXIS if shape[dim] % msize == 0 else None

    def f(dim: int):  # fsdp: data axis if enabled and divisible
        return DATA_AXIS if cfg.fsdp and shape[dim] % dsize == 0 else None

    def spec(base_rank: int, *axes) -> P:
        lead = len(shape) - base_rank
        return P(*([None] * lead + list(axes)))

    if name == "embed":
        return P(m(0), None)
    if name == "lm_head":
        return P(None, m(1))
    if name == "wq":
        return spec(3, f(-3), m(-2), None)  # (d, H, hd)
    if name in ("wk", "wv"):
        return spec(3, f(-3), m(-2), None)  # (d, KV, hd); replicates if KV % m != 0
    if name == "wo":
        return spec(3, m(-3), None, f(-1))  # (H, hd, d)
    if name in ("w_gate", "w_in"):
        if in_moe:
            return spec(3, m(-3), f(-2), None)  # (E, d, ff): EP on experts
        return spec(2, f(-2), m(-1))  # (d, ff): TP on ff
    if name == "w_out":
        if in_moe:
            return spec(3, m(-3), None, f(-1))  # (E, ff, d)
        return spec(2, m(-2), f(-1))  # (ff, d)
    if name == "in_proj":
        return spec(2, f(-2), m(-1))  # (d, packed): TP on the packed dim
    if name == "out_proj":
        return spec(2, m(-2), f(-1))  # (d_inner, d)
    if name == "router":
        return spec(2, None, None)
    # conv / norms / scalars: replicate (tiny).
    return P(*([None] * len(shape)))


def _path_names(path) -> tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return tuple(out)


def make_pspecs(cfg: ArchConfig, mesh: Mesh, params):
    """Pytree of PartitionSpec matching ``params`` (leaves may be arrays or
    ShapeDtypeStructs — only .shape is read)."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [
        _rule(_path_names(path), leaf.shape, cfg, mesh_shape) for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def make_shardings(cfg: ArchConfig, mesh: Mesh, params):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), make_pspecs(cfg, mesh, params)
    )


def batch_pspec(mesh: Mesh) -> P:
    """Token batches shard over every non-model axis (pod x data)."""
    axes = tuple(a for a in mesh.axis_names if a != MODEL_AXIS)
    return P(axes if len(axes) > 1 else axes[0], None)


def cache_pspec(mesh: Mesh, seq_over_model: bool = True) -> P:
    """KV caches (B, S, KV, hd): batch over data axes; sequence over model
    (flash-decode-style partial-KV attention; XLA inserts the softmax psums)."""
    axes = tuple(a for a in mesh.axis_names if a != MODEL_AXIS)
    batch_axes = axes if len(axes) > 1 else axes[0]
    return P(batch_axes, MODEL_AXIS if seq_over_model else None, None, None)


def cache_pspecs(mesh: Mesh, cache, batch: int):
    """PartitionSpec pytree for a decode-cache pytree (path-name driven).

    * attention k/v  (..., B, S, KV, hd): batch over data axes (when it
      divides), sequence over model (flash-decode partial-KV attention).
    * ssm state      (..., B, H, N, P):   batch over data, heads over model.
    * ssm conv       (..., B, K-1, C):    batch over data, channels over model.
    * cross k/v      (..., B, M, KV, hd): like attention (memory over model).
    """
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = mesh_shape.get(MODEL_AXIS, 1)
    data_axes = tuple(a for a in mesh.axis_names if a != MODEL_AXIS)
    dprod = 1
    for a in data_axes:
        dprod *= mesh_shape[a]
    ba = (data_axes if len(data_axes) > 1 else data_axes[0]) if batch % dprod == 0 else None

    def rule(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        shp = leaf.shape
        if "conv" in names[-1:]:
            lead = len(shp) - 3
            ch = MODEL_AXIS if shp[-1] % msize == 0 else None
            return P(*([None] * lead), ba, None, ch)
        if "state" in names[-1:]:
            lead = len(shp) - 4
            hx = MODEL_AXIS if shp[-3] % msize == 0 else None
            return P(*([None] * lead), ba, hx, None, None)
        # attention-like: (..., B, S, KV, hd)
        lead = len(shp) - 4
        seq = MODEL_AXIS if shp[-3] % msize == 0 else None
        return P(*([None] * lead), ba, seq, None, None)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [rule(path, leaf) for path, leaf in flat]
    )
