"""Encoder-decoder transformer (seamless-m4t-medium backbone).

Per the assignment spec, the modality frontend is a STUB: the encoder input
is a precomputed frame-embedding sequence at d_model (provided by
``input_specs()``); the decoder is a standard text decoder with cross
attention over the encoder output.

Entry points mirror transformer.py: init_params / loss_fn / encode /
prefill / decode_step. Decode caches both the decoder self-attention KV and
the (computed-once) cross KV of the encoder memory.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

Params = dict[str, Any]


def _scan(cfg, body, init, xs):
    """lax.scan with the config's unroll factor (see transformer._scan)."""
    unroll = cfg.scan_unroll
    if unroll == 0:
        unroll = jax.tree.leaves(xs)[0].shape[0]
    return jax.lax.scan(body, init, xs, unroll=max(unroll, 1))


def _init_enc_block(key, cfg) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "mlp": L.init_mlp(ks[1], cfg),
    }


def _init_dec_block(key, cfg) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "self_attn": L.init_attention(ks[0], cfg),
        "lnx": L.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "cross_attn": L.init_attention(ks[1], cfg, cross=True),
        "ln2": L.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "mlp": L.init_mlp(ks[2], cfg),
    }


def init_params(cfg: ArchConfig, key) -> Params:
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.dec_layers)
    return {
        "embed": L._dense_init(ks[2], (cfg.padded_vocab, cfg.d_model), cfg.param_dtype, 1.0),
        "encoder": jax.vmap(lambda k: _init_enc_block(k, cfg))(enc_keys),
        "decoder": jax.vmap(lambda k: _init_dec_block(k, cfg))(dec_keys),
        "enc_norm": L.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "lm_head": L._dense_init(ks[3], (cfg.d_model, cfg.padded_vocab), cfg.param_dtype),
    }


def _remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat != "none" else fn


def encode(params: Params, frames: jax.Array, cfg: ArchConfig) -> jax.Array:
    """frames (B, S_enc, d_model) stub embeddings -> encoder memory."""
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = frames.astype(cfg.compute_dtype)

    def body(h, p):
        x = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        h = h + L.attention(p["attn"], x, cfg=cfg, positions=positions, causal=False)
        x = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
        return h + L.mlp(p["mlp"], x, cfg), None

    h, _ = _scan(cfg, _remat(body, cfg), h, params["encoder"])
    return L.rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def _decode_body(cfg, memory, positions):
    def body(h, p):
        x = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        h = h + L.attention(p["self_attn"], x, cfg=cfg, positions=positions)
        x = L.rmsnorm(p["lnx"], h, cfg.norm_eps)
        h = h + L.attention(
            p["cross_attn"], x, cfg=cfg, positions=positions,
            kv_x=memory, causal=False, use_rope=False,
        )
        x = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
        return h + L.mlp(p["mlp"], x, cfg), None

    return body


def forward(params: Params, frames: jax.Array, tokens: jax.Array, cfg: ArchConfig):
    memory = encode(params, frames, cfg)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = params["embed"][tokens].astype(cfg.compute_dtype)
    h, _ = _scan(cfg, 
        _remat(_decode_body(cfg, memory, positions), cfg), h, params["decoder"]
    )
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", h.astype(cfg.compute_dtype),
        params["lm_head"].astype(cfg.compute_dtype),
    ).astype(jnp.float32)
    from repro.models.transformer import _mask_padded_logits
    return _mask_padded_logits(logits, cfg)


def loss_fn(params, frames, tokens, targets, cfg) -> jax.Array:
    logits = forward(params, frames, tokens, cfg)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, mem_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    kv = (batch, max_len, cfg.kv_heads, cfg.head_dim)
    xm = (batch, mem_len, cfg.kv_heads, cfg.head_dim)
    n = cfg.dec_layers
    return {
        "self": {
            "k": jnp.zeros((n,) + kv, dtype),
            "v": jnp.zeros((n,) + kv, dtype),
        },
        "cross": {
            "k": jnp.zeros((n,) + xm, dtype),
            "v": jnp.zeros((n,) + xm, dtype),
        },
    }


def prefill(params, frames, tokens, cfg, max_len=None):
    """Encode + run the decoder prompt, building self/cross caches."""
    cdt = cfg.compute_dtype
    memory = encode(params, frames, cfg)
    b, s = tokens.shape
    max_len = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = params["embed"][tokens].astype(cdt)

    def body(h, p):
        x = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        k = jnp.einsum("btd,dhk->bthk", x.astype(cdt), p["self_attn"]["wk"].astype(cdt))
        v = jnp.einsum("btd,dhk->bthk", x.astype(cdt), p["self_attn"]["wv"].astype(cdt))
        k = L.rope(k, positions, cfg.rope_theta)
        pad = ((0, 0), (0, max_len - s), (0, 0), (0, 0))
        ck = jnp.einsum("btd,dhk->bthk", memory, p["cross_attn"]["wk"].astype(cdt))
        cv = jnp.einsum("btd,dhk->bthk", memory, p["cross_attn"]["wv"].astype(cdt))
        h = h + L.attention(p["self_attn"], x, cfg=cfg, positions=positions)
        x2 = L.rmsnorm(p["lnx"], h, cfg.norm_eps)
        h = h + L.attention(
            p["cross_attn"], x2, cfg=cfg, positions=positions,
            kv_x=memory, causal=False, use_rope=False,
        )
        x3 = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
        h = h + L.mlp(p["mlp"], x3, cfg)
        return h, {"sk": jnp.pad(k, pad), "sv": jnp.pad(v, pad), "ck": ck, "cv": cv}

    h, st = _scan(cfg, _remat(body, cfg), h, params["decoder"])
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    from repro.models.transformer import _mask_padded_logits
    logits = _mask_padded_logits(
        (h[:, -1].astype(cdt) @ params["lm_head"].astype(cdt)).astype(jnp.float32), cfg)
    caches = {
        "self": {"k": st["sk"], "v": st["sv"]},
        "cross": {"k": st["ck"], "v": st["cv"]},
    }
    return logits, caches


def decode_step(params, token, caches, pos, cfg):
    """token (B,) -> (logits (B, V), caches'). Self-attn KV written at pos;
    cross KV reused as-is."""
    cdt = cfg.compute_dtype
    h = params["embed"][token[:, None]].astype(cdt)

    def body(h, xs):
        p, sk, sv, ck, cv = xs
        x = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        y, c2 = L.decode_attention(p["self_attn"], x, {"k": sk, "v": sv}, pos, cfg=cfg)
        h = h + y
        x2 = L.rmsnorm(p["lnx"], h, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", x2.astype(cdt), p["cross_attn"]["wq"].astype(cdt))
        b, _, hh, hd = q.shape
        kvh = ck.shape[2]
        qr = q.reshape(b, 1, kvh, hh // kvh, hd)
        sc = jnp.einsum("bskgd,btkd->bkgst", qr, ck.astype(cdt)).astype(jnp.float32)
        w = jax.nn.softmax(sc * hd**-0.5, axis=-1).astype(cdt)
        o = jnp.einsum("bkgst,btkd->bskgd", w, cv.astype(cdt)).reshape(b, 1, hh, hd)
        h = h + jnp.einsum("bshk,hkd->bsd", o, p["cross_attn"]["wo"].astype(cdt))
        x3 = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
        h = h + L.mlp(p["mlp"], x3, cfg)
        return h, (c2["k"], c2["v"])

    h, (nk, nv) = _scan(cfg, 
        body,
        h,
        (
            params["decoder"],
            caches["self"]["k"], caches["self"]["v"],
            caches["cross"]["k"], caches["cross"]["v"],
        ),
    )
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    from repro.models.transformer import _mask_padded_logits
    logits = _mask_padded_logits(
        (h[:, 0].astype(cdt) @ params["lm_head"].astype(cdt)).astype(jnp.float32), cfg)
    return logits, {
        "self": {"k": nk, "v": nv},
        "cross": caches["cross"],
    }
