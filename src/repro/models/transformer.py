"""Generic decoder LM covering the dense / MoE / SSM / hybrid / VLM families.

A model is ``prefix`` blocks (unscanned) followed by a repeating ``pattern``
of blocks executed under ``lax.scan`` over stacked per-repeat parameters —
the production trick that keeps HLO size O(pattern) instead of O(layers),
with a configurable remat policy on the scan body.

Entry points:
  init_params(cfg, key)                    -> param pytree
  forward(params, tokens, cfg, extras)     -> (B, S, V) f32 logits
  loss_fn(params, tokens, targets, cfg)    -> scalar CE (+ MoE aux)
  prefill(params, tokens, cfg, extras)     -> (last-position logits, caches)
  decode_step(params, token, caches, pos)  -> (logits, caches')

``extras['memory']`` carries the stub modality memory (image patches for the
VLM cross-attention layers), already embedded at d_model per the spec.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BlockDef
from repro.models import layers as L
from repro.models import ssm as S

Params = dict[str, Any]


def _scan(cfg, body, init, xs):
    """lax.scan with the config's unroll factor. ``scan_unroll=0`` means full
    unroll — used by the dry-run's *analysis* lowering because XLA's
    HloCostAnalysis counts a while-loop body once instead of trip-count
    times; production lowering keeps the rolled loop (small HLO)."""
    unroll = cfg.scan_unroll
    if unroll == 0:
        unroll = jax.tree.leaves(xs)[0].shape[0]
    return jax.lax.scan(body, init, xs, unroll=max(unroll, 1))


def _mask_padded_logits(logits: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Vocab padding (pad_vocab_to_multiple) adds never-trained columns so the
    embed/lm_head shard over `model`; mask them out of softmax/argmax."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(ids < cfg.vocab, logits, -1e30)


# -- per-block init/apply -------------------------------------------------------


def init_block(key, bd: BlockDef, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": L.init_rmsnorm(cfg.d_model, cfg.param_dtype)}
    if bd.mixer in ("attn", "cross_attn"):
        p["attn"] = L.init_attention(ks[0], cfg, cross=bd.mixer == "cross_attn")
    elif bd.mixer == "ssm":
        p["ssm"] = S.init_ssd(ks[1], cfg)
    elif bd.mixer == "hybrid":
        p["attn"] = L.init_attention(ks[0], cfg)
        p["ssm"] = S.init_ssd(ks[1], cfg)
        p["norm_attn"] = L.init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["norm_ssm"] = L.init_rmsnorm(cfg.d_model, cfg.param_dtype)
    else:
        raise ValueError(bd.mixer)

    if bd.ffn != "none":
        p["ln2"] = L.init_rmsnorm(cfg.d_model, cfg.param_dtype)
    if bd.ffn == "dense":
        p["mlp"] = L.init_mlp(ks[2], cfg)
    elif bd.ffn == "moe":
        p["moe"] = L.init_moe(ks[3], cfg)
    elif bd.ffn == "moe_dense":  # arctic: MoE + parallel dense residual branch
        p["moe"] = L.init_moe(ks[3], cfg)
        p["mlp"] = L.init_mlp(ks[4], cfg)
    return p


def _mixer(bd: BlockDef, p: Params, h: jax.Array, cfg, positions, extras) -> jax.Array:
    x = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
    if bd.mixer == "attn":
        return L.attention(p["attn"], x, cfg=cfg, positions=positions, window=bd.window)
    if bd.mixer == "cross_attn":
        return L.attention(
            p["attn"], x, cfg=cfg, positions=positions,
            kv_x=extras["memory"], causal=False, use_rope=False,
        )
    if bd.mixer == "ssm":
        return S.ssd(p["ssm"], x, cfg)
    if bd.mixer == "hybrid":
        a = L.attention(p["attn"], x, cfg=cfg, positions=positions, window=bd.window)
        m = S.ssd(p["ssm"], x, cfg)
        # Hymba-style fusion: per-path normalization, then mean.
        return 0.5 * (
            L.rmsnorm(p["norm_attn"], a, cfg.norm_eps)
            + L.rmsnorm(p["norm_ssm"], m, cfg.norm_eps)
        )
    raise ValueError(bd.mixer)


def _ffn(bd: BlockDef, p: Params, h: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """Returns (ffn output, aux loss contribution)."""
    zero = jnp.zeros((), jnp.float32)
    if bd.ffn == "none":
        return jnp.zeros_like(h), zero
    x = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
    if bd.ffn == "dense":
        return L.mlp(p["mlp"], x, cfg), zero
    if bd.ffn == "moe":
        return L.moe(p["moe"], x, cfg), L.moe_aux_loss(p["moe"], x, cfg)
    if bd.ffn == "moe_dense":
        return (
            L.moe(p["moe"], x, cfg) + L.mlp(p["mlp"], x, cfg),
            L.moe_aux_loss(p["moe"], x, cfg),
        )
    raise ValueError(bd.ffn)


def apply_block(bd, p, h, cfg, positions, extras):
    h = h + _mixer(bd, p, h, cfg, positions, extras)
    f, aux = _ffn(bd, p, h, cfg)
    return h + f, aux


# -- model init -------------------------------------------------------------------


def init_params(cfg: ArchConfig, key) -> Params:
    ks = jax.random.split(key, 4 + len(cfg.prefix))
    params: Params = {
        "embed": L._dense_init(ks[0], (cfg.padded_vocab, cfg.d_model), cfg.param_dtype, 1.0),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "lm_head": L._dense_init(ks[1], (cfg.d_model, cfg.padded_vocab), cfg.param_dtype),
    }
    params["prefix"] = [
        init_block(ks[4 + i], bd, cfg) for i, bd in enumerate(cfg.prefix)
    ]
    r = cfg.num_repeats
    groups = []
    for j, bd in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(ks[2], j), r)
        groups.append(jax.vmap(lambda k: init_block(k, bd, cfg))(keys))
    params["groups"] = groups
    return params


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(cfg.remat)


# -- training forward ---------------------------------------------------------------


def forward(params: Params, tokens: jax.Array, cfg: ArchConfig, extras=None) -> jax.Array:
    extras = extras or {}
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = params["embed"][tokens].astype(cfg.compute_dtype)

    aux_total = jnp.zeros((), jnp.float32)
    for bd, p in zip(cfg.prefix, params["prefix"]):
        h, aux = apply_block(bd, p, h, cfg, positions, extras)
        aux_total += aux

    def body(carry, xs):
        h, aux = carry
        for bd, p in zip(cfg.pattern, xs):
            h, a = apply_block(bd, p, h, cfg, positions, extras)
            aux += a
        return (h, aux), None

    (h, aux_total), _ = _scan(cfg, 
        _remat(body, cfg), (h, aux_total), tuple(params["groups"])
    )
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", h.astype(cfg.compute_dtype), params["lm_head"].astype(cfg.compute_dtype)
    ).astype(jnp.float32)
    return _mask_padded_logits(logits, cfg)


def loss_fn(
    params: Params,
    tokens: jax.Array,
    targets: jax.Array,
    cfg: ArchConfig,
    extras=None,
    aux_weight: float = 0.01,
) -> jax.Array:
    extras = extras or {}
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = params["embed"][tokens].astype(cfg.compute_dtype)

    aux_total = jnp.zeros((), jnp.float32)
    for bd, p in zip(cfg.prefix, params["prefix"]):
        h, aux = apply_block(bd, p, h, cfg, positions, extras)
        aux_total += aux

    def body(carry, xs):
        h, aux = carry
        for bd, p in zip(cfg.pattern, xs):
            h, a = apply_block(bd, p, h, cfg, positions, extras)
            aux += a
        return (h, aux), None

    (h, aux_total), _ = _scan(cfg, 
        _remat(body, cfg), (h, aux_total), tuple(params["groups"])
    )
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,dv->bsv", h.astype(cfg.compute_dtype), params["lm_head"].astype(cfg.compute_dtype)
    ).astype(jnp.float32)
    logits = _mask_padded_logits(logits, cfg)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + aux_weight * aux_total / max(cfg.num_layers, 1)


# -- serving: prefill + decode --------------------------------------------------------


def init_block_cache(bd: BlockDef, cfg: ArchConfig, batch: int, max_len: int, dtype):
    c: Params = {}
    if bd.mixer in ("attn", "hybrid"):
        c["attn"] = L.init_attn_cache(cfg, batch, max_len, bd.window, dtype)
    if bd.mixer in ("ssm", "hybrid"):
        c["ssm"] = S.init_ssd_cache(cfg, batch, dtype)
    if bd.mixer == "cross_attn":
        c["cross"] = {
            "k": jnp.zeros((batch, cfg.num_patches, cfg.kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, cfg.num_patches, cfg.kv_heads, cfg.head_dim), dtype),
        }
    return c


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    return {
        "prefix": [
            init_block_cache(bd, cfg, batch, max_len, dtype) for bd in cfg.prefix
        ],
        "groups": [
            jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.num_repeats,) + x.shape).copy()
                if hasattr(x, "shape")
                else x,
                init_block_cache(bd, cfg, batch, max_len, dtype),
            )
            for bd in cfg.pattern
        ],
    }


def _decode_mixer(bd, p, h, cache, pos, cfg, extras):
    x = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
    if bd.mixer == "attn":
        if cfg.flash_decode and bd.window is None and "mesh" in extras:
            y, c2 = L.flash_decode_attention(
                p["attn"], x, cache["attn"], pos, cfg=cfg, mesh=extras["mesh"],
                batch_axes=extras.get("batch_axes", ("data",)),
            )
        else:
            y, c2 = L.decode_attention(
                p["attn"], x, cache["attn"], pos, cfg=cfg, window=bd.window
            )
        return y, {**cache, "attn": c2}
    if bd.mixer == "ssm":
        y, c2 = S.ssd_decode(p["ssm"], x, cache["ssm"], cfg)
        return y, {**cache, "ssm": c2}
    if bd.mixer == "hybrid":
        a, ca = L.decode_attention(p["attn"], x, cache["attn"], pos, cfg=cfg, window=bd.window)
        m, cs = S.ssd_decode(p["ssm"], x, cache["ssm"], cfg)
        y = 0.5 * (
            L.rmsnorm(p["norm_attn"], a, cfg.norm_eps)
            + L.rmsnorm(p["norm_ssm"], m, cfg.norm_eps)
        )
        return y, {**cache, "attn": ca, "ssm": cs}
    if bd.mixer == "cross_attn":
        # Cross K/V were computed at prefill; decode is one cached attention.
        cdt = cfg.compute_dtype
        q = jnp.einsum("bsd,dhk->bshk", x.astype(cdt), p["attn"]["wq"].astype(cdt))
        ck, cv = cache["cross"]["k"].astype(cdt), cache["cross"]["v"].astype(cdt)
        b, _, hh, hd = q.shape
        kvh = ck.shape[2]
        qr = q.reshape(b, 1, kvh, hh // kvh, hd)
        sc = jnp.einsum("bskgd,btkd->bkgst", qr, ck).astype(jnp.float32) * hd**-0.5
        w = jax.nn.softmax(sc, axis=-1).astype(cdt)
        o = jnp.einsum("bkgst,btkd->bskgd", w, cv).reshape(b, 1, hh, hd)
        y = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"].astype(cdt))
        return y, cache
    raise ValueError(bd.mixer)


def decode_block(bd, p, h, cache, pos, cfg, extras):
    y, cache = _decode_mixer(bd, p, h, cache, pos, cfg, extras)
    h = h + y
    f, _ = _ffn(bd, p, h, cfg)
    return h + f, cache


def prefill(params: Params, tokens: jax.Array, cfg: ArchConfig, extras=None, max_len: int | None = None):
    """Full-sequence pass building the decode cache; returns (logits at the
    last position (B, V), caches). ``max_len`` sizes full-attention caches
    for subsequent decode_step writes (defaults to the prompt length)."""
    extras = extras or {}
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = params["embed"][tokens].astype(cfg.compute_dtype)
    cdt = cfg.compute_dtype

    def block_with_cache(bd, p, h):
        x = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        cache: Params = {}
        if bd.mixer in ("attn", "hybrid"):
            k = jnp.einsum("btd,dhk->bthk", x.astype(cdt), p["attn"]["wk"].astype(cdt))
            v = jnp.einsum("btd,dhk->bthk", x.astype(cdt), p["attn"]["wv"].astype(cdt))
            if "q_norm" in p["attn"]:
                k = L.rmsnorm(p["attn"]["k_norm"], k)
            k = L.rope(k, positions, cfg.rope_theta)
            w = bd.window
            if w:
                # Ring layout: position p lives at slot p % w. The last
                # min(s, w) positions are a contiguous run, so a roll (s>=w)
                # or right-padding (s<w) produces the ring.
                cov = min(s, w)
                ks_, vs_ = k[:, -cov:], v[:, -cov:]
                if s >= w:
                    ks_ = jnp.roll(ks_, s % w, axis=1)
                    vs_ = jnp.roll(vs_, s % w, axis=1)
                else:
                    pad = ((0, 0), (0, w - s), (0, 0), (0, 0))
                    ks_, vs_ = jnp.pad(ks_, pad), jnp.pad(vs_, pad)
                cache["attn"] = {"k": ks_, "v": vs_}
            else:
                buf = max_len or s
                pad = ((0, 0), (0, buf - s), (0, 0), (0, 0))
                cache["attn"] = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
        if bd.mixer in ("ssm", "hybrid"):
            _, (state, conv_tail) = S.ssd(p["ssm"], x, cfg, return_final_state=True)
            cache["ssm"] = {"state": state, "conv": conv_tail}
        if bd.mixer == "cross_attn":
            mem = extras["memory"].astype(cdt)
            cache["cross"] = {
                "k": jnp.einsum("btd,dhk->bthk", mem, p["attn"]["wk"].astype(cdt)),
                "v": jnp.einsum("btd,dhk->bthk", mem, p["attn"]["wv"].astype(cdt)),
            }
        h, _ = apply_block(bd, p, h, cfg, positions, extras)
        return h, cache

    prefix_caches = []
    for bd, p in zip(cfg.prefix, params["prefix"]):
        h, c = block_with_cache(bd, p, h)
        prefix_caches.append(c)

    group_caches = []

    def body(h, xs):
        caches = []
        for bd, p in zip(cfg.pattern, xs):
            h, c = block_with_cache(bd, p, h)
            caches.append(c)
        return h, tuple(caches)

    h, stacked = _scan(cfg, _remat(body, cfg), h, tuple(params["groups"]))
    group_caches = list(stacked)

    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    last = h[:, -1].astype(cfg.compute_dtype)
    logits = (last @ params["lm_head"].astype(cfg.compute_dtype)).astype(jnp.float32)
    return _mask_padded_logits(logits, cfg), {"prefix": prefix_caches, "groups": group_caches}


def decode_step(params: Params, token: jax.Array, caches, pos, cfg: ArchConfig, extras=None):
    """token (B,) int32, pos scalar -> (logits (B, V) f32, caches')."""
    extras = extras or {}
    h = params["embed"][token[:, None]].astype(cfg.compute_dtype)  # (B,1,d)

    new_prefix = []
    for bd, p, c in zip(cfg.prefix, params["prefix"], caches["prefix"]):
        h, c2 = decode_block(bd, p, h, c, pos, cfg, extras)
        new_prefix.append(c2)

    new_groups = []

    def body(h, xs):
        params_sl, cache_sl = xs
        new_caches = []
        for bd, p, c in zip(cfg.pattern, params_sl, cache_sl):
            h, c2 = decode_block(bd, p, h, c, pos, cfg, extras)
            new_caches.append(c2)
        return h, tuple(new_caches)

    h, stacked = _scan(cfg, 
        body, h, (tuple(params["groups"]), tuple(caches["groups"]))
    )
    new_groups = list(stacked)

    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (
        h[:, 0].astype(cfg.compute_dtype) @ params["lm_head"].astype(cfg.compute_dtype)
    ).astype(jnp.float32)
    return _mask_padded_logits(logits, cfg), {"prefix": new_prefix, "groups": new_groups}


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
