from repro.models import encdec, layers, sharding, ssm, transformer

__all__ = ["encdec", "layers", "sharding", "ssm", "transformer"]
