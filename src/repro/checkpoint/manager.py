"""Checkpointing with mesh-elastic restore and async save.

Design for 1000+ nodes (DESIGN.md §4):

* Layout: one ``.npz`` per flattened leaf batch + a JSON manifest holding the
  treedef, shapes, dtypes and step. Leaves are written *unsharded* (gathered)
  in this single-process container; on a real multi-host deployment the same
  manifest format holds per-host shard files (the manifest records the mesh,
  so restore can detect a shape change).
* **Elastic restore**: ``restore_pytree`` takes the *target* shardings; data
  is re-laid-out via ``jax.device_put`` with the new NamedSharding, so a
  checkpoint taken on a (16,16) mesh restores onto (8,8) or (2,16,16)
  unchanged — tests cover mesh-shape changes.
* **Async save**: a background thread serializes the host copy so the train
  loop continues; ``wait()`` joins before the next save (single outstanding
  snapshot keeps memory bounded).
* **Integrity**: manifest is written last (write-to-temp + atomic rename);
  a crash mid-save leaves the previous checkpoint intact; ``latest_step``
  only trusts directories with a manifest.
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_pytree(path: Path, tree, step: int | None = None, extra: dict | None = None):
    path = Path(path)
    tmp = path.with_name(path.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    host = [np.asarray(x) for x in leaves]
    np.savez(tmp / "leaves.npz", **{f"l{i}": a for i, a in enumerate(host)})
    manifest = {
        "treedef": str(treedef),
        "num_leaves": len(host),
        "shapes": [list(a.shape) for a in host],
        "dtypes": [str(a.dtype) for a in host],
        "step": step,
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if path.exists():
        shutil.rmtree(path)
    tmp.rename(path)


def restore_pytree(path: Path, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; if ``shardings`` (a
    matching pytree of NamedSharding) is given, leaves are placed onto the
    *current* mesh — this is the elastic-restart path."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "leaves.npz")
    leaves = [data[f"l{i}"] for i in range(manifest["num_leaves"])]
    _, treedef = _flatten(like_tree)
    if treedef.num_leaves != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, target structure expects "
            f"{treedef.num_leaves} — architecture mismatch"
        )
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda x, s: jax.device_put(x, s), restored, shardings
        )
    return restored, manifest


class CheckpointManager:
    """step-numbered checkpoints with retention + async save."""

    def __init__(self, root: str | Path, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    def _dir(self, step: int) -> Path:
        return self.root / f"step_{step:08d}"

    def save(self, step: int, tree, extra: dict | None = None, async_: bool = False):
        self.wait()
        # Snapshot to host *before* returning control (donated buffers may be
        # overwritten by the next step); serialization happens on the thread.
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]
        snapshot = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            save_pytree(self._dir(step), snapshot, step, extra)
            self._gc()

        if async_:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest_step(self) -> int | None:
        steps = [
            int(p.name.split("_")[1])
            for p in self.root.glob("step_*")
            if (p / "manifest.json").exists()
        ]
        return max(steps) if steps else None

    def restore_latest(self, like_tree, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        tree, manifest = restore_pytree(self._dir(step), like_tree, shardings)
        return tree, manifest

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.root.glob("step_*")
            if (p / "manifest.json").exists()
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
