"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, warmup: int, total: int, min_ratio: float = 0.1):
    """Linear warmup then cosine decay to min_ratio. Returns a scale in
    (0, 1] to multiply the base lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * cos
