"""AdamW with global-norm clipping, mixed-precision moments, sharding-aware
state (optimizer state inherits each param's PartitionSpec).

Pure-function style: (grads, state, params) -> (new_params, new_state).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32  # bf16 halves optimizer HBM (hillclimb knob)


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(grads, state: OptState, params, cfg: AdamWConfig, lr_scale=1.0):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1.0 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g * g * (1.0 - cfg.b2)
        update = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        update += cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * update
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, OptState(step, new_m, new_v), gnorm
