"""Gradient compression for the cross-pod (DCI) axis.

Within a pod, ICI is fast (~50 GB/s/link); across pods the data-center links
are the thin pipe. We therefore compress only the *pod-axis* all-reduce:
int8 quantization with a per-tensor scale (16x less traffic than f32 +
scale overhead ~0), reduced in int32 to avoid overflow, then rescaled.

Used inside shard_map over the pod axis (see launch/train.py's multi-pod
path); mathematically it is all_reduce(mean) with quantization noise, and
tests bound that noise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """f32/bf16 -> (int8 values, f32 scale). Symmetric per-tensor scheme."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def pod_allreduce_compressed(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean all-reduce over ``axis_name`` with int8 on-the-wire payload.

    Each participant quantizes with its own scale; scales are all-gathered
    (tiny) and the int8 payloads are summed after per-shard rescaling in
    int32 fixed point against the max scale — a standard one-pass scheme.
    """
    q, scale = compress_int8(x)
    max_scale = jax.lax.pmax(scale, axis_name)
    # Rescale local int8 into the shared grid (still small ints), sum in f32.
    rescaled = q.astype(jnp.float32) * (scale / max_scale)
    total = jax.lax.psum(rescaled, axis_name)
    n = jax.lax.psum(1, axis_name)  # axis size (jax.lax.axis_size is newer jax)
    return (total * max_scale / n).astype(x.dtype)
