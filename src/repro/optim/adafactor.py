"""Adafactor (factored second moment) — the memory-term hillclimb option for
the trillion-parameter configs: O(r+c) optimizer state per matrix instead of
O(r*c), no first moment. See EXPERIMENTS.md §Perf (kimi-k2 memory iteration).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class FactoredState(NamedTuple):
    step: jax.Array
    vr: Any  # row second-moment (or full moment for rank<2 leaves)
    vc: Any  # col second-moment (zeros placeholder for rank<2 leaves)


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params) -> FactoredState:
    def vr(p):
        return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) else jnp.zeros(p.shape, jnp.float32)

    def vc(p):
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) if _factored(p) else jnp.zeros((1,), jnp.float32)

    return FactoredState(
        jnp.zeros((), jnp.int32), jax.tree.map(vr, params), jax.tree.map(vc, params)
    )


def adafactor_update(
    grads,
    state: FactoredState,
    params,
    lr: float = 1e-3,
    decay: float = 0.99,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
):
    step = state.step + 1

    def upd(g, vr, vc, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if _factored(p):
            new_vr = decay * vr + (1 - decay) * jnp.mean(g2, axis=-1)
            new_vc = decay * vc + (1 - decay) * jnp.mean(g2, axis=-2)
            r = new_vr / jnp.maximum(jnp.mean(new_vr, axis=-1, keepdims=True), eps)
            u = g32 / (jnp.sqrt(r)[..., None] * jnp.sqrt(new_vc)[..., None, :])
        else:
            new_vr = decay * vr + (1 - decay) * g2
            new_vc = vc
            u = g32 / jnp.sqrt(new_vr)
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        newp = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), new_vr, new_vc

    out = jax.tree.map(upd, grads, state.vr, state.vc, params)
    leaf = lambda x: isinstance(x, tuple)
    return (
        jax.tree.map(lambda t: t[0], out, is_leaf=leaf),
        FactoredState(
            step,
            jax.tree.map(lambda t: t[1], out, is_leaf=leaf),
            jax.tree.map(lambda t: t[2], out, is_leaf=leaf),
        ),
    )
