from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, OptState
from repro.optim.adafactor import adafactor_init, adafactor_update
from repro.optim.schedule import cosine_schedule
from repro.optim.compression import compress_int8, decompress_int8, pod_allreduce_compressed

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "OptState",
    "adafactor_init", "adafactor_update",
    "cosine_schedule",
    "compress_int8", "decompress_int8", "pod_allreduce_compressed",
]
