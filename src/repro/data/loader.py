"""Window-addressable data sources + host->device staging ("NFS -> RDD").

``ArrayDataSource`` wraps an in-memory cube (tests/benchmarks);
``ShardedStager`` places a window's observation matrix onto the mesh with a
points-sharded NamedSharding — the analog of the paper's parallel data
loading (Algorithm 2), where each node pulls only its points from NFS.

``WindowPrefetcher`` is the executor's load stage: a background thread pulls
work units off a queue, loads + H2D-stages window *k+1* while the device is
still fitting window *k*, and hands staged items to the compute stage
through a bounded queue (depth = how far ahead the loader may run). The
paper gets the same overlap from Spark's pipelined RDD evaluation.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterable, Iterator, TypeVar

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.regions import CubeGeometry, Window

T = TypeVar("T")
U = TypeVar("U")


class ArrayDataSource:
    """In-memory cube: values (slices, lines, points_per_line, n_obs)."""

    def __init__(self, values: np.ndarray):
        if values.ndim != 4:
            raise ValueError("expected (slices, lines, points, n_obs)")
        self.values = values
        self.geometry = CubeGeometry(*values.shape[:3])
        self.num_observations = values.shape[3]

    def load_window(self, w: Window) -> np.ndarray:
        block = self.values[w.slice_i, w.line_start : w.line_end]
        return block.reshape(-1, self.num_observations).astype(np.float32)


class ThrottledSource:
    """Models the paper's NFS read path for any window-addressable source:
    ``load_window`` returns no earlier than ``nbytes / bandwidth`` after the
    call, sleeping for the remainder. The sleep releases the GIL, so a
    prefetch thread reading through this wrapper overlaps with device
    compute exactly like a real remote read — the overlap benchmarks use it
    to reproduce the paper's loading/compute ratio on a container whose
    synthetic generator is far cheaper than a 235 GB NFS volume.
    """

    def __init__(self, source, bandwidth_bytes_per_s: float):
        if bandwidth_bytes_per_s <= 0:
            raise ValueError("bandwidth must be positive")
        self.inner = source
        self.geometry = source.geometry
        self.bandwidth = float(bandwidth_bytes_per_s)

    def load_window(self, w: Window) -> np.ndarray:
        t0 = time.perf_counter()
        block = self.inner.load_window(w)
        remain = block.nbytes / self.bandwidth - (time.perf_counter() - t0)
        if remain > 0:
            time.sleep(remain)
        return block


class ShardedStager:
    """Stages (P, n_obs) windows across the mesh, points over ``axes``.

    Pads the point dimension to the sharding divisor; callers slice results
    back with the returned valid count.
    """

    def __init__(self, mesh: Mesh, axes: tuple[str, ...] = ("data",), donate: bool = False):
        self.mesh = mesh
        self.spec = P(axes)
        self.divisor = int(np.prod([mesh.shape[a] for a in axes]))
        self.donate = donate

    def stage(self, values: np.ndarray) -> tuple[jax.Array, int]:
        p = values.shape[0]
        pad = (-p) % self.divisor
        if pad:
            values = np.concatenate([values, np.repeat(values[-1:], pad, axis=0)])
        sharding = NamedSharding(self.mesh, self.spec)
        # donate=True lets the runtime alias the padded host buffer into the
        # transfer instead of copying, halving peak host memory — but only
        # when the padding concatenate above made a buffer we privately own;
        # an unpadded window is still the caller's array and must be copied.
        donate = self.donate and pad > 0
        return jax.device_put(values, sharding, donate=donate), p


class PrefetchError(RuntimeError):
    """Raised by the consumer when the background load stage failed; the
    original exception is ``__cause__``."""


class _Stop:
    """Queue sentinels: end-of-stream or carried error."""

    def __init__(self, error: BaseException | None = None):
        self.error = error


class WindowPrefetcher(Iterable[U]):
    """Runs ``stage_fn`` over ``items`` in a background thread, ``depth``
    items ahead of the consumer.

    ``stage_fn`` does the load + host->device staging for one work unit and
    returns whatever the compute stage consumes. Order is preserved (FIFO),
    which the reuse cache and resume watermark require. Iteration re-raises
    any loader exception as ``PrefetchError``; ``close()`` stops the thread
    early (e.g. the compute stage crashed) without blocking on a full queue.
    """

    def __init__(self, items: Iterable[T], stage_fn: Callable[[T], U], depth: int = 2):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self._items = items
        self._stage_fn = stage_fn
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, name="window-prefetch", daemon=True
        )
        self._thread.start()

    def _run(self) -> None:
        try:
            for item in self._items:
                if self._stop.is_set():
                    return
                staged = self._stage_fn(item)
                if not self._put(staged):
                    return
            self._put(_Stop())
        except BaseException as e:  # repro: allow[ERR]: parked for the consumer — __iter__ re-raises it as PrefetchError
            self._put(_Stop(e))

    def _put(self, obj) -> bool:
        """Blocking put that stays responsive to close(); False = stopped."""
        while not self._stop.is_set():
            try:
                self._q.put(obj, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def __iter__(self) -> Iterator[U]:
        while True:
            got = self._q.get()
            if isinstance(got, _Stop):
                if got.error is not None:
                    raise PrefetchError("window load stage failed") from got.error
                return
            yield got

    def close(self) -> None:
        self._stop.set()
        while True:  # unblock a producer waiting on a full queue
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)
