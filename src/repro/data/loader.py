"""Window-addressable data sources + host->device staging ("NFS -> RDD").

``ArrayDataSource`` wraps an in-memory cube (tests/benchmarks);
``ShardedStager`` places a window's observation matrix onto the mesh with a
points-sharded NamedSharding — the analog of the paper's parallel data
loading (Algorithm 2), where each node pulls only its points from NFS.
"""

from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.regions import CubeGeometry, Window


class ArrayDataSource:
    """In-memory cube: values (slices, lines, points_per_line, n_obs)."""

    def __init__(self, values: np.ndarray):
        if values.ndim != 4:
            raise ValueError("expected (slices, lines, points, n_obs)")
        self.values = values
        self.geometry = CubeGeometry(*values.shape[:3])
        self.num_observations = values.shape[3]

    def load_window(self, w: Window) -> np.ndarray:
        block = self.values[w.slice_i, w.line_start : w.line_end]
        return block.reshape(-1, self.num_observations).astype(np.float32)


class ShardedStager:
    """Stages (P, n_obs) windows across the mesh, points over ``axes``.

    Pads the point dimension to the sharding divisor; callers slice results
    back with the returned valid count.
    """

    def __init__(self, mesh: Mesh, axes: tuple[str, ...] = ("data",)):
        self.mesh = mesh
        self.spec = P(axes)
        self.divisor = int(np.prod([mesh.shape[a] for a in axes]))

    def stage(self, values: np.ndarray) -> tuple[jax.Array, int]:
        p = values.shape[0]
        pad = (-p) % self.divisor
        if pad:
            values = np.concatenate([values, np.repeat(values[-1:], pad, axis=0)])
        sharding = NamedSharding(self.mesh, self.spec)
        return jax.device_put(values, sharding), p
