"""Monte-Carlo seismic cube generator (§3 + §6.1 of the paper).

The paper's data comes from the HPC4e seismic benchmark: a 16-layer velocity
model; each layer's Vp is uncertain with a known distribution type (the four
types cycle across layers: normal, lognormal, exponential, uniform); each
simulation draws one Vp vector and produces a 3-D cube of values; n
simulations give every point a set of n observation values.

We reproduce that generative *structure* without the wave-propagation solver:
a point's observation value is a smooth nonlinear mixture of the layer Vp
draws, so that (a) each point's observation set follows (approximately) one
of the candidate distribution types, with the dominant layer determined by
depth (slice index), and (b) neighboring points frequently share identical
(mu, sigma) after float32 rounding — the redundancy the paper's Grouping
method exploits (their simulation outputs are quantized the same way).

Everything is generated lazily per window from a seed — a 2.4 TB dataset is
representable without materializing it, exactly like reading a window of
bytes from NFS.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.regions import CubeGeometry, Window

# Layer distribution types cycle every four layers (§3: "The distribution
# type for every four layers are: Normal, Lognormal, Exponential and
# Uniform").
LAYER_TYPE_CYCLE = ("normal", "lognormal", "exponential", "uniform")


@dataclass(frozen=True)
class SimulationConfig:
    geometry: CubeGeometry = CubeGeometry(501, 501, 251)  # Set1 dims (§6.1)
    num_simulations: int = 1000  # observations per point
    num_layers: int = 16
    base_vp: float = 3000.0  # m/s scale of the layered model
    quantize_decimals: int = 3  # output rounding -> grouping redundancy
    group_block: int = 4  # points per line sharing one generator cell
    line_block: int = 2  # consecutive lines sharing generator cells
    seed: int = 0


class SeismicSimulation:
    """Lazy window-addressable observation generator.

    ``load_window(w) -> (num_points, num_simulations) float32``; deterministic
    in (seed, window), so re-loads after a crash return identical data (the
    NFS re-read semantics the paper's restart relies on).
    """

    def __init__(self, config: SimulationConfig = SimulationConfig()):
        self.config = config
        self.geometry = config.geometry
        # Per-layer Vp draws for all simulations: (num_layers, n_sims).
        rng = np.random.default_rng(config.seed)
        n = config.num_simulations
        draws = []
        for layer in range(config.num_layers):
            t = LAYER_TYPE_CYCLE[layer % 4]
            scale = config.base_vp * (1.0 + 0.1 * layer)
            # Parameters chosen so the four families are mutually
            # distinguishable at a few hundred observations (lognormal is
            # visibly skewed, exponential starts at 0, uniform is flat).
            if t == "normal":
                # cv 0.3: wide enough that the (skewed) lognormal MoM fit is
                # clearly worse than the normal fit under Eq. 5.
                draws.append(rng.normal(scale, 0.3 * scale, size=n))
            elif t == "lognormal":
                draws.append(np.exp(rng.normal(np.log(scale), 0.5, size=n)))
            elif t == "exponential":
                draws.append(rng.exponential(scale, size=n))
            else:  # uniform
                draws.append(rng.uniform(0.5 * scale, 1.5 * scale, size=n))
        self._vp = np.asarray(draws, dtype=np.float64)  # (L, n)

    def _dominant_layer(self, slice_i: int) -> int:
        # Slices cycle through the model's layers, so any 4 consecutive
        # slices cover all four distribution types (tree training data).
        return slice_i % self.config.num_layers

    def load_window(self, w: Window) -> np.ndarray:
        """Generate the observation matrix for a window (Algorithm 2's
        GetData over all datasets, vectorized)."""
        cfg = self.config
        geom = self.geometry
        layer = self._dominant_layer(w.slice_i)
        vp = self._vp[layer]  # (n,) dominant layer's draws

        num_pts = w.num_lines * geom.points_per_line
        # Per-generator-cell deterministic spatial modulation. Points within a
        # `group_block` run (and lines within a `line_block` run) share a
        # cell => identical observations — the redundancy §5.2 exploits, both
        # within a window (Grouping) and across windows (Reuse), mirroring
        # the paper's quantized simulation outputs.
        line_idx = np.repeat(
            np.arange(w.line_start, w.line_end) // cfg.line_block,
            geom.points_per_line,
        )
        pt_idx = np.tile(np.arange(geom.points_per_line), w.num_lines)
        cell = pt_idx // cfg.group_block
        # Smooth, deterministic per-cell gains (no RNG: windows independent).
        phase = (
            0.7 * np.sin(0.05 * line_idx + 0.11 * cell)
            + 0.3 * np.cos(0.02 * line_idx * cell / (1.0 + cell))
        )
        gain = 1.0 + 0.05 * phase  # (P,)

        # Observation: the dominant layer's draw through a per-cell
        # MULTIPLICATIVE gain. Scaling through zero preserves all four
        # families exactly (Exp(l)/a = Exp(l*a), logN shifts mu, N scales,
        # U scales), so each point's observation set keeps its layer's type
        # — the paper's 4-types assumption — while cells still differ.
        obs = gain[:, None] * vp[None, :]
        obs = np.round(obs, cfg.quantize_decimals)
        return obs.astype(np.float32).reshape(num_pts, cfg.num_simulations)

    def true_type_index(self, slice_i: int) -> int:
        """Ground-truth dominant distribution type index (into TYPES_4 —
        note TYPES_4 and LAYER_TYPE_CYCLE order differ)."""
        from repro.core.distributions import TYPES_4

        name = LAYER_TYPE_CYCLE[self._dominant_layer(slice_i) % 4]
        return TYPES_4.index(name)

    def nominal_bytes(self) -> int:
        """Dataset size if materialized (for the 235 GB / 2.4 TB analogies)."""
        return self.geometry.total_points * self.config.num_simulations * 4
