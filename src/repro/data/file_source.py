"""Chunked cube-on-disk format: the pipeline's real file/NFS source (§3, §6).

The paper's input is not synthetic — it is a cube "produced by observation
… or numerical simulation programs" persisted on disk/NFS, which Spark's
workers then read window by window. This module is that persistence layer
for the reproduction:

  * ``export_cube`` snapshots ANY window-addressable source (the lazy
    ``SeismicSimulation``, an ``ArrayDataSource``, another file cube) into a
    directory of chunked ``.npy`` files plus a ``manifest.json``, so a
    simulation *spec* becomes real bytes on disk once and every later run
    reads those bytes instead of regenerating them;
  * ``FileCubeSource`` is the window reader: ``load_window`` memmaps only
    the chunks a window overlaps (a window read touches O(window) bytes, not
    the cube), so it plugs straight into ``WindowPrefetcher`` prefetching and
    the ``ThrottledSource`` NFS-bandwidth model like every other source;
  * the manifest carries a per-chunk sha256 and a ``content_sha256`` over
    the whole description — the cube's *data identity*. ``SourceSpec``
    (``kind='file'``) hashes by that digest, so a spec's ``content_hash``
    finally captures what ``kind='external'`` could only warn about: which
    bytes the run actually consumed (DESIGN.md §12).

On-disk layout (``layout='chunked'``, the only layout so far)::

    cube_dir/
      manifest.json                # geometry, dtype, chunk index, hashes
      s00000_l00000.npy            # (lines_per_chunk, ppl, n_obs) float32
      s00000_l00016.npy
      ...

Chunks split each slice along lines (``lines_per_chunk``), independent of
the pipeline's ``window_lines`` — the reader stitches windows from whatever
chunks they overlap, so one exported cube serves every window size.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
from collections import OrderedDict
from pathlib import Path
from typing import Callable

import numpy as np

from repro.core.regions import CubeGeometry, Window, iter_windows

MANIFEST_NAME = "manifest.json"
FORMAT_NAME = "repro-cube"
FORMAT_VERSION = 1
LAYOUTS = ("chunked",)
DEFAULT_LINES_PER_CHUNK = 16

# How many chunk memmaps a reader keeps open at once. Sequential window
# reads touch a sliding band of chunks, so a small LRU is enough; the cap
# keeps a paper-scale cube (thousands of chunks) from exhausting file
# descriptors.
_MMAP_CACHE_SIZE = 64


def _chunk_name(slice_i: int, line_start: int) -> str:
    return f"s{slice_i:05d}_l{line_start:05d}.npy"


def _array_sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def _manifest_content_sha(manifest: dict) -> str:
    """The cube's data identity: sha256 over the canonical JSON of the
    manifest *without* its own ``content_sha256`` field. The per-chunk
    hashes are inside, so any byte of observation data changing changes
    this digest — and with it every dependent spec ``content_hash``."""
    payload = {k: v for k, v in manifest.items() if k != "content_sha256"}
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def read_manifest(path: str | Path) -> dict:
    """Load + sanity-check a cube directory's manifest."""
    f = Path(path) / MANIFEST_NAME
    if not f.exists():
        raise ValueError(
            f"no cube manifest at {f} — export one first with "
            "data.file_source.export_cube(source, out_dir)")
    manifest = json.loads(f.read_text())
    if manifest.get("format") != FORMAT_NAME:
        raise ValueError(
            f"{f} is not a {FORMAT_NAME} manifest (format="
            f"{manifest.get('format')!r})")
    if manifest.get("format_version") != FORMAT_VERSION:
        raise ValueError(
            f"cube format version {manifest.get('format_version')} "
            f"unsupported (this build reads version {FORMAT_VERSION})")
    return manifest


def manifest_sha(path: str | Path) -> str:
    """The cube's ``content_sha256`` — what ``SourceSpec(kind='file')``
    hashes by. Recomputed from the manifest body (not trusted from the
    stored field), so a hand-edited manifest cannot alias another cube's
    provenance."""
    return _manifest_content_sha(read_manifest(path))


def export_cube(
    source,
    out_dir: str | Path,
    lines_per_chunk: int = DEFAULT_LINES_PER_CHUNK,
    progress: Callable[[int, int], None] | None = None,
):
    """Snapshot a window-addressable source to a chunked cube directory.

    ``source`` is either a live source object (``geometry`` +
    ``load_window``) or a ``SourceSpec`` — a simulation spec is materialized
    here (with its NFS-throttle model stripped: the throttle describes the
    *read* path, and export is the write path). Returns a ready-to-run
    ``SourceSpec(kind='file', path=out_dir)`` carrying the original spec's
    throttle, so ``export_cube(spec.source, d)`` drops straight back into a
    ``PipelineSpec``.

    The manifest is written last (tmp + atomic rename): a crashed export
    leaves a directory without a manifest, which every reader refuses —
    never a readable-but-truncated cube.
    """
    from repro.api.spec import SourceSpec, build_source

    throttle = None
    if isinstance(source, SourceSpec):
        throttle = source.throttle_mb_s
        source = build_source(dataclasses.replace(
            source, throttle_mb_s=None))
    if lines_per_chunk < 1:
        raise ValueError(f"lines_per_chunk must be >= 1, got {lines_per_chunk}")

    geom: CubeGeometry = source.geometry
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)

    chunks = []
    num_obs = None
    total = sum(1 for s in range(geom.num_slices)
                for _ in iter_windows(geom, s, lines_per_chunk))
    done = 0
    for s in range(geom.num_slices):
        for w in iter_windows(geom, s, lines_per_chunk):
            block = np.asarray(source.load_window(w), dtype=np.float32)
            if num_obs is None:
                num_obs = block.shape[1]
            arr = block.reshape(w.num_lines, geom.points_per_line, num_obs)
            name = _chunk_name(s, w.line_start)
            np.save(out / name, arr)
            chunks.append({
                "file": name,
                "slice": s,
                "line_start": w.line_start,
                "line_end": w.line_end,
                "sha256": _array_sha256(arr),
            })
            done += 1
            if progress is not None:
                progress(done, total)

    manifest = {
        "format": FORMAT_NAME,
        "format_version": FORMAT_VERSION,
        "layout": "chunked",
        "num_slices": geom.num_slices,
        "lines_per_slice": geom.lines_per_slice,
        "points_per_line": geom.points_per_line,
        "num_observations": int(num_obs),
        "dtype": "float32",
        "lines_per_chunk": lines_per_chunk,
        "chunks": chunks,
    }
    manifest["content_sha256"] = _manifest_content_sha(manifest)
    tmp = out / (MANIFEST_NAME + ".tmp")
    tmp.write_text(json.dumps(manifest, indent=1, sort_keys=True))
    os.replace(tmp, out / MANIFEST_NAME)

    # Geometry fields on a file spec are advisory (the manifest is
    # authoritative, and they are excluded from the hash) — fill them in
    # anyway so the returned spec reads true.
    return SourceSpec(
        kind="file", path=str(out), throttle_mb_s=throttle,
        num_slices=geom.num_slices, lines_per_slice=geom.lines_per_slice,
        points_per_line=geom.points_per_line, observations=int(num_obs))


class FileCubeSource:
    """Window reader over an exported cube directory.

    ``load_window(w) -> (num_points, n_obs) float32``, bit-identical to what
    the exported source produced (tests/test_file_source.py asserts the
    round-trip against the simulation, and through the full pipeline).
    Reads memmap only the chunks the window overlaps and copy them into a
    fresh array — the copy forces the actual page-in, so a wrapping
    ``ThrottledSource`` times real bytes moved, and the buffer handed to the
    prefetcher is safe to donate.

    ``enable_read_verification()`` arms *verified reads*: every chunk a
    window touches is fully loaded (no memmap) and re-hashed against the
    manifest, with ONE automatic re-read on mismatch before raising — a torn
    read over NFS (reader racing a copy, transient bit flip in transit)
    recovers transparently; persistent corruption raises with the chunk path
    and attempt count (DESIGN.md §14). ``verify()`` uses the same re-read
    policy. ``read_hook`` is the chaos-testing seam ``runtime.faults`` uses
    to corrupt chunk bytes deterministically in tests.
    """

    def __init__(self, path: str | Path, verify_reads: bool = False,
                 read_hook: Callable | None = None):
        self.path = Path(path)
        self.verify_reads = bool(verify_reads)
        self.read_hook = read_hook
        self.manifest = read_manifest(self.path)
        m = self.manifest
        self.geometry = CubeGeometry(
            m["num_slices"], m["lines_per_slice"], m["points_per_line"])
        self.num_observations = m["num_observations"]
        self.content_sha256 = _manifest_content_sha(m)
        # Per-slice chunk index, ordered by line_start — and validated to
        # tile every slice exactly: a manifest with a gap (hand-edited,
        # partially synced) would otherwise make load_window silently
        # return uninitialized buffer rows for the uncovered lines.
        self._chunks: dict[int, list[dict]] = {}
        for c in m["chunks"]:
            self._chunks.setdefault(c["slice"], []).append(c)
        for lst in self._chunks.values():
            lst.sort(key=lambda c: c["line_start"])
        for s in range(self.geometry.num_slices):
            line = 0
            for c in self._chunks.get(s, ()):
                if c["line_start"] != line or c["line_end"] <= c["line_start"]:
                    break
                line = c["line_end"]
            if line != self.geometry.lines_per_slice:
                raise ValueError(
                    f"cube manifest at {self.path} does not cover slice {s}: "
                    f"chunks tile lines [0, {line}) of "
                    f"[0, {self.geometry.lines_per_slice})")
        self._mmaps: OrderedDict[str, np.ndarray] = OrderedDict()
        # Speculative re-dispatch (core.executor) can read two windows of
        # one source from two threads; the LRU mutations must not race.
        self._mmap_lock = threading.Lock()

    def enable_read_verification(self, read_hook: Callable | None = None):
        """Arm verified (full-load + sha256 + one re-read) window reads; see
        the class docstring. ``read_hook(slice_i, line_start, arr, attempt)
        -> arr`` intercepts each freshly read chunk — the fault-injection
        seam. Returns ``self`` for chaining."""
        self.verify_reads = True
        if read_hook is not None:
            self.read_hook = read_hook
        return self

    def _mmap(self, entry: dict) -> np.ndarray:
        name = entry["file"]
        with self._mmap_lock:
            if name in self._mmaps:
                self._mmaps.move_to_end(name)
                return self._mmaps[name]
        arr = np.load(self.path / name, mmap_mode="r")
        expect = (entry["line_end"] - entry["line_start"],
                  self.geometry.points_per_line, self.num_observations)
        if arr.shape != expect or arr.dtype != np.float32:
            raise ValueError(
                f"cube chunk {name}: shape {arr.shape} dtype {arr.dtype} "
                f"does not match manifest ({expect}, float32)")
        with self._mmap_lock:
            self._mmaps[name] = arr
            if len(self._mmaps) > _MMAP_CACHE_SIZE:
                self._mmaps.popitem(last=False)
        return arr

    def _read_chunk_verified(self, entry: dict) -> np.ndarray:
        """Fully load one chunk and check its sha256 against the manifest.

        A mismatch triggers exactly ONE re-read (the torn-read/transient
        case self-heals); a second mismatch raises with the chunk path and
        attempt count, so the operator knows retrying was already tried."""
        name = entry["file"]
        attempts = 0
        while True:
            attempts += 1
            arr = np.load(self.path / name)
            if self.read_hook is not None:
                arr = self.read_hook(
                    entry["slice"], entry["line_start"], arr, attempts)
            got = _array_sha256(arr)
            if got == entry["sha256"]:
                return arr
            if attempts >= 2:
                raise ValueError(
                    f"cube chunk {self.path / name} corrupt after "
                    f"{attempts} read attempts: sha256 {got} != "
                    f"manifest {entry['sha256']}")

    def load_window(self, w: Window) -> np.ndarray:
        geom = self.geometry
        if not (0 <= w.slice_i < geom.num_slices
                and 0 <= w.line_start < w.line_end <= geom.lines_per_slice):
            raise ValueError(f"window {w} outside cube {geom}")
        out = np.empty(
            (w.num_lines, geom.points_per_line, self.num_observations),
            dtype=np.float32)
        for entry in self._chunks.get(w.slice_i, ()):
            if entry["line_end"] <= w.line_start:
                continue
            if entry["line_start"] >= w.line_end:
                break
            lo = max(w.line_start, entry["line_start"])
            hi = min(w.line_end, entry["line_end"])
            src = (self._read_chunk_verified(entry) if self.verify_reads
                   else self._mmap(entry))
            out[lo - w.line_start : hi - w.line_start] = src[
                lo - entry["line_start"] : hi - entry["line_start"]]
        return out.reshape(w.num_lines * geom.points_per_line,
                           self.num_observations)

    def verify(self) -> None:
        """Re-hash every chunk against the manifest; raises on the first
        *persistent* mismatch (bit rot, partial copy, or tampering) — each
        chunk gets the standard one-re-read grace for torn reads."""
        for c in self.manifest["chunks"]:
            self._read_chunk_verified(c)

    def nominal_bytes(self) -> int:
        return (self.geometry.total_points * self.num_observations * 4)
