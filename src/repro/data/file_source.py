"""Chunked cube-on-disk format: the pipeline's real file/NFS source (§3, §6).

The paper's input is not synthetic — it is a cube "produced by observation
… or numerical simulation programs" persisted on disk/NFS, which Spark's
workers then read window by window. This module is that persistence layer
for the reproduction:

  * ``export_cube`` snapshots ANY window-addressable source (the lazy
    ``SeismicSimulation``, an ``ArrayDataSource``, another file cube) into a
    directory of chunked ``.npy`` files plus a ``manifest.json``, so a
    simulation *spec* becomes real bytes on disk once and every later run
    reads those bytes instead of regenerating them;
  * ``FileCubeSource`` is the window reader: ``load_window`` memmaps only
    the chunks a window overlaps (a window read touches O(window) bytes, not
    the cube), so it plugs straight into ``WindowPrefetcher`` prefetching and
    the ``ThrottledSource`` NFS-bandwidth model like every other source;
  * the manifest carries a per-chunk sha256 and a ``content_sha256`` over
    the whole description — the cube's *data identity*. ``SourceSpec``
    (``kind='file'``) hashes by that digest, so a spec's ``content_hash``
    finally captures what ``kind='external'`` could only warn about: which
    bytes the run actually consumed (DESIGN.md §12).

On-disk layout (``layout='chunked'``, the only layout so far)::

    cube_dir/
      manifest.json                # geometry, dtype, chunk index, hashes
      s00000_l00000.npy            # (lines_per_chunk, ppl, n_obs) float32
      s00000_l00016.npy
      ...

Chunks split each slice along lines (``lines_per_chunk``), independent of
the pipeline's ``window_lines`` — the reader stitches windows from whatever
chunks they overlap, so one exported cube serves every window size.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
from collections import OrderedDict
from pathlib import Path
from typing import Callable

import numpy as np

from repro.core.regions import CubeGeometry, Window, iter_windows

MANIFEST_NAME = "manifest.json"
FORMAT_NAME = "repro-cube"
# Format 1: immutable snapshot cubes (export_cube). Format 2 adds the
# streaming-append extensions — a monotone manifest ``version``, archived
# ``manifest.vNNNNNN.json`` bodies, and delta chunks carrying an
# ``obs_start``/``obs_end`` observation range (streaming/append.py). A
# reader speaks both; export still writes format 1 so snapshot cubes stay
# readable by builds that predate streaming.
FORMAT_VERSION = 1
APPEND_FORMAT_VERSION = 2
SUPPORTED_FORMAT_VERSIONS = (1, 2)
LAYOUTS = ("chunked",)
DEFAULT_LINES_PER_CHUNK = 16

# How many chunk memmaps a reader keeps open at once. Sequential window
# reads touch a sliding band of chunks, so a small LRU is enough; the cap
# keeps a paper-scale cube (thousands of chunks) from exhausting file
# descriptors.
_MMAP_CACHE_SIZE = 64


def _chunk_name(slice_i: int, line_start: int) -> str:
    return f"s{slice_i:05d}_l{line_start:05d}.npy"


def _array_sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def _manifest_content_sha(manifest: dict) -> str:
    """The cube's data identity: sha256 over the canonical JSON of the
    manifest *without* its own ``content_sha256`` field. The per-chunk
    hashes are inside, so any byte of observation data changing changes
    this digest — and with it every dependent spec ``content_hash``."""
    payload = {k: v for k, v in manifest.items() if k != "content_sha256"}
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def _archive_name(version: int) -> str:
    return f"manifest.v{version:06d}.json"


def read_manifest(path: str | Path, version: int | None = None) -> dict:
    """Load + sanity-check a cube directory's manifest.

    ``version=None`` reads the current manifest; an explicit version reads
    that snapshot of the cube's history — the current manifest if it *is*
    that version, else the ``manifest.vNNNNNN.json`` body an append
    archived (streaming/append.py)."""
    f = Path(path) / MANIFEST_NAME
    if not f.exists():
        raise ValueError(
            f"no cube manifest at {f} — export one first with "
            "data.file_source.export_cube(source, out_dir)")
    manifest = json.loads(f.read_text())
    current = int(manifest.get("version", 1))
    if version is not None and version != current:
        if not 1 <= version < current:
            raise ValueError(
                f"cube at {path} has no version {version} "
                f"(current is {current})")
        arch = Path(path) / _archive_name(version)
        if not arch.exists():
            raise ValueError(
                f"cube at {path}: archived manifest {arch.name} is missing "
                f"(crash-orphaned history?) — only the current version "
                f"{current} is readable")
        manifest = json.loads(arch.read_text())
    if manifest.get("format") != FORMAT_NAME:
        raise ValueError(
            f"{f} is not a {FORMAT_NAME} manifest (format="
            f"{manifest.get('format')!r})")
    if manifest.get("format_version") not in SUPPORTED_FORMAT_VERSIONS:
        raise ValueError(
            f"cube format version {manifest.get('format_version')} "
            f"unsupported (this build reads versions "
            f"{SUPPORTED_FORMAT_VERSIONS})")
    return manifest


def manifest_sha(path: str | Path, version: int | None = None) -> str:
    """The cube's ``content_sha256`` — what ``SourceSpec(kind='file')``
    hashes by. Recomputed from the manifest body (not trusted from the
    stored field), so a hand-edited manifest cannot alias another cube's
    provenance. ``version`` addresses an archived manifest — how the
    incremental layer reconstructs the spec hash a *previous* version of
    the cube ran under (streaming/incremental.py)."""
    return _manifest_content_sha(read_manifest(path, version=version))


def manifest_version(path: str | Path) -> int:
    """The cube's current manifest version (1 for never-appended cubes —
    format-1 manifests carry no ``version`` field)."""
    return int(read_manifest(path).get("version", 1))


def chunk_obs_range(entry: dict, base_obs: int) -> tuple[int, int]:
    """A chunk's observation range ``[obs_start, obs_end)``. Base chunks
    (format 1, or the original export inside an appended cube) carry no
    range and cover the base observations."""
    return (int(entry.get("obs_start", 0)),
            int(entry.get("obs_end", base_obs)))


def slice_chunk_shas(manifest: dict, slice_i: int) -> tuple[str, ...]:
    """The slice's chunk sha256 set in canonical (obs_start, line_start)
    order — the per-slice *dependency fingerprint* the chunk-granular
    ``ResultCache`` invalidation records and compares (api/cache.py):
    equal fingerprints ⇒ the slice's input bytes are unchanged."""
    base_obs = int(manifest["num_observations"])
    mine = [c for c in manifest["chunks"] if c["slice"] == slice_i]
    mine.sort(key=lambda c: (chunk_obs_range(c, base_obs)[0], c["line_start"]))
    return tuple(c["sha256"] for c in mine)


def chunk_diff(path: str | Path, old_version: int,
               new_version: int | None = None) -> dict:
    """What changed between two versions of a cube: the slices whose chunk
    set differs and the chunk entries present only in the newer version.
    Drives chunk-granular invalidation — a consumer holding results for
    ``old_version`` needs to recompute exactly ``changed_slices`` and can
    keep everything else."""
    old_m = read_manifest(path, version=old_version)
    new_m = read_manifest(path, version=new_version)
    old_files = {c["file"] for c in old_m["chunks"]}
    new_chunks = [c for c in new_m["chunks"] if c["file"] not in old_files]
    num_slices = int(new_m["num_slices"])
    changed = sorted({
        s for s in range(num_slices)
        if slice_chunk_shas(old_m, s) != slice_chunk_shas(new_m, s)})
    return {
        "old_version": int(old_m.get("version", 1)),
        "new_version": int(new_m.get("version", 1)),
        "changed_slices": changed,
        "new_chunks": new_chunks,
    }


def export_cube(
    source,
    out_dir: str | Path,
    lines_per_chunk: int = DEFAULT_LINES_PER_CHUNK,
    progress: Callable[[int, int], None] | None = None,
    overwrite: bool = False,
):
    """Snapshot a window-addressable source to a chunked cube directory.

    ``source`` is either a live source object (``geometry`` +
    ``load_window``) or a ``SourceSpec`` — a simulation spec is materialized
    here (with its NFS-throttle model stripped: the throttle describes the
    *read* path, and export is the write path). Returns a ready-to-run
    ``SourceSpec(kind='file', path=out_dir)`` carrying the original spec's
    throttle, so ``export_cube(spec.source, d)`` drops straight back into a
    ``PipelineSpec``.

    The manifest is written last (tmp + atomic rename): a crashed export
    leaves a directory without a manifest, which every reader refuses —
    never a readable-but-truncated cube. A directory that already holds a
    cube (its ``manifest.json`` exists) is refused *before any chunk is
    written* unless ``overwrite=True`` — re-exporting over a live cube
    would silently re-key every spec hash derived from it, so clobbering
    must be explicit (``--force`` on the CLI surface).
    """
    from repro.api.spec import SourceSpec, build_source

    throttle = None
    if isinstance(source, SourceSpec):
        throttle = source.throttle_mb_s
        source = build_source(dataclasses.replace(
            source, throttle_mb_s=None))
    if lines_per_chunk < 1:
        raise ValueError(f"lines_per_chunk must be >= 1, got {lines_per_chunk}")

    geom: CubeGeometry = source.geometry
    out = Path(out_dir)
    if not overwrite and (out / MANIFEST_NAME).exists():
        raise FileExistsError(
            f"{out} already holds a cube ({MANIFEST_NAME} exists) — "
            "exporting over it would replace its data identity; pass "
            "overwrite=True (--force) to clobber, or export elsewhere")
    out.mkdir(parents=True, exist_ok=True)

    chunks = []
    num_obs = None
    total = sum(1 for s in range(geom.num_slices)
                for _ in iter_windows(geom, s, lines_per_chunk))
    done = 0
    for s in range(geom.num_slices):
        for w in iter_windows(geom, s, lines_per_chunk):
            block = np.asarray(source.load_window(w), dtype=np.float32)
            if num_obs is None:
                num_obs = block.shape[1]
            arr = block.reshape(w.num_lines, geom.points_per_line, num_obs)
            name = _chunk_name(s, w.line_start)
            np.save(out / name, arr)
            chunks.append({
                "file": name,
                "slice": s,
                "line_start": w.line_start,
                "line_end": w.line_end,
                "sha256": _array_sha256(arr),
            })
            done += 1
            if progress is not None:
                progress(done, total)

    manifest = {
        "format": FORMAT_NAME,
        "format_version": FORMAT_VERSION,
        "layout": "chunked",
        "num_slices": geom.num_slices,
        "lines_per_slice": geom.lines_per_slice,
        "points_per_line": geom.points_per_line,
        "num_observations": int(num_obs),
        "dtype": "float32",
        "lines_per_chunk": lines_per_chunk,
        "chunks": chunks,
    }
    manifest["content_sha256"] = _manifest_content_sha(manifest)
    tmp = out / (MANIFEST_NAME + ".tmp")
    tmp.write_text(json.dumps(manifest, indent=1, sort_keys=True))
    os.replace(tmp, out / MANIFEST_NAME)

    # Geometry fields on a file spec are advisory (the manifest is
    # authoritative, and they are excluded from the hash) — fill them in
    # anyway so the returned spec reads true.
    return SourceSpec(
        kind="file", path=str(out), throttle_mb_s=throttle,
        num_slices=geom.num_slices, lines_per_slice=geom.lines_per_slice,
        points_per_line=geom.points_per_line, observations=int(num_obs))


class FileCubeSource:
    """Window reader over an exported cube directory.

    ``load_window(w) -> (num_points, n_obs) float32``, bit-identical to what
    the exported source produced (tests/test_file_source.py asserts the
    round-trip against the simulation, and through the full pipeline).
    Reads memmap only the chunks the window overlaps and copy them into a
    fresh array — the copy forces the actual page-in, so a wrapping
    ``ThrottledSource`` times real bytes moved, and the buffer handed to the
    prefetcher is safe to donate.

    ``enable_read_verification()`` arms *verified reads*: every chunk a
    window touches is fully loaded (no memmap) and re-hashed against the
    manifest, with ONE automatic re-read on mismatch before raising — a torn
    read over NFS (reader racing a copy, transient bit flip in transit)
    recovers transparently; persistent corruption raises with the chunk path
    and attempt count (DESIGN.md §14). ``verify()`` uses the same re-read
    policy. ``read_hook`` is the chaos-testing seam ``runtime.faults`` uses
    to corrupt chunk bytes deterministically in tests.
    """

    def __init__(self, path: str | Path, verify_reads: bool = False,
                 read_hook: Callable | None = None,
                 version: int | None = None):
        self.path = Path(path)
        self.verify_reads = bool(verify_reads)
        self.read_hook = read_hook
        self.manifest = read_manifest(self.path, version=version)
        m = self.manifest
        self.version = int(m.get("version", 1))
        self.geometry = CubeGeometry(
            m["num_slices"], m["lines_per_slice"], m["points_per_line"])
        # The BASE observation count (the original export's). Appended
        # slices carry extra observation *layers* on top — per-slice totals
        # come from slice_observations().
        self.num_observations = m["num_observations"]
        self.content_sha256 = _manifest_content_sha(m)
        # Per-slice chunk index, ordered by (obs_start, line_start) — and
        # validated so load_window can never silently return uninitialized
        # buffer regions: every observation layer must tile the slice's
        # lines exactly, and the layers themselves must be contiguous in
        # observations ([0, base), [base, e1), [e1, e2), ...).
        self._chunks: dict[int, list[dict]] = {}
        for c in m["chunks"]:
            self._chunks.setdefault(c["slice"], []).append(c)
        self._slice_obs: dict[int, int] = {}
        for s in range(self.geometry.num_slices):
            lst = self._chunks.get(s, ())
            layers: dict[tuple[int, int], list[dict]] = {}
            for c in lst:
                layers.setdefault(chunk_obs_range(c, self.num_observations),
                                  []).append(c)
            obs_end = 0
            for (o0, o1), layer in sorted(layers.items()):
                if o0 != obs_end or o1 <= o0:
                    raise ValueError(
                        f"cube manifest at {self.path} slice {s}: "
                        f"observation layer [{o0}, {o1}) does not extend "
                        f"the covered range [0, {obs_end})")
                layer.sort(key=lambda c: c["line_start"])
                line = 0
                for c in layer:
                    if c["line_start"] != line or c["line_end"] <= c["line_start"]:
                        break
                    line = c["line_end"]
                if line != self.geometry.lines_per_slice:
                    raise ValueError(
                        f"cube manifest at {self.path} does not cover slice "
                        f"{s} (obs [{o0}, {o1})): chunks tile lines "
                        f"[0, {line}) of [0, {self.geometry.lines_per_slice})")
                obs_end = o1
            if obs_end == 0:
                raise ValueError(
                    f"cube manifest at {self.path} has no chunks for "
                    f"slice {s}")
            self._slice_obs[s] = obs_end
            lst = sorted(
                lst, key=lambda c: (
                    chunk_obs_range(c, self.num_observations)[0],
                    c["line_start"]))
            self._chunks[s] = lst
        self._mmaps: OrderedDict[str, np.ndarray] = OrderedDict()
        # Speculative re-dispatch (core.executor) can read two windows of
        # one source from two threads; the LRU mutations must not race.
        self._mmap_lock = threading.Lock()

    def slice_observations(self, slice_i: int) -> int:
        """Total observations for one slice — the base export's count plus
        every appended layer's (appends touch a subset of slices, so the
        per-slice totals may differ)."""
        return self._slice_obs[slice_i]

    def enable_read_verification(self, read_hook: Callable | None = None):
        """Arm verified (full-load + sha256 + one re-read) window reads; see
        the class docstring. ``read_hook(slice_i, line_start, arr, attempt)
        -> arr`` intercepts each freshly read chunk — the fault-injection
        seam. Returns ``self`` for chaining."""
        self.verify_reads = True
        if read_hook is not None:
            self.read_hook = read_hook
        return self

    def _mmap(self, entry: dict) -> np.ndarray:
        name = entry["file"]
        with self._mmap_lock:
            if name in self._mmaps:
                self._mmaps.move_to_end(name)
                return self._mmaps[name]
        arr = np.load(self.path / name, mmap_mode="r")
        o0, o1 = chunk_obs_range(entry, self.num_observations)
        expect = (entry["line_end"] - entry["line_start"],
                  self.geometry.points_per_line, o1 - o0)
        if arr.shape != expect or arr.dtype != np.float32:
            raise ValueError(
                f"cube chunk {name}: shape {arr.shape} dtype {arr.dtype} "
                f"does not match manifest ({expect}, float32)")
        with self._mmap_lock:
            self._mmaps[name] = arr
            if len(self._mmaps) > _MMAP_CACHE_SIZE:
                self._mmaps.popitem(last=False)
        return arr

    def _read_chunk_verified(self, entry: dict) -> np.ndarray:
        """Fully load one chunk and check its sha256 against the manifest.

        A mismatch triggers exactly ONE re-read (the torn-read/transient
        case self-heals); a second mismatch raises with the chunk path and
        attempt count, so the operator knows retrying was already tried."""
        name = entry["file"]
        attempts = 0
        while True:
            attempts += 1
            arr = np.load(self.path / name)
            if self.read_hook is not None:
                arr = self.read_hook(
                    entry["slice"], entry["line_start"], arr, attempts)
            got = _array_sha256(arr)
            if got == entry["sha256"]:
                return arr
            if attempts >= 2:
                raise ValueError(
                    f"cube chunk {self.path / name} corrupt after "
                    f"{attempts} read attempts: sha256 {got} != "
                    f"manifest {entry['sha256']}")

    def load_window(self, w: Window) -> np.ndarray:
        if w.slice_i not in self._slice_obs:
            raise ValueError(f"window {w} outside cube {self.geometry}")
        return self.load_window_obs(w, 0, self._slice_obs[w.slice_i])

    def load_window_obs(self, w: Window, obs_start: int,
                        obs_end: int) -> np.ndarray:
        """One window restricted to the observation range ``[obs_start,
        obs_end)`` — ``load_window`` is the full range. The restricted form
        is the streaming delta read: an incremental update touches only the
        chunks of the appended layers, O(new data) bytes, never the base
        cube (streaming/incremental.py)."""
        geom = self.geometry
        if not (0 <= w.slice_i < geom.num_slices
                and 0 <= w.line_start < w.line_end <= geom.lines_per_slice):
            raise ValueError(f"window {w} outside cube {geom}")
        slice_obs = self._slice_obs[w.slice_i]
        if not 0 <= obs_start < obs_end <= slice_obs:
            raise ValueError(
                f"observation range [{obs_start}, {obs_end}) outside the "
                f"slice's [0, {slice_obs})")
        width = obs_end - obs_start
        out = np.empty((w.num_lines, geom.points_per_line, width),
                       dtype=np.float32)
        for entry in self._chunks.get(w.slice_i, ()):
            o0, o1 = chunk_obs_range(entry, self.num_observations)
            if o1 <= obs_start or o0 >= obs_end:
                continue
            if entry["line_end"] <= w.line_start or entry["line_start"] >= w.line_end:
                continue
            lo = max(w.line_start, entry["line_start"])
            hi = min(w.line_end, entry["line_end"])
            co0 = max(o0, obs_start)
            co1 = min(o1, obs_end)
            src = (self._read_chunk_verified(entry) if self.verify_reads
                   else self._mmap(entry))
            out[lo - w.line_start : hi - w.line_start, :,
                co0 - obs_start : co1 - obs_start] = src[
                lo - entry["line_start"] : hi - entry["line_start"], :,
                co0 - o0 : co1 - o0]
        return out.reshape(w.num_lines * geom.points_per_line, width)

    def verify(self) -> None:
        """Re-hash every chunk against the manifest; raises on the first
        *persistent* mismatch (bit rot, partial copy, or tampering) — each
        chunk gets the standard one-re-read grace for torn reads."""
        for c in self.manifest["chunks"]:
            self._read_chunk_verified(c)

    def nominal_bytes(self) -> int:
        return sum(self.geometry.points_per_slice * obs * 4
                   for obs in self._slice_obs.values())
