from repro.data.simulation import SeismicSimulation, SimulationConfig
from repro.data.loader import (
    ArrayDataSource,
    PrefetchError,
    ShardedStager,
    ThrottledSource,
    WindowPrefetcher,
)
from repro.data.tokens import TokenPipeline

__all__ = [
    "SeismicSimulation", "SimulationConfig", "ArrayDataSource",
    "ShardedStager", "ThrottledSource", "WindowPrefetcher", "PrefetchError",
    "TokenPipeline",
]
