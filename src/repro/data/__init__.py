from repro.data.simulation import SeismicSimulation, SimulationConfig
from repro.data.file_source import FileCubeSource, export_cube, manifest_sha
from repro.data.loader import (
    ArrayDataSource,
    PrefetchError,
    ShardedStager,
    ThrottledSource,
    WindowPrefetcher,
)
from repro.data.tokens import TokenPipeline

__all__ = [
    "SeismicSimulation", "SimulationConfig", "ArrayDataSource",
    "FileCubeSource", "export_cube", "manifest_sha",
    "ShardedStager", "ThrottledSource", "WindowPrefetcher", "PrefetchError",
    "TokenPipeline",
]
