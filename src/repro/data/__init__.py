from repro.data.simulation import SeismicSimulation, SimulationConfig
from repro.data.loader import ArrayDataSource
from repro.data.tokens import TokenPipeline

__all__ = ["SeismicSimulation", "SimulationConfig", "ArrayDataSource", "TokenPipeline"]
