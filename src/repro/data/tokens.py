"""Deterministic LM token pipeline for the assigned architectures.

Production framework substrate: an infinite, seeded, shardable stream of
(tokens, targets) batches with a restartable cursor — enough to drive the
train examples and smoke tests without external datasets. Sequences follow a
Zipfian unigram mixed with a repeated-motif process so the loss is learnable
(models fit the motifs) yet cheap to generate.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    motif_len: int = 16
    num_motifs: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        vocab = max(self.vocab_size - 1, 1)
        self._motifs = rng.integers(0, vocab, size=(self.num_motifs, self.motif_len))
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self._zipf = (1.0 / ranks) / np.sum(1.0 / ranks)
        self.step = 0

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])

    def next_batch(self) -> tuple[np.ndarray, np.ndarray]:
        """Returns (tokens, targets), each (batch, seq_len) int32; targets are
        tokens shifted left (next-token prediction)."""
        rng = np.random.default_rng((self.seed, self.step))
        b, s = self.batch_size, self.seq_len + 1
        base = rng.choice(len(self._zipf), size=(b, s), p=self._zipf)
        # Overwrite random spans with motifs => predictable structure.
        for i in range(b):
            for _ in range(max(1, s // (4 * self.motif_len))):
                m = self._motifs[rng.integers(0, self.num_motifs)]
                start = rng.integers(0, max(s - self.motif_len, 1))
                base[i, start : start + self.motif_len] = m[: s - start]
        self.step += 1
        tokens = base[:, :-1].astype(np.int32)
        targets = base[:, 1:].astype(np.int32)
        return tokens, targets
