"""``PDFSession``: execute a ``PipelineSpec`` — the one run surface.

A session owns everything a run needs (the data source, the decision tree
when the method wants one, one ``StagedExecutor`` per shard) and exposes a
*streaming* entry point: ``run(slices)`` is a generator yielding one
``SliceResult`` as each slice completes, so callers can persist / print /
aggregate incrementally at paper scale instead of holding every slice's
arrays until the end. ``run_all`` drains it into the familiar
``{slice: result}`` map; ``report()`` aggregates the per-stage executor
reports plus the spec's provenance hash (and, when ``ExecSpec.cache_dir``
routes the session through a ``ResultCache``, the per-slice hit/miss
counts — cache hits stream stored results bitwise-identical without
building an executor at all).

Slices are dealt round-robin over ``spec.execution.shards`` (the paper's
per-node whole-slice assignment, runtime/scheduler.assign_slices); each
shard's executor is cached on the session, so its reuse cache spans all the
slices that shard processes — exactly the semantics of the legacy
``PDFComputer`` facade, which is now a deprecation shim over the same
machinery and produces bitwise-identical results (tests/test_api.py).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Callable, Iterator

from repro.api.cache import ResultCache
from repro.api.spec import PipelineSpec, build_source
from repro.core import ml_predict as mlp
from repro.core import regions
from repro.core.executor import ExecutorReport, SliceResult, StagedExecutor
from repro.runtime import elastic
from repro.runtime.faults import FaultInjector, FaultPlan, ShardLostError
from repro.runtime.scheduler import assign_slices


@dataclass(frozen=True)
class SessionReport:
    """Per-stage totals over every executor run the session has done, plus
    the spec provenance hash (the same hash stamped into persisted
    watermarks and BENCH rows)."""

    spec_hash: str
    slices_done: int
    windows: int
    wall_seconds: float
    load_seconds: float
    wait_seconds: float
    compute_seconds: float
    persist_seconds: float
    # ResultCache traffic (ExecSpec.cache_dir): slices served without any
    # compute vs slices computed (and stored). Both stay 0 with no cache.
    cache_hits: int = 0
    cache_misses: int = 0
    # Streaming (DESIGN.md §16): entries re-keyed across an append because
    # their chunk fingerprints were unchanged (each then counts as a hit),
    # and slices updated by the merge path instead of a full recompute.
    cache_adopted: int = 0
    slices_merged: int = 0
    # Fault-tolerance totals (DESIGN.md §14): transient re-attempts,
    # speculative load re-dispatches, quarantined (degraded-mode) units,
    # and shards that died mid-run whose slices were re-dealt.
    retries: int = 0
    speculations: int = 0
    quarantined_units: int = 0
    shards_lost: tuple[int, ...] = ()
    # Cold-start visibility (DESIGN.md §17): process-wide XLA activity
    # since the session was constructed (jax.monitoring deltas, via
    # runtime.cluster.compile_counters — concurrent sessions in one process
    # share the counters). ``compiles`` fires on persistent-cache hits too
    # (XLA still enters its compile path), so the "zero new traces"
    # cold-start assertion is ``compile_cache_misses == 0`` with
    # ``ExecSpec.compile_cache_dir`` enabled.
    traces: int = 0
    compiles: int = 0
    compile_cache_hits: int = 0
    compile_cache_misses: int = 0
    shard_reports: dict[int, list[ExecutorReport]] = field(default_factory=dict)
    # Per-stage latency percentiles over every completed unit (seconds):
    # {"load"|"compute"|"persist": {"p50": ..., "p99": ...}} — from the
    # executors' StepMonitors, merged across shards. The serve layer's stats
    # endpoint reuses the same monitors/estimator verbatim.
    stage_percentiles: dict[str, dict[str, float]] = field(default_factory=dict)

    @property
    def load_hidden_seconds(self) -> float:
        return max(0.0, self.load_seconds - self.wait_seconds)

    @property
    def load_hidden_fraction(self) -> float:
        return (self.load_hidden_seconds / self.load_seconds
                if self.load_seconds > 0 else 0.0)


class PDFSession:
    """Execute a validated ``PipelineSpec``.

    ``data_source`` overrides the source the spec would build (required for
    ``source.kind='external'``; it must expose ``geometry`` and
    ``load_window``). ``tree`` injects a pre-trained decision tree —
    otherwise one is trained lazily per ``spec.method.tree`` the first time
    an ml/sampling method needs it.
    """

    def __init__(self, spec: PipelineSpec, data_source=None,
                 tree: mlp.DecisionTree | None = None,
                 fault_injector: FaultInjector | None = None):
        if not isinstance(spec, PipelineSpec):
            raise TypeError(f"spec must be a PipelineSpec, got {type(spec).__name__}")
        self.spec = spec
        self.source = data_source if data_source is not None else build_source(spec.source)
        self._tree = tree
        self._executors: dict[int, StagedExecutor] = {}
        self._reports: dict[int, list[ExecutorReport]] = {}
        self._slices_done = 0
        # Chaos layer (DESIGN.md §14): an explicit injector wins; otherwise
        # ExecSpec.fault_plan (the --fault-plan JSON file) builds one. Each
        # shard's executor reads through its own injector-wrapped source,
        # so shard-targeted rules (shard_death) see the right identity.
        self.injector = fault_injector
        if self.injector is None and spec.execution.fault_plan:
            self.injector = FaultInjector(
                FaultPlan.load(spec.execution.fault_plan))
        self.shards_lost: tuple[int, ...] = ()
        # Hashed once: the spec is frozen, and for kind='file' hashing reads
        # + digests the on-disk manifest — per-slice cache lookups must not
        # repeat that (and a manifest swapped mid-run must not split the
        # session across two hashes).
        self._spec_hash = spec.content_hash()
        # Cold-start elimination (DESIGN.md §17): the persistent XLA
        # compilation cache, keyed under <compile_cache_dir>/<spec_hash> so
        # a re-launched identical spec serves every executable from disk.
        # Enabled before any executor compiles; the counter baseline makes
        # report() deltas session-scoped.
        from repro.runtime import cluster as _cluster

        if spec.execution.compile_cache_dir:
            _cluster.enable_compilation_cache(
                spec.execution.compile_cache_dir, self._spec_hash)
        self._compile_baseline = _cluster.compile_counters()
        self.cache = (ResultCache(spec.execution.cache_dir,
                                  max_bytes=spec.execution.cache_max_bytes,
                                  injector=self.injector)
                      if spec.execution.cache_dir else None)
        self.cache_hits = 0
        self.cache_misses = 0
        self.cache_adopted = 0
        self.slices_merged = 0
        self._manifest: dict | None = None  # file-source manifest, read once
        self._lineage: tuple[str, ...] | None = None  # archived-version hashes
        if self.cache is not None and spec.source.kind == "external":
            # Same honesty gap as resume: the hash covers the pipeline
            # knobs but cannot capture an external source's data identity,
            # so a cache entry could be served to a run over different data.
            warnings.warn(
                "result cache with an external data source: the spec hash "
                "keys the pipeline knobs only, not the dataset's identity — "
                "make sure cache_dir belongs to this source (or export the "
                "data with file_source.export_cube and use kind='file')",
                stacklevel=2)

    # -- components ------------------------------------------------------------

    @property
    def geometry(self) -> regions.CubeGeometry:
        return self.source.geometry

    @property
    def spec_hash(self) -> str:
        return self._spec_hash

    def _needs_tree(self) -> bool:
        m = self.spec.method.name
        return "ml" in m or m == "sampling"

    @property
    def tree(self) -> mlp.DecisionTree | None:
        """The decision tree (§5.3.1), trained on demand from the spec's
        TreeSpec when the method requires one."""
        if self._tree is None and self._needs_tree():
            from repro.core.pipeline import train_type_tree

            ts = self.spec.method.tree
            slices = ts.train_slices
            if slices is None:
                slices = tuple(range(min(4, self.geometry.num_slices)))
            self._tree = train_type_tree(
                self.source,
                types=tuple(self.spec.compute.types),
                slices=slices,
                window_lines=ts.train_window_lines,
                depth=ts.depth,
                max_bins=ts.max_bins,
            )
        return self._tree

    def executor(self, shard: int = 0) -> StagedExecutor:
        """The shard's ``StagedExecutor`` (built on first use; its reuse
        cache persists across every slice the shard runs). With a fault
        injector active, the shard reads through an injector-wrapped source
        (read faults, shard death) and its persist stage gets the injector's
        write hook."""
        if shard not in self._executors:
            source = self.source
            if self.injector is not None:
                source = self.injector.wrap_source(source, shard=shard)
            sharding = None
            if self.spec.execution.placement.shard_devices is not None:
                from repro.runtime import cluster

                # the per-shard device placement seam: stage this shard's
                # windows onto its pinned local device (bitwise-invariant —
                # same executable, same inputs, different queue)
                sharding = cluster.device_placement(
                    self.spec.execution.placement, shard)
            recorder = None
            if (self.spec.stream.persist_stats
                    and self.spec.execution.out_dir is not None):
                from repro.streaming.stats import StatsRecorder

                recorder = StatsRecorder(self.spec.execution.out_dir,
                                         self.spec.compute.num_bins,
                                         spec_hash=self.spec_hash)
            self._executors[shard] = StagedExecutor(
                self.spec.pdf_config(),
                source,
                tree=self.tree,
                out_dir=self.spec.execution.out_dir,
                sharding=sharding,
                exec_config=self.spec.exec_config(),
                spec_hash=self.spec_hash,
                injector=self.injector,
                stats_recorder=recorder,
            )
        return self._executors[shard]

    # -- execution -------------------------------------------------------------

    def resolve_slices(self, slices) -> list[int]:
        if slices is None:
            slices = self.spec.execution.slices
        if slices is None:
            slices = range(self.geometry.num_slices)
        return list(slices)

    def run(
        self,
        slices=None,
        resume: bool | None = None,
        on_window: Callable | None = None,
    ) -> Iterator[SliceResult]:
        """Stream ``SliceResult``s (each carries its ``slice_i`` and the
        spec hash). ``slices`` defaults to ``spec.execution.slices`` (then
        to the whole cube); ``resume`` defaults to ``spec.execution.resume``.
        Shards run in assignment order; within a shard, slices stream in the
        order given.

        With ``ExecSpec.cache_dir`` set, each slice first consults the
        ``ResultCache`` under the spec's content hash: a hit streams the
        stored result bitwise-identical (``cached=True``, no executor work,
        no ``on_window`` callbacks), a miss computes the slice and stores
        it. Executors are built lazily, so a fully cache-served run never
        builds one (nor trains the decision tree)."""
        if resume is None:
            resume = self.spec.execution.resume
        if resume and self.spec.source.kind == "external":
            # The hash covers the pipeline knobs but admits it cannot
            # capture an external source's identity — two different
            # datasets with the same knobs hash alike, so the watermark
            # check cannot catch that particular mixup.
            warnings.warn(
                "resuming with an external data source: the spec hash "
                "verifies the pipeline knobs only, not the dataset's "
                "identity — make sure out_dir belongs to this source",
                stacklevel=2)
        exe = self.spec.execution
        bound = self.spec.method.error_bound
        resolved = self.resolve_slices(slices)
        self._adopt_unchanged(resolved)
        lost: list[int] = []
        pending: list[int] = []  # slices stranded on dead shards, in order
        healthy: list[int] = []
        for a in assign_slices(resolved, exe.shards):
            if exe.shard is not None and a.shard != exe.shard:
                continue
            dead = False
            ex = None
            for i, s in enumerate(a.slices):
                if self.cache is not None:
                    hit = self.cache.lookup(self.spec_hash, s)
                    if hit is not None:
                        if bound is not None:
                            hit.error_bound_satisfied = hit.avg_error <= bound
                        self.cache_hits += 1
                        self._slices_done += 1
                        self._persist_cached(hit, resume=resume)
                        yield hit
                        continue
                    self.cache_misses += 1
                merged = self._try_merge(s)
                if merged is not None:
                    # merge-mode incremental update (streaming/incremental):
                    # NOT stored in the ResultCache — merged results are
                    # path-dependent (within the recorded ulp budget, not
                    # bitwise), and the cache serves only bitwise entries.
                    if bound is not None:
                        merged.error_bound_satisfied = merged.avg_error <= bound
                    self._slices_done += 1
                    self.slices_merged += 1
                    yield merged
                    continue
                if ex is None:
                    ex = self.executor(a.shard)
                try:
                    result = self._run_one(ex, a.shard, s, resume, on_window)
                except ShardLostError:
                    # The batch form of a transient failure: the shard is
                    # gone, its unfinished slices get re-dealt below over
                    # whoever survives (runtime/elastic.plan_redeal). In
                    # pinned single-shard mode (a cluster worker) there is
                    # nobody else in this process — the death propagates so
                    # the cross-process protocol (runtime/cluster) can
                    # publish the lost marker and let survivors redeal.
                    if exe.shard is not None:
                        raise
                    lost.append(a.shard)
                    pending.extend(a.slices[i:])
                    dead = True
                    break
                yield result
            if not dead:
                healthy.append(a.shard)
        if pending:
            self.shards_lost = tuple(lost)
            plan = elastic.plan_redeal(pending, healthy, lost)
            # resume=True when persisting: windows the dead shard already
            # made durable are restored, only its remaining units re-run
            # (the watermark + failed-unit manifest are the recovery line).
            redeal_resume = bool(resume or exe.out_dir is not None)
            for h in plan.healthy_shards:
                for s in plan.slices_for(h):
                    yield self._run_one(
                        self.executor(h), h, s, redeal_resume, on_window)

    def run_local(
        self,
        slices,
        shard: int | None = None,
        resume: bool | None = None,
        on_window: Callable | None = None,
    ) -> Iterator[SliceResult]:
        """Run an explicit slice list on ONE shard's executor, bypassing the
        round-robin deal — the redeal seam ``runtime.cluster`` uses: a
        survivor (or join-only worker) takes its ``plan_redeal`` share here,
        where ``run(slices=...)`` would re-deal the list over all shards and
        skip the ones not pinned to this process. ``resume`` defaults to
        True when an out_dir exists (windows a dead shard persisted are
        skipped; recomputed windows are bitwise-identical)."""
        if shard is None:
            shard = self.spec.execution.shard or 0
        if resume is None:
            resume = bool(self.spec.execution.resume
                          or self.spec.execution.out_dir is not None)
        for s in slices:
            yield self._run_one(self.executor(shard), shard, s, resume,
                                on_window)

    def _run_one(self, ex: StagedExecutor, shard: int, s: int,
                 resume: bool, on_window: Callable | None) -> SliceResult:
        """Run one slice on one shard's executor, recording its report and
        result-cache traffic. Degraded results are NOT stored: a cache entry
        answers for the whole slice, and a quarantined window's zeros are a
        hole, not an answer — the cache must only ever serve complete
        slices."""
        plan = regions.build_plan(
            self.geometry, [s], self.spec.compute.window_lines
        )
        result = ex.run(plan, resume=resume, on_window=on_window)[s]
        if ex.last_report is not None:
            self._reports.setdefault(shard, []).append(ex.last_report)
        self._slices_done += 1
        if self.cache is not None:
            if result.degraded:
                warnings.warn(
                    f"slice {s} completed degraded "
                    f"({len(result.quarantined)} quarantined unit(s)) — "
                    "not stored in the result cache", stacklevel=2)
            else:
                self.cache.store(result, deps=self._slice_deps(s))
        return result

    # -- streaming: adoption / merge updates (DESIGN.md §16) -------------------

    def _file_source(self):
        """The underlying ``FileCubeSource`` (unwrapping a throttle), or
        None when the session does not read a file cube."""
        if self.spec.source.kind != "file":
            return None
        src = getattr(self.source, "inner", self.source)
        return src if hasattr(src, "load_window_obs") else None

    def _slice_deps(self, s: int) -> tuple[str, ...] | None:
        """The slice's chunk-dependency fingerprint under the manifest this
        session hashed against (read once — a manifest swapped mid-run must
        not split the session across two fingerprints)."""
        if self.spec.source.kind != "file":
            return None
        from repro.data.file_source import read_manifest, slice_chunk_shas

        if self._manifest is None:
            self._manifest = read_manifest(self.spec.source.path)
        return slice_chunk_shas(self._manifest, s)

    def _adopt_unchanged(self, slices) -> None:
        """Chunk-granular invalidation, the adoption half: re-key cached
        entries from earlier manifest versions whose chunk fingerprints are
        unchanged by the appends since (``ResultCache.adopt`` proves that
        per slice), so only chunk-overlapping slices miss. Most-recent
        version first; each adopted entry becomes a plain cache hit."""
        if (self.cache is None or not self.spec.stream.incremental
                or self._file_source() is None):
            return
        from repro.data.file_source import manifest_version

        try:
            cur = manifest_version(self.spec.source.path)
        except (OSError, ValueError, KeyError):
            return
        remaining = [s for s in slices
                     if not self.cache.path(self.spec_hash, s).exists()]
        for v in range(cur - 1, 0, -1):
            if not remaining:
                return
            try:
                old_hash = self.spec.content_hash(manifest_version=v)
            except (OSError, ValueError, KeyError):
                return  # archived manifest missing: nothing older to scan
            still = []
            for s in remaining:
                deps = self._slice_deps(s)
                if deps and self.cache.adopt(old_hash, self.spec_hash, s, deps):
                    self.cache_adopted += 1
                else:
                    still.append(s)
            remaining = still

    def _lineage_hashes(self) -> tuple[str, ...]:
        """The spec's hashes at every archived manifest version, newest
        first — the set of stamps a sidecar written by an ancestor run of
        THIS spec over THIS cube may legitimately carry (``merge_slice``
        accepts them after a cache-hit persist re-stamped the watermark
        without rewriting the sidecars). Memoized per spec hash;
        ``refresh_source`` invalidates."""
        if self._lineage is None:
            from repro.data.file_source import manifest_version

            hashes: list[str] = []
            try:
                cur = manifest_version(self.spec.source.path)
                for v in range(cur - 1, 0, -1):
                    hashes.append(self.spec.content_hash(manifest_version=v))
            except (OSError, ValueError, KeyError):
                pass  # unversioned/missing archives: lineage ends here
            self._lineage = tuple(hashes)
        return self._lineage

    def _try_merge(self, s: int):
        """The merge-mode incremental path for one slice, or None to fall
        through to a full recompute (strict mode, non-file sources, no
        persisted prior run, or any failed merge precondition)."""
        if (self.spec.stream.update_mode != "merge"
                or self.spec.execution.out_dir is None):
            return None
        src = self._file_source()
        if src is None:
            return None
        from repro.streaming.incremental import merge_slice

        return merge_slice(self.spec, src, s, self.spec_hash,
                           lineage=self._lineage_hashes())

    def refresh_source(self) -> str:
        """Re-open a file source at the cube's current manifest version and
        re-hash the spec — the serve layer's ``invalidate`` and ``run_pdf
        --watch`` call this after an append lands. Executors are dropped
        (their sources pin the old version); returns the new spec hash."""
        from repro.api.spec import build_source as _build

        if self.spec.source.kind == "file":
            self.source = _build(self.spec.source)
        self._manifest = None
        self._lineage = None
        self._executors.clear()
        self._spec_hash = self.spec.content_hash()
        return self._spec_hash

    def _persist_cached(self, result: SliceResult, resume: bool = False) -> None:
        """Honor ``ExecSpec.out_dir`` for cache-served slices: a hit skips
        the executor, so its window ``.npz`` files + watermark are written
        here from the cached arrays instead (same ``PersistStage`` format,
        identical bytes per the cache's bitwise contract) — a run with both
        ``--cache-dir`` and ``--out-dir`` never leaves out_dir empty. A
        resuming run applies the same watermark spec-hash mismatch check
        the executor does: a cache hit must not quietly overwrite another
        computation's watermark where the computed path would refuse."""
        out_dir = self.spec.execution.out_dir
        if out_dir is None:
            return
        from repro.core.executor import _FIELDS, PersistStage

        geom, s = self.geometry, result.slice_i
        persist = PersistStage(out_dir, async_writes=False,
                               spec_hash=self.spec_hash,
                               total_lines=geom.lines_per_slice)
        mark = 0
        if resume:
            info = persist.watermark_info(s)
            persist.check_resume_hash(s, info)
            # like the executor, skip windows the watermark already covers —
            # a resumed cache-hit run over a fully persisted out_dir must
            # not rewrite identical bytes for the whole slice
            mark = int(info["next_line"])
        for w in regions.iter_windows(geom, s, self.spec.compute.window_lines,
                                      start_line=mark):
            lo, hi = w.line_start * geom.points_per_line, w.line_end * geom.points_per_line
            persist.submit(
                s, w, {name: getattr(result, name)[lo:hi] for name in _FIELDS}
            )
        persist.close()
        persist.raise_if_failed()

    def run_all(
        self,
        slices=None,
        resume: bool | None = None,
        on_window: Callable | None = None,
    ) -> dict[int, SliceResult]:
        """Drain ``run`` into a ``{slice: SliceResult}`` map."""
        return {
            r.slice_i: r
            for r in self.run(slices, resume=resume, on_window=on_window)
        }

    def stage_percentiles(self) -> dict[str, dict[str, float]]:
        """p50/p99 unit latency per executor stage (seconds), merged over
        every shard's monitors — not just totals, so a serving/streaming
        consumer can see tail behaviour. Stages with no completed units are
        omitted."""
        from repro.runtime.monitor import percentiles

        merged: dict[str, list[float]] = {}
        for ex in self._executors.values():
            for stage, mon in ex.monitors.items():
                merged.setdefault(stage, []).extend(mon.history)
        return {stage: percentiles(h) for stage, h in merged.items() if h}

    def report(self) -> SessionReport:
        """Aggregate per-stage totals over everything run so far."""
        totals = dict(wall=0.0, load=0.0, wait=0.0, compute=0.0, persist=0.0)
        windows = retries = speculations = quarantined = 0
        for reps in self._reports.values():
            for r in reps:
                totals["wall"] += r.wall_seconds
                totals["load"] += r.load_seconds
                totals["wait"] += r.wait_seconds
                totals["compute"] += r.compute_seconds
                totals["persist"] += r.persist_seconds
                windows += r.units
                retries += r.retries
                speculations += r.speculations
                quarantined += r.quarantined
        from repro.runtime import cluster as _cluster

        compile_delta = _cluster.counters_delta(self._compile_baseline)
        return SessionReport(
            spec_hash=self.spec_hash,
            slices_done=self._slices_done,
            windows=windows,
            traces=compile_delta["traces"],
            compiles=compile_delta["compiles"],
            compile_cache_hits=compile_delta["persistent_cache_hits"],
            compile_cache_misses=compile_delta["persistent_cache_misses"],
            cache_hits=self.cache_hits,
            cache_misses=self.cache_misses,
            cache_adopted=self.cache_adopted,
            slices_merged=self.slices_merged,
            retries=retries,
            speculations=speculations,
            quarantined_units=quarantined,
            shards_lost=self.shards_lost,
            wall_seconds=totals["wall"],
            load_seconds=totals["load"],
            wait_seconds=totals["wait"],
            compute_seconds=totals["compute"],
            persist_seconds=totals["persist"],
            shard_reports={k: list(v) for k, v in self._reports.items()},
            stage_percentiles=self.stage_percentiles(),
        )
