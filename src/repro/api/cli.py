"""Argparse flags generated from the spec — declared once, used everywhere.

``add_spec_args(parser)`` walks the ``PipelineSpec`` dataclass tree and
registers one flag per field from the field's own metadata (help text,
choices, parser), plus ``--spec FILE`` (load a JSON spec) and ``--serial``
(the prefetch+async-persist kill switch the launchers always offered).
``spec_from_args(parsed)`` rebuilds the spec: start from ``--spec``'s JSON
(or the launcher's ``base`` spec, or all defaults) and overlay *only the
flags the user actually passed* — generated flags default to
``argparse.SUPPRESS``, so a launcher-specific base default (e.g. the
dry-run's 20 bins) survives unless overridden explicitly.

No consumer declares a pipeline knob by hand anymore: adding a field to a
spec dataclass (with its ``_meta``) is all it takes for every launcher,
benchmark, and example to grow the flag — the drift class where one surface
silently dropped ``--group-tol`` (PR 3's dryrun fix) cannot recur.
"""

from __future__ import annotations

import argparse
import dataclasses
from dataclasses import fields
from pathlib import Path

from repro.api.spec import _GROUPS, PipelineSpec


def _dest(path: str, name: str) -> str:
    return f"spec__{path.replace('.', '_')}__{name}"


def _iter_flag_fields():
    for path, cls, prefix in _GROUPS:
        for f in fields(cls):
            meta = f.metadata
            if not meta or meta.get("type") is None:
                continue  # nested spec fields (e.g. method.tree) have no flag
            yield path, prefix, f, meta


def add_spec_args(parser: argparse.ArgumentParser) -> None:
    """Register every spec field as a flag (grouped per sub-spec), plus
    ``--spec`` and ``--serial``. Safe to call once per parser."""
    parser.add_argument(
        "--spec", default=None, metavar="FILE",
        help="JSON PipelineSpec to load; explicit flags override its fields")
    parser.add_argument(
        "--serial", action="store_true", default=argparse.SUPPRESS,
        help="disable prefetch + async persist (the serial reference path)")
    groups = {}
    for path, prefix, f, meta in _iter_flag_fields():
        top = path.split(".")[0]
        if top not in groups:
            groups[top] = parser.add_argument_group(f"{top} spec")
        flag = meta.get("flag") or "--" + prefix + f.name.replace("_", "-")
        kwargs: dict = {
            "dest": _dest(path, f.name),
            "default": argparse.SUPPRESS,
            "help": meta["help"],
        }
        if meta["type"] is bool:
            kwargs["action"] = argparse.BooleanOptionalAction
        else:
            kwargs["type"] = meta["type"]
            if meta.get("choices"):
                kwargs["choices"] = meta["choices"]
            if meta.get("nargs"):
                kwargs["nargs"] = meta["nargs"]
        groups[top].add_argument(flag, **kwargs)


def explicit_fields(args: argparse.Namespace) -> set[str]:
    """Dotted spec paths the user passed explicitly (e.g. ``method.name``,
    ``compute.types``) — launchers use this to distinguish 'user chose X'
    from 'X is the default' (generated flags default to SUPPRESS)."""
    out = set()
    for path, _prefix, f, _meta in _iter_flag_fields():
        if hasattr(args, _dest(path, f.name)):
            out.add(f"{path}.{f.name}")
    return out


def spec_from_args(
    args: argparse.Namespace, base: PipelineSpec | None = None
) -> PipelineSpec:
    """Build the run's ``PipelineSpec`` from parsed args.

    Precedence: explicit flags > ``--spec`` JSON > ``base`` > spec defaults.
    Every override goes through ``dataclasses.replace``, so the frozen
    specs re-validate after overlay."""
    spec_file = getattr(args, "spec", None)
    if spec_file:
        spec = PipelineSpec.from_json(Path(spec_file).read_text())
    else:
        spec = base if base is not None else PipelineSpec()

    overrides: dict[str, dict] = {}
    for path, _prefix, f, meta in _iter_flag_fields():
        dest = _dest(path, f.name)
        if not hasattr(args, dest):
            continue
        v = getattr(args, dest)
        if meta.get("convert") is not None:
            v = meta["convert"](v)
        elif isinstance(v, list):
            v = tuple(v)
        overrides.setdefault(path, {})[f.name] = v
    if getattr(args, "serial", False):
        overrides.setdefault("execution", {}).update(
            prefetch=False, async_persist=False)

    tree = dataclasses.replace(spec.method.tree, **overrides.get("method.tree", {}))
    method_over = overrides.get("method", {})
    if tree != spec.method.tree:
        method_over = {**method_over, "tree": tree}
    return dataclasses.replace(
        spec,
        source=dataclasses.replace(spec.source, **overrides.get("source", {})),
        method=dataclasses.replace(spec.method, **method_over),
        compute=dataclasses.replace(spec.compute, **overrides.get("compute", {})),
        execution=dataclasses.replace(spec.execution, **overrides.get("execution", {})),
    )
