"""Public API: one declarative ``PipelineSpec`` + ``PDFSession`` runner.

    from repro.api import PipelineSpec, MethodSpec, PDFSession

    spec = PipelineSpec(method=MethodSpec(name="grouping_ml"))
    for result in PDFSession(spec).run(slices=[0, 1]):
        print(result.slice_i, result.avg_error)

Specs round-trip through JSON (``to_json``/``from_json``), carry a stable
content hash (``content_hash``) stamped into persisted watermarks and BENCH
rows, and generate the CLI surface of every launcher (``api.cli``). See
DESIGN.md §API.
"""

from repro.api.cache import ResultCache
from repro.api.cli import add_spec_args, explicit_fields, spec_from_args
from repro.api.session import PDFSession, SessionReport
from repro.api.spec import (
    SPEC_VERSION,
    ComputeSpec,
    ExecSpec,
    MethodSpec,
    PipelineSpec,
    PlacementSpec,
    ServeSpec,
    SourceSpec,
    StreamSpec,
    TreeSpec,
    build_source,
    source_spec_for,
    spec_from_config,
)

__all__ = [
    "SPEC_VERSION",
    "ComputeSpec",
    "ExecSpec",
    "MethodSpec",
    "PDFSession",
    "PipelineSpec",
    "PlacementSpec",
    "ResultCache",
    "ServeSpec",
    "SessionReport",
    "SourceSpec",
    "StreamSpec",
    "TreeSpec",
    "add_spec_args",
    "build_source",
    "explicit_fields",
    "source_spec_for",
    "spec_from_args",
    "spec_from_config",
]
