"""The declarative pipeline spec: one serializable object drives every run.

The paper's pipeline is one conceptual object — a cube source, a method
(baseline / grouping / reuse / ML / sampling), and an execution strategy —
but through PRs 1-3 the public surface fractured into ``PDFConfig`` +
``ExecutorConfig`` + per-launcher flag subsets that drifted (the dry-run
silently dropped ``--group-tol`` for a whole PR). ``PipelineSpec`` is the
fix: a frozen, versioned dataclass tree that

  * validates every knob at construction (not deep inside a run),
  * round-trips through JSON (``to_json`` / ``from_json``), and
  * has a stable content hash over its *result-defining* subtree
    (``content_hash``) — embedded in persisted ``.npz`` watermarks and
    BENCH rows for provenance and resume-mismatch detection.

Hash rule: ``version + source + method + compute`` are hashed; ``execution``
is NOT — staging knobs (prefetch, shards, persist dir, result cache) are
bitwise-invariant by the staged-executor equivalence contract (DESIGN.md §9),
so two runs with the same hash must produce identical per-point results —
the invariant the spec-hash-keyed ``ResultCache`` is built on.
``kind='file'`` sources hash by their on-disk manifest's content sha256
(DESIGN.md §12), so the hash pins the bytes read, not just the knobs.

Every field carries its own CLI metadata (``help``/``choices``/parsers), so
``api.cli`` can generate argparse flags from this single declaration —
consumers never declare a pipeline knob by hand.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import warnings
from dataclasses import dataclass, field, fields
from typing import Any

from repro.core import distributions as dists
from repro.core import fitting
from repro.core import grouping as grp
from repro.core.executor import (
    METHODS,
    SAMPLERS,
    SELECT_BACKENDS,
    ExecutorConfig,
    PDFConfig,
)

# Version 2: SourceSpec grew kind='file' (+ path/layout) and file sources
# hash by their manifest's content sha256 — a semantic change to the hash
# payload, so version-1 specs must be re-emitted.
# Version 3: the ``stream`` section (StreamSpec — streaming ingestion /
# incremental recompute). Staging-only, so version-2 specs upgrade in place:
# ``from_dict`` loads them with ``stream`` defaults and a warning (the
# forward-compat shim), not an error.
# Version 4: the ``execution.placement`` section (PlacementSpec —
# multi-process cluster execution) and ``execution.compile_cache_dir``
# (persistent XLA compilation cache). Staging-only again, so version-2/3
# specs upgrade in place through the same shim.
SPEC_VERSION = 4

MODES = ("faithful", "fused")
SOURCE_KINDS = ("simulation", "external", "file")
FILE_LAYOUTS = ("chunked",)  # mirrors data.file_source.LAYOUTS (tested)

# The hash subtree, declared once and machine-checked: ``content_hash``
# covers exactly these top-level sections (``execution``/``serve`` are
# staging-only by the bitwise-equivalence contracts, DESIGN.md §9/§13),
# minus the per-section carve-outs below (location and bandwidth do not
# change the observations read). Every field additionally carries a
# ``hashed=`` tag in its ``_meta`` — the static HASH rule
# (``python -m repro.analysis``) cross-checks tags against these
# declarations, and tests/test_analysis.py asserts the tags agree with
# actual ``content_hash`` behavior for every single field.
HASHED_SECTIONS = ("source", "method", "compute")
HASH_EXCLUDED_FIELDS = {"source": ("throttle_mb_s", "path", "layout")}


def _meta(help_: str, *, hashed: bool, type_: Any = None, choices=None,
          nargs=None, flag: str | None = None, convert=None) -> dict:
    """CLI metadata attached to a spec field (consumed by ``api.cli``):
    ``type_``/``choices``/``nargs`` feed argparse, ``flag`` overrides the
    auto-derived flag name, ``convert`` post-processes the parsed value
    (e.g. '--types 4' -> the TYPES_4 tuple). ``hashed`` is the
    machine-readable tag for whether this field feeds ``content_hash`` —
    required, so no spec field can ship without declaring its hash
    behavior (the HASH rule verifies the tag against HASHED_SECTIONS /
    HASH_EXCLUDED_FIELDS)."""
    return {"help": help_, "hashed": hashed, "type": type_,
            "choices": choices, "nargs": nargs, "flag": flag,
            "convert": convert}


def _types_convert(vals):
    """'--types 4' / '--types 10' expand to the paper's candidate sets;
    anything else is an explicit list of distribution names."""
    vals = list(vals)
    if vals == ["4"]:
        return dists.TYPES_4
    if vals == ["10"]:
        return dists.TYPES_10
    return tuple(vals)


@dataclass(frozen=True)
class SourceSpec:
    """Where observations come from. ``kind='simulation'`` is the lazy
    Monte-Carlo seismic cube (data/simulation.py) and is fully described by
    these fields; ``kind='file'`` is an exported cube directory on disk/NFS
    (data/file_source.py) identified by ``path`` — geometry comes from its
    manifest and the spec hashes by the manifest's content sha256, so
    provenance tracks the actual bytes read; ``kind='external'`` marks a
    caller-supplied window source (``PDFSession(spec, data_source=...)`` or
    the ``PDFComputer`` shim) whose identity the spec cannot capture —
    geometry fields are advisory for both non-simulation kinds."""

    kind: str = field(default="simulation", metadata=_meta(
        "observation source", hashed=True, type_=str, choices=list(SOURCE_KINDS)))
    path: str | None = field(default=None, metadata=_meta(
        "exported cube directory (kind='file'; see data.file_source)", hashed=False,
        type_=str, flag="--source-path"))
    layout: str = field(default="chunked", metadata=_meta(
        "on-disk cube layout (kind='file')", hashed=False, type_=str,
        choices=list(FILE_LAYOUTS)))
    num_slices: int = field(default=8, metadata=_meta(
        "cube depth (slices)", hashed=True, type_=int))
    lines_per_slice: int = field(default=24, metadata=_meta(
        "lines per slice", hashed=True, type_=int, flag="--lines"))
    points_per_line: int = field(default=60, metadata=_meta(
        "points per line", hashed=True, type_=int, flag="--ppl"))
    observations: int = field(default=300, metadata=_meta(
        "Monte-Carlo observations per point", hashed=True, type_=int, flag="--obs"))
    num_layers: int = field(default=16, metadata=_meta(
        "velocity-model layers (type cycle)", hashed=True, type_=int))
    base_vp: float = field(default=3000.0, metadata=_meta(
        "m/s scale of the layered velocity model", hashed=True, type_=float))
    quantize_decimals: int = field(default=3, metadata=_meta(
        "output rounding -> grouping redundancy", hashed=True, type_=int))
    group_block: int = field(default=4, metadata=_meta(
        "points per line sharing one generator cell", hashed=True, type_=int))
    line_block: int = field(default=2, metadata=_meta(
        "consecutive lines sharing generator cells", hashed=True, type_=int))
    seed: int = field(default=0, metadata=_meta(
        "simulation seed", hashed=True, type_=int))
    throttle_mb_s: float | None = field(default=None, metadata=_meta(
        "model NFS reads at this bandwidth (MB/s; overlap benchmarks)", hashed=False,
        type_=float))

    def __post_init__(self):
        if self.kind not in SOURCE_KINDS:
            raise ValueError(f"source kind must be one of {SOURCE_KINDS}, "
                             f"got {self.kind!r}")
        if self.kind == "file" and not self.path:
            raise ValueError(
                "source.kind='file' requires source.path (an exported cube "
                "directory — data.file_source.export_cube writes one)")
        if self.kind != "file" and self.path is not None:
            raise ValueError(
                f"source.path is only meaningful for kind='file', "
                f"got path={self.path!r} with kind={self.kind!r}")
        if self.layout not in FILE_LAYOUTS:
            raise ValueError(
                f"source.layout must be one of {FILE_LAYOUTS}, "
                f"got {self.layout!r}")
        for name in ("num_slices", "lines_per_slice", "points_per_line",
                     "observations", "num_layers", "group_block", "line_block"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 1:
                raise ValueError(f"source.{name} must be a positive int, got {v!r}")
        if self.quantize_decimals < 0:
            raise ValueError(
                f"source.quantize_decimals must be >= 0, got {self.quantize_decimals}")
        if self.throttle_mb_s is not None and not self.throttle_mb_s > 0:
            raise ValueError(
                f"source.throttle_mb_s must be > 0, got {self.throttle_mb_s}")

    def hash_payload(self, manifest_version: int | None = None) -> dict:
        """The source's contribution to ``content_hash``.

        ``throttle_mb_s`` is always excluded (the NFS model only sleeps);
        ``path``/``layout`` are excluded too — *where* a cube sits and how
        its chunks are laid out do not change the observations read, so a
        cube moved to another mount keeps its hash. For ``kind='file'`` the
        geometry knobs are advisory (the manifest is authoritative) and the
        payload is the manifest's content sha256 instead: the hash tracks
        the actual bytes, so re-exporting different data to the same path
        is a different computation. Reads the manifest — a file spec whose
        cube does not exist (yet) cannot be hashed, by design.

        ``manifest_version`` pins an archived manifest version of an
        append-able cube (default: the current one) — the streaming layer
        hashes the same spec at two versions to re-key unchanged slices
        across an append (``ResultCache.adopt``)."""
        if self.kind == "file":
            from repro.data.file_source import manifest_sha

            return {"kind": "file",
                    "manifest_sha256": manifest_sha(self.path,
                                                    version=manifest_version)}
        d = dataclasses.asdict(self)
        for name in HASH_EXCLUDED_FIELDS["source"]:
            d.pop(name)
        return d


@dataclass(frozen=True)
class TreeSpec:
    """§5.3.1 decision-tree training config (used by the ml/sampling
    methods). ``train_slices=None`` auto-selects the first
    ``min(4, num_slices)`` slices — four consecutive slices cover all four
    distribution types in the synthetic cube."""

    depth: int = field(default=4, metadata=_meta(
        "decision tree depth", hashed=True, type_=int, flag="--tree-depth"))
    max_bins: int = field(default=32, metadata=_meta(
        "candidate split thresholds per feature", hashed=True, type_=int,
        flag="--tree-max-bins"))
    train_slices: tuple[int, ...] | None = field(default=None, metadata=_meta(
        "slices of 'previously generated output data' (default: first 4)", hashed=True,
        type_=int, nargs="+", flag="--tree-train-slices"))
    train_window_lines: int = field(default=4, metadata=_meta(
        "window size for the training baseline runs", hashed=True, type_=int,
        flag="--tree-train-window-lines"))

    def __post_init__(self):
        if self.depth < 1:
            raise ValueError(f"tree.depth must be >= 1, got {self.depth}")
        if self.max_bins < 2:
            raise ValueError(f"tree.max_bins must be >= 2, got {self.max_bins}")
        if self.train_window_lines < 1:
            raise ValueError(
                f"tree.train_window_lines must be >= 1, got {self.train_window_lines}")
        if self.train_slices is not None:
            ts = tuple(self.train_slices)
            object.__setattr__(self, "train_slices", ts)
            if not ts or any((not isinstance(s, int)) or s < 0 for s in ts):
                raise ValueError(
                    f"tree.train_slices must be non-empty non-negative ints, got {ts}")


@dataclass(frozen=True)
class MethodSpec:
    """Which of the paper's methods runs, with its knobs — including
    sampling (§5.4, Algorithm 5), which is a first-class registry entry
    here rather than benchmark-side glue."""

    name: str = field(default="baseline", metadata=_meta(
        "paper method (§5/§6)", hashed=True, type_=str, choices=list(METHODS),
        flag="--method"))
    group_tol: float = field(default=grp.DEFAULT_TOL, metadata=_meta(
        "grouping tolerance (§5.2 'acceptable fluctuation')", hashed=True, type_=float))
    rep_bucket: int = field(default=64, metadata=_meta(
        "geometric padding bucket for representative batches "
        "(64 suits reduced workloads, 256 at paper scale)", hashed=True, type_=int))
    error_bound: float | None = field(default=None, metadata=_meta(
        "the paper's bounded-error constraint on Eq.-6 E", hashed=True, type_=float))
    sample_frac: float = field(default=0.1, metadata=_meta(
        "sampling rate for method=sampling", hashed=True, type_=float))
    sampler: str = field(default="random", metadata=_meta(
        "point sampler for method=sampling", hashed=True, type_=str,
        choices=list(SAMPLERS)))
    kmeans_iters: int = field(default=10, metadata=_meta(
        "Lloyd iterations for sampler=kmeans", hashed=True, type_=int))
    sample_seed: int = field(default=0, metadata=_meta(
        "base seed for the per-window sample draw", hashed=True, type_=int))
    tree: TreeSpec = field(default=TreeSpec(), metadata=_meta(
        "decision-tree training config", hashed=True))

    def __post_init__(self):
        if self.name not in METHODS:
            raise ValueError(f"method must be one of {METHODS}, got {self.name!r}")
        if not self.group_tol > 0:
            raise ValueError(f"method.group_tol must be > 0, got {self.group_tol}")
        if self.rep_bucket < 1:
            raise ValueError(f"method.rep_bucket must be >= 1, got {self.rep_bucket}")
        if self.error_bound is not None and not self.error_bound > 0:
            raise ValueError(
                f"method.error_bound must be > 0 (or null), got {self.error_bound}")
        if not 0 < self.sample_frac <= 1:
            raise ValueError(
                f"method.sample_frac must be in (0, 1], got {self.sample_frac}")
        if self.sampler not in SAMPLERS:
            raise ValueError(
                f"method.sampler must be one of {SAMPLERS}, got {self.sampler!r}")
        if self.kmeans_iters < 1:
            raise ValueError(
                f"method.kmeans_iters must be >= 1, got {self.kmeans_iters}")


@dataclass(frozen=True)
class ComputeSpec:
    """The per-window device computation: candidate set, binning, windowing,
    and which backend implements fit / Select."""

    types: tuple[str, ...] = field(default=dists.TYPES_4, metadata=_meta(
        "candidate distribution set: '4', '10', or explicit names", hashed=True,
        type_=str, nargs="+", convert=_types_convert))
    num_bins: int = field(default=64, metadata=_meta(
        "histogram bins L for the Eq.-5 error", hashed=True, type_=int))
    window_lines: int = field(default=6, metadata=_meta(
        "lines per window (§4.2; grouping dedup scope)", hashed=True, type_=int))
    mode: str = field(default="fused", metadata=_meta(
        "shared-histogram fit vs paper-faithful per-type passes", hashed=True,
        type_=str, choices=list(MODES)))
    fit_backend: str = field(default="fused", metadata=_meta(
        "device-work implementation (DESIGN.md §2.1)", hashed=True, type_=str,
        choices=list(fitting.FIT_BACKENDS)))
    select_backend: str = field(default="host", metadata=_meta(
        "where Select's dedup runs (DESIGN.md §6)", hashed=True, type_=str,
        choices=list(SELECT_BACKENDS)))

    def __post_init__(self):
        object.__setattr__(self, "types", tuple(self.types))
        if not self.types:
            raise ValueError("compute.types must not be empty")
        for t in self.types:
            if t not in dists.TYPES_10:
                raise ValueError(
                    f"unknown distribution type {t!r} (candidates: {dists.TYPES_10})")
        if self.num_bins < 2:
            raise ValueError(f"compute.num_bins must be >= 2, got {self.num_bins}")
        if self.window_lines < 1:
            raise ValueError(
                f"compute.window_lines must be >= 1, got {self.window_lines}")
        if self.mode not in MODES:
            raise ValueError(f"compute.mode must be one of {MODES}, got {self.mode!r}")
        if self.fit_backend not in fitting.FIT_BACKENDS:
            raise ValueError(
                f"compute.fit_backend must be one of {fitting.FIT_BACKENDS}, "
                f"got {self.fit_backend!r}")
        if self.select_backend not in SELECT_BACKENDS:
            raise ValueError(
                f"compute.select_backend must be one of {SELECT_BACKENDS}, "
                f"got {self.select_backend!r}")


@dataclass(frozen=True)
class PlacementSpec:
    """Multi-process placement (``runtime.cluster``, DESIGN.md §17): how
    many worker processes form the mesh, where the ``jax.distributed``
    coordinator lives, which process this is, and (optionally) which local
    device each shard's executor stages onto. Staging-only like the rest of
    ``ExecSpec`` — slices are whole-slice partitions computed independently
    per process (the paper's per-node assignment), so any placement produces
    bitwise-identical results to the single-process run.

    ``process_id >= num_processes`` marks a *join-only* worker: it takes no
    initial assignment and no seat in the ``jax.distributed`` world (whose
    size is fixed at init), but participates in the marker/redeal protocol —
    the grow half of elastic execution (shrink is shard death + redeal)."""

    num_processes: int = field(default=1, metadata=_meta(
        "worker processes in the cluster run (1 = single-process)", hashed=False,
        type_=int))
    process_id: int | None = field(default=None, metadata=_meta(
        "this process's id (0-based; >= num_processes joins as extra "
        "capacity for redeal only)", hashed=False, type_=int))
    coordinator: str = field(default="127.0.0.1:12723", metadata=_meta(
        "host:port of the jax.distributed coordinator (process 0)", hashed=False,
        type_=str))
    distributed: bool = field(default=True, metadata=_meta(
        "initialize jax.distributed across the processes (off = marker "
        "protocol only, no coordination service)", hashed=False, type_=bool))
    shard_devices: tuple[int, ...] | None = field(default=None, metadata=_meta(
        "local device index per shard (round-robin when shorter; default: "
        "the backend's default device)", hashed=False, type_=int, nargs="+"))
    redeal: bool = field(default=True, metadata=_meta(
        "survivors re-deal a dead process's unfinished slices "
        "(runtime.elastic.plan_redeal over the done/lost markers)", hashed=False,
        type_=bool))
    peer_timeout_s: float = field(default=120.0, metadata=_meta(
        "how long a finished worker waits for peers' done/lost markers "
        "before treating a silent peer as lost", hashed=False, type_=float))

    def __post_init__(self):
        if self.num_processes < 1:
            raise ValueError(
                f"placement.num_processes must be >= 1, got {self.num_processes}")
        if self.process_id is not None and self.process_id < 0:
            raise ValueError(
                f"placement.process_id must be >= 0, got {self.process_id}")
        host, sep, port = self.coordinator.rpartition(":")
        if not (host and sep and port.isdigit()):
            raise ValueError(
                f"placement.coordinator must be 'host:port', "
                f"got {self.coordinator!r}")
        if self.shard_devices is not None:
            sd = tuple(self.shard_devices)
            object.__setattr__(self, "shard_devices", sd)
            if not sd or any((not isinstance(d, int)) or d < 0 for d in sd):
                raise ValueError(
                    f"placement.shard_devices must be non-empty non-negative "
                    f"ints, got {sd}")
        if not self.peer_timeout_s > 0:
            raise ValueError(
                f"placement.peer_timeout_s must be > 0, "
                f"got {self.peer_timeout_s}")


@dataclass(frozen=True)
class ExecSpec:
    """Execution strategy: slice assignment, staging, persistence, resume.
    Excluded from ``content_hash`` — none of these change per-point results
    (the staged-executor bitwise-equivalence contract, DESIGN.md §9)."""

    slices: tuple[int, ...] | None = field(default=None, metadata=_meta(
        "slices to run (default: every slice of the cube)", hashed=False, type_=int,
        nargs="+"))
    shards: int = field(default=1, metadata=_meta(
        "shards of the mesh data axis (per-node slice assignment)", hashed=False, type_=int))
    shard: int | None = field(default=None, metadata=_meta(
        "run only this shard's assignment (per-node mode)", hashed=False, type_=int))
    prefetch: bool = field(default=True, metadata=_meta(
        "overlap window loading with device compute", hashed=False, type_=bool))
    prefetch_depth: int = field(default=2, metadata=_meta(
        "how many windows the load stage may run ahead", hashed=False, type_=int))
    async_persist: bool = field(default=True, metadata=_meta(
        "write .npz watermarks off the critical path", hashed=False, type_=bool))
    out_dir: str | None = field(default=None, metadata=_meta(
        "persist per-window .npz + watermarks here", hashed=False, type_=str, flag="--out-dir"))
    resume: bool = field(default=False, metadata=_meta(
        "skip windows completed under a matching spec hash", hashed=False, type_=bool))
    cache_dir: str | None = field(default=None, metadata=_meta(
        "spec-hash-keyed result cache: serve identical reruns per slice "
        "and store misses (api.ResultCache)", hashed=False, type_=str, flag="--cache-dir"))
    cache_max_bytes: int | None = field(default=None, metadata=_meta(
        "LRU size cap for cache_dir in bytes (oldest-used entries evicted; "
        "default: unbounded)", hashed=False, type_=int, flag="--cache-max-bytes"))
    # Fault tolerance (DESIGN.md §14). Like every other ExecSpec knob,
    # none of these change per-point results: retried/speculated/re-dealt
    # units recompute identical bytes, so they stay hash-excluded.
    max_retries: int = field(default=2, metadata=_meta(
        "transient-failure re-attempts per work unit before quarantine "
        "(exponential backoff + deterministic jitter)", hashed=False, type_=int))
    retry_backoff_s: float = field(default=0.05, metadata=_meta(
        "base backoff between work-unit retries (doubles per attempt)", hashed=False,
        type_=float))
    speculate: bool = field(default=True, metadata=_meta(
        "re-dispatch straggling window loads (first result wins; safe — "
        "launches are bitwise-identical by construction)", hashed=False, type_=bool))
    straggler_grace_s: float = field(default=1.0, metadata=_meta(
        "absolute floor below which a load is never flagged as straggling", hashed=False,
        type_=float))
    degraded_mode: bool = field(default=True, metadata=_meta(
        "complete runs despite unrecoverable units: quarantine them "
        "(type_idx=-1) and emit a failed-unit manifest instead of aborting", hashed=False,
        type_=bool))
    fault_plan: str | None = field(default=None, metadata=_meta(
        "JSON FaultPlan file for deterministic fault injection (chaos "
        "testing; runtime.faults)", hashed=False, type_=str, flag="--fault-plan"))
    # Cluster execution + cold-start elimination (DESIGN.md §17). Both
    # staging-only: placement deals whole slices to independent processes
    # (bitwise by the per-slice independence contract) and the compilation
    # cache only skips re-compiling executables that would be identical.
    compile_cache_dir: str | None = field(default=None, metadata=_meta(
        "persistent XLA compilation cache root: executables cached under "
        "<dir>/<spec_hash>, so a re-launched identical spec never "
        "re-compiles (runtime.cluster)", hashed=False, type_=str,
        flag="--compile-cache-dir"))
    placement: PlacementSpec = field(default=PlacementSpec(), metadata=_meta(
        "multi-process placement (see execution.placement)", hashed=False))

    def __post_init__(self):
        if self.cache_max_bytes is not None and self.cache_max_bytes <= 0:
            raise ValueError(
                f"execution.cache_max_bytes must be > 0 (or null), "
                f"got {self.cache_max_bytes}")
        if self.cache_max_bytes is not None and self.cache_dir is None:
            raise ValueError(
                "execution.cache_max_bytes requires execution.cache_dir")
        if self.shards < 1:
            raise ValueError(f"execution.shards must be >= 1, got {self.shards}")
        if self.shard is not None and not 0 <= self.shard < self.shards:
            raise ValueError(
                f"execution.shard {self.shard} outside range 0..{self.shards - 1}")
        if self.prefetch_depth < 1:
            raise ValueError(
                f"execution.prefetch_depth must be >= 1, got {self.prefetch_depth}")
        if self.slices is not None:
            ts = tuple(self.slices)
            object.__setattr__(self, "slices", ts)
            if not ts or any((not isinstance(s, int)) or s < 0 for s in ts):
                raise ValueError(
                    f"execution.slices must be non-empty non-negative ints, got {ts}")
        if self.resume and self.out_dir is None:
            raise ValueError("execution.resume requires execution.out_dir")
        if self.max_retries < 0:
            raise ValueError(
                f"execution.max_retries must be >= 0, got {self.max_retries}")
        if self.retry_backoff_s < 0:
            raise ValueError(
                f"execution.retry_backoff_s must be >= 0, "
                f"got {self.retry_backoff_s}")
        if self.straggler_grace_s < 0:
            raise ValueError(
                f"execution.straggler_grace_s must be >= 0, "
                f"got {self.straggler_grace_s}")
        if self.placement.num_processes > 1 and self.out_dir is None:
            raise ValueError(
                "execution.placement.num_processes > 1 requires "
                "execution.out_dir: processes share results and the "
                "done/lost marker protocol through it")


@dataclass(frozen=True)
class ServeSpec:
    """The serving layer's knobs (``repro.serve.PDFServer``): request
    coalescing, launch batching, and the in-memory hot-window cache.
    Staging-only — excluded from ``content_hash`` like ``ExecSpec``: served
    answers are bitwise-identical with any of these settings (the
    coalescing-equivalence contract, tests/test_serve.py)."""

    tick_seconds: float = field(default=0.001, metadata=_meta(
        "how long the batcher keeps draining the queue after the first "
        "pending request before launching (the coalescing window)", hashed=False,
        type_=float, flag="--serve-tick-seconds"))
    max_batch_windows: int = field(default=32, metadata=_meta(
        "max deduplicated windows per fused launch (larger batches are "
        "chunked)", hashed=False, type_=int, flag="--serve-max-batch-windows"))
    coalesce: bool = field(default=True, metadata=_meta(
        "batch concurrent requests into shared launches; off = the naive "
        "one-launch-per-query baseline (benchmarks/serve_bench.py)", hashed=False,
        type_=bool, flag="--serve-coalesce"))
    window_cache_entries: int = field(default=256, metadata=_meta(
        "in-memory hot-window LRU entries held by the server (0 disables)", hashed=False,
        type_=int, flag="--serve-window-cache-entries"))
    # Fault tolerance (DESIGN.md §14): deadlines, launch retry, shedding.
    request_deadline_s: float | None = field(default=None, metadata=_meta(
        "fail a request's future with TimeoutError if not answered within "
        "this many seconds of submit (default: no deadline)", hashed=False,
        type_=float, flag="--serve-deadline-s"))
    max_queue_depth: int = field(default=0, metadata=_meta(
        "reject submits (ServerOverloadedError) once this many requests "
        "are pending — load shedding with backpressure (0 = unbounded)",
        hashed=False, type_=int, flag="--serve-max-queue-depth"))
    retry_transient: int = field(default=2, metadata=_meta(
        "transient launch-failure re-attempts per batch chunk; exhaustion "
        "fails only the affected windows' futures, not the server", hashed=False,
        type_=int, flag="--serve-retries"))

    def __post_init__(self):
        if not self.tick_seconds >= 0:
            raise ValueError(
                f"serve.tick_seconds must be >= 0, got {self.tick_seconds}")
        if self.max_batch_windows < 1:
            raise ValueError(
                f"serve.max_batch_windows must be >= 1, "
                f"got {self.max_batch_windows}")
        if self.window_cache_entries < 0:
            raise ValueError(
                f"serve.window_cache_entries must be >= 0, "
                f"got {self.window_cache_entries}")
        if self.request_deadline_s is not None and not self.request_deadline_s > 0:
            raise ValueError(
                f"serve.request_deadline_s must be > 0 (or null), "
                f"got {self.request_deadline_s}")
        if self.max_queue_depth < 0:
            raise ValueError(
                f"serve.max_queue_depth must be >= 0, "
                f"got {self.max_queue_depth}")
        if self.retry_transient < 0:
            raise ValueError(
                f"serve.retry_transient must be >= 0, "
                f"got {self.retry_transient}")


UPDATE_MODES = ("merge", "strict")


@dataclass(frozen=True)
class StreamSpec:
    """Streaming ingestion (``repro.streaming``): how a run reacts to cube
    appends. Staging-only — excluded from ``content_hash`` like ``ExecSpec``.
    That exclusion is sound because the cache never holds merge-path
    results: cached entries are always fresh full computes (or dep-verified
    adoptions of one), bitwise-reproducible by the hash rule, while
    ``update_mode='merge'`` updates live only in the persisted windows,
    whose watermarks record the merge tolerance (``MERGE_ULP_BUDGET``)."""

    update_mode: str = field(default="merge", metadata=_meta(
        "how appends update already-fitted windows: 'merge' re-fits from "
        "merged sufficient statistics (histograms bitwise, moments within "
        "the recorded ulp budget), 'strict' recomputes affected windows "
        "in full for a bitwise guarantee", hashed=False, type_=str,
        choices=list(UPDATE_MODES), flag="--stream-update-mode"))
    persist_stats: bool = field(default=False, metadata=_meta(
        "write per-window sufficient-statistic sidecars next to persisted "
        ".npz windows (required for merge-mode updates of old windows)",
        hashed=False, type_=bool, flag="--stream-persist-stats"))
    incremental: bool = field(default=True, metadata=_meta(
        "adopt cached slices whose chunk fingerprints are unchanged across "
        "an append, recomputing only touched slices", hashed=False,
        type_=bool, flag="--stream-incremental"))
    poll_interval_s: float = field(default=1.0, metadata=_meta(
        "manifest-version polling interval for run_pdf --watch", hashed=False,
        type_=float, flag="--stream-poll-interval-s"))
    max_updates: int | None = field(default=None, metadata=_meta(
        "stop --watch after applying this many appends (default: run until "
        "interrupted)", hashed=False, type_=int, flag="--stream-max-updates"))

    def __post_init__(self):
        if self.update_mode not in UPDATE_MODES:
            raise ValueError(
                f"stream.update_mode must be one of {UPDATE_MODES}, "
                f"got {self.update_mode!r}")
        if not self.poll_interval_s > 0:
            raise ValueError(
                f"stream.poll_interval_s must be > 0, "
                f"got {self.poll_interval_s}")
        if self.max_updates is not None and self.max_updates < 1:
            raise ValueError(
                f"stream.max_updates must be >= 1 (or null), "
                f"got {self.max_updates}")


_GROUPS: tuple[tuple[str, type, str], ...] = (
    # (dotted path into PipelineSpec, dataclass, auto flag prefix)
    ("source", SourceSpec, ""),
    ("method", MethodSpec, ""),
    ("method.tree", TreeSpec, "tree-"),
    ("compute", ComputeSpec, ""),
    ("execution", ExecSpec, ""),
    ("execution.placement", PlacementSpec, ""),
    ("serve", ServeSpec, ""),
    ("stream", StreamSpec, ""),
)


@dataclass(frozen=True)
class PipelineSpec:
    """The one public entry point: everything a run needs, declared once.

    Construct directly, from JSON (``from_json``), or from CLI flags
    (``api.cli.spec_from_args``); execute with ``api.PDFSession``.
    """

    version: int = SPEC_VERSION
    source: SourceSpec = SourceSpec()
    method: MethodSpec = MethodSpec()
    compute: ComputeSpec = ComputeSpec()
    execution: ExecSpec = ExecSpec()
    serve: ServeSpec = ServeSpec()
    stream: StreamSpec = StreamSpec()

    def __post_init__(self):
        if self.version != SPEC_VERSION:
            raise ValueError(
                f"spec version {self.version} unsupported (this build speaks "
                f"version {SPEC_VERSION}; re-emit the spec with to_json)")
        if self.execution.slices is not None and self.source.kind == "simulation":
            bad = [s for s in self.execution.slices if s >= self.source.num_slices]
            if bad:
                raise ValueError(
                    f"execution.slices {bad} outside the cube's "
                    f"{self.source.num_slices} slices")

    # -- serialization ---------------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineSpec":
        if not isinstance(d, dict):
            raise ValueError(f"spec must be a JSON object, got {type(d).__name__}")
        d = dict(d)
        parts = {}
        for name, sub_cls in (("source", SourceSpec), ("method", MethodSpec),
                              ("compute", ComputeSpec), ("execution", ExecSpec),
                              ("serve", ServeSpec), ("stream", StreamSpec)):
            if name in d:
                parts[name] = _sub_from_dict(sub_cls, d.pop(name), name)
        version = d.pop("version", SPEC_VERSION)
        if version in (2, 3):
            # Forward-compat shim: versions 3 and 4 only ADDED staging-only
            # surface (v3: the ``stream`` section; v4: ``execution.placement``
            # + ``execution.compile_cache_dir``), so an older spec is a valid
            # version-4 spec with the new knobs defaulted. Note the upgrade
            # DOES change the spec's content_hash (the version feeds the hash
            # payload) — persisted watermarks from the old build won't resume
            # against it, which is exactly the resume-mismatch detection
            # working.
            warnings.warn(
                f"upgrading spec from version {version} to {SPEC_VERSION}: "
                "the sections/fields added since take their defaults",
                stacklevel=2)
            version = SPEC_VERSION
        if d:
            raise ValueError(f"unknown spec keys: {sorted(d)}")
        return cls(version=version, **parts)

    @classmethod
    def from_json(cls, text: str) -> "PipelineSpec":
        return cls.from_dict(json.loads(text))

    # -- provenance ------------------------------------------------------------

    def content_hash(self, manifest_version: int | None = None) -> str:
        """Stable hash of the result-defining subtree (version + source +
        method + compute). Two specs with equal hashes must produce bitwise
        identical per-point results; ``execution``, ``serve`` and ``stream``
        are staging-only and excluded, and so is ``source.throttle_mb_s`` — the
        NFS-bandwidth model only *sleeps* (data is unchanged), so a throttled
        benchmark run and its unthrottled resume are the same computation.
        ``kind='file'`` sources hash by their manifest's content sha256
        (``SourceSpec.hash_payload``), so the hash pins the exact bytes the
        run reads — the key the ``ResultCache`` relies on (DESIGN.md §12).
        ``manifest_version`` hashes a file source at an archived manifest
        version (streaming adoption; see ``SourceSpec.hash_payload``)."""
        payload: dict[str, Any] = {"version": self.version}
        for name in HASHED_SECTIONS:
            sub = getattr(self, name)
            payload[name] = (sub.hash_payload(manifest_version)
                             if hasattr(sub, "hash_payload")
                             else dataclasses.asdict(sub))
        blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    # -- bridges to the internal configs --------------------------------------

    def pdf_config(self) -> PDFConfig:
        return PDFConfig(
            types=tuple(self.compute.types),
            num_bins=self.compute.num_bins,
            window_lines=self.compute.window_lines,
            method=self.method.name,
            mode=self.compute.mode,
            group_tol=self.method.group_tol,
            rep_bucket=self.method.rep_bucket,
            error_bound=self.method.error_bound,
            fit_backend=self.compute.fit_backend,
            select_backend=self.compute.select_backend,
            sample_frac=self.method.sample_frac,
            sampler=self.method.sampler,
            kmeans_iters=self.method.kmeans_iters,
            sample_seed=self.method.sample_seed,
        )

    def exec_config(self) -> ExecutorConfig:
        return ExecutorConfig(
            prefetch=self.execution.prefetch,
            prefetch_depth=self.execution.prefetch_depth,
            async_persist=self.execution.async_persist,
            max_retries=self.execution.max_retries,
            retry_backoff_s=self.execution.retry_backoff_s,
            speculate=self.execution.speculate,
            straggler_grace_s=self.execution.straggler_grace_s,
            degraded_mode=self.execution.degraded_mode,
        )


def _sub_from_dict(cls, d: dict, path: str):
    if not isinstance(d, dict):
        raise ValueError(f"spec.{path} must be a JSON object, got {type(d).__name__}")
    d = dict(d)
    kwargs = {}
    for f in fields(cls):
        if f.name not in d:
            continue
        v = d.pop(f.name)
        if f.name == "tree":
            v = _sub_from_dict(TreeSpec, v, f"{path}.tree")
        elif f.name == "placement":
            v = _sub_from_dict(PlacementSpec, v, f"{path}.placement")
        elif isinstance(v, list):
            v = tuple(v)
        kwargs[f.name] = v
    if d:
        raise ValueError(f"unknown spec.{path} keys: {sorted(d)}")
    return cls(**kwargs)


# -- spec construction from legacy configs / live sources ----------------------


def spec_from_config(
    config: PDFConfig,
    exec_config: ExecutorConfig | None = None,
    source: SourceSpec | None = None,
) -> PipelineSpec:
    """Lift a legacy ``PDFConfig`` (+``ExecutorConfig``) into a spec — the
    ``PDFComputer`` shim uses this so even legacy construction stamps the
    same provenance hash the session would."""
    ec = exec_config or ExecutorConfig()
    return PipelineSpec(
        source=source or SourceSpec(kind="external"),
        method=MethodSpec(
            name=config.method,
            group_tol=config.group_tol,
            rep_bucket=config.rep_bucket,
            error_bound=config.error_bound,
            sample_frac=config.sample_frac,
            sampler=config.sampler,
            kmeans_iters=config.kmeans_iters,
            sample_seed=config.sample_seed,
        ),
        compute=ComputeSpec(
            types=tuple(config.types),
            num_bins=config.num_bins,
            window_lines=config.window_lines,
            mode=config.mode,
            fit_backend=config.fit_backend,
            select_backend=config.select_backend,
        ),
        execution=ExecSpec(
            prefetch=ec.prefetch,
            prefetch_depth=ec.prefetch_depth,
            async_persist=ec.async_persist,
            max_retries=ec.max_retries,
            retry_backoff_s=ec.retry_backoff_s,
            speculate=ec.speculate,
            straggler_grace_s=ec.straggler_grace_s,
            degraded_mode=ec.degraded_mode,
        ),
    )


def source_spec_for(data_source) -> SourceSpec:
    """Describe a live window source as a ``SourceSpec``: the synthetic
    simulation and the file cube reader (optionally behind a
    ``ThrottledSource``) round-trip exactly; anything else is marked
    ``kind='external'``."""
    from repro.data.file_source import FileCubeSource
    from repro.data.loader import ThrottledSource
    from repro.data.simulation import SeismicSimulation

    throttle = None
    if isinstance(data_source, ThrottledSource):
        throttle = data_source.bandwidth / 1e6
        data_source = data_source.inner
    if isinstance(data_source, FileCubeSource):
        g = data_source.geometry
        # advisory geometry from the manifest, like export_cube's returned
        # spec — the hash is manifest-based either way, but the serialized
        # spec should read true
        return SourceSpec(kind="file", path=str(data_source.path),
                          throttle_mb_s=throttle,
                          num_slices=g.num_slices,
                          lines_per_slice=g.lines_per_slice,
                          points_per_line=g.points_per_line,
                          observations=data_source.num_observations)
    if isinstance(data_source, SeismicSimulation):
        cfg = data_source.config
        g = cfg.geometry
        return SourceSpec(
            kind="simulation",
            num_slices=g.num_slices,
            lines_per_slice=g.lines_per_slice,
            points_per_line=g.points_per_line,
            observations=cfg.num_simulations,
            num_layers=cfg.num_layers,
            base_vp=cfg.base_vp,
            quantize_decimals=cfg.quantize_decimals,
            group_block=cfg.group_block,
            line_block=cfg.line_block,
            seed=cfg.seed,
            throttle_mb_s=throttle,
        )
    return SourceSpec(kind="external", throttle_mb_s=throttle)


def build_source(spec: SourceSpec):
    """Materialize the window source a ``SourceSpec`` describes."""
    from repro.core.regions import CubeGeometry
    from repro.data.file_source import FileCubeSource
    from repro.data.loader import ThrottledSource
    from repro.data.simulation import SeismicSimulation, SimulationConfig

    if spec.kind == "file":
        src = FileCubeSource(spec.path)
        if spec.throttle_mb_s is not None:
            return ThrottledSource(src, spec.throttle_mb_s * 1e6)
        return src
    if spec.kind != "simulation":
        raise ValueError(
            "source.kind='external' cannot be materialized from the spec — "
            "pass the live object (PDFSession(spec, data_source=...)), or "
            "snapshot it to disk once with data.file_source.export_cube(...) "
            "and run it as a materializable kind='file' source")
    sim = SeismicSimulation(SimulationConfig(
        geometry=CubeGeometry(spec.num_slices, spec.lines_per_slice,
                              spec.points_per_line),
        num_simulations=spec.observations,
        num_layers=spec.num_layers,
        base_vp=spec.base_vp,
        quantize_decimals=spec.quantize_decimals,
        group_block=spec.group_block,
        line_block=spec.line_block,
        seed=spec.seed,
    ))
    if spec.throttle_mb_s is not None:
        return ThrottledSource(sim, spec.throttle_mb_s * 1e6)
    return sim
