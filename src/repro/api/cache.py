"""``ResultCache``: content-addressed per-slice results, keyed by spec hash.

The hash rule (DESIGN.md §11/§12) makes this cache sound: equal
``content_hash`` ⇒ bitwise-identical per-point results, so a ``SliceResult``
persisted under a hash can be served verbatim to ANY later run of an equal
spec — across processes, benchmark sweeps, and ``ExecSpec``-only variations
(staging knobs are excluded from the hash by the staged-executor
equivalence contract). A ``kind='file'`` source hashes by its manifest's
content sha256, so the cache also misses when the underlying bytes change,
not just when a knob does.

Layout: one ``.npz`` per (spec hash, slice) —

    cache_dir/<spec_hash>/slice<N>.npz    # _FIELDS arrays + avg_error

Writes are tmp + atomic rename, so two concurrent runs of the same spec
race benignly (last writer wins with identical bytes) and a crashed write
never leaves a half-entry a later run could load. ``PDFSession`` consults
the cache per slice when ``ExecSpec.cache_dir`` is set and counts
hits/misses into its ``report()``.
"""

from __future__ import annotations

import os
import tempfile
import warnings
import zipfile
from pathlib import Path

import numpy as np

from repro.core.executor import _FIELDS, SliceResult


class ResultCache:
    """Filesystem-backed map ``(spec_hash, slice) -> SliceResult``."""

    def __init__(self, cache_dir: str | Path):
        self.dir = Path(cache_dir)

    def path(self, spec_hash: str, slice_i: int) -> Path:
        return self.dir / spec_hash / f"slice{slice_i}.npz"

    def lookup(self, spec_hash: str, slice_i: int) -> SliceResult | None:
        """The cached ``SliceResult``, or ``None`` on miss. Served results
        carry ``cached=True`` and empty window ``stats`` (no work ran — the
        same shape a fully resumed slice has)."""
        f = self.path(spec_hash, slice_i)
        if not f.exists():
            return None
        try:
            with np.load(f) as z:  # close the zip handle: no fd per hit
                if str(z["spec_hash"]) != spec_hash:  # misfiled: miss
                    return None
                return SliceResult(
                    *(z[name] for name in _FIELDS),
                    avg_error=float(z["avg_error"]),
                    stats=[],
                    slice_i=slice_i,
                    spec_hash=spec_hash,
                    cached=True,
                )
        except (OSError, ValueError, KeyError, EOFError,
                zipfile.BadZipFile) as e:
            # A truncated / foreign / partially-synced entry (e.g. an
            # interrupted copy into a shared cache_dir — the writer's
            # tmp+rename cannot protect against that) is a miss, not a
            # crash: the slice recomputes and the store overwrites it.
            warnings.warn(f"ignoring unreadable cache entry {f}: {e}",
                          stacklevel=2)
            return None

    def store(self, result: SliceResult) -> None:
        """Persist one computed slice under its own ``spec_hash``."""
        if result.spec_hash is None or result.slice_i is None:
            raise ValueError(
                "cannot cache a SliceResult without spec_hash and slice_i")
        f = self.path(result.spec_hash, result.slice_i)
        f.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=f.parent, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as fh:
                np.savez(
                    fh,
                    spec_hash=result.spec_hash,
                    slice_i=result.slice_i,
                    avg_error=result.avg_error,
                    **{name: getattr(result, name) for name in _FIELDS},
                )
            os.replace(tmp, f)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
