"""``ResultCache``: content-addressed per-slice results, keyed by spec hash.

The hash rule (DESIGN.md §11/§12) makes this cache sound: equal
``content_hash`` ⇒ bitwise-identical per-point results, so a ``SliceResult``
persisted under a hash can be served verbatim to ANY later run of an equal
spec — across processes, benchmark sweeps, and ``ExecSpec``-only variations
(staging knobs are excluded from the hash by the staged-executor
equivalence contract). A ``kind='file'`` source hashes by its manifest's
content sha256, so the cache also misses when the underlying bytes change,
not just when a knob does.

Layout: one ``.npz`` per (spec hash, slice) —

    cache_dir/<spec_hash>/slice<N>.npz    # _FIELDS arrays + avg_error

Writes are tmp + atomic rename, so two concurrent runs of the same spec
race benignly (last writer wins with identical bytes) and a crashed write
never leaves a half-entry a later run could load. ``PDFSession`` consults
the cache per slice when ``ExecSpec.cache_dir`` is set and counts
hits/misses into its ``report()``.

Long-lived shared ``cache_dir``s (the serve layer, cross-run benchmark
sweeps) add two requirements this module owns:

* **LRU size cap** — ``max_bytes`` bounds the directory: after every store
  the oldest-*used* entries are evicted until the total fits. Recency is
  the entry's mtime, touched atomically on every hit (``os.utime``), so
  eviction is LRU rather than FIFO. Eviction is plain ``unlink``: a reader
  that already opened the file keeps its data (POSIX), a reader that opens
  later sees a clean miss — eviction can never corrupt a concurrent read.
* **crash hygiene** — ``*.tmp`` files left by writers that died before
  their rename are reaped at open time once they are old enough to be
  provably dead (``tmp_reap_seconds``; a live writer's tmp is always
  younger). Every cross-process race on unlink/utime tolerates the file
  vanishing first.
"""

from __future__ import annotations

import os
import tempfile
import threading
import time
import warnings
import zipfile
from pathlib import Path

import numpy as np

from repro.core.executor import _FIELDS, SliceResult

# A writer holds its .tmp only for one np.savez + rename; a tmp this old
# belongs to a crashed process, not a slow one.
TMP_REAP_SECONDS = 3600.0

# A .lock is held only for one entry write or unlink; one this old belongs
# to a process that died holding it, and may be broken.
LOCK_STALE_SECONDS = 30.0


class _DirLock:
    """Best-effort cross-process mutex for one cache entry directory: an
    ``O_CREAT | O_EXCL`` ``.lock`` file (atomic on POSIX and NFSv3+ —
    exactly the shared-filesystem case two processes sharing a cache_dir
    are in). Store-vs-evict races coordinate through this; contention
    *degrades* (the caller warns and skips) — it never hangs, because
    acquisition is a bounded poll and locks older than ``stale_s`` are
    presumed orphaned by a dead holder and broken."""

    def __init__(self, dirpath: Path, timeout_s: float,
                 stale_s: float = LOCK_STALE_SECONDS,
                 name: str = ".lock"):
        self.path = dirpath / name
        self.timeout_s = timeout_s
        self.stale_s = stale_s
        self.acquired = False

    def acquire(self) -> bool:
        deadline = time.monotonic() + self.timeout_s
        while True:
            try:
                fd = os.open(self.path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                try:
                    if time.time() - self.path.stat().st_mtime > self.stale_s:
                        os.unlink(self.path)  # break a dead holder's lock
                        continue
                except OSError:
                    continue  # holder released between open and stat: retry
                if time.monotonic() >= deadline:
                    return False
                time.sleep(0.005)
                continue
            except OSError:
                return False  # unwritable/vanished dir: degrade, never hang
            try:
                os.write(fd, str(os.getpid()).encode())
            finally:
                os.close(fd)
            self.acquired = True
            return True

    def release(self) -> None:
        if self.acquired:
            self.acquired = False
            try:
                os.unlink(self.path)
            except OSError:
                pass


class ResultCache:
    """Filesystem-backed map ``(spec_hash, slice) -> SliceResult``.

    ``max_bytes=None`` (default) leaves the directory unbounded — the
    pre-existing behaviour. With a cap, ``store`` evicts least-recently-used
    entries (see module docstring); the cap is advisory during a store burst
    (entries land, then eviction trims), exact between stores.
    """

    def __init__(self, cache_dir: str | Path, max_bytes: int | None = None,
                 tmp_reap_seconds: float = TMP_REAP_SECONDS,
                 lock_timeout_s: float = 5.0, injector=None):
        if max_bytes is not None and max_bytes <= 0:
            raise ValueError(f"max_bytes must be > 0 (or None), got {max_bytes}")
        if lock_timeout_s < 0:
            raise ValueError(
                f"lock_timeout_s must be >= 0, got {lock_timeout_s}")
        self.dir = Path(cache_dir)
        self.max_bytes = max_bytes
        self.lock_timeout_s = lock_timeout_s
        self.injector = injector  # faults.FaultInjector (on_cache hook)
        # one cache instance is shared across threads (a PDFServer's serving
        # thread stores slices while the owning session reads): counter
        # bumps hold _stats_lock (the LOCK rule enforces consistency)
        self._stats_lock = threading.Lock()
        self.evictions = 0  # entries unlinked by the size cap, this process
        self.lock_misses = 0  # stores/evictions skipped on lock contention
        self.adoptions = 0  # entries re-keyed across an append (streaming)
        self._reap_stale_tmps(tmp_reap_seconds)

    def path(self, spec_hash: str, slice_i: int) -> Path:
        return self.dir / spec_hash / f"slice{slice_i}.npz"

    def lookup(self, spec_hash: str, slice_i: int) -> SliceResult | None:
        """The cached ``SliceResult``, or ``None`` on miss. Served results
        carry ``cached=True`` and empty window ``stats`` (no work ran — the
        same shape a fully resumed slice has). A hit touches the entry's
        mtime so the LRU cap evicts cold entries first."""
        f = self.path(spec_hash, slice_i)
        if not f.exists():
            return None
        try:
            if self.injector is not None:
                # InjectedFault is an OSError: a chaos plan's cache_error
                # exercises exactly this warned-miss path.
                self.injector.on_cache("lookup", slice_i)
            with np.load(f) as z:  # close the zip handle: no fd per hit
                if str(z["spec_hash"]) != spec_hash:  # misfiled: miss
                    return None
                result = SliceResult(
                    *(z[name] for name in _FIELDS),
                    avg_error=float(z["avg_error"]),
                    stats=[],
                    slice_i=slice_i,
                    spec_hash=spec_hash,
                    cached=True,
                )
        except (OSError, ValueError, KeyError, EOFError,
                zipfile.BadZipFile) as e:
            # A truncated / foreign / partially-synced entry (e.g. an
            # interrupted copy into a shared cache_dir — the writer's
            # tmp+rename cannot protect against that) is a miss, not a
            # crash: the slice recomputes and the store *atomically
            # replaces* it (never a partial overwrite another reader could
            # trip on — it keeps serving the corrupt bytes until the rename
            # and gets its own warned miss).
            warnings.warn(f"ignoring unreadable cache entry {f}: {e}",
                          stacklevel=2)
            return None
        self._touch(f)
        return result

    def store(self, result: SliceResult,
              deps: tuple[str, ...] | None = None) -> None:
        """Persist one computed slice under its own ``spec_hash``; then, with
        a ``max_bytes`` cap, evict least-recently-used entries until the
        directory fits again (never the entry just written).

        ``deps`` is the slice's chunk-dependency fingerprint (the sha256s of
        every cube chunk the slice reads, ``file_source.slice_chunk_shas``):
        stored inside the entry so ``adopt`` can later prove the slice's
        input bytes are unchanged across an append (chunk-granular
        invalidation — entries *without* deps simply can never be adopted).

        The write happens under the entry dir's ``.lock`` (``_DirLock``) so
        it cannot race another process's eviction pass over the same dir.
        Lock contention — and any IO failure — degrades to a *warned skip*:
        the cache is an optimization, a failed store must cost a future
        recompute, never the run."""
        if result.spec_hash is None or result.slice_i is None:
            raise ValueError(
                "cannot cache a SliceResult without spec_hash and slice_i")
        payload = {
            "spec_hash": result.spec_hash,
            "slice_i": result.slice_i,
            "avg_error": result.avg_error,
            **{name: getattr(result, name) for name in _FIELDS},
        }
        if deps is not None:
            payload["deps"] = np.asarray(list(deps), dtype=np.str_)
        f = self.path(result.spec_hash, result.slice_i)
        try:
            if self.injector is not None:
                self.injector.on_cache("store", result.slice_i)
            if not self._write_entry(f, payload):
                warnings.warn(
                    f"cache entry dir {f.parent} locked by another process — "
                    f"skipping store for slice {result.slice_i}", stacklevel=2)
                return
        except OSError as e:
            warnings.warn(
                f"cache store failed for {f}: {e} — continuing without "
                "caching this slice", stacklevel=2)
            return
        if self.max_bytes is not None:
            self._evict(keep=f)

    def _write_entry(self, f: Path, payload: dict) -> bool:
        """tmp + atomic-rename one entry under its dir's ``.lock``; False on
        lock contention (counted), OSError propagates to the caller's
        warned-skip handling."""
        f.parent.mkdir(parents=True, exist_ok=True)
        lock = _DirLock(f.parent, self.lock_timeout_s)
        if not lock.acquire():
            with self._stats_lock:
                self.lock_misses += 1
            return False
        try:
            fd, tmp = tempfile.mkstemp(dir=f.parent, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as fh:
                    np.savez(fh, **payload)
                os.replace(tmp, f)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        finally:
            lock.release()
        return True

    # -- chunk-granular adoption (streaming appends) ---------------------------

    def deps(self, spec_hash: str, slice_i: int) -> tuple[str, ...] | None:
        """The chunk-dependency fingerprint stored with an entry, or None
        when the entry is missing or predates dependency tracking."""
        f = self.path(spec_hash, slice_i)
        try:
            with np.load(f) as z:
                if "deps" not in z.files:
                    return None
                return tuple(str(d) for d in z["deps"])
        except (OSError, ValueError, KeyError, EOFError,
                zipfile.BadZipFile):
            return None

    def adopt(self, old_hash: str, new_hash: str, slice_i: int,
              expected_deps: tuple[str, ...]) -> bool:
        """Re-key one slice's entry from ``old_hash`` to ``new_hash`` iff
        its stored chunk fingerprint equals ``expected_deps``.

        This is the soundness core of incremental recompute across appends:
        the two hashes come from the SAME spec differing only in manifest
        version, so equal fingerprints prove the slice reads identical
        bytes under both — the old result is bitwise-valid for the new
        hash. Anything less (missing deps, mismatched fingerprint, entry
        gone) refuses, and the slice recomputes normally. Returns True when
        the new entry exists afterwards."""
        target = self.path(new_hash, slice_i)
        if target.exists():
            return True
        if not expected_deps:
            return False
        old = self.path(old_hash, slice_i)
        if not old.exists():
            return False
        try:
            with np.load(old) as z:
                if str(z["spec_hash"]) != old_hash or "deps" not in z.files:
                    return False
                if tuple(str(d) for d in z["deps"]) != tuple(expected_deps):
                    return False
                payload = {
                    "spec_hash": new_hash,
                    "slice_i": slice_i,
                    "avg_error": float(z["avg_error"]),
                    "deps": z["deps"],
                    **{name: z[name] for name in _FIELDS},
                }
        except (OSError, ValueError, KeyError, EOFError,
                zipfile.BadZipFile) as e:
            warnings.warn(f"ignoring unreadable cache entry {old}: {e}",
                          stacklevel=2)
            return False
        try:
            if not self._write_entry(target, payload):
                return False
        except OSError as e:
            warnings.warn(
                f"cache adopt failed for {target}: {e} — slice will "
                "recompute", stacklevel=2)
            return False
        with self._stats_lock:
            self.adoptions += 1
        return True

    # -- size accounting / eviction -------------------------------------------

    def entries(self) -> list[tuple[Path, float, int]]:
        """Every ``(path, mtime, size)`` entry currently in the cache,
        oldest-used first. Entries vanishing mid-scan (a concurrent evictor
        or store race) are skipped, not errors."""
        out = []
        if not self.dir.is_dir():
            return out
        for f in self.dir.glob("*/slice*.npz"):
            try:
                st = f.stat()
            except OSError:
                continue  # lost a race with a concurrent unlink
            out.append((f, st.st_mtime, st.st_size))
        out.sort(key=lambda e: (e[1], str(e[0])))
        return out

    def size_bytes(self) -> int:
        return sum(size for _, _, size in self.entries())

    def _evict(self, keep: Path | None = None) -> None:
        """Unlink oldest-used entries until the cap holds. ``keep`` (the
        entry a store just wrote) is never evicted, even when it alone
        exceeds the cap — a store must not erase its own result.

        The whole pass runs under a root-level ``.sweep.lock`` so two
        processes sharing one cache_dir never trim from independent stale
        snapshots (each would over-evict, blind to the other's unlinks); a
        contended sweep is skipped outright — the other process is already
        enforcing the cap. Each unlink additionally takes its entry dir's
        ``.lock`` with a short timeout so it cannot race another process's
        in-flight store into the same dir; a contended dir is simply
        skipped this pass (the next store's eviction will see it again)."""
        sweep = _DirLock(self.dir, min(0.1, self.lock_timeout_s),
                         name=".sweep.lock")
        if not sweep.acquire():
            with self._stats_lock:
                self.lock_misses += 1
            return
        try:
            self._evict_locked(keep)
        finally:
            sweep.release()

    def _evict_locked(self, keep: Path | None) -> None:
        entries = self.entries()
        total = sum(size for _, _, size in entries)
        for f, _mtime, size in entries:
            if total <= self.max_bytes:
                break
            if keep is not None and f == keep:
                continue
            lock = _DirLock(f.parent, min(0.1, self.lock_timeout_s))
            if not lock.acquire():
                with self._stats_lock:
                    self.lock_misses += 1
                continue
            try:
                os.unlink(f)
            except OSError:
                continue  # another process evicted it first: size unknown,
                # stay conservative and keep trimming from our own snapshot
            finally:
                lock.release()
            total -= size
            with self._stats_lock:
                self.evictions += 1

    def _touch(self, f: Path) -> None:
        """Refresh an entry's recency; racing with eviction is benign (a
        touched-then-evicted entry is simply a future miss)."""
        try:
            os.utime(f)
        except OSError:
            pass

    def _reap_stale_tmps(self, reap_seconds: float) -> None:
        """Remove ``*.tmp`` files old enough that their writer must have
        crashed before its atomic rename. Younger tmps may belong to a live
        concurrent writer and are left alone; unlink races are benign."""
        if not self.dir.is_dir():
            return
        cutoff = time.time() - reap_seconds
        for tmp in self.dir.glob("*/*.tmp"):
            try:
                if tmp.stat().st_mtime <= cutoff:
                    os.unlink(tmp)
            except OSError:
                continue
