"""``repro.serve``: the long-lived PDF query server (DESIGN.md §13).

    from repro.api import PipelineSpec
    from repro.serve import PDFServer, PointQuery

    with PDFServer(PipelineSpec()) as server:
        ans = server.query(PointQuery(slice_i=0, line=3, point=7))
        print(ans.type_idx, ans.error)

The server owns warm per-shard executors and the lazily-trained tree for
one ``PipelineSpec``, accepts point / window / region queries through a
thread-safe queue, and coalesces whatever is pending each tick into a
single batched fused-kernel launch — answers are bitwise-identical to
running each query through the batch pipeline serially.
"""

from repro.serve.server import (
    PDFServer,
    PointQuery,
    QueryAnswer,
    RegionQuery,
    ServerOverloadedError,
    ServerStats,
    WindowQuery,
)

__all__ = [
    "PDFServer",
    "PointQuery",
    "QueryAnswer",
    "RegionQuery",
    "ServerOverloadedError",
    "ServerStats",
    "WindowQuery",
]
