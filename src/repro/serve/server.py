"""``PDFServer``: coalescing query serving over warm executors (DESIGN.md §13).

The batch pipeline answers "compute every PDF of the cube"; the paper's
stated consumers ask much smaller questions, concurrently — the PDF at one
point, one horizon span, one slice. This module is the long-lived layer
between those consumers and the warm machinery a ``PDFSession`` owns:

  submit     callers (any thread) put queries on a FIFO queue and get a
             ``Future``; ``query()`` is the blocking convenience.
  coalesce   one background thread drains whatever is pending each tick,
             maps every query onto the aligned window grid
             (``compute.window_lines`` — the executor's unit of work), and
             deduplicates: ten point queries in one hot window become ONE
             window to produce.
  resolve    each needed window comes from, in order: the in-memory
             hot-window LRU, the spec-hash-keyed ``ResultCache`` (a stored
             slice is sliced into windows without touching an executor),
             else the compute batch.
  launch     every window still missing is computed by ONE
             ``StagedExecutor.run_window_batch`` call (chunked at
             ``serve.max_batch_windows``) — shared H2D + barrier, packed
             representative fits — not one synced dispatch per query.
  scatter    per-request answers are cut from the resolved windows and set
             on the futures; completed slices are stored back to the
             ``ResultCache`` so the next server process starts warm.

The batching thread follows the offline-inference engine pattern the
ROADMAP points at (batch slots + request queue + background thread that
fails loudly), refined by a transient/fatal split (DESIGN.md §14): a
*transient* launch failure (``faults.is_transient`` — injected faults,
OSError, timeouts) is retried up to ``serve.retry_transient`` times and,
if still failing, fails ONLY the futures whose windows that launch
covered — the server keeps serving everything else. Any *fatal* exception
keeps the original behaviour: it fails the in-flight batch's futures,
poisons the server, and re-raises — a wedged server is impossible to
mistake for a slow one. Two more overload guards: ``serve.max_queue_depth``
sheds submissions (``ServerOverloadedError``) once the queue gauge hits
the cap, and ``serve.request_deadline_s`` expires requests that waited in
the queue longer than their deadline (their futures get ``TimeoutError``
before any compute is spent on them).

**Coalescing-equivalence contract**: answers are bitwise-identical to
running each query's windows through the executor serially
(``serve.coalesce=False`` is exactly that baseline), because
``run_window_batch`` only issues launches at the exact shapes the serial
path compiles — sharing syncs and fit launches, never an executable of a
different shape (DESIGN.md §13.2) — so no per-window Select decision or
reduction order changes. That is the contract ``ServeSpec`` being
excluded from ``content_hash`` rests on (tests/test_serve.py).
"""

from __future__ import annotations

import queue
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np

from repro.api.session import PDFSession
from repro.api.spec import PipelineSpec
from repro.core import regions
from repro.core.executor import RESULT_FIELDS, SliceResult, WindowResult
from repro.runtime.faults import is_transient
from repro.runtime.monitor import StepMonitor, StragglerPolicy, percentiles

_SHUTDOWN = object()


@dataclass
class _Invalidate:
    """Control item for ``PDFServer.invalidate``: processed on the serving
    thread (never mid-batch), so it can rewire the session and prune the
    hot-window state without racing a launch."""

    version: int | None
    done: threading.Event
    result: dict
    error: BaseException | None = None


class ServerOverloadedError(RuntimeError):
    """Raised by ``submit`` when the queue gauge is at
    ``serve.max_queue_depth``: load shedding — the caller should back off
    and retry, the server is protecting its latency for admitted work."""


# -- queries -------------------------------------------------------------------


@dataclass(frozen=True)
class PointQuery:
    """The PDF at one point of the cube."""

    slice_i: int
    line: int
    point: int


@dataclass(frozen=True)
class WindowQuery:
    """Per-point PDFs over a span of lines ``[line_start, line_end)`` of one
    slice — any span, not necessarily aligned to the window grid."""

    slice_i: int
    line_start: int
    line_end: int


@dataclass(frozen=True)
class RegionQuery:
    """Per-point PDFs of one whole slice."""

    slice_i: int


@dataclass
class QueryAnswer:
    """Per-point results for the queried span (arrays are 1-point long for a
    ``PointQuery``), plus where its windows came from."""

    query: object
    spec_hash: str
    type_idx: np.ndarray  # (Q,) int32
    params: np.ndarray  # (Q, 3)
    error: np.ndarray  # (Q,)
    mean: np.ndarray  # (Q,)
    std: np.ndarray  # (Q,)
    skew: np.ndarray  # (Q,)
    kurt: np.ndarray  # (Q,)
    windows_computed: int = 0
    windows_from_memory: int = 0
    windows_from_disk: int = 0
    latency_seconds: float = 0.0


@dataclass(frozen=True)
class ServerStats:
    """A consistent snapshot of the server's counters (``PDFServer.stats()``).

    ``coalesce_ratio`` is windows requested (pre-dedup, over all queries)
    per window actually computed — the fused-launch sharing factor;
    ``batch_occupancy`` is computed windows per launch. ``latency`` /
    ``stage_percentiles`` quote the same p50/p99 estimator as
    ``SessionReport`` (runtime.monitor.percentiles)."""

    spec_hash: str
    queries: int
    queries_by_kind: dict[str, int]
    ticks: int
    launches: int
    windows_requested: int
    windows_unique: int
    windows_computed: int
    windows_from_memory: int
    windows_from_disk: int
    slices_stored: int
    max_queue_depth: int
    latency: dict[str, float]  # request p50/p99, seconds
    launch_latency: dict[str, float]  # run_window_batch p50/p99, seconds
    stage_percentiles: dict[str, dict[str, float]] = field(default_factory=dict)
    # failure-model counters (DESIGN.md §14)
    shed_requests: int = 0  # submits refused at serve.max_queue_depth
    deadline_expired: int = 0  # requests timed out waiting in the queue
    launch_retries: int = 0  # transient launch failures (retried attempts)
    windows_failed: int = 0  # windows whose launches exhausted retries

    @property
    def coalesce_ratio(self) -> float:
        return self.windows_requested / max(self.windows_computed, 1)

    @property
    def batch_occupancy(self) -> float:
        return self.windows_computed / max(self.launches, 1)

    @property
    def window_hit_rate(self) -> float:
        served = self.windows_from_memory + self.windows_from_disk
        return served / max(served + self.windows_computed, 1)


class _Pending(NamedTuple):
    query: object
    slice_i: int
    lo: int  # point span within the slice, [lo, hi)
    hi: int
    windows: tuple[regions.Window, ...]  # aligned windows covering the span
    future: Future
    t_submit: float


class PDFServer:
    """Serve point / window / region PDF queries for one ``PipelineSpec``.

    Construction is cheap: executors compile and the tree trains lazily on
    the first computed window (a server in front of a fully-populated
    ``ResultCache`` never builds either). Start/stop with ``start()`` /
    ``close()`` or use as a context manager. ``data_source`` / ``tree``
    forward to ``PDFSession``.
    """

    def __init__(self, spec: PipelineSpec, data_source=None, tree=None):
        self.session = PDFSession(spec, data_source=data_source, tree=tree)
        self.spec = self.session.spec
        self._serve = spec.serve
        self._grid = spec.compute.window_lines
        geom = self.session.geometry
        self._geom = geom
        self._ppl = geom.points_per_line
        self._windows_per_slice = regions.num_windows(geom, self._grid)

        self._queue: queue.SimpleQueue = queue.SimpleQueue()
        # _depth and _counts are mutated from caller threads (submit/shed)
        # AND the serving thread — every mutation holds _stats_lock (the
        # LOCK rule enforces this); stats() reads are lock-snapshot too.
        self._stats_lock = threading.Lock()
        self._depth = 0  # queued-request gauge
        self._lru: OrderedDict[tuple[int, int], WindowResult] = OrderedDict()
        # per-slice window accumulation -> ResultCache store on completion
        self._parts: dict[int, dict[tuple[int, int], WindowResult]] = {}
        self._stored_slices: set[int] = set()

        self.monitors = {
            # serving latencies are ms-scale: drop the straggler grace floor
            # so the percentile reservoirs stay meaningful out of the box
            "request": StepMonitor(StragglerPolicy(grace_seconds=0.0)),
            "launch": StepMonitor(StragglerPolicy(grace_seconds=0.0)),
        }
        self._counts = dict(
            queries=0, ticks=0, launches=0, windows_requested=0,
            windows_unique=0, windows_computed=0, windows_from_memory=0,
            windows_from_disk=0, slices_stored=0, max_queue_depth=0,
            shed_requests=0, deadline_expired=0, launch_retries=0,
            windows_failed=0,
        )
        self._by_kind: dict[str, int] = {}
        self._failure: BaseException | None = None
        self._closed = False
        self._thread: threading.Thread | None = None

    # -- lifecycle -------------------------------------------------------------

    def start(self) -> "PDFServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._thread = threading.Thread(
            target=self._serve_loop, name="pdf-serve", daemon=True
        )
        self._thread.start()
        return self

    def close(self, timeout: float | None = None) -> None:
        """Graceful drain: stop accepting new queries, serve everything
        already queued (FIFO up to the shutdown marker), stop the thread.

        The *first* close re-raises a serving-thread failure (a crash must
        surface loudly at least once); every later close is a silent no-op,
        so ``close()`` is safe from ``finally`` blocks and ``__exit__``
        stacks even after the serving thread died mid-batch."""
        if self._closed:
            return
        self._closed = True
        if self._thread is not None:
            self._queue.put(_SHUTDOWN)
            self._thread.join(timeout)
        self.raise_if_failed()

    def raise_if_failed(self) -> None:
        if self._failure is not None:
            raise RuntimeError("PDF server thread failed") from self._failure

    def __enter__(self) -> "PDFServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission ------------------------------------------------------------

    def submit(self, q) -> Future:
        """Enqueue a query; returns a ``Future`` resolving to its
        ``QueryAnswer``. Raises immediately on malformed queries, a closed
        server, a failed serving thread, or (``ServerOverloadedError``) a
        queue already at ``serve.max_queue_depth``."""
        self.raise_if_failed()
        if self._closed:
            raise RuntimeError("server is closed")
        if self._thread is None:
            raise RuntimeError("server not started (use start() or 'with')")
        cap = self._serve.max_queue_depth
        if cap:
            with self._stats_lock:
                if self._depth >= cap:
                    self._counts["shed_requests"] += 1
                    raise ServerOverloadedError(
                        f"queue depth {self._depth} at max_queue_depth={cap}"
                        " — request shed, retry with backoff")
        pending = self._resolve_span(q)
        with self._stats_lock:
            self._depth += 1
            self._counts["max_queue_depth"] = max(
                self._counts["max_queue_depth"], self._depth)
        self._queue.put(pending)
        return pending.future

    def query(self, q, timeout: float | None = None) -> QueryAnswer:
        """Submit + wait."""
        return self.submit(q).result(timeout)

    # -- streaming invalidation (DESIGN.md §16) --------------------------------

    def invalidate(self, version: int | None = None,
                   timeout: float | None = None) -> dict:
        """Pick up an append to the served file cube without a restart.

        Computes the chunk-diff from the version this server opened to
        ``version`` (default: the cube's current manifest), re-opens the
        source at the new version (re-hashing the spec), adopts cached
        results for slices the diff proves untouched, and drops the
        hot-window LRU / pending slice assemblies / known-stored marks for
        exactly the changed slices — untouched slices keep serving from
        memory bitwise-identically (their bytes are unchanged; that is what
        the fingerprint check certifies).

        Applied on the serving thread between batches: queries submitted
        before the call are answered from pre-append state, queries after
        see the new version. Returns ``{"old_version", "new_version",
        "changed_slices", "adopted"}``. Requires a ``kind='file'`` source."""
        self.raise_if_failed()
        inv = _Invalidate(version, threading.Event(), {})
        if self._thread is None or self._closed:
            # not serving: no batch to race — apply inline (lets a server be
            # invalidated before start(), e.g. warm-up flows)
            self._apply_invalidate(inv)
        else:
            self._queue.put(inv)
            if not inv.done.wait(timeout):
                raise TimeoutError("invalidate not applied within timeout")
        if inv.error is not None:
            raise inv.error
        return inv.result

    def _apply_invalidate(self, inv: _Invalidate) -> None:
        try:
            src = self.session._file_source()
            if src is None:
                raise ValueError(
                    "invalidate() requires a kind='file' source (appends "
                    "land as manifest versions of an exported cube)")
            from repro.data.file_source import chunk_diff

            old_version = src.version
            diff = chunk_diff(self.spec.source.path, old_version, inv.version)
            changed = set(diff["changed_slices"])
            adopted0 = self.session.cache_adopted
            self.session.refresh_source()
            if self.session.cache is not None:
                self.session._adopt_unchanged(
                    [s for s in range(self._geom.num_slices)
                     if s not in changed])
            for key in [k for k in self._lru if k[0] in changed]:
                del self._lru[key]
            for s in changed:
                self._parts.pop(s, None)
                self._stored_slices.discard(s)
            inv.result.update(
                old_version=old_version,
                new_version=diff["new_version"],
                changed_slices=sorted(changed),
                adopted=self.session.cache_adopted - adopted0,
            )
        except BaseException as e:  # repro: allow[ERR]: parked — invalidate() re-raises it on the calling thread
            inv.error = e
        finally:
            inv.done.set()

    def _resolve_span(self, q) -> _Pending:
        """Validate a query and map it to its within-slice point span plus
        the aligned windows covering it."""
        geom = self._geom
        if isinstance(q, PointQuery):
            s, lo_line, hi_line = q.slice_i, q.line, q.line + 1
            if not 0 <= q.point < self._ppl:
                raise ValueError(f"point {q.point} outside line of {self._ppl}")
            if not 0 <= q.line < geom.lines_per_slice:
                raise ValueError(
                    f"line {q.line} outside slice of {geom.lines_per_slice}")
            lo = q.line * self._ppl + q.point
            hi = lo + 1
        elif isinstance(q, WindowQuery):
            s, lo_line, hi_line = q.slice_i, q.line_start, q.line_end
            if not 0 <= lo_line < hi_line <= geom.lines_per_slice:
                raise ValueError(
                    f"lines [{lo_line}, {hi_line}) outside slice of "
                    f"{geom.lines_per_slice}")
            lo, hi = lo_line * self._ppl, hi_line * self._ppl
        elif isinstance(q, RegionQuery):
            s, lo_line, hi_line = q.slice_i, 0, geom.lines_per_slice
            lo, hi = 0, geom.points_per_slice
        else:
            raise TypeError(f"unknown query type {type(q).__name__}")
        if not 0 <= s < geom.num_slices:
            raise ValueError(f"slice {s} outside cube of {geom.num_slices}")
        first = (lo_line // self._grid) * self._grid
        windows = tuple(
            regions.Window(s, ls, min(ls + self._grid, geom.lines_per_slice))
            for ls in range(first, hi_line, self._grid)
        )
        return _Pending(q, s, lo, hi, windows, Future(), time.perf_counter())

    # -- the serving thread ----------------------------------------------------

    def _serve_loop(self) -> None:
        try:
            while True:
                item = self._queue.get()
                if item is _SHUTDOWN:
                    break
                if isinstance(item, _Invalidate):
                    self._apply_invalidate(item)
                    continue
                batch = [item]
                invs: list[_Invalidate] = []
                stop = False
                while True:  # free drain: whatever is already pending
                    try:
                        nxt = self._queue.get_nowait()
                    except queue.Empty:
                        break
                    if nxt is _SHUTDOWN:
                        stop = True
                        break
                    if isinstance(nxt, _Invalidate):
                        # applied after this batch: queries submitted before
                        # the invalidate are answered from pre-append state
                        invs.append(nxt)
                        continue
                    batch.append(nxt)
                # The coalescing wait only pays off when a launch is coming:
                # a batch fully covered by the hot-window LRU / known-stored
                # slices is answered immediately, so cache hits never pay
                # the tick tax (the cold/warm gap serve_bench measures).
                if (not stop and self._serve.tick_seconds > 0
                        and self._needs_compute(batch)):
                    deadline = time.monotonic() + self._serve.tick_seconds
                    while True:
                        wait = deadline - time.monotonic()
                        if wait <= 0:
                            break
                        try:
                            nxt = self._queue.get(timeout=wait)
                        except queue.Empty:
                            break
                        if nxt is _SHUTDOWN:
                            stop = True
                            break
                        if isinstance(nxt, _Invalidate):
                            invs.append(nxt)
                            continue
                        batch.append(nxt)
                with self._stats_lock:
                    self._depth -= len(batch)
                self._serve_batch(batch)
                for inv in invs:
                    self._apply_invalidate(inv)
                if stop:
                    break
        except BaseException as e:  # noqa: BLE001 — fail loudly (see below)
            self._failure = e
            self._drain_failed(e)
            raise
        finally:
            self._drain_failed(RuntimeError("server closed"))

    def _needs_compute(self, batch: list[_Pending]) -> bool:
        """Cheap host-side guess at whether this batch will launch anything:
        a window neither in the LRU nor in a slice known stored on disk.
        Only gates the coalescing wait — resolution stays authoritative."""
        for p in batch:
            for w in p.windows:
                if ((w.slice_i, w.line_start) not in self._lru
                        and w.slice_i not in self._stored_slices):
                    return True
        return False

    def _drain_failed(self, exc: BaseException) -> None:
        """Fail anything still queued (post-shutdown stragglers, or the
        whole queue after a serving-thread crash)."""
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                return
            if item is _SHUTDOWN:
                continue
            if isinstance(item, _Invalidate):
                item.error = exc
                item.done.set()
                continue
            if not item.future.done():
                item.future.set_exception(exc)

    def _bump(self, key: str, n: int = 1) -> None:
        """All counter mutations funnel through here: ``_counts`` is shared
        with caller threads (shed/queue-depth accounting in ``submit``), so
        even serving-thread increments hold ``_stats_lock``."""
        with self._stats_lock:
            self._counts[key] += n

    def _serve_batch(self, batch: list[_Pending]) -> None:
        self._bump("ticks")
        batch = self._expire(batch)
        if not batch:
            return
        try:
            if self._serve.coalesce:
                resolved, failed = self._resolve_coalesced(batch)
            else:
                resolved, failed = self._resolve_naive(batch)
        except BaseException as e:
            for p in batch:
                if not p.future.done():
                    p.future.set_exception(e)
            raise
        now = time.perf_counter()
        rmon = self.monitors["request"]
        for i, p in enumerate(batch):
            self._bump("queries")
            kind = type(p.query).__name__
            self._by_kind[kind] = self._by_kind.get(kind, 0) + 1
            bad = None
            if failed:
                for w in p.windows:
                    bad = failed.get((w.slice_i, w.line_start))
                    if bad is not None:
                        break
            if bad is not None:
                # Only the requests touching a failed launch's windows fail;
                # the rest of the batch is answered normally.
                if not p.future.done():
                    p.future.set_exception(bad)
                continue
            rmon.start(f"q{self._counts['queries']}", now=p.t_submit)
            latency = rmon.finish(f"q{self._counts['queries']}", now=now)
            p.future.set_result(self._answer(p, resolved, latency))

    def _expire(self, batch: list[_Pending]) -> list[_Pending]:
        """Fail (``TimeoutError``) requests that sat in the queue longer
        than ``serve.request_deadline_s`` — no compute is spent on an answer
        the caller has already given up on. Returns the live remainder."""
        deadline = self._serve.request_deadline_s
        if deadline is None:
            return batch
        now = time.perf_counter()
        live = []
        for p in batch:
            waited = now - p.t_submit
            if waited > deadline:
                self._bump("deadline_expired")
                if not p.future.done():
                    p.future.set_exception(TimeoutError(
                        f"request expired: queued {waited:.3f}s > "
                        f"deadline {deadline}s"))
            else:
                live.append(p)
        return live

    def _resolve_coalesced(self, batch):
        """Dedup every pending query's windows, serve what the caches hold,
        compute the rest in (chunked) single launches. Returns
        ``(resolved, failed)``: windows whose launch exhausted its transient
        retries land in ``failed`` (key -> exception) instead of poisoning
        the server."""
        needed: OrderedDict[tuple[int, int], str] = OrderedDict()
        for p in batch:
            self._bump("windows_requested", len(p.windows))
            for w in p.windows:
                needed.setdefault((w.slice_i, w.line_start), w)
        self._bump("windows_unique", len(needed))

        resolved: dict[tuple[int, int], tuple[str, WindowResult]] = {}
        failed: dict[tuple[int, int], BaseException] = {}
        to_compute: list[regions.Window] = []
        for key, w in needed.items():
            served = self._from_caches(key, w)
            if served is not None:
                resolved[key] = served
            else:
                to_compute.append(w)

        ex = self.session.executor(0) if to_compute else None
        for i in range(0, len(to_compute), self._serve.max_batch_windows):
            chunk = to_compute[i:i + self._serve.max_batch_windows]
            results = self._launch(
                lambda: ex.run_window_batch(chunk), chunk, failed)
            if results is None:
                continue
            self._bump("windows_computed", len(chunk))
            for wr in results:
                key = (wr.window.slice_i, wr.window.line_start)
                resolved[key] = ("computed", wr)
                self._remember(key, wr)
        return resolved, failed

    def _resolve_naive(self, batch):
        """The one-launch-per-query baseline: no cross-request dedup, each
        query's windows dispatched individually (cache layers still apply —
        coalescing is the lever this baseline isolates)."""
        resolved: dict[tuple[int, int], tuple[str, WindowResult]] = {}
        failed: dict[tuple[int, int], BaseException] = {}
        for p in batch:
            self._bump("windows_requested", len(p.windows))
            for w in p.windows:
                key = (w.slice_i, w.line_start)
                self._bump("windows_unique")
                if key in resolved or key in failed:
                    continue
                served = self._from_caches(key, w)
                if served is not None:
                    resolved[key] = served
                    continue
                ex = self.session.executor(0)
                results = self._launch(
                    lambda: [ex.run_window(w)], (w,), failed)
                if results is None:
                    continue
                self._bump("windows_computed")
                resolved[key] = ("computed", results[0])
                self._remember(key, results[0])
        return resolved, failed

    def _launch(self, run, chunk, failed):
        """One monitored launch with transient retry (DESIGN.md §14).

        ``run()`` computes the ``WindowResult``s for ``chunk``. A transient
        failure (``faults.is_transient``) is retried up to
        ``serve.retry_transient`` times with a short linear backoff — the
        failed attempt's timing is abandoned so it cannot skew the launch
        percentiles. Exhaustion marks every window of the chunk in
        ``failed`` (only their requests' futures fail) and returns None; a
        fatal error raises and keeps the poison-the-server path."""
        lmon = self.monitors["launch"]
        last: BaseException | None = None
        for attempt in range(self._serve.retry_transient + 1):
            uid = f"launch{self._counts['launches']}"
            lmon.start(uid, now=time.perf_counter())
            try:
                results = run()
            except Exception as e:
                lmon.abandon(uid)
                if not is_transient(e):
                    raise
                last = e
                self._bump("launch_retries")
                time.sleep(0.01 * (attempt + 1))
                continue
            lmon.finish(uid, now=time.perf_counter())
            self._bump("launches")
            return results
        for w in chunk:
            failed[(w.slice_i, w.line_start)] = last
            self._bump("windows_failed")
        return None

    # -- cache layers ----------------------------------------------------------

    def _from_caches(self, key, w: regions.Window):
        wr = self._lru_get(key)
        if wr is not None:
            self._bump("windows_from_memory")
            return ("memory", wr)
        wr = self._from_result_cache(w)
        if wr is not None:
            self._bump("windows_from_disk")
            self._lru_put(key, wr)
            return ("disk", wr)
        return None

    def _from_result_cache(self, w: regions.Window) -> WindowResult | None:
        """Serve one window out of a ``ResultCache``-stored slice (the hot
        path that never touches an executor). A slice known stored skips the
        disk probe for slices this server itself completed."""
        cache = self.session.cache
        if cache is None:
            return None
        hit = cache.lookup(self.session.spec_hash, w.slice_i)
        if hit is None:
            return None
        self._stored_slices.add(w.slice_i)
        lo, hi = w.line_start * self._ppl, w.line_end * self._ppl
        return WindowResult(
            w, *(getattr(hit, name)[lo:hi] for name in RESULT_FIELDS))

    def _lru_get(self, key) -> WindowResult | None:
        wr = self._lru.get(key)
        if wr is not None:
            self._lru.move_to_end(key)
        return wr

    def _lru_put(self, key, wr: WindowResult) -> None:
        cap = self._serve.window_cache_entries
        if cap <= 0:
            return
        self._lru[key] = wr
        self._lru.move_to_end(key)
        while len(self._lru) > cap:
            self._lru.popitem(last=False)

    def _remember(self, key, wr: WindowResult) -> None:
        """A freshly computed window enters the LRU and, when a
        ``ResultCache`` is configured, the per-slice assembly — a slice
        whose every window the server has computed is stored back, so the
        next server (or batch run) of this spec starts warm."""
        self._lru_put(key, wr)
        cache = self.session.cache
        s = wr.window.slice_i
        if cache is None or s in self._stored_slices:
            return
        parts = self._parts.setdefault(s, {})
        parts[key] = wr
        if len(parts) < self._windows_per_slice:
            return
        total = self._geom.points_per_slice
        outs = {
            name: np.zeros((total, 3) if name == "params" else (total,),
                           dtype=wr.arrays()[name].dtype)
            for name in RESULT_FIELDS
        }
        for part in parts.values():
            lo = part.window.line_start * self._ppl
            hi = part.window.line_end * self._ppl
            for name in RESULT_FIELDS:
                outs[name][lo:hi] = getattr(part, name)
        result = SliceResult(
            *(outs[name] for name in RESULT_FIELDS),
            avg_error=float(outs["error"].mean()),
            stats=[], slice_i=s, spec_hash=self.session.spec_hash,
        )
        # deps-stamped like the session's stores, so invalidate() can adopt
        # this entry across a later append when the slice's chunks survive
        cache.store(result, deps=self.session._slice_deps(s))
        self._stored_slices.add(s)
        self._bump("slices_stored")
        del self._parts[s]

    # -- answers / stats -------------------------------------------------------

    def _answer(self, p: _Pending, resolved, latency: float) -> QueryAnswer:
        n = p.hi - p.lo
        first = resolved[(p.slice_i, p.windows[0].line_start)][1]
        outs = {
            name: np.empty((n, 3) if name == "params" else (n,),
                           dtype=first.arrays()[name].dtype)
            for name in RESULT_FIELDS
        }
        origin = dict(computed=0, memory=0, disk=0)
        for w in p.windows:
            source, wr = resolved[(w.slice_i, w.line_start)]
            origin[source] += 1
            w_lo = w.line_start * self._ppl
            lo = max(p.lo, w_lo)
            hi = min(p.hi, w.line_end * self._ppl)
            for name in RESULT_FIELDS:
                outs[name][lo - p.lo:hi - p.lo] = (
                    getattr(wr, name)[lo - w_lo:hi - w_lo])
        return QueryAnswer(
            query=p.query, spec_hash=self.session.spec_hash,
            **outs,
            windows_computed=origin["computed"],
            windows_from_memory=origin["memory"],
            windows_from_disk=origin["disk"],
            latency_seconds=latency,
        )

    def stats(self) -> ServerStats:
        """Consistent counter snapshot (taken under ``_stats_lock``, so a
        mid-tick read never sees a half-updated counter set)."""
        with self._stats_lock:
            c = dict(self._counts)
        return ServerStats(
            spec_hash=self.session.spec_hash,
            queries=c["queries"],
            queries_by_kind=dict(self._by_kind),
            ticks=c["ticks"],
            launches=c["launches"],
            windows_requested=c["windows_requested"],
            windows_unique=c["windows_unique"],
            windows_computed=c["windows_computed"],
            windows_from_memory=c["windows_from_memory"],
            windows_from_disk=c["windows_from_disk"],
            slices_stored=c["slices_stored"],
            max_queue_depth=c["max_queue_depth"],
            shed_requests=c["shed_requests"],
            deadline_expired=c["deadline_expired"],
            launch_retries=c["launch_retries"],
            windows_failed=c["windows_failed"],
            latency=self.monitors["request"].percentiles(),
            launch_latency=self.monitors["launch"].percentiles(),
            stage_percentiles=self.session.stage_percentiles(),
        )
