"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (kv=16 => MHA)
d_ff=4096 vocab=256206; enc-dec, multimodal. [arXiv:2308.11596; hf]

Backbone only per spec: 12 encoder + 12 decoder layers; the audio frontend
is a stub — input_specs() provides precomputed frame embeddings at d_model.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=24,  # 12 enc + 12 dec
    d_model=1024,
    q_heads=16,
    kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    enc_layers=12,
    dec_layers=12,
    rope_theta=10_000.0,
    notes=(
        "enc-dec; audio frontend stubbed (frame embeddings in input_specs). "
        "Full attention -> long_500k skipped. decode shapes lower the decoder "
        "step against a precomputed encoder memory."
    ),
)
