"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000; GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]

All linear layers are bias-free (the zoo's layers are bias-free throughout,
matching this config natively). FSDP on: at 35B dense, params+Adam in f32
exceed a single v5e HBM without data-axis sharding."""

from repro.configs.base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    q_heads=64,
    kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab=256000,
    pattern=(BlockDef(mixer="attn", ffn="dense"),),
    rope_theta=10_000.0,
    fsdp=True,
    notes="no-bias GQA dense; full attention (long_500k skipped); fsdp for memory.",
)
