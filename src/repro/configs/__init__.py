from repro.configs.base import ArchConfig, BlockDef

__all__ = ["ArchConfig", "BlockDef"]
