"""The paper's own workload config: PDF computation over the HPC4e-style
seismic cube (§6.1 datasets + §5 method settings)."""

from __future__ import annotations

import dataclasses

from repro.core.distributions import TYPES_4, TYPES_10
from repro.core.regions import CubeGeometry


@dataclasses.dataclass(frozen=True)
class PDFWorkloadConfig:
    name: str
    geometry: CubeGeometry
    num_simulations: int
    types: tuple[str, ...]
    num_bins: int = 20
    window_lines: int = 25  # the paper's tuned optimum (Fig. 8/9)
    slice_index: int = 201  # "Slice 201 because it has interesting information"
    method: str = "grouping_ml"  # the paper's winner at <=10 nodes


# Set1: 235 GB — 251 x 501 x 501, 1000 observations/point.
SET1 = PDFWorkloadConfig(
    "pdf-seismic-set1", CubeGeometry(501, 501, 251), 1000, TYPES_4
)
# Set2: 1.9 TB — 501 x 1001 x 1001, 1000 observations/point.
SET2 = PDFWorkloadConfig(
    "pdf-seismic-set2", CubeGeometry(1001, 1001, 501), 1000, TYPES_4
)
# Set3: 2.4 TB — 251 x 501 x 501, 10000 observations/point.
SET3 = PDFWorkloadConfig(
    "pdf-seismic-set3", CubeGeometry(501, 501, 251), 10000, TYPES_4
)

SET1_10TYPES = dataclasses.replace(SET1, name="pdf-seismic-set1-10t", types=TYPES_10)

CONFIG = SET1


def to_spec(cfg: PDFWorkloadConfig = CONFIG):
    """Express a paper-scale workload as a declarative ``PipelineSpec``
    (DESIGN.md §11) — ``to_spec(SET1).to_json()`` is a runnable
    ``--spec`` file for the launchers."""
    from repro.api import (ComputeSpec, ExecSpec, MethodSpec, PipelineSpec,
                           SourceSpec)

    g = cfg.geometry
    return PipelineSpec(
        source=SourceSpec(
            num_slices=g.num_slices,
            lines_per_slice=g.lines_per_slice,
            points_per_line=g.points_per_line,
            observations=cfg.num_simulations,
        ),
        method=MethodSpec(name=cfg.method, rep_bucket=256),
        compute=ComputeSpec(types=cfg.types, num_bins=cfg.num_bins,
                            window_lines=cfg.window_lines),
        execution=ExecSpec(slices=(cfg.slice_index,)),
    )
