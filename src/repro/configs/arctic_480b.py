"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual branch in parallel (Snowflake Arctic's
dense-MoE hybrid). [hf:Snowflake/snowflake-arctic-base; hf]"""

from repro.configs.base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    q_heads=56,
    kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab=32000,
    pattern=(BlockDef(mixer="attn", ffn="moe_dense"),),  # MoE + parallel dense
    num_experts=128,
    moe_top_k=2,
    rope_theta=10_000.0,
    fsdp=True,
    notes="dense-MoE hybrid residual; EP over model axis; full attention (long_500k skipped).",
)
