"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256; cross-attn image layers. [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]

100 layers = 20 repeats of (4 self-attn + 1 cross-attn); the vision tower is
a stub — input_specs() provides (batch, num_patches, d_model) patch
embeddings. FSDP on (90B dense-scale params)."""

from repro.configs.base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    q_heads=64,
    kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    pattern=(
        BlockDef(mixer="attn"),
        BlockDef(mixer="attn"),
        BlockDef(mixer="attn"),
        BlockDef(mixer="attn"),
        BlockDef(mixer="cross_attn"),
    ),
    num_patches=1601,  # 1 tile x (40x40 + 1 cls), llama-3.2 vision geometry
    rope_theta=500_000.0,
    fsdp=True,
    notes="vision frontend stubbed; full attention (long_500k skipped).",
)
