"""Architecture registry: ``--arch <id>`` lookup for launchers/tests/benches."""

from __future__ import annotations

from repro.configs import (
    arctic_480b,
    command_r_35b,
    gemma3_12b,
    granite_3_8b,
    hymba_1_5b,
    kimi_k2_1t,
    llama32_vision_90b,
    mamba2_780m,
    mistral_nemo_12b,
    seamless_m4t_medium,
)
from repro.configs.base import ArchConfig

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        granite_3_8b.CONFIG,
        gemma3_12b.CONFIG,
        command_r_35b.CONFIG,
        mistral_nemo_12b.CONFIG,
        seamless_m4t_medium.CONFIG,
        llama32_vision_90b.CONFIG,
        arctic_480b.CONFIG,
        kimi_k2_1t.CONFIG,
        mamba2_780m.CONFIG,
        hymba_1_5b.CONFIG,
    ]
}


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]


def names() -> list[str]:
    return list(ARCHS)
