"""Assigned input shapes x applicability, and ShapeDtypeStruct input specs.

Shapes (identical set for every LM arch):
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token, KV cache of seq)
  long_500k    seq 524,288 global_batch 1     -> serve_step; SSM/hybrid only

``input_specs`` returns (kwargs of ShapeDtypeStruct, matching PartitionSpec
kwargs) for the step function chosen by the shape — weak-type-correct,
shardable, zero allocation.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import sharding as sh


@dataclasses.dataclass(frozen=True)
class ShapeDef:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeDef] = {
    "train_4k": ShapeDef("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeDef("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeDef("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeDef("long_500k", "decode", 524_288, 1),
}

# Encoder-decoder prefill uses a short decoder prompt against the long
# encoder memory (the 32k is the audio-frame sequence).
ENCDEC_PROMPT = 128


def applicable(cfg: ArchConfig, shape: ShapeDef) -> tuple[bool, str]:
    """(runs?, reason-if-skip). long_500k needs sub-quadratic attention:
    only the SSM/hybrid families qualify (DESIGN.md §5)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "skip(full-attn)"
    return True, ""


def _batch_axes(mesh: Mesh):
    axes = tuple(a for a in mesh.axis_names if a != sh.MODEL_AXIS)
    return axes if len(axes) > 1 else axes[0]


def input_specs(cfg: ArchConfig, shape: ShapeDef, mesh: Mesh):
    """Returns (args: dict[str, ShapeDtypeStruct], pspecs: dict[str, P-tree]).

    Keys depend on (family, shape.kind):
      train:   tokens, targets [, memory | frames]
      prefill: tokens [, memory | frames]
      decode:  token, caches, pos [, memory]
    """
    b, s = shape.global_batch, shape.seq_len
    ba = _batch_axes(mesh)
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    tok_p = P(ba, None)
    f32 = jnp.float32

    if shape.kind == "train":
        args = {"tokens": tok, "targets": tok}
        specs = {"tokens": tok_p, "targets": tok_p}
        if cfg.family == "vlm":
            args["memory"] = jax.ShapeDtypeStruct((b, cfg.num_patches, cfg.d_model), f32)
            specs["memory"] = P(ba, None, None)
        if cfg.family == "encdec":
            args["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), f32)
            specs["frames"] = P(ba, None, None)
        return args, specs

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            args = {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
                "tokens": jax.ShapeDtypeStruct((b, ENCDEC_PROMPT), jnp.int32),
            }
            specs = {"frames": P(ba, None, None), "tokens": tok_p}
            return args, specs
        args = {"tokens": tok}
        specs = {"tokens": tok_p}
        if cfg.family == "vlm":
            args["memory"] = jax.ShapeDtypeStruct((b, cfg.num_patches, cfg.d_model), f32)
            specs["memory"] = P(ba, None, None)
        return args, specs

    if shape.kind == "decode":
        from repro.models import encdec as ED
        from repro.models import transformer as T

        cdt = cfg.compute_dtype
        dprod = 1
        for a in mesh.axis_names:
            if a != sh.MODEL_AXIS:
                dprod *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
        tok_ba = ba if b % dprod == 0 else None
        if cfg.family == "encdec":
            cache = jax.eval_shape(
                lambda: ED.init_cache(cfg, b, s, mem_len=s, dtype=cdt)
            )
        else:
            cache = jax.eval_shape(lambda: T.init_cache(cfg, b, s, dtype=cdt))
        cache_specs = sh.cache_pspecs(mesh, cache, b)
        args = {"token": jax.ShapeDtypeStruct((b,), jnp.int32), "caches": cache}
        specs = {"token": P(tok_ba), "caches": cache_specs}
        if cfg.family == "vlm":
            args["memory"] = jax.ShapeDtypeStruct((b, cfg.num_patches, cfg.d_model), f32)
            specs["memory"] = P(tok_ba, None, None)
        return args, specs

    raise ValueError(shape.kind)
