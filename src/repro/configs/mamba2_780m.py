"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060; unverified]

d_inner = 2*d_model = 3072, 48 SSD heads of dim 64, chunked scan (Q=256).
Attention-free => O(1)-state decode; long_500k runs for this arch."""

from repro.configs.base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    q_heads=0,
    kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50280,
    pattern=(BlockDef(mixer="ssm", ffn="none"),),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    notes="pure SSD stack; runs long_500k (state size independent of seq).",
)
