"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attention + mamba heads fused per block.
[arXiv:2411.13676; hf]

Attention path uses a 1024 sliding window on all scanned layers (Hymba keeps
3 global layers; we keep the scanned pattern uniform-SWA and make the first
prefix layer global, giving bounded decode caches => long_500k runs).
head_dim 64 (25 x 64 = 1600); meta-tokens are not modeled (stub note)."""

from repro.configs.base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    q_heads=25,
    kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    prefix=(BlockDef(mixer="hybrid", window=None, ffn="dense"),),  # global layer
    pattern=(BlockDef(mixer="hybrid", window=1024, ffn="dense"),),
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=50,  # d_inner 3200 = 64 heads x 50
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    notes=(
        "parallel attn+SSM heads; SWA window 1024 + SSM state => bounded "
        "decode cache; single global prefix layer is O(S) per decode step "
        "(linear, sub-quadratic) so long_500k runs."
    ),
)
