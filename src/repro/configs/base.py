"""Architecture config schema shared by the model zoo, launchers and tests.

Every assigned architecture instantiates ``ArchConfig`` (one file per arch in
this package); ``reduced()`` derives the CPU smoke-test variant. The paper's
own workload (the PDF pipeline) has its own config in pdf_seismic.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BlockDef:
    """One layer's shape inside the repeating pattern."""

    mixer: str = "attn"  # attn | ssm | hybrid | cross_attn
    window: int | None = None  # sliding-window size for attn mixers
    ffn: str = "dense"  # dense | moe | moe_dense (MoE + parallel dense) | none


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    q_heads: int
    kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # layer structure: `prefix` layers run unscanned (e.g. kimi's dense layer
    # 0), then `pattern` repeats (num_layers - len(prefix)) / len(pattern)
    # times under lax.scan.
    pattern: tuple[BlockDef, ...] = (BlockDef(),)
    prefix: tuple[BlockDef, ...] = ()

    # MoE
    num_experts: int = 0
    moe_top_k: int = 0
    moe_shared_ff: int = 0  # shared-expert FFN width (kimi-k2 style), 0 = off

    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # VLM / enc-dec
    num_patches: int = 0  # stub image-patch sequence length (frontend is a stub)
    enc_layers: int = 0
    dec_layers: int = 0

    rope_theta: float = 10_000.0
    qk_norm: bool = False
    norm_eps: float = 1e-6
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: str = "full"  # none | full | dots
    scan_unroll: int = 1  # 0 = full unroll (dry-run analysis lowering)
    fsdp: bool = False  # additionally shard big param dims over the data axis

    # -- beyond-paper optimization knobs (EXPERIMENTS.md §Perf) --------------
    block_local_attn: bool = False  # banded O(S*W) kernel for windowed layers
    moe_scan_dispatch: bool = False  # log-depth scan for MoE position assign
    pad_vocab_to_multiple: int = 0  # pad embed/lm_head so vocab shards
    gqa_repeat_kv: bool = False  # repeat KV to q_heads (full head sharding)
    adam_moments_bf16: bool = False  # halve optimizer HBM
    use_adafactor: bool = False  # factored second moment (kimi memory)
    flash_decode: bool = False  # shard_map partial-KV decode attention
    sequence_parallel: bool = False  # shard seq dim of activations over model
    notes: str = ""

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_to_multiple
        if m <= 0:
            return self.vocab
        return -(-self.vocab // m) * m

    @property
    def num_repeats(self) -> int:
        body = self.num_layers - len(self.prefix)
        if body % len(self.pattern):
            raise ValueError(
                f"{self.name}: {body} body layers not divisible by pattern "
                f"of {len(self.pattern)}"
            )
        return body // len(self.pattern)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/pattern, tiny dims."""
        kv = min(self.kv_heads, 2)
        q = max(kv * 2, 4) if self.q_heads else 0
        pat_len = len(self.pattern)
        return self.replace(
            num_layers=len(self.prefix) + 2 * pat_len,
            d_model=64,
            q_heads=q,
            kv_heads=kv,
            head_dim=16,
            d_ff=128,
            vocab=512,
            num_experts=min(self.num_experts, 8) if self.num_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            moe_shared_ff=64 if self.moe_shared_ff else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8 if self.ssm_state else 256,
            num_patches=16 if self.num_patches else 0,
            enc_layers=2 if self.enc_layers else 0,
            dec_layers=2 if self.dec_layers else 0,
            param_dtype=jnp.float32,
            compute_dtype=jnp.float32,
            remat="none",
        )
