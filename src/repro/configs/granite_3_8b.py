"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155. [hf:ibm-granite/granite-3.0-2b-base; hf]"""

from repro.configs.base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    q_heads=32,
    kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab=49155,
    pattern=(BlockDef(mixer="attn", ffn="dense"),),
    rope_theta=10_000.0,
    notes="GQA dense decoder; full attention (long_500k skipped).",
)
