"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144; 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

head_dim is 256 (gemma3 family uses wider heads than d_model/q_heads);
qk-norm on; sliding window 1024 on the 5 local layers of each 6-layer
pattern. The 1-in-6 global layers are full attention, so long_500k is
skipped per the spec (needs sub-quadratic attention throughout)."""

from repro.configs.base import ArchConfig, BlockDef

_W = 1024  # local sliding window

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    q_heads=16,
    kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    pattern=(
        BlockDef(mixer="attn", window=_W),
        BlockDef(mixer="attn", window=_W),
        BlockDef(mixer="attn", window=_W),
        BlockDef(mixer="attn", window=_W),
        BlockDef(mixer="attn", window=_W),
        BlockDef(mixer="attn", window=None),  # global layer
    ),
    qk_norm=True,
    rope_theta=1_000_000.0,
    notes="5:1 local:global; global layers are full attention -> long_500k skipped.",
)
