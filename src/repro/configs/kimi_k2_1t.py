"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per
expert) vocab=163840, MoE 384 experts top-8, first layer dense, one shared
expert (DeepSeek-V3-lineage design). [arXiv:2501.kimi2; unverified]

~1T total params, ~32B active. FSDP + EP; memory iterations for this config
are the §Perf kimi hillclimb (bf16 params + Adafactor vs f32 + Adam)."""

from repro.configs.base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    q_heads=64,
    kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab=163840,
    prefix=(BlockDef(mixer="attn", ffn="dense"),),  # layer 0 dense
    pattern=(BlockDef(mixer="attn", ffn="moe"),),
    num_experts=384,
    moe_top_k=8,
    moe_shared_ff=2048,  # one shared expert
    rope_theta=50_000.0,
    fsdp=True,
    notes=(
        "trillion-param MoE; first layer dense + shared expert. Dense layer-0 "
        "d_ff uses the expert width x top_k scale via the dense prefix block "
        "(see registry note). Full attention (long_500k skipped)."
    ),
)
