"""mistral-nemo-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072; 128k ctx. [hf:mistralai/Mistral-Nemo-Base-2407; hf]"""

from repro.configs.base import ArchConfig, BlockDef

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    q_heads=32,
    kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    pattern=(BlockDef(mixer="attn", ffn="dense"),),
    rope_theta=1_000_000.0,
    notes="GQA dense, 128k-ctx rope base; full attention (long_500k skipped).",
)
