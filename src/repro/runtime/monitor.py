"""Step/window heartbeat monitoring + straggler policy.

XLA steps are SPMD-synchronous, so intra-step straggler mitigation happens at
the *work-unit* level (a window of the PDF pipeline, a data shard, a
checkpoint write): the host records a heartbeat per unit, and units that
exceed ``k x median`` of the trailing distribution are flagged for
re-dispatch (the PDF pipeline's windows are idempotent — re-running one is
safe, results overwrite byte-identically because data loading is
deterministic).

On a real cluster the same monitor ingests per-host heartbeats; here it is
driven by the single-process loops and unit-tested with synthetic timings.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

# Trailing-latency reservoir per monitor: enough samples for stable p99 at
# serving rates while bounding memory on long-lived daemons (a PDFServer's
# request monitor outlives any single run).
HISTORY_LIMIT = 8192


def percentiles(durations, qs=(0.5, 0.99)) -> dict[str, float]:
    """``{"p50": ..., "p99": ...}`` over a duration sample (nearest-rank on
    the sorted sample; empty input -> zeros). Shared by ``SessionReport``
    and the serve layer's stats so every latency surface quotes the same
    estimator."""
    s = sorted(durations)
    if not s:
        return {f"p{int(q * 100)}": 0.0 for q in qs}
    return {
        f"p{int(q * 100)}": s[min(len(s) - 1, int(q * (len(s) - 1) + 0.5))]
        for q in qs
    }


@dataclass(frozen=True)
class StragglerPolicy:
    window: int = 32  # trailing sample count for the median
    threshold: float = 3.0  # flag units slower than threshold x median
    min_samples: int = 5
    grace_seconds: float = 1.0  # never flag below this absolute duration


@dataclass
class StepMonitor:
    policy: StragglerPolicy = field(default_factory=StragglerPolicy)

    def __post_init__(self):
        self._durations: deque[float] = deque(maxlen=self.policy.window)
        # Separate, larger reservoir for percentile reporting: the straggler
        # median deliberately tracks only the trailing `policy.window` units,
        # but p50/p99 need the run's full distribution (bounded).
        self._history: deque[float] = deque(maxlen=HISTORY_LIMIT)
        self._inflight: dict[str, float] = {}
        self.flagged: list[str] = []
        self.completed: int = 0

    # -- heartbeat API --------------------------------------------------------

    def start(self, unit_id: str, now: float | None = None):
        self._inflight[unit_id] = now if now is not None else time.monotonic()

    def finish(self, unit_id: str, now: float | None = None) -> float:
        now = now if now is not None else time.monotonic()
        dur = now - self._inflight.pop(unit_id)
        self._durations.append(dur)
        self._history.append(dur)
        self.completed += 1
        return dur

    def abandon(self, unit_id: str) -> None:
        """Drop an inflight unit without recording a duration — for failed
        or superseded attempts (a retry, a losing speculative launch). The
        duration of an attempt that *didn't complete* must not enter the
        straggler median: an injected 10s stall recorded as a sample would
        triple the re-dispatch limit for every unit after it."""
        self._inflight.pop(unit_id, None)

    @property
    def history(self) -> tuple[float, ...]:
        """Completed-unit durations (trailing ``HISTORY_LIMIT``), oldest
        first — the percentile reservoir."""
        return tuple(self._history)

    def percentiles(self, qs=(0.5, 0.99)) -> dict[str, float]:
        """p50/p99 (by default) over every completed unit this monitor has
        seen — the per-stage latency surface of ``SessionReport`` and the
        serve-layer stats."""
        return percentiles(self._history, qs)

    def median(self) -> float | None:
        if len(self._durations) < self.policy.min_samples:
            return None
        s = sorted(self._durations)
        return s[len(s) // 2]

    def check_stragglers(self, now: float | None = None) -> list[str]:
        """Inflight units exceeding threshold x median -> flagged for
        re-dispatch. Idempotent units may simply be re-run."""
        now = now if now is not None else time.monotonic()
        med = self.median()
        if med is None:
            return []
        limit = max(self.policy.threshold * med, self.policy.grace_seconds)
        out = [u for u, t0 in self._inflight.items() if now - t0 > limit]
        for u in out:
            if u not in self.flagged:
                self.flagged.append(u)
        return out
