from repro.runtime.monitor import StepMonitor, StragglerPolicy
from repro.runtime.elastic import ElasticPlan, plan_remesh

__all__ = ["StepMonitor", "StragglerPolicy", "ElasticPlan", "plan_remesh"]
