from repro.runtime.monitor import StepMonitor, StragglerPolicy
from repro.runtime.elastic import ElasticPlan, plan_remesh
from repro.runtime.scheduler import (
    ShardAssignment,
    SliceScheduler,
    assign_slices,
    mesh_num_shards,
)

__all__ = [
    "StepMonitor", "StragglerPolicy", "ElasticPlan", "plan_remesh",
    "ShardAssignment", "SliceScheduler", "assign_slices", "mesh_num_shards",
]
