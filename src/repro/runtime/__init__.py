from repro.runtime.monitor import StepMonitor, StragglerPolicy, percentiles
from repro.runtime.elastic import ElasticPlan, plan_remesh
from repro.runtime.scheduler import (
    ShardAssignment,
    SliceScheduler,
    assign_slices,
    mesh_num_shards,
)

__all__ = [
    "StepMonitor", "StragglerPolicy", "percentiles", "ElasticPlan",
    "plan_remesh", "ShardAssignment", "SliceScheduler", "assign_slices",
    "mesh_num_shards",
]
