"""Slice-level scheduling across the mesh data axis (paper §4/§6).

The paper assigns each Spark worker *whole slices* — windows of one slice
stay on one node so the reuse cache and the resume watermark remain local.
``assign_slices`` reproduces that: slices are dealt round-robin over the
shards of the mesh data axis (balanced to within one slice), and each shard
runs its own ``regions.Plan`` through a ``core.executor.StagedExecutor``.

In this single-process repo the shards execute in turn (or a single
``shard`` — "this node's" assignment — runs alone, which is what
``launch/run_pdf.py`` does per process); per-shard wall clocks and
per-window durations feed ``StepMonitor`` instances so straggler flagging
(runtime/monitor.py) works at both granularities.

This module deliberately does not import the executor: any object with
``data.geometry``, ``config.window_lines`` and ``run(plan, resume=...,
on_window=...)`` schedules fine, which also keeps the import graph acyclic
(core.executor already depends on runtime.monitor).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Mapping, Sequence

from repro.core import regions
from repro.runtime import elastic
from repro.runtime.faults import ShardLostError
from repro.runtime.monitor import StepMonitor, StragglerPolicy


@dataclass(frozen=True)
class ShardAssignment:
    shard: int
    slices: tuple[int, ...]


def assign_slices(slices: Sequence[int], num_shards: int) -> tuple[ShardAssignment, ...]:
    """Deal ``slices`` round-robin over ``num_shards`` (balanced within 1;
    preserves the given slice order within each shard)."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    return tuple(
        ShardAssignment(i, tuple(slices[i::num_shards])) for i in range(num_shards)
    )


def mesh_num_shards(mesh, axis: str = "data") -> int:
    """Shard count = size of the mesh's data axis (per-node slice assignment
    maps onto the axis the loader already shards points over)."""
    return int(mesh.shape[axis])


class SliceScheduler:
    """Runs per-shard slice plans and monitors them.

    ``num_shards`` may be given directly or derived from a mesh's data
    axis. ``shard_monitor`` times whole shard runs with the real clock (so
    ``check_stragglers`` can flag a hung shard from another thread);
    ``window_monitor`` accumulates per-window durations reported by the
    executors (medians across shards — the trailing distribution that
    re-dispatch decisions use).
    """

    def __init__(
        self,
        num_shards: int | None = None,
        mesh=None,
        axis: str = "data",
        policy: StragglerPolicy | None = None,
    ):
        if num_shards is None:
            if mesh is None:
                raise ValueError("pass num_shards or a mesh")
            num_shards = mesh_num_shards(mesh, axis)
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.num_shards = num_shards
        self.shard_monitor = StepMonitor(policy or StragglerPolicy())
        self.window_monitor = StepMonitor(policy or StragglerPolicy())
        self.last_reports: dict[int, object] = {}
        self.lost_shards: tuple[int, ...] = ()
        self.last_redeal: elastic.RedealPlan | None = None

    def assignments(self, slices: Sequence[int]) -> tuple[ShardAssignment, ...]:
        return assign_slices(slices, self.num_shards)

    def plan_for(
        self, geom: regions.CubeGeometry, slices: Sequence[int],
        window_lines: int, shard: int,
    ) -> regions.Plan:
        a = self.assignments(slices)[shard]
        return regions.build_plan(geom, a.slices, window_lines)

    def run(
        self,
        executor_factory: Callable[[int], object],
        slices: Sequence[int],
        window_lines: int | None = None,
        shard: int | None = None,
        resume: bool = False,
        on_window: Callable | None = None,
        joined: Sequence[int] = (),
    ) -> Mapping[int, object]:
        """Execute the assignment; returns {slice -> SliceResult} merged
        over the shards that ran.

        ``executor_factory(shard)`` builds (or returns) the executor for one
        shard — on a cluster that is the per-node construction site; here it
        usually returns executors over the same data source. ``shard``
        restricts execution to one shard ("this node").

        Shard loss (``ShardLostError`` escaping an executor run) is
        survivable when other shards ran: the dead shard's *unfinished*
        slices are re-dealt over the healthy shards via
        ``elastic.plan_redeal`` and run there (with ``resume=True``, so
        windows the dead shard already persisted are skipped). One level
        only — a shard dying during its re-dealt work propagates.
        ``joined`` names shards outside the original deal that may take
        redealt slices (grown capacity — executors for them come from the
        same factory).
        """
        results: dict[int, object] = {}
        self.last_reports = {}
        self.last_redeal = None
        lost: list[int] = []
        pending: list[int] = []  # slices stranded on dead shards, in order
        healthy: list[int] = []
        for a in self.assignments(slices):
            if shard is not None and a.shard != shard:
                continue
            if not a.slices:
                healthy.append(a.shard)
                continue
            try:
                results.update(self._run_shard(
                    executor_factory, a.shard, a.slices, window_lines,
                    resume, on_window,
                ))
                healthy.append(a.shard)
            except ShardLostError:
                lost.append(a.shard)
                pending.extend(s for s in a.slices if s not in results)
        if lost:
            self.lost_shards = tuple(lost)
            plan = elastic.plan_redeal(pending, healthy, lost, joined=joined)
            self.last_redeal = plan
            for h in plan.healthy_shards:
                redealt = plan.slices_for(h)
                if redealt:
                    # resume=True: skip whatever the dead shard persisted
                    # before dying (the watermark is the recovery line).
                    results.update(self._run_shard(
                        executor_factory, h, redealt, window_lines,
                        True, on_window,
                    ))
        return results

    def _run_shard(
        self,
        executor_factory: Callable[[int], object],
        shard: int,
        shard_slices: Sequence[int],
        window_lines: int | None,
        resume: bool,
        on_window: Callable | None,
    ) -> Mapping[int, object]:
        ex = executor_factory(shard)
        wl = window_lines if window_lines is not None else ex.config.window_lines
        plan = regions.build_plan(ex.data.geometry, shard_slices, wl)

        def hook(ws):
            uid = f"s{ws.window.slice_i}/l{ws.window.line_start:05d}"
            self.window_monitor.start(uid, now=0.0)
            self.window_monitor.finish(
                uid, now=ws.load_seconds + ws.compute_seconds
            )
            if on_window:
                on_window(ws)

        sid = f"shard{shard}"
        self.shard_monitor.start(sid)
        try:
            out = ex.run(plan, resume=resume, on_window=hook)
        finally:
            self.shard_monitor.finish(sid)
        self.last_reports[shard] = getattr(ex, "last_report", None)
        return out
