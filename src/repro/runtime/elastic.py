"""Elastic plans: respond to node/shard loss by re-planning the work.

Two granularities live here. ``plan_remesh`` is the training-style contract
at 1000+ nodes: a failure shrinks the healthy device set; pick the largest
(data', model') grid that fits it, preserve model-axis divisibility, keep
the global batch via grad-accumulation, and let CheckpointManager.restore
re-layout.

``plan_redeal`` is the PDF pipeline's batch form of the same thing
(DESIGN.md §14): slices are dealt round-robin over shards
(``scheduler.assign_slices``), and whole slices are the unit of locality —
so when a shard dies mid-run (``faults.ShardLostError``), its *unfinished
slices* are simply re-dealt round-robin over the surviving shards. Safe by
the same argument as retry/speculation: slices are independently
recomputable, the watermark/resume machinery skips whatever the dead shard
already persisted, and re-running a window yields bitwise-identical bytes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence


@dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    pods: int
    grad_accum: int  # multiplier to preserve global batch

    @property
    def devices(self) -> int:
        return self.data * self.model * self.pods


def plan_remesh(
    healthy_devices: int,
    model_divisors: tuple[int, ...],
    target_global_batch: int,
    old_plan: ElasticPlan,
) -> ElasticPlan:
    """Choose the best mesh for the healthy device count.

    ``model_divisors``: acceptable model-axis sizes for the architecture
    (e.g. (16, 8, 4) — d_ff/head divisibility). Prefers the largest total
    device usage, then the largest model axis (keeps per-device memory low).
    """
    best: ElasticPlan | None = None
    for m in sorted(model_divisors, reverse=True):
        if m > healthy_devices:
            continue
        d = healthy_devices // m
        used = d * m
        accum_scale = max(
            1, (old_plan.data * old_plan.pods * old_plan.grad_accum + d - 1) // d
        )
        cand = ElasticPlan(data=d, model=m, pods=1, grad_accum=accum_scale)
        if best is None or cand.devices > best.devices or (
            cand.devices == best.devices and cand.model > best.model
        ):
            best = cand
    if best is None:
        raise ValueError(f"no viable mesh for {healthy_devices} devices")
    return best


@dataclass(frozen=True)
class RedealPlan:
    """Recovery plan for lost shards: which slices move where."""

    lost_shards: tuple[int, ...]
    healthy_shards: tuple[int, ...]
    # slice -> healthy shard that takes it over, round-robin in slice order.
    assignments: tuple[tuple[int, int], ...]

    def slices_for(self, shard: int) -> tuple[int, ...]:
        return tuple(s for s, sh in self.assignments if sh == shard)


def plan_redeal(
    pending_slices: Sequence[int],
    healthy_shards: Sequence[int],
    lost_shards: Sequence[int] = (),
    joined: Sequence[int] = (),
) -> RedealPlan:
    """Re-deal a dead shard's unfinished slices over the healthy shards.

    Round-robin in the given slice order, mirroring ``assign_slices`` — the
    re-deal stays balanced to within one slice. ``joined`` adds shards that
    were NOT part of the original deal (grown capacity: an idle shard of a
    widened mesh, or a cluster join-only worker) — they take redealt slices
    exactly like survivors, which is the grow half of elastic execution.
    Raises when no shard (healthy or joined) remains: with every worker
    dead and nobody joining there is no degraded mode, the run must fail
    loudly."""
    healthy = tuple(dict.fromkeys([*healthy_shards, *joined]))
    if not healthy:
        raise ValueError(
            f"cannot re-deal slices {tuple(pending_slices)}: no healthy "
            f"shards remain (lost: {tuple(lost_shards)})")
    assignments = tuple(
        (s, healthy[i % len(healthy)]) for i, s in enumerate(pending_slices)
    )
    return RedealPlan(
        lost_shards=tuple(lost_shards),
        healthy_shards=healthy,
        assignments=assignments,
    )
