"""Elastic re-meshing plans: respond to node loss / scale-up by choosing a
new mesh shape and re-sharding from the last checkpoint.

The contract at 1000+ nodes: a failure shrinks the healthy device set; we
pick the largest (data', model') grid that (a) fits the healthy count,
(b) preserves the model-axis divisibility the arch needs, and (c) keeps the
global batch by raising grad-accumulation. CheckpointManager.restore with
the new mesh's shardings performs the actual re-layout (device_put handles
arbitrary source->target resharding).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    pods: int
    grad_accum: int  # multiplier to preserve global batch

    @property
    def devices(self) -> int:
        return self.data * self.model * self.pods


def plan_remesh(
    healthy_devices: int,
    model_divisors: tuple[int, ...],
    target_global_batch: int,
    old_plan: ElasticPlan,
) -> ElasticPlan:
    """Choose the best mesh for the healthy device count.

    ``model_divisors``: acceptable model-axis sizes for the architecture
    (e.g. (16, 8, 4) — d_ff/head divisibility). Prefers the largest total
    device usage, then the largest model axis (keeps per-device memory low).
    """
    best: ElasticPlan | None = None
    for m in sorted(model_divisors, reverse=True):
        if m > healthy_devices:
            continue
        d = healthy_devices // m
        used = d * m
        accum_scale = max(
            1, (old_plan.data * old_plan.pods * old_plan.grad_accum + d - 1) // d
        )
        cand = ElasticPlan(data=d, model=m, pods=1, grad_accum=accum_scale)
        if best is None or cand.devices > best.devices or (
            cand.devices == best.devices and cand.model > best.model
        ):
            best = cand
    if best is None:
        raise ValueError(f"no viable mesh for {healthy_devices} devices")
    return best
