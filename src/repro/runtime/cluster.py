"""Multi-process cluster execution + cold-start elimination (DESIGN.md §17).

The paper's weak-scaling runs put independent Spark workers on separate
nodes, each computing whole slices of the cube against shared storage. This
module is that topology for the JAX pipeline: N ``launch/run_pdf``
processes, each pinned to one shard of the round-robin slice deal
(``scheduler.assign_slices``), optionally joined into one
``jax.distributed`` world, all persisting to a shared ``out_dir``. There
are **no cross-process collectives** — slices are independently
recomputable partitions (the Random Sample Partition model), so bitwise
identity with the single-process run follows from the staged executor's
per-slice equivalence contract, and process failure is survivable by
construction.

Three seams live here:

* **Placement** (``ExecSpec.placement``): ``apply_placement`` pins the
  process to its shard; ``device_placement`` maps a shard to a local device
  (``SingleDeviceSharding`` through ``StagedExecutor``'s ``sharding=``
  seam); ``init_distributed`` joins the ``jax.distributed`` world.
* **Elasticity** (shrink *and* grow): every worker writes ``alive`` →
  ``done``/``lost`` marker files under ``out_dir/cluster``. Survivors wait
  for every original shard's terminal marker, then re-deal the incomplete
  slices of lost shards over the *done* set (``elastic.plan_redeal``) —
  deterministic across survivors because the healthy set is exactly the
  original shards with ``done`` markers. A join-only worker
  (``process_id >= num_processes``) adds itself via ``plan_redeal``'s
  ``joined`` parameter: it duplicates at worst (identical bytes), and when
  every original shard died it completes the run alone.
* **Cold start**: ``enable_compilation_cache`` keys the persistent XLA
  compilation cache under ``<compile_cache_dir>/<spec_hash>``, so a
  re-launched identical spec serves every executable from disk;
  ``compile_counters`` snapshots the process-wide trace/compile/cache
  event counts (``jax.monitoring``) that ``SessionReport`` exposes so
  "zero new compilations" is assertable. A corrupt cache entry is a warned
  miss (JAX recompiles), never a crash.

``python -m repro.runtime.cluster --compare REF OUT`` verifies two persisted
output directories bitwise — the invariant line CI's distributed-smoke job
greps for.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from pathlib import Path
from typing import Callable, Iterator

from repro.runtime import elastic
from repro.runtime.faults import ShardLostError, shard_lost_from
from repro.runtime.scheduler import assign_slices

# -- compile/trace counters (cold-start visibility) ----------------------------

_COUNTS = {
    "traces": 0,
    "compiles": 0,
    "persistent_cache_hits": 0,
    "persistent_cache_misses": 0,
}
_COUNTS_LOCK = threading.Lock()
_LISTENERS_INSTALLED = False

_EVENT_KEYS = {
    "/jax/compilation_cache/cache_hits": "persistent_cache_hits",
    "/jax/compilation_cache/cache_misses": "persistent_cache_misses",
}
_DURATION_KEYS = {
    "/jax/core/compile/backend_compile_duration": "compiles",
    "/jax/core/compile/jaxpr_trace_duration": "traces",
}


def _on_event(event: str, **kw) -> None:
    key = _EVENT_KEYS.get(event)
    if key is not None:
        with _COUNTS_LOCK:
            _COUNTS[key] += 1


def _on_duration(event: str, duration: float, **kw) -> None:
    key = _DURATION_KEYS.get(event)
    if key is not None:
        with _COUNTS_LOCK:
            _COUNTS[key] += 1


def install_compile_listeners() -> None:
    """Register the ``jax.monitoring`` listeners feeding ``compile_counters``
    (once per process; listeners cannot be unregistered, so the counters are
    process-wide monotonic)."""
    global _LISTENERS_INSTALLED
    if _LISTENERS_INSTALLED:
        return
    from jax._src import monitoring

    monitoring.register_event_listener(_on_event)
    monitoring.register_event_duration_secs_listener(_on_duration)
    _LISTENERS_INSTALLED = True


def compile_counters() -> dict[str, int]:
    """Snapshot of process-wide XLA activity since the listeners went in:
    ``traces`` (jaxpr traces), ``compiles`` (backend compile calls — these
    fire on persistent-cache hits too, XLA still invokes the compiler entry
    point), and the persistent compilation cache's hit/miss counts. The
    cold-start indicator is ``persistent_cache_misses == 0``: with the
    cache enabled, a miss is exactly "an executable that had to be built
    fresh". ``PDFSession`` snapshots at construction and reports the delta."""
    install_compile_listeners()
    with _COUNTS_LOCK:
        return dict(_COUNTS)


def counters_delta(baseline: dict[str, int]) -> dict[str, int]:
    now = compile_counters()
    return {k: now[k] - baseline.get(k, 0) for k in now}


# -- persistent compilation cache ----------------------------------------------


def enable_compilation_cache(base_dir: str | Path, spec_hash: str) -> Path:
    """Point JAX's persistent compilation cache at ``<base_dir>/<spec_hash>``
    — keyed next to the spec hash so the cache directory carries the same
    provenance as every other artifact, and a spec change never pollutes or
    reuses another spec's entries. Thresholds are dropped to cache
    everything (the pipeline's executables are small and re-launch cost is
    the point). Safe to call repeatedly; switching directories resets JAX's
    in-memory cache handle."""
    import jax

    path = Path(base_dir) / spec_hash
    path.mkdir(parents=True, exist_ok=True)
    previous = getattr(jax.config, "jax_compilation_cache_dir", None)
    jax.config.update("jax_compilation_cache_dir", str(path))
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    if previous and previous != str(path):
        try:
            from jax._src import compilation_cache

            compilation_cache.reset_cache()
        except (ImportError, AttributeError):  # cache handle resets lazily
            pass
    return path


# -- placement -----------------------------------------------------------------


def apply_placement(spec):
    """Pin a spec to this process's seat in the cluster: with
    ``placement.num_processes > 1``, ``execution.shards`` becomes the
    process count and ``execution.shard`` this process's id — the same
    per-node single-shard mode ``run_pdf --shard`` always offered, now
    derived from the placement section. Join-only workers
    (``process_id >= num_processes``) get no shard pin (they run nothing
    until redeal). Single-process specs pass through unchanged."""
    pl = spec.execution.placement
    if pl.num_processes <= 1 and pl.process_id is None:
        return spec
    if pl.num_processes > 1 and pl.process_id is None:
        raise ValueError(
            "placement.num_processes > 1 requires placement.process_id: "
            "each worker process must know its seat (launch/cluster.sh "
            "passes --process-id per process)")
    if spec.execution.shards not in (1, pl.num_processes):
        raise ValueError(
            f"execution.shards={spec.execution.shards} conflicts with "
            f"placement.num_processes={pl.num_processes} — leave shards "
            "unset in cluster mode (the placement section owns the deal)")
    shard = pl.process_id if pl.process_id < pl.num_processes else None
    return dataclasses.replace(spec, execution=dataclasses.replace(
        spec.execution, shards=pl.num_processes, shard=shard))


_DISTRIBUTED = {"initialized": False}


def init_distributed(placement) -> bool:
    """Join the ``jax.distributed`` world this placement describes
    (idempotent). Returns True when this process holds a seat — join-only
    workers and single-process runs return False (the world size is fixed
    at initialization, which is exactly why growth goes through the marker
    protocol instead)."""
    if placement.num_processes <= 1 or not placement.distributed:
        return False
    pid = placement.process_id
    if pid is None or pid >= placement.num_processes:
        return False
    if _DISTRIBUTED["initialized"]:
        return True
    import jax

    jax.distributed.initialize(
        coordinator_address=placement.coordinator,
        num_processes=placement.num_processes,
        process_id=pid,
    )
    _DISTRIBUTED["initialized"] = True
    return True


def device_placement(placement, shard: int):
    """The ``jax.sharding.Sharding`` a shard's executor stages onto, or
    None for the backend default. ``shard_devices`` indexes
    ``jax.local_devices()`` round-robin — the per-shard device placement
    seam (``StagedExecutor(sharding=...)``); single-device staging keeps
    results bitwise-identical on any placement."""
    if placement is None or placement.shard_devices is None:
        return None
    import jax

    devices = jax.local_devices()
    idx = placement.shard_devices[shard % len(placement.shard_devices)]
    if idx >= len(devices):
        raise ValueError(
            f"placement.shard_devices asks for local device {idx} but only "
            f"{len(devices)} local device(s) exist")
    return jax.sharding.SingleDeviceSharding(devices[idx])


# -- the marker protocol -------------------------------------------------------

MARKER_DIRNAME = "cluster"
_POLL_S = 0.05


def _marker_dir(out_dir: str | Path) -> Path:
    return Path(out_dir) / MARKER_DIRNAME


def marker_path(out_dir: str | Path, shard: int, state: str) -> Path:
    return _marker_dir(out_dir) / f"shard{shard}.{state}"


def write_marker(out_dir: str | Path, shard: int, state: str,
                 payload: dict | None = None) -> None:
    """Atomically publish a worker state file (tmp + rename, so a peer never
    reads a torn marker)."""
    d = _marker_dir(out_dir)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f".shard{shard}.{state}.tmp"
    tmp.write_text(json.dumps({"shard": shard, "pid": os.getpid(),
                               **(payload or {})}))
    tmp.replace(marker_path(out_dir, shard, state))


def wait_for_peers(out_dir: str | Path, placement,
                   my_shard: int) -> tuple[list[int], list[int]]:
    """Block until every original shard has a terminal (done/lost) marker,
    up to ``peer_timeout_s`` — silent peers past the deadline are treated
    as lost. Returns ``(done, lost)`` sorted; ``done`` includes this worker
    when it holds an original seat. Because every survivor waits for the
    same terminal set, all survivors compute the same redeal plan."""
    deadline = time.monotonic() + placement.peer_timeout_s
    peers = [s for s in range(placement.num_processes) if s != my_shard]
    done = {my_shard} if my_shard < placement.num_processes else set()
    lost: set[int] = set()
    while True:
        for s in peers:
            if s in done or s in lost:
                continue
            if marker_path(out_dir, s, "done").exists():
                done.add(s)
            elif marker_path(out_dir, s, "lost").exists():
                lost.add(s)
        if len(done) + len(lost) >= placement.num_processes:
            break
        if time.monotonic() > deadline:
            lost.update(s for s in peers if s not in done)
            break
        time.sleep(_POLL_S)
    return sorted(done), sorted(lost)


def slice_complete(out_dir: str | Path, slice_i: int, lines_per_slice: int,
                   spec_hash: str | None) -> bool:
    """Whether a slice's persisted watermark says it finished under this
    spec — the recovery line the redeal scan uses to compute a dead shard's
    *unfinished* slices. Prefers the watermark's explicit ``complete`` stamp
    (PersistStage writes one when it knows the slice's line count), falling
    back to the line-count comparison for watermarks from older runs."""
    f = Path(out_dir) / f"slice{slice_i}_watermark.json"
    if not f.exists():
        return False
    try:
        info = json.loads(f.read_text())
    except (OSError, ValueError):
        return False  # torn mid-write: treat as incomplete, recompute
    stored = info.get("spec_hash")
    if stored and spec_hash and stored != spec_hash:
        return False
    if "complete" in info:
        return bool(info["complete"])
    return int(info.get("next_line", 0)) >= lines_per_slice


# -- the worker loop -----------------------------------------------------------


def run_worker(session, on_window: Callable | None = None,
               log: Callable[[str], None] | None = None) -> Iterator:
    """One cluster worker's whole life, as a ``SliceResult`` generator:
    run this process's dealt slices, publish the terminal marker, then (with
    ``placement.redeal``) wait for peers and pick up this worker's share of
    any dead peer's unfinished slices (``resume=True`` — windows the dead
    worker persisted are skipped, recomputed windows are bitwise-identical).
    A worker whose own shard dies (``ShardLostError``) publishes ``lost``
    and stops — its recovery belongs to the survivors. Join-only workers
    skip the initial run and enter directly at the redeal step via
    ``plan_redeal(joined=...)``."""
    spec = session.spec
    pl = spec.execution.placement
    out_dir = spec.execution.out_dir
    if out_dir is None:
        raise ValueError("cluster workers require execution.out_dir")
    emit = log if log is not None else (lambda s: None)
    my = pl.process_id if pl.process_id is not None else (
        spec.execution.shard or 0)
    joiner = my >= pl.num_processes
    write_marker(out_dir, my, "alive", {"join": joiner})
    try:
        if not joiner:
            yield from session.run(on_window=on_window)
    except Exception as e:
        if shard_lost_from(e) is None:
            write_marker(out_dir, my, "lost", {"error": repr(e)})
            raise
        write_marker(out_dir, my, "lost", {"injected": True})
        emit(f"[cluster] shard {my} lost mid-run — survivors will redeal")
        return
    write_marker(out_dir, my, "done", {})
    if not pl.redeal or pl.num_processes <= 1:
        return
    done, lost = wait_for_peers(out_dir, pl, my)
    if not lost:
        return
    resolved = session.resolve_slices(None)
    assignment = {a.shard: a.slices
                  for a in assign_slices(resolved, pl.num_processes)}
    lines = session.geometry.lines_per_slice
    pending = [s for sh in lost for s in assignment.get(sh, ())
               if not slice_complete(out_dir, s, lines, session.spec_hash)]
    if not pending:
        return
    session.shards_lost = tuple(lost)
    plan = elastic.plan_redeal(pending, done, lost,
                               joined=(my,) if joiner else ())
    mine = plan.slices_for(my)
    if not mine:
        return
    emit(f"[cluster] shard {my} redealing slices {list(mine)} from lost "
         f"shard(s) {lost}")
    yield from session.run_local(mine, shard=my, resume=True,
                                 on_window=on_window)


# -- bitwise output verification (the distributed-smoke invariant) -------------


def verify_outputs(ref_dir: str | Path, out_dir: str | Path) -> tuple[int, int]:
    """Assert two persisted output directories hold bitwise-identical window
    results. Compares the full ``slice*_window_*.npz`` sets — same file
    names, same array keys, ``np.array_equal`` on every array (the files'
    raw zip bytes differ by timestamps; the *arrays* are the contract).
    Returns ``(windows, arrays)`` compared; raises ``AssertionError`` on
    any divergence."""
    import numpy as np

    ref_dir, out_dir = Path(ref_dir), Path(out_dir)
    ref_files = sorted(p.name for p in ref_dir.glob("slice*_window_*.npz"))
    out_files = sorted(p.name for p in out_dir.glob("slice*_window_*.npz"))
    if not ref_files:
        raise AssertionError(f"no persisted windows under {ref_dir}")
    if ref_files != out_files:
        raise AssertionError(
            f"window sets differ: only-ref={sorted(set(ref_files) - set(out_files))} "
            f"only-out={sorted(set(out_files) - set(ref_files))}")
    arrays = 0
    for name in ref_files:
        with np.load(ref_dir / name, allow_pickle=False) as a, \
                np.load(out_dir / name, allow_pickle=False) as b:
            if sorted(a.files) != sorted(b.files):
                raise AssertionError(
                    f"{name}: array keys differ ({sorted(a.files)} vs "
                    f"{sorted(b.files)})")
            for k in a.files:
                if not np.array_equal(a[k], b[k]):
                    raise AssertionError(
                        f"{name}[{k}]: arrays differ (not bitwise-identical)")
                arrays += 1
    return len(ref_files), arrays


def main(argv: list[str] | None = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.runtime.cluster",
        description="cluster tooling: bitwise output verification")
    ap.add_argument("--compare", nargs=2, metavar=("REF", "OUT"),
                    help="assert two persisted out_dirs are bitwise-identical")
    args = ap.parse_args(argv)
    if not args.compare:
        ap.error("nothing to do — pass --compare REF OUT")
    windows, arrays = verify_outputs(*args.compare)
    print(f"[cluster] bitwise-identical windows={windows} arrays={arrays}")


if __name__ == "__main__":
    main()
