"""Deterministic fault injection + the pipeline's error taxonomy (DESIGN.md §14).

Spark's defining runtime property — lineage-based task re-execution on
worker failure, speculative re-launch of stragglers — only matters if it
can be *exercised*. This module is the chaos layer that exercises it: a
seeded, schedulable ``FaultPlan`` whose ``FaultInjector`` wraps the window
source, the persist stage, and the result-cache IO to deterministically
inject

  * transient read errors  (``kind='read_error'`` — an NFS hiccup),
  * latency spikes         (``kind='latency'`` — a straggling read),
  * corrupt chunk bytes    (``kind='corrupt'`` — torn/partial file reads,
                            detectable through the cube manifest's
                            per-chunk sha256),
  * shard "death"          (``kind='shard_death'`` — a worker lost mid-run,
                            the batch form the scheduler re-deals), and
  * persist / cache errors (``kind='persist_error'`` / ``'cache_error'``).

Every decision is a pure function of ``(plan.seed, rule, target, attempt)``
— never of thread timing or call order — so a chaos run is reproducible,
and the retry/speculation machinery it drives can be held to the layer's
one invariant: **any completed result under injected faults is
bitwise-identical to the fault-free run** (work units are independently
recomputable partitions; re-loading a window yields the same bytes, so
re-running a unit yields the same bits — tests/test_faults.py).

What the injector can and cannot simulate: it covers IO-path failures
(reads, writes, cache traffic, whole-shard loss) and scheduling skew
(latency). It does NOT simulate wrong-answer device compute (silent
numerical corruption on the accelerator has no detection story here — the
manifest hashes cover bytes *read*, not math), process crashes mid-persist
(that is the watermark/resume contract's job, tested separately), or
network partitions between real nodes (single-process repo).

Usable from three surfaces: tests construct ``FaultInjector(FaultPlan(...))``
directly; benchmarks pass one to ``PDFSession``; the CLI loads a JSON plan
via ``--fault-plan FILE`` (``ExecSpec.fault_plan``).
"""

from __future__ import annotations

import hashlib
import json
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

FAULT_KINDS = (
    "read_error", "latency", "corrupt", "shard_death",
    "persist_error", "cache_error",
)


# -- error taxonomy ------------------------------------------------------------


class TransientError(Exception):
    """An error worth retrying: the operation may well succeed on a fresh
    attempt (NFS hiccup, torn read, momentary contention). The executor's
    per-unit retry and the server's launch retry key off this."""


class InjectedFault(TransientError, OSError):
    """A fault the injector raised. Also an ``OSError`` so IO layers that
    already degrade gracefully on real OS errors (the ResultCache's
    warned-miss path) treat injected faults exactly like the real thing."""


class ShardLostError(RuntimeError):
    """A shard (worker) died. NOT retryable at the work-unit level — the
    scheduler re-deals the shard's remaining slices over the healthy shards
    (``runtime.elastic.plan_redeal``)."""

    def __init__(self, shard: int, message: str | None = None):
        self.shard = shard
        super().__init__(message or f"shard {shard} lost")


def is_transient(exc: BaseException) -> bool:
    """Transient/fatal classification for the retry machinery.

    Transient: ``TransientError``, ``OSError`` (incl. ``TimeoutError`` /
    ``ConnectionError`` — the real-world IO failures the injector models).
    Fatal: everything else — a ``ValueError`` from shape validation or a
    compile error will fail identically on every attempt, so retrying it
    only delays the loud failure. ``ShardLostError`` is explicitly fatal at
    unit level (its recovery is re-dealing, not re-reading). Wrapper
    exceptions (``PrefetchError``, persist-stage ``RuntimeError``) are
    classified by their ``__cause__`` chain."""
    seen: set[int] = set()
    while exc is not None and id(exc) not in seen:
        seen.add(id(exc))
        if isinstance(exc, ShardLostError):
            return False
        if isinstance(exc, (TransientError, OSError, TimeoutError)):
            return True
        exc = exc.__cause__
    return False


def shard_lost_from(exc: BaseException) -> ShardLostError | None:
    """The ``ShardLostError`` in ``exc``'s ``__cause__`` chain, or None.

    Cluster workers (``runtime.cluster.run_worker``) classify a failed run
    with this: shard death — possibly wrapped by a prefetch/persist layer —
    publishes a ``lost`` marker and hands recovery to the survivors, while
    any other exception is a real crash that must propagate."""
    seen: set[int] = set()
    while exc is not None and id(exc) not in seen:
        seen.add(id(exc))
        if isinstance(exc, ShardLostError):
            return exc
        exc = exc.__cause__
    return None


# -- the plan ------------------------------------------------------------------


@dataclass(frozen=True)
class FaultRule:
    """One scheduled fault. ``slice_i``/``line_start`` target a window (or
    chunk); ``None`` matches any. ``times`` bounds how many *attempts* per
    target are afflicted — ``times <= max_retries`` injects a recoverable
    fault, ``times`` large makes the unit unrecoverable (quarantine path).
    ``rate`` afflicts only that deterministic fraction of matching targets
    (hashed from the plan seed, not sampled). ``shard``/``after_units``
    configure ``shard_death``: the shard serves ``after_units`` window
    loads, then every subsequent load on it raises ``ShardLostError``."""

    kind: str
    slice_i: int | None = None
    line_start: int | None = None
    times: int = 1
    seconds: float = 0.25  # latency: injected sleep per afflicted attempt
    rate: float = 1.0
    shard: int | None = None
    after_units: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind must be one of {FAULT_KINDS}, got {self.kind!r}")
        if self.times < 1:
            raise ValueError(f"fault times must be >= 1, got {self.times}")
        if self.seconds < 0:
            raise ValueError(f"fault seconds must be >= 0, got {self.seconds}")
        if not 0 < self.rate <= 1:
            raise ValueError(f"fault rate must be in (0, 1], got {self.rate}")
        if self.kind == "shard_death" and self.shard is None:
            raise ValueError("shard_death rules require a target shard")
        if self.after_units < 0:
            raise ValueError(
                f"fault after_units must be >= 0, got {self.after_units}")


@dataclass(frozen=True)
class FaultPlan:
    """A seeded schedule of fault rules — JSON-serializable so a chaos run
    is one ``--fault-plan plan.json`` flag away from any spec CLI."""

    seed: int = 0
    rules: tuple[FaultRule, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))

    def to_dict(self) -> dict:
        return {
            "seed": self.seed,
            "rules": [
                {k: v for k, v in vars(r).items()} for r in self.rules
            ],
        }

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        if not isinstance(d, dict):
            raise ValueError(
                f"fault plan must be a JSON object, got {type(d).__name__}")
        d = dict(d)
        rules = tuple(FaultRule(**r) for r in d.pop("rules", []))
        seed = int(d.pop("seed", 0))
        if d:
            raise ValueError(f"unknown fault plan keys: {sorted(d)}")
        return cls(seed=seed, rules=rules)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls.from_dict(json.loads(text))

    @classmethod
    def load(cls, path: str | Path) -> "FaultPlan":
        return cls.from_json(Path(path).read_text())


# -- the injector --------------------------------------------------------------


class FaultInjector:
    """Runtime state for one plan: thread-safe per-(rule, target) attempt
    counters plus event counts for reporting. Affliction is decided by
    hashing ``(seed, rule index, target)`` — identical across runs and
    independent of which thread asks first, which is what lets the chaos
    tests assert bitwise equality against the fault-free run."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._attempts: dict[tuple[int, object], int] = {}
        self._shard_units: dict[int, int] = {}
        self.events: dict[str, int] = {}

    # -- deterministic decision machinery --------------------------------------

    def _afflicted(self, rule_i: int, rule: FaultRule, key) -> bool:
        if rule.rate >= 1.0:
            return True
        blob = json.dumps([self.plan.seed, rule_i, key], sort_keys=True)
        h = int(hashlib.sha256(blob.encode()).hexdigest()[:8], 16)
        return h / float(0x100000000) < rule.rate

    def _bump(self, rule_i: int, key) -> int:
        """Post-increment attempt counter for (rule, target); returns the
        attempt index BEFORE this call (0 on the first)."""
        with self._lock:
            n = self._attempts.get((rule_i, key), 0)
            self._attempts[(rule_i, key)] = n + 1
            return n

    def _note(self, kind: str) -> None:
        with self._lock:
            self.events[kind] = self.events.get(kind, 0) + 1

    @staticmethod
    def _match(rule: FaultRule, slice_i: int, line_start: int) -> bool:
        return ((rule.slice_i is None or rule.slice_i == slice_i)
                and (rule.line_start is None or rule.line_start == line_start))

    # -- hooks ------------------------------------------------------------------

    def on_read(self, slice_i: int, line_start: int,
                shard: int | None = None) -> None:
        """Window-read hook (``FaultySource.load_window``): may sleep
        (latency), raise ``InjectedFault`` (read_error), or raise
        ``ShardLostError`` (shard_death). Attempt counters are per target,
        so a retry or a speculative re-dispatch of the same window sees a
        fresh — typically fault-free — attempt, exactly like a real
        transient."""
        for i, r in enumerate(self.plan.rules):
            if r.kind == "shard_death" and shard is not None and r.shard == shard:
                with self._lock:
                    n = self._shard_units.get(shard, 0)
                    self._shard_units[shard] = n + 1
                if n >= r.after_units:
                    self._note("shard_death")
                    raise ShardLostError(shard)
                continue
            if r.kind not in ("read_error", "latency"):
                continue
            if not self._match(r, slice_i, line_start):
                continue
            key = (slice_i, line_start)
            if not self._afflicted(i, r, key):
                continue
            if self._bump(i, key) >= r.times:
                continue
            if r.kind == "latency":
                self._note("latency")
                time.sleep(r.seconds)
            else:
                self._note("read_error")
                raise InjectedFault(
                    f"injected transient read error "
                    f"(slice {slice_i}, line {line_start})")

    def chunk_hook(self, slice_i: int, line_start: int, arr: np.ndarray,
                   attempt: int) -> np.ndarray:
        """File-chunk read hook (``FileCubeSource`` verified reads): returns
        the chunk bytes a read observes — corrupted for the first ``times``
        reads of a targeted chunk, pristine after, so the re-read recovers.
        ``attempt`` is the source's 1-based re-read counter (unused for the
        decision — the injector keeps its own per-chunk count so corruption
        does not recur when a chunk is read again later)."""
        for i, r in enumerate(self.plan.rules):
            if r.kind != "corrupt" or not self._match(r, slice_i, line_start):
                continue
            key = ("chunk", slice_i, line_start)
            if not self._afflicted(i, r, key):
                continue
            if self._bump(i, key) >= r.times:
                continue
            self._note("corrupt")
            bad = np.array(arr, copy=True)
            flat = bad.view(np.uint8).reshape(-1)
            flat[:: max(1, flat.size // 17)] ^= 0xFF  # scatter bit flips
            return bad
        return arr

    def on_persist(self, slice_i: int, line_start: int) -> None:
        """Persist-stage hook: raises ``InjectedFault`` before the window's
        ``.npz`` write for the first ``times`` attempts of a target."""
        for i, r in enumerate(self.plan.rules):
            if r.kind != "persist_error" or not self._match(r, slice_i, line_start):
                continue
            key = ("persist", slice_i, line_start)
            if (self._afflicted(i, r, key)
                    and self._bump(i, key) < r.times):
                self._note("persist_error")
                raise InjectedFault(
                    f"injected persist error (slice {slice_i}, "
                    f"line {line_start})")

    def on_cache(self, op: str, slice_i: int) -> None:
        """ResultCache hook (``op`` is 'lookup' or 'store'): raises
        ``InjectedFault`` — which the cache's existing OSError handling
        degrades to a warned miss / skipped store, never a crash."""
        for i, r in enumerate(self.plan.rules):
            if r.kind != "cache_error":
                continue
            if r.slice_i is not None and r.slice_i != slice_i:
                continue
            key = ("cache", op, slice_i)
            if (self._afflicted(i, r, key)
                    and self._bump(i, key) < r.times):
                self._note("cache_error")
                raise InjectedFault(
                    f"injected cache {op} error (slice {slice_i})")

    # -- wiring -----------------------------------------------------------------

    def wrap_source(self, source, shard: int | None = None) -> "FaultySource":
        """Wrap a window source with this injector's read-path faults.
        ``corrupt`` rules additionally arm the underlying
        ``FileCubeSource``'s verified-read path: corruption is only a
        *recoverable* fault when a checksum can detect it, which is what
        keeps completed results bitwise-identical (an undetected flip would
        silently change results — exactly what the manifest exists to
        prevent)."""
        if any(r.kind == "corrupt" for r in self.plan.rules):
            from repro.data.file_source import FileCubeSource

            inner = source
            while not isinstance(inner, FileCubeSource) and hasattr(inner, "inner"):
                inner = inner.inner
            if not isinstance(inner, FileCubeSource):
                raise ValueError(
                    "corrupt fault rules need a file-backed source "
                    "(source.kind='file'): detection relies on the cube "
                    "manifest's per-chunk sha256")
            inner.enable_read_verification(read_hook=self.chunk_hook)
        return FaultySource(source, self, shard=shard)


class FaultySource:
    """A window source with the injector's read hook in front of every
    ``load_window``. Forwards everything else to the wrapped source
    (``geometry``, ``num_observations``, ...)."""

    def __init__(self, inner, injector: FaultInjector, shard: int | None = None):
        self.inner = inner
        self.injector = injector
        self.shard = shard
        self.geometry = inner.geometry

    def load_window(self, w) -> np.ndarray:
        self.injector.on_read(w.slice_i, w.line_start, shard=self.shard)
        return self.inner.load_window(w)

    def __getattr__(self, name):
        return getattr(self.inner, name)
