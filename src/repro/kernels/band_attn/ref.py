"""Pure-jnp oracle for the banded attention kernel: full masked attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def banded_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, window: int
) -> jax.Array:
    """(B, S, H, hd) x (B, S, KV, hd) -> (B, S, H, hd); causal sliding-window
    attention over the full S^2 masked score matrix (small inputs only)."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qr = q.reshape(b, s, kvh, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qr, k).astype(jnp.float32)
    scores *= hd**-0.5
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    ok = (kj <= qi) & (kj > qi - window)
    scores = jnp.where(ok[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, hd)
