"""Pallas TPU kernel: banded (sliding-window) causal flash attention.

The §Perf hymba/gemma3 endgame: the jnp block-local path (models/layers.
_block_local_attention) still materializes the (S/W, W, 2W) score band in
HBM — ~13.4 GB/layer/device at hymba prefill_32k. This kernel keeps each
query block's (W, 2W) scores in VMEM: per (batch, q-head, q-block) grid cell
it loads the q block plus the previous+current key/value blocks, computes the
masked band softmax in f32 on-chip, and writes only the (W, hd) output.

HBM traffic per layer drops to the q/k/v/out streams (the scores never leave
VMEM). GQA is handled in the index maps (k/v blocks indexed by h // group).

VMEM budget at W=1024, hd=128 (f32 scores): q 0.5MB + 4 k/v blocks 2MB +
2x(W, W) scores 8MB + out 0.5MB ~ 11MB of ~16MB/core. For W <= 512 the
budget is under 3MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@functools.partial(jax.jit, static_argnames=("window", "s_valid", "interpret"))
def banded_attention_kernel(
    q: jax.Array,  # (B, S, H, hd) — rope already applied, S % window == 0
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,  # (B, S, KV, hd)
    window: int,
    s_valid: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    w = window
    assert s % w == 0, "pad S to a window multiple in ops.py"
    nb = s // w
    s_valid = s if s_valid is None else s_valid

    def q_idx(bi, hi, ji):
        return (bi, ji, hi, 0)

    def k_self_idx(bi, hi, ji):
        return (bi, ji, hi // g, 0)

    def k_prev_idx(bi, hi, ji):
        return (bi, jnp.maximum(ji - 1, 0), hi // g, 0)

    def kernel(q_ref, kp_ref, ks_ref, vp_ref, vs_ref, o_ref):
        j = pl.program_id(2)
        qb = q_ref[0, :, 0, :].astype(jnp.float32) * (hd**-0.5)
        kp = kp_ref[0, :, 0, :].astype(jnp.float32)
        ks = ks_ref[0, :, 0, :].astype(jnp.float32)
        # (W, W) score tiles against the previous and current key blocks —
        # VMEM-resident, never written to HBM.
        sp = jax.lax.dot_general(qb, kp, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ss = jax.lax.dot_general(qb, ks, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        qi = jax.lax.broadcasted_iota(jnp.int32, (w, w), 0)
        kj = jax.lax.broadcasted_iota(jnp.int32, (w, w), 1)
        # With W == window the band condition (qpos - W < kpos <= qpos)
        # reduces to kj > qi on the previous block (absent for block 0) and
        # causal kj <= qi on the current block; the padded tail of the last
        # block is masked against s_valid.
        ok_p = (kj > qi) & (j > 0)
        ok_s = (kj <= qi) & (j * w + kj < s_valid)
        sp = jnp.where(ok_p, sp, -1e30)
        ss = jnp.where(ok_s, ss, -1e30)
        m = jnp.maximum(jnp.max(sp, axis=1), jnp.max(ss, axis=1))  # (W,)
        ep = jnp.exp(sp - m[:, None])
        es = jnp.exp(ss - m[:, None])
        den = jnp.sum(ep, axis=1) + jnp.sum(es, axis=1)
        out = jax.lax.dot_general(
            ep, vp_ref[0, :, 0, :].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        ) + jax.lax.dot_general(
            es, vs_ref[0, :, 0, :].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )
        o_ref[0, :, 0, :] = (
            out / jnp.maximum(den, 1e-30)[:, None]
        ).astype(o_ref.dtype)

    spec_q = pl.BlockSpec((1, w, 1, hd), q_idx)
    spec_ks = pl.BlockSpec((1, w, 1, hd), k_self_idx)
    spec_kp = pl.BlockSpec((1, w, 1, hd), k_prev_idx)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nb),
        in_specs=[spec_q, spec_kp, spec_ks, spec_kp, spec_ks],
        out_specs=pl.BlockSpec((1, w, 1, hd), q_idx),
        out_shape=jax.ShapeDtypeStruct((b, s, h, hd), q.dtype),
        interpret=interpret,
    )(q, k, k, v, v)
