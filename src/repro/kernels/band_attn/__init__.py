from repro.kernels.band_attn.ops import banded_attention
from repro.kernels.band_attn.ref import banded_attention_ref

__all__ = ["banded_attention", "banded_attention_ref"]
