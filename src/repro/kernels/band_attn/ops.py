"""Jitted wrapper for the banded attention kernel: padding + dispatch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.band_attn.kernel import banded_attention_kernel


def banded_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    window: int,
    interpret: bool | None = None,
) -> jax.Array:
    """(B, S, H, hd) sliding-window causal attention; any S (padded to a
    window multiple internally, padded keys masked in-kernel)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, s, h, hd = q.shape
    pad = (-s) % window
    if pad:
        cfg = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, cfg), jnp.pad(k, cfg), jnp.pad(v, cfg)
    out = banded_attention_kernel(
        q, k, v, window, s_valid=s, interpret=interpret
    )
    return out[:, :s]
