"""Pure-jnp oracle for the histogram kernel: repro.core.pdf_error.histogram."""

from __future__ import annotations

import jax

from repro.core.pdf_error import histogram as _histogram


def hist_ref(values: jax.Array, vmin: jax.Array, vmax: jax.Array, num_bins: int) -> jax.Array:
    return _histogram(values, vmin, vmax, num_bins)
