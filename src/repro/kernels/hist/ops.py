"""Jitted wrapper for the histogram kernel (padding + backend dispatch).

Signature matches repro.core.pdf_error.histogram so fitting.py can swap it in
via ``histogram_fn=``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hist.kernel import hist_counts


def histogram(
    values: jax.Array,
    vmin: jax.Array,
    vmax: jax.Array,
    num_bins: int,
    block_points: int = 8,
    block_obs: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """(..., n) values + (...,) min/max -> (..., num_bins) counts."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    shape = values.shape
    flat = values.reshape(-1, shape[-1])
    flo = vmin.reshape(-1)
    fhi = vmax.reshape(-1)
    p = flat.shape[0]
    bp = min(block_points, max(1, p))
    pad = (-p) % bp
    if pad:
        flat = jnp.concatenate([flat, flat[-1:].repeat(pad, axis=0)], axis=0)
        flo = jnp.concatenate([flo, flo[-1:].repeat(pad, axis=0)])
        fhi = jnp.concatenate([fhi, fhi[-1:].repeat(pad, axis=0)])
    counts = hist_counts(
        flat, flo, fhi, num_bins, block_points=bp, block_obs=block_obs, interpret=interpret
    )[:p]
    return counts.reshape(shape[:-1] + (num_bins,)).astype(values.dtype)
