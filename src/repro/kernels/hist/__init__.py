from repro.kernels.hist.ops import histogram
from repro.kernels.hist.ref import hist_ref

__all__ = ["histogram", "hist_ref"]
