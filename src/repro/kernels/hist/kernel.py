"""Pallas TPU kernel: Eq.-5 interval histogram.

The second O(n) hot loop of the PDF pipeline: per point, count observations
per interval of the evenly split [min, max] range (L intervals). The fitted
CDF masses are O(L) per type and are computed *outside* the kernel — this
kernel only streams the data once.

Per (point-tile, obs-chunk) grid cell: compute each observation's bin index
and accumulate a one-hot sum into the (bp, L) output block, which stays
resident in VMEM across the sequential obs-chunk axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(n_valid: int, num_bins: int, x_ref, lo_ref, hi_ref, out_ref):
    j = pl.program_id(1)
    bp, bn = x_ref.shape

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)
    lo = lo_ref[...]  # (bp, 1)
    hi = hi_ref[...]
    span = jnp.maximum(hi - lo, 1e-12)

    col = jax.lax.broadcasted_iota(jnp.int32, (bp, bn), 1) + j * bn
    valid = col < n_valid
    idx = jnp.floor((x - lo) / span * num_bins)
    idx = jnp.clip(idx, 0, num_bins - 1).astype(jnp.int32)
    # Invalid (padding) columns vote for bin -1 => match nothing.
    idx = jnp.where(valid, idx, -1)

    bins = jax.lax.broadcasted_iota(jnp.int32, (1, 1, num_bins), 2)
    onehot = (idx[:, :, None] == bins).astype(jnp.float32)  # (bp, bn, L)
    out_ref[...] += jnp.sum(onehot, axis=1)


@functools.partial(
    jax.jit, static_argnames=("num_bins", "block_points", "block_obs", "interpret")
)
def hist_counts(
    values: jax.Array,
    vmin: jax.Array,
    vmax: jax.Array,
    num_bins: int,
    block_points: int = 8,
    block_obs: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """values (P, n), vmin/vmax (P,) -> counts (P, num_bins) f32.
    P % block_points == 0 required (ops.py pads); n masked in-kernel."""
    p, n = values.shape
    bp = min(block_points, p)
    bn = min(block_obs, max(128, 128 * ((n + 127) // 128)))
    grid = (p // bp, -(-n // bn))
    n_padded = grid[1] * bn
    if n_padded != n:
        values = jnp.pad(values, ((0, 0), (0, n_padded - n)))

    return pl.pallas_call(
        functools.partial(_hist_kernel, n, num_bins),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bp, num_bins), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, num_bins), jnp.float32),
        interpret=interpret,
    )(values, vmin.reshape(p, 1).astype(jnp.float32), vmax.reshape(p, 1).astype(jnp.float32))
