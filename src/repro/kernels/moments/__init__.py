from repro.kernels.moments.ops import moments
from repro.kernels.moments.ref import moments_ref, stats_ref

__all__ = ["moments", "moments_ref", "stats_ref"]
