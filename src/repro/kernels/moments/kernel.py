"""Pallas TPU kernel: fused streaming moments over the observation axis.

The PDF pipeline's first O(n) hot loop (Algorithm 2 lines 11-12 plus the
skew/kurt/min/max the fitters need). One HBM->VMEM pass per (point-tile,
obs-chunk) computes shifted power sums s1..s4 and min/max; the final chunk
converts shifted sums to central moments. Shifting by each point's first
observation kills the float32 catastrophic cancellation of raw power sums
(Vp ~ 3000 m/s with std ~ 10 would lose all variance bits unshifted).

Grid: (P/bp, n/bn), obs-chunk axis innermost (sequential on TPU), so the
VMEM scratch accumulators carry across chunks of the same point tile.
Block shapes are (bp, bn) with bn a multiple of 128 (lane width) and bp a
multiple of 8 (sublanes) — MXU is not involved; this is a VPU reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NUM_STATS = 8  # mean, var(unbiased), skew, kurt, min, max, (2 pad lanes)


def _moments_kernel(n_valid: int, x_ref, out_ref, acc_ref, shift_ref):
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    bp, bn = x_ref.shape

    x = x_ref[...].astype(jnp.float32)
    col = jax.lax.broadcasted_iota(jnp.int32, (bp, bn), 1) + j * bn
    valid = col < n_valid

    @pl.when(j == 0)
    def _init():
        # Shift = first observation of each point (any in-range value works).
        shift_ref[...] = x[:, 0:1]
        acc_ref[...] = jnp.zeros_like(acc_ref)

    shift = shift_ref[...]  # (bp, 1)
    d = jnp.where(valid, x - shift, 0.0)
    big = jnp.float32(3.4e38)
    xmin = jnp.min(jnp.where(valid, x, big), axis=1)
    xmax = jnp.max(jnp.where(valid, x, -big), axis=1)

    acc = acc_ref[...]
    s1 = acc[:, 0] + jnp.sum(d, axis=1)
    s2 = acc[:, 1] + jnp.sum(d * d, axis=1)
    s3 = acc[:, 2] + jnp.sum(d * d * d, axis=1)
    s4 = acc[:, 3] + jnp.sum(d * d * d * d, axis=1)
    mn = jnp.where(j == 0, xmin, jnp.minimum(acc[:, 4], xmin))
    mx = jnp.where(j == 0, xmax, jnp.maximum(acc[:, 5], xmax))
    acc_ref[...] = jnp.stack([s1, s2, s3, s4, mn, mx, s1, s1], axis=1)

    @pl.when(j == nj - 1)
    def _finalize():
        n = jnp.float32(n_valid)
        md = s1 / n  # mean of shifted values
        m2 = jnp.maximum(s2 / n - md * md, 0.0)
        m3 = s3 / n - 3.0 * md * (s2 / n) + 2.0 * md**3
        m4 = s4 / n - 4.0 * md * (s3 / n) + 6.0 * md * md * (s2 / n) - 3.0 * md**4
        mean = shift[:, 0] + md
        var = m2 * n / jnp.maximum(n - 1.0, 1.0)
        sig = jnp.sqrt(jnp.maximum(m2, 1e-12))
        skew = m3 / sig**3
        kurt = m4 / jnp.maximum(m2, 1e-12) ** 2 - 3.0
        out_ref[...] = jnp.stack(
            [mean, var, skew, kurt, mn, mx, jnp.zeros_like(mean), jnp.zeros_like(mean)],
            axis=1,
        )


@functools.partial(jax.jit, static_argnames=("block_points", "block_obs", "interpret"))
def moments_stats(
    values: jax.Array,
    block_points: int = 8,
    block_obs: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """values (P, n) -> stats (P, NUM_STATS) f32. P % bp == 0 required
    (ops.py pads); n is masked in-kernel so any n works."""
    p, n = values.shape
    bp = min(block_points, p)
    bn = min(block_obs, max(128, 128 * ((n + 127) // 128)))
    grid = (p // bp, -(-n // bn))
    n_padded = grid[1] * bn
    if n_padded != n:
        values = jnp.pad(values, ((0, 0), (0, n_padded - n)))

    return pl.pallas_call(
        functools.partial(_moments_kernel, n),
        grid=grid,
        in_specs=[pl.BlockSpec((bp, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bp, NUM_STATS), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, NUM_STATS), jnp.float32),
        scratch_shapes=[
            # VMEM accumulators persist across the sequential obs-chunk axis.
            pltpu.VMEM((bp, NUM_STATS), jnp.float32),
            pltpu.VMEM((bp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(values)
