"""Pure-jnp oracle for the moments kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.distributions import Moments, moments_from_values


def moments_ref(values: jax.Array) -> Moments:
    """(P, n) -> Moments of each row; two-pass centered reference."""
    return moments_from_values(values.astype(jnp.float32), axis=-1)


def stats_ref(values: jax.Array) -> jax.Array:
    """(P, n) -> (P, 8) in the kernel's packed stats layout."""
    m = moments_ref(values)
    z = jnp.zeros_like(m.mean)
    return jnp.stack([m.mean, m.var, m.skew, m.kurt, m.vmin, m.vmax, z, z], axis=1)
