"""Jitted wrapper for the moments kernel: padding, backend dispatch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.distributions import Moments
from repro.kernels.moments.kernel import NUM_STATS, moments_stats


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def moments(
    values: jax.Array,
    block_points: int = 8,
    block_obs: int = 512,
    interpret: bool | None = None,
) -> Moments:
    """(P, n) or (..., n) -> Moments. Pads P to the point-tile multiple;
    interpret defaults to True on CPU (kernel body executed in Python) and
    False on TPU (Mosaic compile)."""
    if interpret is None:
        interpret = _is_cpu()
    shape = values.shape
    flat = values.reshape(-1, shape[-1])
    p = flat.shape[0]
    bp = min(block_points, max(1, p))
    pad = (-p) % bp
    if pad:
        flat = jnp.concatenate([flat, flat[-1:].repeat(pad, axis=0)], axis=0)
    stats = moments_stats(
        flat, block_points=bp, block_obs=block_obs, interpret=interpret
    )[:p]
    lead = shape[:-1]
    return Moments(*(stats[:, i].reshape(lead) for i in range(6)))
