"""Pure-jnp oracle for the fused fit kernels: the chained reference path
(one-hot histogram, materialized masses tensor) from core/fitting."""

from __future__ import annotations

from typing import Sequence

import jax

from repro.core import distributions as dists
from repro.core import pdf_error as pe


def fit_errors_ref(
    values: jax.Array,
    moments: dists.Moments,
    params_all: jax.Array,
    types: Sequence[str],
    num_bins: int,
) -> jax.Array:
    """(..., n) + (..., T, 3) -> (..., T) Eq.-5 errors via the full chain:
    edges -> one-hot histogram -> (..., T, L) masses -> L1 reduction."""
    edges = pe.interval_edges(moments.vmin, moments.vmax, num_bins)
    freq = pe.histogram(values, moments.vmin, moments.vmax, num_bins)
    masses = pe.cdf_masses(types, params_all, edges)
    return pe.pdf_error_from_freq(freq, masses)
