"""Pallas TPU kernels: the fused single-launch fit path.

Two kernels replace the chained moments -> histogram -> (P, T, L) CDF-mass
tensor -> Eq.-5 reduction device computations of ComputePDF&Error
(Algorithms 3-4):

* ``moments_edges_stats`` — the streaming-moments kernel extended to also
  emit the Eq.-5 interval edges from its final min/max, so callers that
  need the bin geometry (persisted PDF descriptors, the standalone fused
  fit, tests) get it from the same single pass over the data.
* ``fit_error_counts`` — histogram + error: streams the raw window once,
  accumulates the ``(bp, L)`` frequency block in a VMEM scratch, and —
  with that block still resident — the last obs-chunk's epilogue
  evaluates every candidate type's CDF masses at the edges and reduces
  the Eq.-5 L1 error. Only the ``(P, T)`` error matrix reaches HBM: the
  ``(P, n, L)`` one-hot, the ``(P, T, L)`` masses tensor and the
  ``(P, L)`` frequency round-trip of the chained path never exist. The
  ``(P, L+1)`` edges ride along as an *input* (~L/n of the data volume)
  rather than being re-derived in-register: the in-kernel formula compiles
  1 ulp away from the XLA ``interval_edges``, and f32 ``gammainc`` at the
  huge shape parameters the gamma fitter produces for near-normal windows
  amplifies 1 ulp of edge into ~5e-2 of Eq.-5 error — bit-identical edges
  keep every backend's errors allclose at normal f32 tolerances.

The histogram accumulation strategy is a static switch: compare-and-sum
one-hot for the Mosaic TPU path (same scheme as kernels/hist), and a
rank-decomposed matmul for interpret/CPU — ``freq[a, b] = sum_n
onehot_hi[n, a] * onehot_lo[n, b]`` with ``bin = a * B + b`` — which
contracts on the (multi-threaded) XLA dot path instead of the L-wide
one-hot or XLA CPU's single-threaded scatter (~4.6x faster than scatter
at L=64; counts are exact integer sums either way). Grid layout matches
the moments kernel: (P/bp, n/bn) with the obs-chunk axis innermost
(sequential on TPU) so VMEM accumulators carry across chunks of a point
tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import distributions as dists

NUM_STATS = 8  # mean, var(unbiased), skew, kurt, min, max, (2 pad lanes)
_EPS = 1e-12


def _moments_edges_kernel(
    n_valid: int, num_bins: int, x_ref, stats_ref, edges_ref, acc_ref, shift_ref
):
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    bp, bn = x_ref.shape

    x = x_ref[...].astype(jnp.float32)
    col = jax.lax.broadcasted_iota(jnp.int32, (bp, bn), 1) + j * bn
    valid = col < n_valid

    @pl.when(j == 0)
    def _init():
        # Shift = first observation of each point (any in-range value works);
        # kills the float32 cancellation of raw power sums.
        shift_ref[...] = x[:, 0:1]
        acc_ref[...] = jnp.zeros_like(acc_ref)

    shift = shift_ref[...]  # (bp, 1)
    d = jnp.where(valid, x - shift, 0.0)
    big = jnp.float32(3.4e38)
    xmin = jnp.min(jnp.where(valid, x, big), axis=1)
    xmax = jnp.max(jnp.where(valid, x, -big), axis=1)

    acc = acc_ref[...]
    s1 = acc[:, 0] + jnp.sum(d, axis=1)
    s2 = acc[:, 1] + jnp.sum(d * d, axis=1)
    s3 = acc[:, 2] + jnp.sum(d * d * d, axis=1)
    s4 = acc[:, 3] + jnp.sum(d * d * d * d, axis=1)
    mn = jnp.where(j == 0, xmin, jnp.minimum(acc[:, 4], xmin))
    mx = jnp.where(j == 0, xmax, jnp.maximum(acc[:, 5], xmax))
    acc_ref[...] = jnp.stack([s1, s2, s3, s4, mn, mx, s1, s1], axis=1)

    @pl.when(j == nj - 1)
    def _finalize():
        n = jnp.float32(n_valid)
        md = s1 / n  # mean of shifted values
        m2 = jnp.maximum(s2 / n - md * md, 0.0)
        m3 = s3 / n - 3.0 * md * (s2 / n) + 2.0 * md**3
        m4 = s4 / n - 4.0 * md * (s3 / n) + 6.0 * md * md * (s2 / n) - 3.0 * md**4
        mean = shift[:, 0] + md
        var = m2 * n / jnp.maximum(n - 1.0, 1.0)
        sig = jnp.sqrt(jnp.maximum(m2, 1e-12))
        skew = m3 / sig**3
        kurt = m4 / jnp.maximum(m2, 1e-12) ** 2 - 3.0
        stats_ref[...] = jnp.stack(
            [mean, var, skew, kurt, mn, mx, jnp.zeros_like(mean), jnp.zeros_like(mean)],
            axis=1,
        )
        # Eq.-5 interval edges, same formula as pdf_error.interval_edges.
        span = jnp.maximum(mx - mn, _EPS)
        k = jax.lax.broadcasted_iota(jnp.int32, (bp, num_bins + 1), 1).astype(
            jnp.float32
        )
        edges_ref[...] = mn[:, None] + span[:, None] * k / num_bins


@functools.partial(
    jax.jit, static_argnames=("num_bins", "block_points", "block_obs", "interpret")
)
def moments_edges_stats(
    values: jax.Array,
    num_bins: int,
    block_points: int = 8,
    block_obs: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """values (P, n) -> (stats (P, NUM_STATS), edges (P, L+1)) f32.
    P % bp == 0 required (ops.py pads); n is masked in-kernel."""
    p, n = values.shape
    bp = min(block_points, p)
    bn = min(block_obs, max(128, 128 * ((n + 127) // 128)))
    grid = (p // bp, -(-n // bn))
    n_padded = grid[1] * bn
    if n_padded != n:
        values = jnp.pad(values, ((0, 0), (0, n_padded - n)))

    return pl.pallas_call(
        functools.partial(_moments_edges_kernel, n, num_bins),
        grid=grid,
        in_specs=[pl.BlockSpec((bp, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bp, NUM_STATS), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, num_bins + 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, NUM_STATS), jnp.float32),
            jax.ShapeDtypeStruct((p, num_bins + 1), jnp.float32),
        ],
        scratch_shapes=[
            # VMEM accumulators persist across the sequential obs-chunk axis.
            pltpu.VMEM((bp, NUM_STATS), jnp.float32),
            pltpu.VMEM((bp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(values)


def _fit_error_kernel(
    n_valid: int,
    num_bins: int,
    types: tuple[str, ...],
    matmul_hist: bool,
    x_ref,
    lo_ref,
    hi_ref,
    edges_ref,
    params_ref,
    err_ref,
    freq_ref,
):
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    bp, bn = x_ref.shape

    @pl.when(j == 0)
    def _init():
        freq_ref[...] = jnp.zeros_like(freq_ref)

    x = x_ref[...].astype(jnp.float32)
    lo = lo_ref[...]  # (bp, 1)
    hi = hi_ref[...]
    span = jnp.maximum(hi - lo, _EPS)

    col = jax.lax.broadcasted_iota(jnp.int32, (bp, bn), 1) + j * bn
    valid = col < n_valid
    idx = jnp.floor((x - lo) / span * num_bins)
    idx = jnp.clip(idx, 0, num_bins - 1).astype(jnp.int32)

    if matmul_hist:
        # Interpret/CPU: decompose bin = a*B + b and contract the two narrow
        # one-hots over the obs axis on the dot path. Padding columns carry
        # idx = -1: floor-div gives a = -1 (matches no hi slot), so they
        # contribute nothing.
        idx = jnp.where(valid, idx, -1)
        b_width = min(16, num_bins)
        a_width = -(-num_bins // b_width)
        hi = (
            idx[:, :, None] // b_width
            == jax.lax.broadcasted_iota(jnp.int32, (1, 1, a_width), 2)
        ).astype(jnp.float32)
        lo_bits = (
            idx[:, :, None] % b_width
            == jax.lax.broadcasted_iota(jnp.int32, (1, 1, b_width), 2)
        ).astype(jnp.float32)
        counts = jnp.einsum("pna,pnb->pab", hi, lo_bits)
        freq_ref[...] += counts.reshape(bp, a_width * b_width)[:, :num_bins]
    else:
        # Mosaic TPU: dense compare-and-sum (no scatter support); padding
        # columns vote for bin -1 => match nothing.
        idx = jnp.where(valid, idx, -1)
        bins = jax.lax.broadcasted_iota(jnp.int32, (1, 1, num_bins), 2)
        onehot = (idx[:, :, None] == bins).astype(jnp.float32)  # (bp, bn, L)
        freq_ref[...] += jnp.sum(onehot, axis=1)

    @pl.when(j == nj - 1)
    def _epilogue():
        # Frequency block still VMEM-resident: evaluate every candidate
        # type's CDF masses at the edges and the Eq.-5 error in-register.
        freq = freq_ref[...]  # (bp, L)
        rel = freq / jnp.float32(max(n_valid, 1))
        edges = edges_ref[...]  # (bp, L+1)
        errs = []
        for t, name in enumerate(types):
            pk = jnp.stack(
                [params_ref[:, 3 * t + s] for s in range(3)], axis=-1
            )[:, None, :]  # (bp, 1, 3) broadcast against edges (bp, L+1)
            cdf = dists.cdf(name, pk, edges)  # (bp, L+1)
            masses = cdf[:, 1:] - cdf[:, :-1]
            errs.append(jnp.sum(jnp.abs(rel - masses), axis=1))
        err_ref[...] = jnp.stack(errs, axis=1)


@functools.partial(
    jax.jit,
    static_argnames=(
        "types", "num_bins", "block_points", "block_obs", "interpret", "matmul_hist"
    ),
)
def fit_error_counts(
    values: jax.Array,
    vmin: jax.Array,
    vmax: jax.Array,
    edges: jax.Array,
    params: jax.Array,
    types: tuple[str, ...],
    num_bins: int,
    block_points: int = 8,
    block_obs: int = 512,
    interpret: bool = False,
    matmul_hist: bool = False,
) -> jax.Array:
    """values (P, n), vmin/vmax (P,), edges (P, L+1), params (P, T, 3)
    -> Eq.-5 errors (P, T). P % block_points == 0 required (ops.py pads);
    n masked in-kernel."""
    p, n = values.shape
    t = len(types)
    bp = min(block_points, p)
    bn = min(block_obs, max(128, 128 * ((n + 127) // 128)))
    grid = (p // bp, -(-n // bn))
    n_padded = grid[1] * bn
    if n_padded != n:
        values = jnp.pad(values, ((0, 0), (0, n_padded - n)))

    return pl.pallas_call(
        functools.partial(_fit_error_kernel, n, num_bins, tuple(types), matmul_hist),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, num_bins + 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, 3 * t), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bp, t), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, t), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bp, num_bins), jnp.float32)],
        interpret=interpret,
    )(
        values,
        vmin.reshape(p, 1).astype(jnp.float32),
        vmax.reshape(p, 1).astype(jnp.float32),
        edges.reshape(p, num_bins + 1).astype(jnp.float32),
        params.reshape(p, 3 * t).astype(jnp.float32),
    )
