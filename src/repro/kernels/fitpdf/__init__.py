from repro.kernels.fitpdf.ops import fit_errors, moments, moments_and_edges
from repro.kernels.fitpdf.ref import fit_errors_ref

__all__ = ["fit_errors", "fit_errors_ref", "moments", "moments_and_edges"]
