"""Jitted wrappers for the fused fit kernels: padding, block/backend dispatch.

Block defaults are per execution mode: interpret (CPU) wants few, large grid
cells — the interpreter's per-cell overhead dominates, and the matmul-
decomposed histogram accumulation beats both the L-wide one-hot and XLA
CPU's scatter — while the Mosaic TPU path keeps VMEM-sized tiles and the
one-hot scheme. ``benchmarks/kernel_bench.py`` audits the TPU tile bytes
against the 16 MiB/core VMEM budget.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pdf_error as pe
from repro.core.distributions import Moments
from repro.kernels.fitpdf.kernel import fit_error_counts, moments_edges_stats

# Interpret mode: few big cells + matmul accumulation (measured on CPU).
INTERP_BLOCK_POINTS, INTERP_BLOCK_OBS = 64, 4096
# Mosaic TPU: VMEM-sized tiles + one-hot accumulation.
TPU_BLOCK_POINTS, TPU_BLOCK_OBS = 8, 512


def _dispatch(interpret: bool | None, block_points: int | None, block_obs: int | None):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if block_points is None:
        block_points = INTERP_BLOCK_POINTS if interpret else TPU_BLOCK_POINTS
    if block_obs is None:
        block_obs = INTERP_BLOCK_OBS if interpret else TPU_BLOCK_OBS
    return interpret, block_points, block_obs


def _pad_rows(flat: jax.Array, bp: int) -> jax.Array:
    pad = (-flat.shape[0]) % bp
    if pad:
        flat = jnp.concatenate([flat, flat[-1:].repeat(pad, axis=0)], axis=0)
    return flat


def moments_and_edges(
    values: jax.Array,
    num_bins: int,
    block_points: int | None = None,
    block_obs: int | None = None,
    interpret: bool | None = None,
) -> tuple[Moments, jax.Array]:
    """(..., n) -> (Moments, edges (..., L+1)): one pass over the data."""
    interpret, block_points, block_obs = _dispatch(interpret, block_points, block_obs)
    shape = values.shape
    flat = values.reshape(-1, shape[-1])
    p = flat.shape[0]
    bp = min(block_points, max(1, p))
    flat = _pad_rows(flat, bp)
    stats, edges = moments_edges_stats(
        flat, num_bins, block_points=bp, block_obs=block_obs, interpret=interpret
    )
    lead = shape[:-1]
    m = Moments(*(stats[:p, i].reshape(lead) for i in range(6)))
    return m, edges[:p].reshape(lead + (num_bins + 1,))


def moments(
    values: jax.Array,
    num_bins: int = 64,
    block_points: int | None = None,
    block_obs: int | None = None,
    interpret: bool | None = None,
) -> Moments:
    """(..., n) -> Moments via the extended kernel (edges discarded)."""
    return moments_and_edges(
        values, num_bins, block_points=block_points, block_obs=block_obs,
        interpret=interpret,
    )[0]


def fit_errors(
    values: jax.Array,
    moments: Moments,
    params_all: jax.Array,
    types: tuple[str, ...],
    num_bins: int,
    edges: jax.Array | None = None,
    block_points: int | None = None,
    block_obs: int | None = None,
    interpret: bool | None = None,
    row_indices: jax.Array | None = None,
) -> jax.Array:
    """(..., n) values + (..., T, 3) params -> (..., T) Eq.-5 errors.

    Single launch: the histogram never reaches HBM, the CDF masses and the
    Eq.-5 reduction run in the kernel epilogue while the frequency block is
    still VMEM-resident. ``edges`` defaults to ``pe.interval_edges`` (the
    reference formula); pass the moments kernel's emitted edges to chain
    the two launches (see kernel.py on why edges are an input).

    ``row_indices`` (1-D, optional) is the rep-indexed gather prologue of
    the grouping-aware dispatch: ``values`` stays the *full* window while
    ``moments`` / ``params_all`` / ``edges`` are already per-representative
    (leading dims == ``row_indices.shape``); the representatives' value rows
    are gathered here, inside the same jitted computation as the kernel, so
    the compacted batch is produced by the launch that consumes it instead
    of bouncing through a host re-dispatch. Bitwise-identical to calling
    with pre-gathered ``values[row_indices]``.
    """
    interpret, block_points, block_obs = _dispatch(interpret, block_points, block_obs)
    t = len(types)
    if edges is None:
        edges = pe.interval_edges(moments.vmin, moments.vmax, num_bins)
    if row_indices is not None:
        values = values.reshape(-1, values.shape[-1])[row_indices]
    shape = values.shape
    flat = values.reshape(-1, shape[-1])
    p = flat.shape[0]
    bp = min(block_points, max(1, p))
    flat = _pad_rows(flat, bp)
    flo = _pad_rows(moments.vmin.reshape(-1, 1), bp)
    fhi = _pad_rows(moments.vmax.reshape(-1, 1), bp)
    fedg = _pad_rows(edges.reshape(-1, num_bins + 1), bp)
    fpar = _pad_rows(params_all.reshape(-1, t * 3), bp)
    errs = fit_error_counts(
        flat, flo, fhi, fedg, fpar, tuple(types), num_bins,
        block_points=bp, block_obs=block_obs, interpret=interpret,
        matmul_hist=interpret,
    )
    return errs[:p].reshape(shape[:-1] + (t,))
