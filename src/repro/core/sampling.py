"""Sampling (§5.4, Algorithm 5): fast slice features from a fraction of points.

Estimates a slice's features — average mean, average std, distribution-type
percentages — by sampling points, computing their moments, optionally
grouping, and classifying types with the decision tree (no Eq.-5 fitting at
all, which is why the paper's PDF-computation stage drops to ~2 s).

Both samplers from the paper are provided: random (the recommended one) and
k-means (Lloyd with a fixed iteration count on (mu, sigma); the point closest
to each centroid becomes a "double sampled" point).
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grouping as grp
from repro.core import ml_predict as mlp


class SliceFeatures(NamedTuple):
    avg_mean: float
    avg_std: float
    type_percentage: np.ndarray  # (T,) fractions summing to ~1
    num_sampled: int


def sample_indices_random(
    num_points: int, rate: float, seed: int = 0
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    k = max(1, int(round(num_points * rate)))
    return np.sort(rng.choice(num_points, size=k, replace=False))


def _assign_chunked(
    features: np.ndarray, centers: np.ndarray, scratch_floats: int = 1 << 22
) -> tuple[np.ndarray, np.ndarray]:
    """Nearest-centroid assignment without materializing the (P, k) distance
    matrix: points are processed in chunks sized so the scratch — including
    the (chunk, k, n_features) broadcast temp — stays at ~``scratch_floats``
    floats regardless of P (at paper-scale P and k = rate*P the full matrix
    would be hundreds of GB). Returns the assignment and each point's
    squared distance to its own centroid."""
    p, k = len(features), len(centers)
    chunk = max(1, scratch_floats // max(k * features.shape[-1], 1))
    assign = np.empty(p, dtype=np.int64)
    d2_own = np.empty(p, dtype=np.float64)
    for lo in range(0, p, chunk):
        block = features[lo : lo + chunk]
        d2 = ((block[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
        a = d2.argmin(axis=1)
        assign[lo : lo + chunk] = a
        d2_own[lo : lo + chunk] = d2[np.arange(len(block)), a]
    return assign, d2_own


def sample_indices_kmeans(
    features: np.ndarray, rate: float, iters: int = 10, seed: int = 0
) -> np.ndarray:
    """k-means 'double sampling': k = rate * P clusters on (mu, sigma); the
    member closest to each centroid is selected. Fixed Lloyd iterations."""
    rng = np.random.default_rng(seed)
    p = len(features)
    k = max(1, int(round(p * rate)))
    centers = features[rng.choice(p, size=k, replace=False)].astype(np.float64)
    for _ in range(iters):
        assign, _ = _assign_chunked(features, centers)
        sums = np.zeros_like(centers)
        np.add.at(sums, assign, features)
        counts = np.bincount(assign, minlength=k)
        occupied = counts > 0
        centers[occupied] = sums[occupied] / counts[occupied, None]
    assign, d2_own = _assign_chunked(features, centers)
    # closest member per occupied cluster: stable sort by (cluster, distance)
    # puts each cluster's argmin first in its run (ties keep original order,
    # matching argmin semantics).
    order = np.lexsort((d2_own, assign))
    first = np.ones(len(order), dtype=bool)
    first[1:] = assign[order[1:]] != assign[order[:-1]]
    return np.sort(np.unique(order[first].astype(np.int64)))


def predict_types(
    mean: np.ndarray,
    std: np.ndarray,
    tree: mlp.DecisionTree,
    group_first: bool = True,
    group_tol: float = grp.DEFAULT_TOL,
    skew: np.ndarray | None = None,
    kurt: np.ndarray | None = None,
) -> np.ndarray:
    """Algorithm 5 lines 15-24: (optionally) group, then tree-classify —
    returns the per-point type prediction. Grouped predictions are expanded
    back through the inverse map, so the output is always (P,).

    ``skew``/``kurt`` extend the features when the tree was trained with the
    scale-invariant feature set (executor.TREE_FEATURES); they are free
    outputs of the fused moments kernel. This is the classification core of
    both the standalone feature helper below and the staged executor's
    ``method='sampling'`` path.
    """
    if skew is not None:
        from repro.core.executor import tree_features_np

        feats = tree_features_np(mean, std, skew,
                                 kurt if kurt is not None else np.zeros_like(skew))
    else:  # paper-faithful 2-feature mode (tests cover it)
        feats = np.stack([mean, std], axis=-1).astype(np.float32)
    if group_first:
        # One key definition repo-wide (DESIGN.md §2.0): the f64-widened
        # grouping quantization — the previous inline np.round(mean / tol)
        # ran on the f32 loop, the exact aliasing PR 3 fixed elsewhere.
        keys = grp.quantize_features_host(mean, std, group_tol)
        groups = grp.group_host(keys)
        rep_feats = feats[groups.rep_indices]
        rep_pred = np.asarray(mlp.predict(tree.as_device(), jnp.asarray(rep_feats)))
        return rep_pred[groups.inverse]
    return np.asarray(mlp.predict(tree.as_device(), jnp.asarray(feats)))


def slice_features_from_moments(
    mean: np.ndarray,
    std: np.ndarray,
    tree: mlp.DecisionTree,
    types: Sequence[str],
    group_first: bool = True,
    group_tol: float = grp.DEFAULT_TOL,
    skew: np.ndarray | None = None,
    kurt: np.ndarray | None = None,
) -> SliceFeatures:
    """Algorithm 5 lines 15-26: classify (``predict_types``) + aggregate.

    Note the type percentages are over *points* (grouped predictions already
    expanded), matching the paper's per-point percentage definition."""
    pred = predict_types(mean, std, tree, group_first=group_first,
                         group_tol=group_tol, skew=skew, kurt=kurt)
    pct = np.bincount(pred, minlength=len(types)).astype(np.float64) / len(pred)
    return SliceFeatures(float(mean.mean()), float(std.mean()), pct, len(mean))


def type_percentage_distance(a: np.ndarray, b: np.ndarray) -> float:
    """Fig. 17's Euclidean distance between type-percentage vectors."""
    return float(np.sqrt(((a - b) ** 2).sum()))
