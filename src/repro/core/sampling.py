"""Sampling (§5.4, Algorithm 5): fast slice features from a fraction of points.

Estimates a slice's features — average mean, average std, distribution-type
percentages — by sampling points, computing their moments, optionally
grouping, and classifying types with the decision tree (no Eq.-5 fitting at
all, which is why the paper's PDF-computation stage drops to ~2 s).

Both samplers from the paper are provided: random (the recommended one) and
k-means (Lloyd with a fixed iteration count on (mu, sigma); the point closest
to each centroid becomes a "double sampled" point).
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grouping as grp
from repro.core import ml_predict as mlp


class SliceFeatures(NamedTuple):
    avg_mean: float
    avg_std: float
    type_percentage: np.ndarray  # (T,) fractions summing to ~1
    num_sampled: int


def sample_indices_random(
    num_points: int, rate: float, seed: int = 0
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    k = max(1, int(round(num_points * rate)))
    return np.sort(rng.choice(num_points, size=k, replace=False))


def sample_indices_kmeans(
    features: np.ndarray, rate: float, iters: int = 10, seed: int = 0
) -> np.ndarray:
    """k-means 'double sampling': k = rate * P clusters on (mu, sigma); the
    member closest to each centroid is selected. Fixed Lloyd iterations."""
    rng = np.random.default_rng(seed)
    p = len(features)
    k = max(1, int(round(p * rate)))
    centers = features[rng.choice(p, size=k, replace=False)]
    for _ in range(iters):
        d2 = ((features[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
        assign = d2.argmin(axis=1)
        for c in range(k):
            members = features[assign == c]
            if len(members):
                centers[c] = members.mean(axis=0)
    d2 = ((features[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
    assign = d2.argmin(axis=1)
    chosen = []
    for c in range(k):
        member_idx = np.nonzero(assign == c)[0]
        if len(member_idx):
            chosen.append(member_idx[d2[member_idx, c].argmin()])
    return np.sort(np.unique(np.asarray(chosen, dtype=np.int64)))


def slice_features_from_moments(
    mean: np.ndarray,
    std: np.ndarray,
    tree: mlp.DecisionTree,
    types: Sequence[str],
    group_first: bool = True,
    group_tol: float = grp.DEFAULT_TOL,
    skew: np.ndarray | None = None,
    kurt: np.ndarray | None = None,
) -> SliceFeatures:
    """Algorithm 5 lines 15-26: (optionally) group, predict types, aggregate.

    Note the type percentages are over *points*, so grouped predictions are
    expanded back through the inverse map before the percentage calculation.
    ``skew``/``kurt`` extend the features when the tree was trained with the
    4-moment feature set (pipeline.TREE_FEATURES); they are free outputs of
    the fused moments kernel.
    """
    if skew is not None:
        from repro.core.pipeline import tree_features_np

        feats = tree_features_np(mean, std, skew,
                                 kurt if kurt is not None else np.zeros_like(skew))
    else:  # paper-faithful 2-feature mode (tests cover it)
        feats = np.stack([mean, std], axis=-1).astype(np.float32)
    if group_first:
        keys = np.stack(
            [np.round(mean / group_tol), np.round(std / group_tol)], axis=-1
        ).astype(np.int64)
        groups = grp.group_host(keys)
        rep_feats = feats[groups.rep_indices]
        rep_pred = np.asarray(mlp.predict(tree.as_device(), jnp.asarray(rep_feats)))
        pred = rep_pred[groups.inverse]
    else:
        pred = np.asarray(mlp.predict(tree.as_device(), jnp.asarray(feats)))

    pct = np.bincount(pred, minlength=len(types)).astype(np.float64) / len(pred)
    return SliceFeatures(float(mean.mean()), float(std.mean()), pct, len(mean))


def type_percentage_distance(a: np.ndarray, b: np.ndarray) -> float:
    """Fig. 17's Euclidean distance between type-percentage vectors."""
    return float(np.sqrt(((a - b) ** 2).sum()))
