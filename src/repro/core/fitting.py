"""Algorithm 3 (fit-all-types, keep min error) and Algorithm 4 (ML path).

The paper's Algorithm 3 loops over T candidate types, fitting and scoring
each; complexity O(T) in the number of types, with each iteration costing a
full pass over the n observation values (the external R program re-reads the
data). Algorithm 4 replaces the loop with a single fit of the decision-tree
predicted type.

Here both are dense, batched XLA computations over a window of points:

* ``mode='faithful'`` reproduces the paper's cost structure: the O(n)
  histogram pass is executed once per candidate type (T times for
  Algorithm 3, once for Algorithm 4). This is the paper-faithful baseline
  whose roofline/§Perf numbers are reported as "baseline".
* ``mode='fused'`` is the beyond-paper optimization: moments and the Eq.-5
  histogram depend only on the data, never on the candidate type, so they
  are computed once and shared across all T types. Both modes return
  bit-identical results (tests assert this).

Orthogonal to the mode, the *fit backend* selects how the device work is
implemented (``FIT_BACKENDS``):

* ``reference`` — pure-jnp chain (scatter-add histogram; the one-hot
  ``pe.histogram`` remains the test oracle only).
* ``kernels``   — the chain with the Pallas moments + histogram kernels
  swapped in (two kernel launches, masses still materialized in XLA).
* ``fused``     — the single-launch path (``kernels/fitpdf``): one kernel
  emits moments + Eq.-5 edges, a second streams the window once more and
  reduces histogram, CDF masses and Eq.-5 error in its epilogue, so only
  the (P, T) errors reach HBM. The default executor path.

``mode='faithful'`` deliberately keeps the per-type chain structure for
every backend — a fused single pass cannot represent the paper's per-type
data passes, so the fused backend falls back to the chain there.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import distributions as dists
from repro.core import pdf_error as pe

_BIG = 1e30

FIT_BACKENDS = ("reference", "kernels", "fused")


class FitResult(NamedTuple):
    """Per-point PDF: distribution type index, its 3-slot params, Eq.-5 error."""

    type_idx: jax.Array  # (...,) int32 into the candidate `types` tuple
    params: jax.Array  # (..., 3)
    error: jax.Array  # (...,)


def _finite_or_big(err: jax.Array) -> jax.Array:
    return jnp.where(jnp.isfinite(err), err, _BIG)


def select_best(params_all: jax.Array, errs: jax.Array) -> FitResult:
    """(..., T, 3) params + (..., T) errors -> argmin-selected FitResult."""
    errs = _finite_or_big(errs)
    best = jnp.argmin(errs, axis=-1).astype(jnp.int32)
    params = jnp.take_along_axis(params_all, best[..., None, None], axis=-2)[..., 0, :]
    error = jnp.take_along_axis(errs, best[..., None], axis=-1)[..., 0]
    return FitResult(best, params, error)


def select_predicted(
    params_all: jax.Array, errs: jax.Array, predicted_type: jax.Array
) -> FitResult:
    """(..., T, 3) params + (..., T) errors -> the tree-predicted type's fit."""
    pred = predicted_type.astype(jnp.int32)
    params = jnp.take_along_axis(params_all, pred[..., None, None], axis=-2)[..., 0, :]
    error = jnp.take_along_axis(_finite_or_big(errs), pred[..., None], axis=-1)[..., 0]
    return FitResult(pred, params, error)


def gather_rows(
    values: jax.Array, moments: dists.Moments, row_indices: jax.Array
) -> tuple[jax.Array, dists.Moments]:
    """Representative gather: the window's values rows plus every moment
    field at ``row_indices`` in one expression — a single executable when
    jitted (the per-field np round-trips used to dominate small grouped
    windows), and the prologue of the grouping-aware device dispatch."""
    return values[row_indices], jax.tree.map(lambda f: f[row_indices], moments)


def fit_all_rows(
    backend: "FitBackend",
    values: jax.Array,
    moments: dists.Moments,
    row_indices: jax.Array,
    types: Sequence[str],
    num_bins: int,
    mode: str = "fused",
) -> FitResult:
    """Algorithm 3 restricted to ``row_indices`` rows of the window (the
    grouping representatives): gather + fit as one computation.

    On the fused backend the gather rides into the kernel wrapper as a
    rep-indexed prologue (``kernels/fitpdf`` ``ops.fit_errors(row_indices=)``)
    so the compacted batch is produced inside the same launch that consumes
    it; other backends (and ``mode='faithful'``) gather with ``gather_rows``
    and run their ordinary ``fit_all``. Results are bitwise-identical either
    way — both paths run the same per-row ops on the same gathered rows.
    """
    if backend.name == "fused" and mode != "faithful":
        from repro.kernels.fitpdf import ops as fops

        sub_mom = jax.tree.map(lambda f: f[row_indices], moments)
        params_all = dists.fit_all(types, sub_mom)
        errs = fops.fit_errors(
            values, sub_mom, params_all, types, num_bins, row_indices=row_indices
        )
        return select_best(params_all, errs)
    sub_vals, sub_mom = gather_rows(values, moments, row_indices)
    return backend.fit_all(sub_vals, sub_mom, types, num_bins, mode)


def compute_pdf_and_error(
    values: jax.Array,
    moments: dists.Moments,
    types: Sequence[str],
    num_bins: int,
    mode: str = "fused",
    histogram_fn=None,
) -> FitResult:
    """Algorithm 3 for a batch of points: values (..., n) -> FitResult (...,).

    ``histogram_fn(values, vmin, vmax, num_bins)`` may be supplied to swap in
    the Pallas histogram kernel; defaults to the jnp scatter-add reference
    (the one-hot ``pe.histogram`` is kept as the test oracle only).
    """
    hist = histogram_fn or pe.histogram_scatter
    params_all = dists.fit_all(types, moments)  # (..., T, 3)
    edges = pe.interval_edges(moments.vmin, moments.vmax, num_bins)
    masses = pe.cdf_masses(types, params_all, edges)  # (..., T, L)

    if mode == "fused":
        freq = hist(values, moments.vmin, moments.vmax, num_bins)  # (..., L)
        errs = pe.pdf_error_from_freq(freq, masses)  # (..., T)
    elif mode == "faithful":
        # One histogram pass per candidate type — the paper's cost model
        # (its R subprocess re-reads the data for every candidate). XLA would
        # CSE the T identical passes away, so each pass reads the data through
        # a distinct optimization_barrier'd unit scale; the extra O(n) multiply
        # per type *is* the faithful per-type data pass.
        ones = jax.lax.optimization_barrier(jnp.ones((len(types),), values.dtype))
        per_type = []
        for t in range(len(types)):
            freq_t = hist(values * ones[t], moments.vmin, moments.vmax, num_bins)
            per_type.append(pe.pdf_error_from_freq(freq_t, masses[..., t, :]))
        errs = jnp.stack(per_type, axis=-1)
    else:
        raise ValueError(f"unknown mode {mode!r}")

    return select_best(params_all, errs)


def compute_pdf_with_predicted_type(
    values: jax.Array,
    moments: dists.Moments,
    predicted_type: jax.Array,
    types: Sequence[str],
    num_bins: int,
    histogram_fn=None,
) -> FitResult:
    """Algorithm 4: fit only the tree-predicted type (one error pass).

    All T method-of-moments fits are O(1) scalar math per point, so we still
    stack them and select — the *expensive* part the paper saves (the per-type
    data pass / error evaluation) is done exactly once here.
    """
    hist = histogram_fn or pe.histogram_scatter
    params_all = dists.fit_all(types, moments)  # (..., T, 3)
    params = jnp.take_along_axis(
        params_all, predicted_type[..., None, None].astype(jnp.int32), axis=-2
    )[..., 0, :]

    edges = pe.interval_edges(moments.vmin, moments.vmax, num_bins)
    # Evaluate only the chosen type's CDF masses via a masked dense eval:
    # T is tiny and static, so computing each type's edge-CDF and selecting is
    # cheaper on TPU than a gather-of-functions; the O(n) histogram runs once.
    masses_all = pe.cdf_masses(types, params_all, edges)  # (..., T, L)
    masses = jnp.take_along_axis(
        masses_all, predicted_type[..., None, None].astype(jnp.int32), axis=-2
    )[..., 0, :]
    freq = hist(values, moments.vmin, moments.vmax, num_bins)
    error = _finite_or_big(pe.pdf_error_from_freq(freq, masses))
    return FitResult(predicted_type.astype(jnp.int32), params, error)


class FitBackend(NamedTuple):
    """One implementation of the per-window device work.

    ``moments`` maps values (..., n) -> Moments; ``histogram`` is the
    chain-path histogram_fn (also used by ``mode='faithful'``); ``fit_all``
    and ``fit_predicted`` are Algorithms 3 and 4.

    ``merge_stats``/``merge_hist`` are the streaming layer's pairwise
    sufficient-statistic and histogram-count merges (repro.streaming.moments)
    in the backend's own array module: host/float64 for ``reference``, jnp
    for the kernel backends. Same formulas either way — the registry carries
    them so incremental updates pick the path matching the backend that
    produced the stats.
    """

    name: str
    moments: Callable[[jax.Array], dists.Moments]
    histogram: Callable[..., jax.Array]
    fit_all: Callable[..., FitResult]  # (values, moments, types, num_bins, mode)
    fit_predicted: Callable[..., FitResult]  # (values, moments, pred, types, num_bins)
    merge_stats: Callable = None  # (SuffStats, SuffStats) -> SuffStats
    merge_hist: Callable = None  # (counts, counts) -> counts


@functools.lru_cache(maxsize=16)
def get_fit_backend(name: str = "fused", num_bins: int = 64) -> FitBackend:
    """Resolve a ``FIT_BACKENDS`` name; kernel imports stay lazy so the
    reference backend never touches Pallas."""
    # Lazy like the kernel imports: fitting must stay importable without
    # pulling the streaming subsystem in (and vice versa — streaming.moments
    # imports only distributions from core).
    from repro.streaming import moments as sm

    if name == "reference":
        hist = pe.histogram_scatter

        def fit_all(values, moments, types, num_bins, mode="fused"):
            return compute_pdf_and_error(
                values, moments, types, num_bins, mode=mode, histogram_fn=hist
            )

        def fit_predicted(values, moments, pred, types, num_bins):
            return compute_pdf_with_predicted_type(
                values, moments, pred, types, num_bins, histogram_fn=hist
            )

        return FitBackend(name, dists.moments_from_values, hist, fit_all,
                          fit_predicted, sm.merge_suffstats, sm.merge_counts)

    if name == "kernels":
        from repro.kernels.hist import ops as hops
        from repro.kernels.moments import ops as mops

        def fit_all(values, moments, types, num_bins, mode="fused"):
            return compute_pdf_and_error(
                values, moments, types, num_bins, mode=mode,
                histogram_fn=hops.histogram,
            )

        def fit_predicted(values, moments, pred, types, num_bins):
            return compute_pdf_with_predicted_type(
                values, moments, pred, types, num_bins, histogram_fn=hops.histogram
            )

        return FitBackend(name, mops.moments, hops.histogram, fit_all,
                          fit_predicted, sm.merge_suffstats_jnp,
                          sm.merge_counts_jnp)

    if name == "fused":
        from repro.kernels.fitpdf import ops as fops

        def moments_fn(values):
            return fops.moments(values, num_bins)

        def fit_all(values, moments, types, num_bins, mode="fused"):
            if mode == "faithful":
                # The paper's per-type pass structure cannot be a single
                # fused launch; keep the chain (scatter histogram per type).
                return compute_pdf_and_error(
                    values, moments, types, num_bins, mode=mode,
                    histogram_fn=pe.histogram_scatter,
                )
            params_all = dists.fit_all(types, moments)
            errs = fops.fit_errors(values, moments, params_all, types, num_bins)
            return select_best(params_all, errs)

        def fit_predicted(values, moments, pred, types, num_bins):
            params_all = dists.fit_all(types, moments)
            errs = fops.fit_errors(values, moments, params_all, types, num_bins)
            return select_predicted(params_all, errs, pred)

        return FitBackend(name, moments_fn, pe.histogram_scatter, fit_all,
                          fit_predicted, sm.merge_suffstats_jnp,
                          sm.merge_counts_jnp)

    raise ValueError(f"fit_backend must be one of {FIT_BACKENDS}, got {name!r}")
