"""Algorithms 1-2: the windowed PDF-computation pipeline.

Orchestration mirrors the paper exactly:

  data loading  (Algorithm 2)  -> per-window host->device staging + moments
  Select        (per method)   -> grouping / reuse-cache filtering on host
  ComputePDF&Error (Alg. 3/4)  -> batched fit on device (all types or
                                  tree-predicted type)
  persist + Eq. 6 average      -> per-window npz watermark (restartable)

Methods (§5/§6 naming): ``baseline``, ``grouping``, ``reuse``, ``ml``
(= baseline+ML), ``grouping_ml``, ``reuse_ml``. Sampling (Algorithm 5) lives
in sampling.py since it computes slice features, not per-point PDFs.

Fault tolerance: after each window the per-window results are persisted as
``window_NNNN.npz`` plus a watermark; ``run_slice`` with ``resume=True``
skips completed windows — a restart after a crash re-does at most one window
(the paper's window-at-a-time structure, reused for restartability).
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributions as dists
from repro.core import fitting
from repro.core import grouping as grp
from repro.core import ml_predict as mlp
from repro.core import pdf_error as pe
from repro.core import regions
from repro.core.reuse import ReuseCache

METHODS = ("baseline", "grouping", "reuse", "ml", "grouping_ml", "reuse_ml")

# Tree features: scale-invariant moments (cv = sigma/|mu|, skew, excess
# kurtosis). The paper uses (mu, sigma) and notes higher normalized moments
# "may take additional time" — our fused moments kernel computes them in the
# same pass, so they are free; scale-invariance makes the classifier
# transfer across slices whose value scales differ (DESIGN.md §8).
TREE_FEATURES = ("cv", "skew", "kurt")


def tree_features(moments: dists.Moments):
    cv = moments.std / jnp.maximum(jnp.abs(moments.mean), 1e-12)
    return jnp.stack([cv, moments.skew, moments.kurt], axis=-1)


def tree_features_np(mean, std, skew, kurt):
    cv = std / np.maximum(np.abs(mean), 1e-12)
    return np.stack([cv, skew, kurt], axis=-1).astype(np.float32)


@dataclass(frozen=True)
class PDFConfig:
    types: tuple[str, ...] = dists.TYPES_4
    num_bins: int = 64
    window_lines: int = 25
    method: str = "baseline"
    mode: str = "fused"  # 'faithful' reproduces the paper's per-type pass cost
    group_tol: float = grp.DEFAULT_TOL
    rep_bucket: int = 256  # padding bucket for representative batches
    error_bound: float | None = None  # the paper's bounded-error constraint
    use_kernels: bool = False  # route moments/histogram through Pallas ops

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"method must be one of {METHODS}, got {self.method!r}")


class WindowStats(NamedTuple):
    window: regions.Window
    num_points: int
    num_fitted: int  # points actually sent through ComputePDF&Error
    load_seconds: float
    compute_seconds: float
    cache_hits: int


@dataclass
class SliceResult:
    type_idx: np.ndarray  # (P,) int32
    params: np.ndarray  # (P, 3)
    error: np.ndarray  # (P,)
    mean: np.ndarray  # (P,)
    std: np.ndarray  # (P,)
    skew: np.ndarray  # (P,)  (normalized 3rd moment — paper footnote 1)
    kurt: np.ndarray  # (P,)  (excess kurtosis)
    avg_error: float  # Eq. 6
    stats: list[WindowStats] = field(default_factory=list)
    error_bound_satisfied: bool | None = None

    @property
    def total_load_seconds(self) -> float:
        return sum(s.load_seconds for s in self.stats)

    @property
    def total_compute_seconds(self) -> float:
        return sum(s.compute_seconds for s in self.stats)


import functools


@functools.lru_cache(maxsize=64)
def _jitted_fns(types: tuple, num_bins: int, mode: str, use_kernels: bool):
    """Module-level jit cache: every PDFComputer with the same (types, bins,
    mode, kernels) shares compiled executables — windows, slices and method
    variants reuse them instead of recompiling per instance."""
    mom = _moments_fn(use_kernels)
    hist = _hist_fn(use_kernels)

    @jax.jit
    def moments_f(values):
        return mom(values)

    @jax.jit
    def fit_all_f(values, moments):
        r = fitting.compute_pdf_and_error(
            values, moments, types, num_bins, mode=mode, histogram_fn=hist
        )
        return r.type_idx, r.params, r.error

    @jax.jit
    def fit_pred_f(values, moments, pred):
        r = fitting.compute_pdf_with_predicted_type(
            values, moments, pred, types, num_bins, histogram_fn=hist
        )
        return r.type_idx, r.params, r.error

    return moments_f, fit_all_f, fit_pred_f


def _moments_fn(use_kernels: bool):
    if use_kernels:
        from repro.kernels.moments import ops as mops

        return mops.moments
    return dists.moments_from_values


def _hist_fn(use_kernels: bool):
    if use_kernels:
        from repro.kernels.hist import ops as hops

        return hops.histogram
    return pe.histogram


class PDFComputer:
    """Drives Algorithms 1-2 over a slice for a given data source.

    ``data_source`` must expose ``geometry: regions.CubeGeometry`` and
    ``load_window(window) -> np.ndarray (num_points, n_obs) float32``.
    """

    def __init__(
        self,
        config: PDFConfig,
        data_source,
        tree: mlp.DecisionTree | None = None,
        out_dir: str | Path | None = None,
        sharding: jax.sharding.Sharding | None = None,
    ):
        self.config = config
        self.data = data_source
        self.tree = tree
        self.out_dir = Path(out_dir) if out_dir else None
        self.sharding = sharding
        self.cache = ReuseCache()
        if "ml" in config.method and tree is None:
            raise ValueError(f"method {config.method!r} requires a decision tree")

        self._moments, self._fit_all, self._fit_pred = _jitted_fns(
            tuple(config.types), config.num_bins, config.mode, config.use_kernels
        )
        self._tree_arrays = tree.as_device() if tree else None

    # -- staging ------------------------------------------------------------

    def _stage(self, values: np.ndarray) -> jax.Array:
        arr = jnp.asarray(values, dtype=jnp.float32)
        if self.sharding is not None:
            arr = jax.device_put(arr, self.sharding)
        return arr

    # -- ComputePDF&Error dispatch per method --------------------------------

    def _fit(self, values: jax.Array, moments: dists.Moments):
        """Fit every row of ``values``; returns np arrays (type, params, err)."""
        if self._tree_arrays is not None and "ml" in self.config.method:
            feats = tree_features(moments)
            pred = mlp.predict(self._tree_arrays, feats)
            t, p, e = self._fit_pred(values, moments, pred)
        else:
            t, p, e = self._fit_all(values, moments)
        return np.asarray(t), np.asarray(p), np.asarray(e)

    def _select_and_fit(self, values: jax.Array, moments: dists.Moments):
        """The Select step (§5.1/5.2): returns per-point results + bookkeeping."""
        method = self.config.method
        if method in ("baseline", "ml"):
            t, p, e = self._fit(values, moments)
            return t, p, e, values.shape[0], 0

        # grouping / reuse variants: dedup on host, fit representatives only.
        mean = np.asarray(moments.mean)
        std = np.asarray(moments.std)
        keys = np.stack(
            [
                np.round(mean / self.config.group_tol),
                np.round(std / self.config.group_tol),
            ],
            axis=-1,
        ).astype(np.int64)
        groups = grp.group_host(keys)
        rep_idx = groups.rep_indices
        cache_hits = 0

        if method.startswith("reuse"):
            hit, cached = self.cache.lookup_window(keys[rep_idx])
            cache_hits = int(hit.sum())
            todo = rep_idx[~hit]
        else:
            hit = np.zeros((len(rep_idx),), dtype=bool)
            cached = np.zeros((len(rep_idx), 5))
            todo = rep_idx

        rep_t = np.zeros((len(rep_idx),), dtype=np.int32)
        rep_p = np.zeros((len(rep_idx), 3), dtype=np.float32)
        rep_e = np.zeros((len(rep_idx),), dtype=np.float32)
        rep_t[hit] = cached[hit, 0].astype(np.int32)
        rep_p[hit] = cached[hit, 1:4]
        rep_e[hit] = cached[hit, 4]

        if len(todo):
            padded = grp.pad_representatives(todo, self.config.rep_bucket)
            sub_vals = values[jnp.asarray(padded)]
            sub_mom = dists.Moments(*(jnp.asarray(np.asarray(f)[padded]) for f in moments))
            t, p, e = self._fit(sub_vals, sub_mom)  # dispatches ML per method
            t, p, e = t[: len(todo)], p[: len(todo)], e[: len(todo)]
            rep_t[~hit], rep_p[~hit], rep_e[~hit] = t, p, e
            if method.startswith("reuse"):
                self.cache.insert_window(
                    keys[todo],
                    np.concatenate(
                        [t[:, None], p, e[:, None]], axis=-1
                    ).astype(np.float64),
                )

        inv = groups.inverse
        return rep_t[inv], rep_p[inv], rep_e[inv], len(todo), cache_hits

    # -- main loop (Algorithm 1) ---------------------------------------------

    def run_slice(
        self,
        slice_i: int,
        resume: bool = False,
        on_window: Callable[[WindowStats], None] | None = None,
    ) -> SliceResult:
        geom = self.data.geometry
        ppl = geom.points_per_line
        total = geom.points_per_slice
        out_t = np.zeros((total,), dtype=np.int32)
        out_p = np.zeros((total, 3), dtype=np.float32)
        out_e = np.zeros((total,), dtype=np.float32)
        out_mu = np.zeros((total,), dtype=np.float32)
        out_sig = np.zeros((total,), dtype=np.float32)
        out_sk = np.zeros((total,), dtype=np.float32)
        out_ku = np.zeros((total,), dtype=np.float32)
        stats: list[WindowStats] = []

        start_line = self._watermark(slice_i) if resume else 0
        if resume and start_line > 0:
            self._restore_windows(
                slice_i, start_line, out_t, out_p, out_e, out_mu, out_sig, out_sk, out_ku
            )

        for w in regions.iter_windows(geom, slice_i, self.config.window_lines, start_line):
            t0 = time.perf_counter()
            raw = self.data.load_window(w)  # (P, n_obs)
            values = self._stage(raw)
            moments = jax.block_until_ready(self._moments(values))
            t1 = time.perf_counter()

            t, p, e, fitted, hits = self._select_and_fit(values, dists.Moments(*moments))
            t2 = time.perf_counter()

            lo, hi = w.line_start * ppl, w.line_end * ppl
            out_t[lo:hi], out_p[lo:hi], out_e[lo:hi] = t, p, e
            out_mu[lo:hi] = np.asarray(moments[0])
            out_sig[lo:hi] = np.sqrt(np.maximum(np.asarray(moments[1]), 0))
            out_sk[lo:hi] = np.asarray(moments[2])
            out_ku[lo:hi] = np.asarray(moments[3])
            ws = WindowStats(w, hi - lo, fitted, t1 - t0, t2 - t1, hits)
            stats.append(ws)
            self._persist_window(slice_i, w, out_t[lo:hi], out_p[lo:hi], out_e[lo:hi],
                                 out_mu[lo:hi], out_sig[lo:hi], out_sk[lo:hi], out_ku[lo:hi])
            if on_window:
                on_window(ws)

        avg_err = float(out_e.mean())
        result = SliceResult(out_t, out_p, out_e, out_mu, out_sig, out_sk, out_ku,
                             avg_err, stats)
        if self.config.error_bound is not None:
            result.error_bound_satisfied = avg_err <= self.config.error_bound
        return result

    # -- persistence / watermark ----------------------------------------------

    def _persist_window(self, slice_i, w, t, p, e, mu, sig, sk, ku) -> None:
        if self.out_dir is None:
            return
        self.out_dir.mkdir(parents=True, exist_ok=True)
        np.savez(
            self.out_dir / f"slice{slice_i}_window_{w.line_start:05d}.npz",
            type_idx=t, params=p, error=e, mean=mu, std=sig, skew=sk, kurt=ku,
            line_start=w.line_start, line_end=w.line_end,
        )
        (self.out_dir / f"slice{slice_i}_watermark.json").write_text(
            json.dumps({"next_line": int(w.line_end)})
        )

    def _watermark(self, slice_i: int) -> int:
        if self.out_dir is None:
            return 0
        f = self.out_dir / f"slice{slice_i}_watermark.json"
        if not f.exists():
            return 0
        return int(json.loads(f.read_text())["next_line"])

    def _restore_windows(self, slice_i, upto_line, out_t, out_p, out_e, out_mu,
                         out_sig, out_sk, out_ku):
        ppl = self.data.geometry.points_per_line
        for f in sorted(self.out_dir.glob(f"slice{slice_i}_window_*.npz")):
            z = np.load(f)
            if int(z["line_end"]) <= upto_line:
                lo, hi = int(z["line_start"]) * ppl, int(z["line_end"]) * ppl
                out_t[lo:hi] = z["type_idx"]
                out_p[lo:hi] = z["params"]
                out_e[lo:hi] = z["error"]
                out_mu[lo:hi] = z["mean"]
                out_sig[lo:hi] = z["std"]
                out_sk[lo:hi] = z["skew"]
                out_ku[lo:hi] = z["kurt"]


def train_type_tree(
    data_source,
    types=dists.TYPES_4,
    slices=(0, 1, 2, 3),
    window_lines: int = 4,
    depth: int = 4,
    max_bins: int = 32,
):
    """§5.3.1 flow: produce 'previously generated output data' with the
    baseline over ``slices`` and train the (mu, sigma) -> type decision tree.

    The paper trains on 25k points of Slice 0; our synthetic slices are
    type-pure (one dominant layer each), so training spans four consecutive
    slices to cover all four types (DESIGN.md §8)."""
    feats, labels = [], []
    for s in slices:
        res = PDFComputer(
            PDFConfig(types=types, window_lines=window_lines, method="baseline"),
            data_source,
        ).run_slice(s)
        feats.append(tree_features_np(res.mean, res.std, res.skew, res.kurt))
        labels.append(res.type_idx)
    x = np.concatenate(feats).astype(np.float32)
    y = np.concatenate(labels).astype(np.int32)
    return mlp.train_tree(x, y, len(types), depth=depth, max_bins=max_bins)
