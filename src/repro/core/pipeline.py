"""Algorithms 1-2: the windowed PDF-computation pipeline (facade).

The actual machinery lives in ``core/executor.py``: a staged executor that
decouples data loading (Algorithm 2, prefetched window *k+1* while the
device fits window *k*), Select + ComputePDF&Error (Alg. 3/4, per-method
dispatch), and persistence (async ``.npz`` watermarks off the critical
path) over a schedulable queue of (slice, window) WorkUnits
(``core/regions.py``). ``PDFComputer`` here is a thin facade over one
``StagedExecutor`` so every method (§5/§6 naming: ``baseline``,
``grouping``, ``reuse``, ``ml``, ``grouping_ml``, ``reuse_ml``) and the
sampling path run through one pipeline; ``runtime/scheduler.py`` shards
whole slices across the mesh data axis on top of the same executor. The
per-window device work is a pluggable fit backend
(``PDFConfig.fit_backend``, DESIGN.md §2.1) defaulting to the fused
single-launch kernel path in ``kernels/fitpdf``.

Fault tolerance: after each window the per-window results are persisted as
``window_NNNN.npz`` plus a watermark; ``run_slice`` with ``resume=True``
skips completed windows — a restart after a crash re-does at most one window
(the paper's window-at-a-time structure, reused for restartability).

NOTE: the public entry point is now ``repro.api`` (``PipelineSpec`` +
``PDFSession``, DESIGN.md §API); ``PDFComputer`` remains as a
bitwise-equivalent deprecation shim for existing callers.
"""

from __future__ import annotations

from pathlib import Path
from typing import Callable

import jax
import numpy as np

from repro.core import ml_predict as mlp
from repro.core import distributions as dists
from repro.core import regions

# Re-exported so existing imports (tests, benchmarks, examples) keep working;
# the definitions moved to core/executor.py with the staged-executor refactor.
from repro.core.executor import (  # noqa: F401
    METHODS,
    SELECT_BACKENDS,
    TREE_FEATURES,
    ExecutorConfig,
    ExecutorReport,
    PDFConfig,
    SliceResult,
    StagedExecutor,
    WindowStats,
    tree_features,
    tree_features_np,
)

__all__ = [
    "METHODS", "SELECT_BACKENDS", "TREE_FEATURES", "ExecutorConfig",
    "ExecutorReport", "PDFConfig", "PDFComputer", "SliceResult",
    "StagedExecutor", "WindowStats", "tree_features", "tree_features_np",
    "train_type_tree",
]


class PDFComputer:
    """DEPRECATED shim over the ``repro.api`` surface — prefer
    ``api.PipelineSpec`` + ``api.PDFSession`` for new code.

    Keeps the historical construction/`run_slice` surface and produces
    bitwise-identical results to a session running the equivalent spec
    (asserted in tests/test_api.py). Internally it lifts its
    ``PDFConfig``/``ExecutorConfig`` pair into a ``PipelineSpec``
    (``api.spec.spec_from_config``), so even legacy construction stamps the
    same provenance hash into persisted watermarks that a session would —
    resume works across the two surfaces. ``data_source`` must expose
    ``geometry: regions.CubeGeometry`` and ``load_window(window) ->
    np.ndarray (num_points, n_obs) float32``.
    """

    def __init__(
        self,
        config: PDFConfig,
        data_source,
        tree: mlp.DecisionTree | None = None,
        out_dir: str | Path | None = None,
        sharding: jax.sharding.Sharding | None = None,
        exec_config: ExecutorConfig | None = None,
    ):
        # Lazy import: api.spec imports core.executor; loading it here (not
        # at module top) keeps the import graph acyclic.
        from repro.api.spec import source_spec_for, spec_from_config

        self.config = config
        self.data = data_source
        self.tree = tree
        self.out_dir = Path(out_dir) if out_dir else None
        self.sharding = sharding
        self.spec = spec_from_config(
            config, exec_config, source=source_spec_for(data_source)
        )
        self._executor = StagedExecutor(
            config, data_source, tree=tree, out_dir=out_dir,
            sharding=sharding, exec_config=exec_config,
            spec_hash=self.spec.content_hash(),
        )

    @property
    def executor(self) -> StagedExecutor:
        return self._executor

    @property
    def cache(self):
        """The reuse cache (§5.2.1) — lives on the executor so it spans
        windows and consecutive slices, as it always has."""
        return self._executor.cache

    @property
    def last_report(self) -> ExecutorReport | None:
        """Per-stage totals of the most recent run (overlap evidence)."""
        return self._executor.last_report

    def _warn_unverifiable_resume(self, resume: bool):
        if resume and self.spec.source.kind == "external":
            import warnings

            warnings.warn(
                "resuming with an external data source: the spec hash "
                "verifies the pipeline knobs only, not the dataset's "
                "identity — make sure out_dir belongs to this source",
                stacklevel=3)

    def run_slice(
        self,
        slice_i: int,
        resume: bool = False,
        on_window: Callable[[WindowStats], None] | None = None,
    ) -> SliceResult:
        self._warn_unverifiable_resume(resume)
        return self._executor.run_slice(slice_i, resume=resume, on_window=on_window)

    def run(
        self,
        slices,
        resume: bool = False,
        on_window: Callable[[WindowStats], None] | None = None,
    ) -> dict[int, SliceResult]:
        """Multi-slice entry point: one plan spanning ``slices`` (processed
        slice-major, sharing the reuse cache across slices)."""
        self._warn_unverifiable_resume(resume)
        plan = regions.build_plan(
            self.data.geometry, list(slices), self.config.window_lines
        )
        return self._executor.run(plan, resume=resume, on_window=on_window)

    # -- back-compat helpers ---------------------------------------------------

    def _watermark(self, slice_i: int) -> int:
        return self._executor.watermark(slice_i)


def train_type_tree(
    data_source,
    types=dists.TYPES_4,
    slices=(0, 1, 2, 3),
    window_lines: int = 4,
    depth: int = 4,
    max_bins: int = 32,
):
    """§5.3.1 flow: produce 'previously generated output data' with the
    baseline over ``slices`` and train the (mu, sigma) -> type decision tree.

    The paper trains on 25k points of Slice 0; our synthetic slices are
    type-pure (one dominant layer each), so training spans four consecutive
    slices to cover all four types (DESIGN.md §8)."""
    feats, labels = [], []
    for s in slices:
        res = PDFComputer(
            PDFConfig(types=types, window_lines=window_lines, method="baseline"),
            data_source,
        ).run_slice(s)
        feats.append(tree_features_np(res.mean, res.std, res.skew, res.kurt))
        labels.append(res.type_idx)
    x = np.concatenate(feats).astype(np.float32)
    y = np.concatenate(labels).astype(np.int32)
    return mlp.train_tree(x, y, len(types), depth=depth, max_bins=max_bins)
