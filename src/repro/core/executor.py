"""Staged PDF executor: load / compute / persist as decoupled stages.

The paper's speedup is not only the per-point kernels — Spark overlaps data
loading with computation and spreads slices across the cluster. This module
is that layer for the JAX reproduction:

  load stage     WindowPrefetcher (data/loader.py) pulls WorkUnits off the
                 plan in order, loading window *k+1* from the data source
                 and staging it host->device while the device is still
                 fitting window *k* (device work, including the moments
                 kernel, stays on the compute stage — see _StagedWindow).
  compute stage  the main thread: Select (grouping / reuse / ML dispatch)
                 on host + batched ComputePDF&Error on device — identical
                 operations, in identical order, to the old serial loop, so
                 results are bitwise-equal with prefetch on or off.
  persist stage  a single writer thread appends per-window ``.npz``
                 watermarks off the critical path; submission order is
                 preserved so the watermark never runs ahead of a persisted
                 window, and ``close()`` flushes before the executor
                 returns (or re-raises), keeping the serial path's
                 crash-consistency guarantee.

Per-stage heartbeats feed ``runtime.monitor.StepMonitor`` instances (one per
stage), so straggler flagging and stage medians come for free; the
``ExecutorReport`` summarizes how much load time was hidden behind compute
(``wait_seconds`` is the only part of the load the device actually blocked
on).

``PDFComputer`` (pipeline.py) is a thin facade over this executor; the
multi-slice entry point is ``run`` on a ``regions.Plan``, which
``runtime.scheduler`` uses for per-node slice assignment.
"""

from __future__ import annotations

import functools
import hashlib
import json
import queue
import threading
import time
import warnings
from concurrent import futures
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributions as dists
from repro.core import fitting
from repro.core import grouping as grp
from repro.core import ml_predict as mlp
from repro.core import regions
from repro.core.reuse import ReuseCache
from repro.data.loader import PrefetchError, WindowPrefetcher
from repro.runtime.faults import ShardLostError, is_transient
from repro.runtime.monitor import StepMonitor, StragglerPolicy

METHODS = (
    "baseline", "grouping", "reuse", "ml", "grouping_ml", "reuse_ml",
    # §5.4 / Algorithm 5: estimate slice features from a sampled fraction of
    # points — tree classification only, no Eq.-5 fitting. A first-class
    # registry entry so the sampling figures run through the same staged
    # executor as every other method (it used to be benchmark-side glue).
    "sampling",
)

# Point samplers for method='sampling' (§5.4: random is the paper's
# recommendation; k-means "double sampling" wins at tiny rates).
SAMPLERS = ("random", "kmeans")

# Where the Select step's dedup runs (DESIGN.md §6): 'host' bounces the
# window's quantized keys through np.unique + a padded representative
# re-dispatch; 'device' keeps quantize -> group_device -> representative
# gather -> fit -> scatter on the accelerator (one jitted launch for the
# grouping methods; reuse keeps its host cache but deduplicates on device).
# Both produce bitwise-identical per-point results (tests/test_select_backends).
SELECT_BACKENDS = ("host", "device")

# Tree features: scale-invariant moments (cv = sigma/|mu|, skew, excess
# kurtosis). The paper uses (mu, sigma) and notes higher normalized moments
# "may take additional time" — our fused moments kernel computes them in the
# same pass, so they are free; scale-invariance makes the classifier
# transfer across slices whose value scales differ (DESIGN.md §8).
TREE_FEATURES = ("cv", "skew", "kurt")


def _quiet_donation(f):
    """The fit executables donate their (P, n) window buffer (memory headroom
    on real accelerators: the staged window is dead once consumed). None of
    the small fit outputs can alias a (P, n) buffer, so XLA warns the
    donation went unused on backends where it finds no other use — expected,
    not actionable. Suppressed per-call so importers' own warning state is
    untouched (the compute stage is single-threaded)."""

    @functools.wraps(f)
    def wrapped(*args):
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable"
            )
            return f(*args)

    return wrapped


def tree_features(moments: dists.Moments):
    cv = moments.std / jnp.maximum(jnp.abs(moments.mean), 1e-12)
    return jnp.stack([cv, moments.skew, moments.kurt], axis=-1)  # repro: allow[SHAPE]: fixed (P, 3) feature triple inside every executable — not a batch-shape seam


def tree_features_np(mean, std, skew, kurt):
    cv = std / np.maximum(np.abs(mean), 1e-12)
    return np.stack([cv, skew, kurt], axis=-1).astype(np.float32)


@dataclass(frozen=True)
class PDFConfig:
    types: tuple[str, ...] = dists.TYPES_4
    num_bins: int = 64
    window_lines: int = 25
    method: str = "baseline"
    mode: str = "fused"  # 'faithful' reproduces the paper's per-type pass cost
    group_tol: float = grp.DEFAULT_TOL
    rep_bucket: int = 256  # padding bucket for representative batches
    error_bound: float | None = None  # the paper's bounded-error constraint
    # Device-work implementation (fitting.FIT_BACKENDS): 'reference' (jnp
    # chain), 'kernels' (Pallas moments+hist, chained), 'fused' (the
    # single-launch kernels/fitpdf path — the default hot path).
    fit_backend: str = "fused"
    # Where Select's dedup runs (SELECT_BACKENDS). 'host' stays the default:
    # on small CPU devices np.unique beats the device sort; 'device' removes
    # the per-window key D2H + rep-index H2D bounce entirely (the win on real
    # accelerators — see the kernel/select_* BENCH rows).
    select_backend: str = "host"
    # method='sampling' (§5.4): fraction of window points classified, which
    # sampler draws them, and the Lloyd iteration count for 'kmeans'. The
    # per-window draw is seeded from (sample_seed, slice, line), so results
    # are independent of window execution order and survive resume.
    sample_frac: float = 0.1
    sampler: str = "random"
    kmeans_iters: int = 10
    sample_seed: int = 0

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"method must be one of {METHODS}, got {self.method!r}")
        if self.num_bins < 2:
            raise ValueError(f"num_bins must be >= 2, got {self.num_bins}")
        if self.window_lines < 1:
            raise ValueError(f"window_lines must be >= 1, got {self.window_lines}")
        if self.error_bound is not None and not self.error_bound > 0:
            # error_bound <= 0 used to sail through construction and report
            # error_bound_satisfied=False at the end of a full run
            raise ValueError(
                f"error_bound must be > 0 (or None), got {self.error_bound}")
        if not 0 < self.sample_frac <= 1:
            raise ValueError(f"sample_frac must be in (0, 1], got {self.sample_frac}")
        if self.sampler not in SAMPLERS:
            raise ValueError(f"sampler must be one of {SAMPLERS}, got {self.sampler!r}")
        if self.kmeans_iters < 1:
            raise ValueError(f"kmeans_iters must be >= 1, got {self.kmeans_iters}")
        if self.fit_backend not in fitting.FIT_BACKENDS:
            raise ValueError(
                f"fit_backend must be one of {fitting.FIT_BACKENDS}, "
                f"got {self.fit_backend!r}"
            )
        if self.select_backend not in SELECT_BACKENDS:
            raise ValueError(
                f"select_backend must be one of {SELECT_BACKENDS}, "
                f"got {self.select_backend!r}"
            )
        if self.rep_bucket < 1:
            # padded_size(g, 0) would spin forever (0 * 2 == 0), and the
            # bucket is now CLI-exposed (--rep-bucket)
            raise ValueError(f"rep_bucket must be >= 1, got {self.rep_bucket}")


@dataclass(frozen=True)
class ExecutorConfig:
    """Staging + fault-tolerance knobs; ``prefetch=False,
    async_persist=False`` reproduces the pre-executor strictly serial loop
    (the reference path for equivalence tests and overlap benchmarks).

    None of these change per-point results — the bitwise-equivalence
    contract: a retried, speculated, or re-dealt work unit recomputes the
    exact bytes the first attempt would have produced (loads are
    deterministic, fits are row-pure), which is precisely what makes
    first-result-wins and re-dealing safe (DESIGN.md §14)."""

    prefetch: bool = True
    prefetch_depth: int = 2  # how many windows the load stage may run ahead
    async_persist: bool = True
    # Work-unit retry: how many *re*-attempts a transiently failing unit
    # gets (so max_retries + 1 attempts total) before it is quarantined
    # (degraded_mode=True) or the run aborts (False). Backoff is
    # exponential (retry_backoff_s * 2^attempt) with a deterministic
    # per-(unit, attempt) jitter.
    max_retries: int = 2
    retry_backoff_s: float = 0.05
    # Straggler speculation: when a window load exceeds
    # max(threshold x trailing-median, straggler_grace_s), re-dispatch an
    # identical load and take whichever finishes first.
    speculate: bool = True
    straggler_grace_s: float = 1.0
    # Degraded completion: quarantine units that exhaust their retries
    # (type_idx = -1, failed-unit manifest next to the watermark) instead
    # of aborting the run.
    degraded_mode: bool = True

    def __post_init__(self):
        if self.prefetch_depth < 1:
            raise ValueError(f"prefetch_depth must be >= 1, got {self.prefetch_depth}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.retry_backoff_s < 0:
            raise ValueError(
                f"retry_backoff_s must be >= 0, got {self.retry_backoff_s}")
        if self.straggler_grace_s < 0:
            raise ValueError(
                f"straggler_grace_s must be >= 0, got {self.straggler_grace_s}")


class WindowStats(NamedTuple):
    window: regions.Window
    num_points: int
    num_fitted: int  # points actually sent through ComputePDF&Error
    load_seconds: float
    compute_seconds: float
    cache_hits: int
    wait_seconds: float = 0.0  # compute stage blocked waiting for this window


@dataclass
class SliceResult:
    type_idx: np.ndarray  # (P,) int32
    params: np.ndarray  # (P, 3)
    error: np.ndarray  # (P,)
    mean: np.ndarray  # (P,)
    std: np.ndarray  # (P,)
    skew: np.ndarray  # (P,)  (normalized 3rd moment — paper footnote 1)
    kurt: np.ndarray  # (P,)  (excess kurtosis)
    avg_error: float  # Eq. 6
    stats: list[WindowStats] = field(default_factory=list)
    error_bound_satisfied: bool | None = None
    slice_i: int | None = None
    # Provenance: content hash of the PipelineSpec that produced this result
    # (api/spec.py); also stamped into persisted .npz files and watermarks.
    spec_hash: str | None = None
    # True when this result was served from a spec-hash-keyed ResultCache
    # (api/cache.py) instead of being computed; cached results are bitwise
    # identical to computed ones but carry no window stats.
    cached: bool = False
    # Fault-tolerance bookkeeping (DESIGN.md §14): transient re-attempts,
    # speculative re-dispatches, and the quarantined windows of a degraded
    # run — each a dict with unit_id/line_start/line_end/attempts/error,
    # mirrored in the slice's failed-unit manifest on disk. A quarantined
    # window's points carry type_idx = -1 and zero params/moments.
    retries: int = 0
    speculations: int = 0
    quarantined: tuple = ()

    @property
    def degraded(self) -> bool:
        """True when any work unit was quarantined — the result is complete
        for every other window but NOT cacheable as the slice's answer."""
        return len(self.quarantined) > 0

    def features(self, types) -> "object":
        """§5.4 slice features (SliceFeatures) from this result: average
        mean/std and type percentages over the *classified* points — all of
        them for the fitting methods, the sampled subset for
        ``method='sampling'`` (unsampled points carry ``type_idx == -1``)."""
        from repro.core.sampling import SliceFeatures

        m = self.type_idx >= 0
        n = int(m.sum())
        pct = (np.bincount(self.type_idx[m], minlength=len(types))
               .astype(np.float64) / max(n, 1))
        return SliceFeatures(
            float(self.mean[m].mean()) if n else 0.0,
            float(self.std[m].mean()) if n else 0.0,
            pct, n,
        )

    @property
    def total_load_seconds(self) -> float:
        return sum(s.load_seconds for s in self.stats)

    @property
    def total_compute_seconds(self) -> float:
        return sum(s.compute_seconds for s in self.stats)

    @property
    def total_wait_seconds(self) -> float:
        return sum(s.wait_seconds for s in self.stats)


@dataclass(frozen=True)
class ExecutorReport:
    """Per-stage totals for one ``run``. ``wait_seconds`` is the time the
    compute stage spent blocked on the load stage — with prefetch it should
    be a small fraction of ``load_seconds`` (the rest was hidden behind
    compute); serially the two are equal by construction."""

    wall_seconds: float
    units: int
    load_seconds: float
    wait_seconds: float
    compute_seconds: float
    persist_seconds: float
    # Fault-tolerance totals across the run's slices (DESIGN.md §14).
    retries: int = 0
    speculations: int = 0
    speculation_wins: int = 0
    quarantined: int = 0

    @property
    def load_hidden_seconds(self) -> float:
        return max(0.0, self.load_seconds - self.wait_seconds)

    @property
    def load_hidden_fraction(self) -> float:
        return self.load_hidden_seconds / self.load_seconds if self.load_seconds > 0 else 0.0


@functools.lru_cache(maxsize=64)
def _jitted_fns(types: tuple, num_bins: int, mode: str, fit_backend: str):
    """Module-level jit cache: every executor with the same (types, bins,
    mode, backend) shares compiled executables — windows, slices and method
    variants reuse them instead of recompiling per instance.

    The fit entry points donate their window buffer: the prefetcher's staged
    array (or the grouping path's gathered representative batch) is dead
    once the fit has consumed it, so XLA reuses it in place instead of
    copying (moments_f runs first on the same buffer and must not donate).
    """
    backend = fitting.get_fit_backend(fit_backend, num_bins)

    @jax.jit
    def moments_f(values):
        return backend.moments(values)

    @_quiet_donation
    @functools.partial(jax.jit, donate_argnums=(0,))
    def fit_all_f(values, moments):
        r = backend.fit_all(values, moments, types, num_bins, mode)
        return r.type_idx, r.params, r.error

    @_quiet_donation
    @functools.partial(jax.jit, donate_argnums=(0,))
    def fit_pred_f(values, moments, tree_arrays):
        # Tree features + the fixed-depth descent live inside the executable:
        # the predict step is ~15 eager dispatches per window otherwise.
        pred = mlp.predict(tree_arrays, tree_features(moments))
        r = backend.fit_predicted(values, moments, pred, types, num_bins)
        return r.type_idx, r.params, r.error

    @jax.jit
    def gather_f(values, moments, idx):
        # One executable for the grouping/reuse representative gather: the
        # values rows and all six moment fields in a single dispatch (the
        # per-field np round-trips used to dominate small grouped windows).
        return fitting.gather_rows(values, moments, idx)

    return moments_f, fit_all_f, fit_pred_f, gather_f


class _SelectFns(NamedTuple):
    """Jitted entry points of the device Select path (select_backend='device').

    ``probe`` is the only per-window sync: it returns the device partition
    (rep_for_point, is_rep stay on device) plus the scalar group count
    the host needs to pick a static padded batch size. ``select_fit_all`` /
    ``select_fit_pred`` then run gather -> fit -> scatter in one launch;
    ``compact`` serves the reuse methods, which keep their host cache but
    never bounce the full (P,) keys through np.unique."""

    probe: Callable
    select_fit_all: Callable
    select_fit_pred: Callable
    compact: Callable


@functools.lru_cache(maxsize=64)
def _jitted_select_fns(
    types: tuple, num_bins: int, mode: str, fit_backend: str, group_tol: float
) -> _SelectFns:
    """Device-side Select executables (ROADMAP 'grouping-aware fused
    dispatch'): quantize -> group_device -> representative gather -> fit ->
    scatter without the host dedup bounce. Safe to build on the now-exact
    hi/lo keys: the device partition is bit-identical to the host f64 one,
    so per-point results match the host Select path bitwise (per-row fit
    determinism: every backend's fit is row-independent, so batch order and
    padding rows cannot change a representative's result)."""
    backend = fitting.get_fit_backend(fit_backend, num_bins)

    @jax.jit
    def probe_f(moments):
        # The keys themselves are NOT an output: the grouping methods never
        # consume them, and re-deriving them in compact_f (elementwise, no
        # sort) is cheaper than committing a (P, 4) buffer every window.
        keys = grp.quantize_keys_from_var(moments.mean, moments.var, group_tol)
        g = grp.group_device(keys)
        return g.num_groups, g.rep_for_point, g.is_rep

    @_quiet_donation
    @functools.partial(jax.jit, static_argnums=(4,), donate_argnums=(0,))
    def select_fit_all_f(values, moments, rep_for_point, is_rep, padded_g):
        gather_idx, point_slot = grp.compact_representatives(
            rep_for_point, is_rep, padded_g
        )
        r = fitting.fit_all_rows(
            backend, values, moments, gather_idx, types, num_bins, mode
        )
        return (
            grp.scatter_group_results(r.type_idx, point_slot),
            grp.scatter_group_results(r.params, point_slot),
            grp.scatter_group_results(r.error, point_slot),
        )

    @_quiet_donation
    @functools.partial(jax.jit, static_argnums=(5,), donate_argnums=(0,))
    def select_fit_pred_f(values, moments, rep_for_point, is_rep, tree_arrays, padded_g):
        gather_idx, point_slot = grp.compact_representatives(
            rep_for_point, is_rep, padded_g
        )
        sub_vals, sub_mom = fitting.gather_rows(values, moments, gather_idx)
        pred = mlp.predict(tree_arrays, tree_features(sub_mom))
        r = backend.fit_predicted(sub_vals, sub_mom, pred, types, num_bins)
        return (
            grp.scatter_group_results(r.type_idx, point_slot),
            grp.scatter_group_results(r.params, point_slot),
            grp.scatter_group_results(r.error, point_slot),
        )

    @functools.partial(jax.jit, static_argnums=(3,))
    def compact_f(moments, rep_for_point, is_rep, padded_g):
        keys = grp.quantize_keys_from_var(moments.mean, moments.var, group_tol)
        gather_idx, point_slot = grp.compact_representatives(
            rep_for_point, is_rep, padded_g
        )
        return gather_idx, keys[gather_idx], point_slot

    return _SelectFns(probe_f, select_fit_all_f, select_fit_pred_f, compact_f)


class _StagedWindow(NamedTuple):
    """Load-stage output: device-resident values, ready for the moments
    kernel. Moments are deliberately NOT dispatched here: launching them
    from the prefetch thread makes two XLA computations contend for the
    device (a measurable slowdown on small CPU devices), while the kernel
    itself is cheap relative to the fit — so it stays on the compute
    stage's critical path, like every other device op."""

    unit: regions.WorkUnit
    values: jax.Array
    load_seconds: float


class _FailedUnit(NamedTuple):
    """Load/compute-stage output for a unit that exhausted its retries in
    degraded mode: flows down the same stream as ``_StagedWindow`` (raising
    from the prefetch thread would kill the whole stream) and is quarantined
    by the run loop instead of computed."""

    unit: regions.WorkUnit
    error: str
    attempts: int


def _errstr(e: BaseException) -> str:
    return f"{type(e).__name__}: {e}"


# The per-point result arrays of a SliceResult, in persisted/cached order —
# the one canonical list (persist stage, ResultCache, benchmarks and the
# bitwise-equality tests all import it; a new field added here is
# automatically persisted, cached, and covered).
RESULT_FIELDS = ("type_idx", "params", "error", "mean", "std", "skew", "kurt")
_FIELDS = RESULT_FIELDS


class WindowResult(NamedTuple):
    """Per-point results of ONE window — the unit the serving layer
    caches, scatters into answers, and assembles into ``SliceResult``s.
    Field order after ``window`` matches ``RESULT_FIELDS``."""

    window: regions.Window
    type_idx: np.ndarray  # (P,) int32
    params: np.ndarray  # (P, 3) float32
    error: np.ndarray  # (P,)
    mean: np.ndarray  # (P,)
    std: np.ndarray  # (P,)
    skew: np.ndarray  # (P,)
    kurt: np.ndarray  # (P,)

    def arrays(self) -> dict[str, np.ndarray]:
        return {name: getattr(self, name) for name in _FIELDS}


class PersistStage:
    """Writes per-window ``.npz`` + watermark, optionally off-thread.

    One writer thread drains a FIFO queue, so windows of a slice persist in
    submission order and the watermark (``next_line``) is only advanced
    after its window file is durable — exactly the serial path's restart
    contract. ``flush()`` blocks until everything submitted is written;
    the executor flushes before returning *and* before propagating any
    compute-stage exception, so a crash loses at most the in-flight window.
    """

    def __init__(self, out_dir: str | Path | None, async_writes: bool = True,
                 monitor: StepMonitor | None = None,
                 spec_hash: str | None = None,
                 injector=None,
                 total_lines: int | None = None):
        self.out_dir = Path(out_dir) if out_dir else None
        self.monitor = monitor
        self.spec_hash = spec_hash  # stamped into every .npz + watermark
        # Lines per slice, when the caller knows it: lets the watermark
        # carry an explicit ``complete`` stamp (the cluster redeal scan's
        # recovery line) instead of readers re-deriving it from geometry.
        self.total_lines = total_lines
        self.injector = injector  # faults.FaultInjector (on_persist hook)
        self.seconds = 0.0
        self.writes = 0
        self.retries = 0  # transient write failures absorbed in _write
        self._error: BaseException | None = None
        self._async = bool(async_writes and self.out_dir is not None)
        if self._async:
            self._q: queue.Queue = queue.Queue()
            self._thread = threading.Thread(
                target=self._loop, name="window-persist", daemon=True
            )
            self._thread.start()

    # -- submission -----------------------------------------------------------

    def submit(self, slice_i: int, w: regions.Window, arrays: dict[str, np.ndarray]):
        """``arrays`` maps _FIELDS names to the window's result views; the
        views stay valid because windows are disjoint and the output buffers
        outlive the stage."""
        if self.out_dir is None:
            return
        if self._async:
            self._q.put((slice_i, w, arrays))
        else:
            self._write(slice_i, w, arrays)

    def _loop(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                if self._error is None:
                    self._write(*item)
            except BaseException as e:  # repro: allow[ERR]: parked — flush()/raise_if_failed re-raise on the main thread
                self._error = e
            finally:
                self._q.task_done()

    def _write(self, slice_i: int, w: regions.Window, arrays: dict[str, np.ndarray]):
        uid = f"persist:s{slice_i}/l{w.line_start:05d}"
        t0 = time.perf_counter()
        if self.monitor is not None:
            self.monitor.start(uid, now=t0)
        try:
            # Transient write failures (an NFS hiccup mid-savez, or the
            # injector's persist_error) get two quiet re-attempts — a
            # partially-written .npz is simply overwritten, and the
            # watermark only advances after a successful write.
            for attempt in range(3):
                try:
                    if self.injector is not None:
                        self.injector.on_persist(slice_i, w.line_start)
                    self._write_once(slice_i, w, arrays)
                    break
                except OSError:
                    if attempt == 2:
                        raise
                    self.retries += 1
                    time.sleep(0.01 * (attempt + 1))
        except BaseException:
            if self.monitor is not None:
                self.monitor.abandon(uid)
            raise
        t1 = time.perf_counter()
        if self.monitor is not None:
            self.monitor.finish(uid, now=t1)
        self.seconds += t1 - t0
        self.writes += 1

    def _write_once(self, slice_i: int, w: regions.Window,
                    arrays: dict[str, np.ndarray]):
        self.out_dir.mkdir(parents=True, exist_ok=True)
        extra = {"spec_hash": self.spec_hash} if self.spec_hash else {}
        np.savez(
            self.out_dir / f"slice{slice_i}_window_{w.line_start:05d}.npz",
            line_start=w.line_start, line_end=w.line_end, **extra, **arrays,
        )
        mark: dict = {"next_line": int(w.line_end), **extra}
        if self.total_lines is not None:
            mark["complete"] = int(w.line_end) >= self.total_lines
        (self.out_dir / f"slice{slice_i}_watermark.json").write_text(
            json.dumps(mark)
        )

    # -- lifecycle ------------------------------------------------------------

    def flush(self):
        if self._async:
            self._q.join()

    def raise_if_failed(self):
        if self._error is not None:
            raise RuntimeError("persist stage failed") from self._error

    def close(self):
        """Flush pending writes and stop the writer; never raises (call
        ``raise_if_failed`` on the success path)."""
        if self._async and self._thread.is_alive():
            self._q.put(None)
            self._q.join()
            self._thread.join(timeout=5.0)

    # -- watermark / restore (resume) -----------------------------------------

    def watermark_info(self, slice_i: int) -> dict:
        if self.out_dir is None:
            return {"next_line": 0}
        f = self.out_dir / f"slice{slice_i}_watermark.json"
        if not f.exists():
            return {"next_line": 0}
        return json.loads(f.read_text())

    def watermark(self, slice_i: int) -> int:
        return int(self.watermark_info(slice_i)["next_line"])

    def check_resume_hash(self, slice_i: int, info: dict):
        """Resume-mismatch detection: a watermark written under a different
        spec hash describes a *different computation* (other tolerance,
        candidate set, source seed...) — silently mixing its windows into
        this run would corrupt the output, so refuse."""
        stored = info.get("spec_hash")
        if stored and self.spec_hash and stored != self.spec_hash:
            raise ValueError(
                f"resume mismatch for slice {slice_i}: watermark in "
                f"{self.out_dir} was written by spec {stored}, this run is "
                f"spec {self.spec_hash} — point --out-dir elsewhere or "
                "re-run without resume")

    def restore_windows(self, slice_i: int, upto_line: int, ppl: int,
                        outs: dict[str, np.ndarray]):
        for f in sorted(self.out_dir.glob(f"slice{slice_i}_window_*.npz")):
            z = np.load(f)
            if int(z["line_end"]) <= upto_line:
                lo, hi = int(z["line_start"]) * ppl, int(z["line_end"]) * ppl
                for name in _FIELDS:
                    outs[name][lo:hi] = z[name]

    # -- degraded mode: the failed-unit manifest -------------------------------

    def failed_manifest_path(self, slice_i: int) -> Path:
        return self.out_dir / f"slice{slice_i}_failed_units.json"

    def write_failed_manifest(self, slice_i: int, entries: list[dict]):
        """Record a degraded slice's quarantined units next to its watermark
        — the completion contract of degraded mode (DESIGN.md §14): the run
        *finished*, and this file says exactly which windows it finished
        without. An empty entry list deletes the manifest (the slice was
        repaired, e.g. by a resume that re-ran the quarantined units)."""
        if self.out_dir is None:
            return
        f = self.failed_manifest_path(slice_i)
        if not entries:
            f.unlink(missing_ok=True)
            return
        self.out_dir.mkdir(parents=True, exist_ok=True)
        f.write_text(json.dumps(
            {"spec_hash": self.spec_hash, "slice": slice_i, "failed": entries},
            indent=1,
        ))

    def failed_lines(self, slice_i: int) -> set[int]:
        """line_start of every quarantined unit recorded for the slice —
        resume re-runs these even below the watermark (their .npz was never
        written, so the watermark alone cannot see the hole)."""
        if self.out_dir is None:
            return set()
        f = self.failed_manifest_path(slice_i)
        if not f.exists():
            return set()
        return {int(e["line_start"])
                for e in json.loads(f.read_text()).get("failed", ())}


class StagedExecutor:
    """Drives Algorithms 1-2 over a Plan of (slice, window) work units.

    ``data_source`` must expose ``geometry: regions.CubeGeometry`` and
    ``load_window(window) -> np.ndarray (num_points, n_obs) float32``.
    The reuse cache lives on the executor, so windows — and consecutive
    slices of a multi-slice plan — share it exactly as consecutive
    ``run_slice`` calls on one ``PDFComputer`` always have.
    """

    def __init__(
        self,
        config: PDFConfig,
        data_source,
        tree: mlp.DecisionTree | None = None,
        out_dir: str | Path | None = None,
        sharding: jax.sharding.Sharding | None = None,
        exec_config: ExecutorConfig | None = None,
        spec_hash: str | None = None,
        injector=None,
        stats_recorder=None,
    ):
        self.config = config
        self.data = data_source
        self.tree = tree
        self.out_dir = Path(out_dir) if out_dir else None
        self.sharding = sharding
        self.exec_config = exec_config or ExecutorConfig()
        self.spec_hash = spec_hash  # provenance stamp (api/spec.py hash)
        self.injector = injector  # faults.FaultInjector (persist-path hook)
        # streaming.stats.StatsRecorder (or any callable taking
        # (window, values, moments)): observes each full window's staged
        # values + moments before the fit donates the buffer, so merge-able
        # sufficient statistics can be persisted without a second read.
        self.stats_recorder = stats_recorder
        self.cache = ReuseCache()
        if ("ml" in config.method or config.method == "sampling") and tree is None:
            raise ValueError(f"method {config.method!r} requires a decision tree")

        self._moments, self._fit_all, self._fit_pred, self._gather = _jitted_fns(
            tuple(config.types), config.num_bins, config.mode, config.fit_backend
        )
        self._sel_fns = (
            _jitted_select_fns(
                tuple(config.types), config.num_bins, config.mode,
                config.fit_backend, config.group_tol,
            )
            if config.select_backend == "device"
            else None
        )
        self._key_buf: np.ndarray | None = None  # cached (P, 2) quantize buffer
        self._tree_arrays = tree.as_device() if tree else None
        # One StepMonitor per stage: medians/straggler flags per stage. The
        # load monitor's grace floor is configurable so chaos tests can
        # exercise speculation without second-long stalls; under
        # speculation the load monitor sees one start/finish per *attempt*
        # (deque/dict ops are GIL-atomic, failed attempts are abandoned so
        # they never enter the straggler median).
        self.monitors = {
            "load": StepMonitor(StragglerPolicy(
                grace_seconds=self.exec_config.straggler_grace_s)),
            "compute": StepMonitor(),
            "persist": StepMonitor(),
        }
        self.last_report: ExecutorReport | None = None
        # Per-run fault bookkeeping: {slice -> counter dict} + quarantined
        # unit records, reset by run(); the lock covers prefetch-thread vs
        # compute-thread increments.
        self._fault_lock = threading.Lock()
        self._fault_counts: dict[int, dict[str, int]] = {}
        self._spec_pool: futures.ThreadPoolExecutor | None = None

    # -- load stage -----------------------------------------------------------

    def _stage(self, values: np.ndarray) -> jax.Array:
        arr = jnp.asarray(values, dtype=jnp.float32)
        if self.sharding is not None:
            arr = jax.device_put(arr, self.sharding)
        return arr

    def _load_unit(self, unit: regions.WorkUnit,
                   uid: str | None = None) -> _StagedWindow:
        """Load + H2D-stage one window (host work only — device kernels stay
        on the compute stage); runs on the prefetch thread when prefetch is
        enabled, or on speculation-pool threads under re-dispatch. ``uid``
        distinguishes attempts of the same unit in the load monitor; failed
        attempts are abandoned (no duration recorded) so an injected stall
        cannot poison the straggler median."""
        mon = self.monitors["load"]
        uid = uid or unit.unit_id
        t0 = time.perf_counter()
        mon.start(uid, now=t0)
        try:
            raw = self.data.load_window(unit.window)  # (P, n_obs)
            values = self._stage(raw)
        except BaseException:
            mon.abandon(uid)
            raise
        t1 = time.perf_counter()
        mon.finish(uid, now=t1)
        return _StagedWindow(unit, values, t1 - t0)

    # -- fault tolerance: retry, speculation, quarantine (DESIGN.md §14) -------

    def _note_fault(self, slice_i: int, key: str, n: int = 1):
        with self._fault_lock:
            c = self._fault_counts.setdefault(
                slice_i,
                {"retries": 0, "speculations": 0, "speculation_wins": 0},
            )
            c[key] += n

    def _backoff(self, unit: regions.WorkUnit, attempt: int) -> float:
        """Exponential backoff with *deterministic* jitter: hashed from
        (unit, attempt) so a re-run backs off identically — randomness
        would be the one nondeterminism in an otherwise replayable failure
        path. Jitter in [0.5x, 1.5x) still de-correlates units that failed
        together (the thundering-herd concern jitter exists for)."""
        h = hashlib.sha256(f"{unit.unit_id}:{attempt}".encode()).digest()
        jitter = 0.5 + h[0] / 256.0
        return self.exec_config.retry_backoff_s * (2 ** attempt) * jitter

    def _pool(self) -> futures.ThreadPoolExecutor:
        # 4 workers: a straggling loser may still occupy one while the next
        # unit's primary + speculative pair runs — 2 would deadlock behind it.
        if self._spec_pool is None:
            self._spec_pool = futures.ThreadPoolExecutor(
                max_workers=4, thread_name_prefix="load-spec")
        return self._spec_pool

    def _load_speculative(self, unit: regions.WorkUnit,
                          uid: str) -> _StagedWindow:
        """One load attempt with straggler speculation: if the primary load
        exceeds max(threshold x trailing-median, grace), dispatch a
        bitwise-identical second load and take whichever finishes first
        (the Spark speculative-execution contract — safe because loads are
        deterministic and fits row-pure, so winner identity cannot change
        the result's bytes). Below ``min_samples`` completed loads there is
        no median and the attempt runs inline."""
        mon = self.monitors["load"]
        med = mon.median()
        if med is None:
            return self._load_unit(unit, uid=uid)
        pol = mon.policy
        limit = max(pol.threshold * med, pol.grace_seconds)
        pool = self._pool()
        primary = pool.submit(self._load_unit, unit, uid)
        done, _ = futures.wait([primary], timeout=limit)
        if primary in done:
            return primary.result()  # raises the load's own error if it failed

        # Straggler: re-dispatch. First *success* wins; the loser runs to
        # completion in the pool (its duration is a real completed load, so
        # letting it report is correct) and its staged buffer is dropped.
        self._note_fault(unit.window.slice_i, "speculations")
        if uid not in mon.flagged:
            mon.flagged.append(uid)
        spec = pool.submit(self._load_unit, unit, f"{uid}#spec")
        pending = {primary, spec}
        while pending:
            done, pending = futures.wait(
                pending, return_when=futures.FIRST_COMPLETED)
            for f in done:
                if f.exception() is None:
                    if f is spec:
                        self._note_fault(
                            unit.window.slice_i, "speculation_wins")
                    return f.result()
        raise primary.exception()  # both attempts failed

    def _load_guarded(self, unit: regions.WorkUnit):
        """The load stage's retry wrapper (the prefetcher's stage_fn):
        transient failures back off and re-attempt up to ``max_retries``
        times; exhaustion returns a ``_FailedUnit`` sentinel — raising here
        would kill the whole prefetch stream, and would reach the consumer
        wrapped in an opaque ``PrefetchError``. The run loop turns the
        sentinel into quarantine (degraded mode) or a clean per-unit error.
        Fatal errors — including ``ShardLostError`` — always raise."""
        ec = self.exec_config
        last: BaseException | None = None
        for attempt in range(ec.max_retries + 1):
            uid = unit.unit_id if attempt == 0 else f"{unit.unit_id}#r{attempt}"
            try:
                if ec.speculate:
                    return self._load_speculative(unit, uid)
                return self._load_unit(unit, uid=uid)
            except Exception as e:  # noqa: BLE001 — classified below
                if not is_transient(e):
                    raise
                last = e
                if attempt < ec.max_retries:
                    self._note_fault(unit.window.slice_i, "retries")
                    time.sleep(self._backoff(unit, attempt))
        return _FailedUnit(unit, _errstr(last), ec.max_retries + 1)

    # -- compute stage: ComputePDF&Error dispatch per method -------------------

    def _fit(self, values: jax.Array, moments: dists.Moments):
        """Fit every row of ``values``; returns np arrays (type, params, err)."""
        if self._tree_arrays is not None and "ml" in self.config.method:
            t, p, e = self._fit_pred(values, moments, self._tree_arrays)
        else:
            t, p, e = self._fit_all(values, moments)
        return np.asarray(t), np.asarray(p), np.asarray(e)

    def _quantized_keys(self, moments: dists.Moments) -> np.ndarray:
        """Host-side (mu, sigma) quantization into a cached (P, 2) buffer
        (one allocation per window size instead of five temporaries per
        window; sigma is derived from var on host to skip a device op).

        The actual arithmetic lives in ``grouping.quantize_keys_host`` — the
        single definition of the key semantics, which the device path
        (``grouping.quantize_keys_from_var``) matches bit-for-bit. The
        previous inline version fed the f32 mean straight to ``np.divide``
        with an f64 ``out``, which numpy computes on the *f32* loop — at
        mean ~ 3e3 and tol = 1e-6 the ~3e9 quotient aliased on f32's 2^24
        grid in ~256-step buckets, merging points whose means differ by
        ~256x the configured tolerance (the exact failure this path's
        docstring claimed to have fixed)."""
        mean = np.asarray(moments.mean)
        var = np.asarray(moments.var)
        p = mean.shape[0]
        if self._key_buf is None or self._key_buf.shape[0] != p:
            self._key_buf = np.empty((p, 2), dtype=np.int64)
            self._key_tmp = np.empty((p,), dtype=np.float64)
        return grp.quantize_keys_host(
            mean, var, self.config.group_tol, out=self._key_buf, tmp=self._key_tmp
        )

    def _select_and_fit(self, values: jax.Array, moments: dists.Moments,
                        window: regions.Window,
                        sample_idx: np.ndarray | None = None,
                        total_points: int | None = None):
        """The Select step (§5.1/5.2): returns per-point results + bookkeeping.

        Dispatches on ``config.select_backend``: 'host' dedups via np.unique
        over host-quantized keys, 'device' keeps the dedup on the
        accelerator. Both are bitwise-equivalent (the device keys are exact
        hi/lo splits of the host int64 keys, and fits are row-deterministic).
        ``window``/``sample_idx``/``total_points`` only feed the sampling
        method (for every other method ``values`` covers the whole window).
        """
        method = self.config.method
        num_points = values.shape[0]
        if method == "sampling":
            return self._sample_classify(
                moments, window, total_points or num_points, sample_idx
            )
        if method in ("baseline", "ml"):
            t, p, e = self._fit(values, moments)
            return t, p, e, num_points, 0
        if self._sel_fns is not None:
            return self._select_device(values, moments)

        # grouping / reuse variants: dedup on host, fit representatives only.
        keys = self._quantized_keys(moments)
        groups = grp.group_host(keys)
        rep_t, rep_p, rep_e, fitted, cache_hits = self._fit_representatives(
            values, moments, keys[groups.rep_indices], groups.rep_indices
        )
        inv = groups.inverse
        return rep_t[inv], rep_p[inv], rep_e[inv], fitted, cache_hits

    def _fit_representatives(
        self,
        values: jax.Array,
        moments: dists.Moments,
        rep_keys: np.ndarray,
        rep_rows: np.ndarray,
    ):
        """Fit one row per group — the Select core shared by both backends.

        ``rep_keys`` (G, 2) int64 is each group's cache identity; ``rep_rows``
        (G,) the representatives' window row indices. Consults the reuse
        cache when the method carries one, fits the misses via the padded
        re-dispatch, and returns per-*group* results
        ``(rep_t, rep_p, rep_e, fitted, cache_hits)`` — the caller scatters
        them per point with its own inverse map."""
        method = self.config.method
        g = len(rep_rows)
        if method.startswith("reuse"):
            hit, cached = self.cache.lookup_window(rep_keys)
            cache_hits = int(hit.sum())
            todo = rep_rows[~hit]
        else:
            hit = np.zeros((g,), dtype=bool)
            cached = np.zeros((g, 5))
            todo = rep_rows
            cache_hits = 0

        rep_t = np.zeros((g,), dtype=np.int32)
        rep_p = np.zeros((g, 3), dtype=np.float32)
        rep_e = np.zeros((g,), dtype=np.float32)
        rep_t[hit] = cached[hit, 0].astype(np.int32)
        rep_p[hit] = cached[hit, 1:4]
        rep_e[hit] = cached[hit, 4]

        if len(todo):
            padded = grp.pad_representatives(todo, self.config.rep_bucket)
            # Single device gather for values + all moment fields (the old
            # per-field np.asarray round-trips cost ~7 transfers per window).
            sub_vals, sub_mom = self._gather(values, moments, jnp.asarray(padded))
            t, p, e = self._fit(sub_vals, sub_mom)  # dispatches ML per method
            t, p, e = t[: len(todo)], p[: len(todo)], e[: len(todo)]
            rep_t[~hit], rep_p[~hit], rep_e[~hit] = t, p, e
            if method.startswith("reuse"):
                self.cache.insert_window(
                    rep_keys[~hit],
                    np.concatenate(
                        [t[:, None], p, e[:, None]], axis=-1
                    ).astype(np.float64),
                )

        return rep_t, rep_p, rep_e, len(todo), cache_hits

    def _select_device(self, values: jax.Array, moments: dists.Moments):
        """Device-side Select (select_backend='device'): the grouping hot
        path never leaves the accelerator. ``probe`` quantizes + sorts on
        device; the only D2H is the scalar group count (needed to pick the
        static padded batch), after which one launch gathers the
        representatives, fits them, and scatters per-point results — no
        (P, 2) key download, no np.unique, no rep-index upload.

        The reuse methods keep the host cache (its store is a host dict by
        design) but swap the np.unique dedup for the device partition: only
        the compacted (G,) representative keys and the (P,) slot map come
        down, and cache misses reuse the existing padded re-dispatch, so
        results — and the evolving cache contents — stay bitwise-identical
        to the host path."""
        method = self.config.method
        fns = self._sel_fns
        num_g, rep_for_point, is_rep = fns.probe(moments)
        g = int(num_g)  # the one sync of the device Select path
        padded_g = grp.padded_size(g, self.config.rep_bucket)

        if method.startswith("grouping"):
            if self._tree_arrays is not None and "ml" in method:
                t, p, e = fns.select_fit_pred(
                    values, moments, rep_for_point, is_rep,
                    self._tree_arrays, padded_g,
                )
            else:
                t, p, e = fns.select_fit_all(
                    values, moments, rep_for_point, is_rep, padded_g
                )
            return np.asarray(t), np.asarray(p), np.asarray(e), g, 0

        # reuse / reuse_ml: device dedup + host cache — only the compacted
        # (G,) rep keys/rows and the (P,) slot map come down, then the
        # representative-fit core runs exactly as on the host path.
        gather_idx, rep_keys4, point_slot = fns.compact(
            moments, rep_for_point, is_rep, padded_g
        )
        rep_rows = np.asarray(gather_idx)[:g].astype(np.int64)
        rep_keys = grp.keys_to_int64(np.asarray(rep_keys4)[:g])  # (G, 2) int64
        rep_t, rep_p, rep_e, fitted, cache_hits = self._fit_representatives(
            values, moments, rep_keys, rep_rows
        )
        inv = np.asarray(point_slot)
        return rep_t[inv], rep_p[inv], rep_e[inv], fitted, cache_hits

    def _sample_seed(self, w: regions.Window) -> int:
        """Per-window draw seed from (sample_seed, slice, line): results do
        not depend on window execution order and survive resume."""
        return (self.config.sample_seed * 1_000_003 + w.slice_i * 100_003
                + w.line_start)

    def _draw_sample(self, num_points: int, w: regions.Window) -> np.ndarray:
        """The random sampler's index draw — needs only the window's point
        count, so the compute stage can subset the window *before* the
        moments pass (§5.4's cost is meant to fall with the rate)."""
        from repro.core import sampling as smp

        return smp.sample_indices_random(
            num_points, self.config.sample_frac, seed=self._sample_seed(w)
        )

    def _sample_classify(self, moments: dists.Moments, w: regions.Window,
                         num_points: int, idx: np.ndarray | None):
        """method='sampling' (§5.4, Algorithm 5): classify the sampled
        points' types with the decision tree (grouping-first dedup, Alg. 5
        lines 15-26) — no Eq.-5 fitting at all, which is the method's
        entire speedup. Unsampled points get ``type_idx = -1`` and zero
        params/error; ``SliceResult.features`` aggregates over the sampled
        subset only.

        ``idx`` is the pre-drawn random sample (``moments`` then cover only
        those rows — the run loop subsets the window before the moments
        pass, so load-side device work scales with the rate). For the
        k-means sampler ``idx`` is None: double sampling clusters on every
        point's (mu, sigma), so it inherently needs the full moments pass
        (the paper's extra cost for k-means, Fig. 16)."""
        from repro.core import sampling as smp

        cfg = self.config
        mean = np.asarray(moments.mean)
        var = np.asarray(moments.var)
        std = np.sqrt(np.maximum(var, 0.0))
        if idx is None:  # kmeans: cluster over the full window's features
            idx = smp.sample_indices_kmeans(
                np.stack([mean, std], axis=-1), cfg.sample_frac,
                iters=cfg.kmeans_iters, seed=self._sample_seed(w),
            )
            sub_mean, sub_std = mean[idx], std[idx]
            sub_skew = np.asarray(moments.skew)[idx]
            sub_kurt = np.asarray(moments.kurt)[idx]
        else:  # random: moments were computed on the sampled rows only
            sub_mean, sub_std = mean, std
            sub_skew = np.asarray(moments.skew)
            sub_kurt = np.asarray(moments.kurt)

        pred = smp.predict_types(
            sub_mean, sub_std, self.tree, group_tol=cfg.group_tol,
            skew=sub_skew, kurt=sub_kurt,
        )
        t = np.full((num_points,), -1, dtype=np.int32)
        t[idx] = pred
        params = np.zeros((num_points, 3), dtype=np.float32)
        err = np.zeros((num_points,), dtype=np.float32)
        # 'fitted' reports the classified sample count (nothing runs through
        # ComputePDF&Error for this method — that is the point).
        return t, params, err, len(idx), 0

    # -- run (Algorithm 1 over a Plan) -----------------------------------------

    def run(
        self,
        plan: regions.Plan,
        resume: bool = False,
        on_window: Callable[[WindowStats], None] | None = None,
    ) -> dict[int, SliceResult]:
        """Execute every unit of ``plan``; returns one SliceResult per slice.

        Pass the *full* plan even when resuming — completed windows are
        filtered against each slice's watermark here and their results
        restored from the persisted ``.npz`` files.
        """
        geom = self.data.geometry
        ppl = geom.points_per_line
        total = geom.points_per_slice
        requested = plan.slices

        persist = PersistStage(
            self.out_dir,
            async_writes=self.exec_config.async_persist,
            monitor=self.monitors["persist"],
            spec_hash=self.spec_hash,
            injector=self.injector,
            total_lines=geom.lines_per_slice,
        )

        outs = {
            s: {
                "type_idx": np.zeros((total,), dtype=np.int32),
                "params": np.zeros((total, 3), dtype=np.float32),
                "error": np.zeros((total,), dtype=np.float32),
                "mean": np.zeros((total,), dtype=np.float32),
                "std": np.zeros((total,), dtype=np.float32),
                "skew": np.zeros((total,), dtype=np.float32),
                "kurt": np.zeros((total,), dtype=np.float32),
            }
            for s in requested
        }
        stats: dict[int, list[WindowStats]] = {s: [] for s in requested}

        units = list(plan.units)
        if resume and self.out_dir is not None:
            infos = {s: persist.watermark_info(s) for s in requested}
            for s, info in infos.items():
                persist.check_resume_hash(s, info)
            marks = {s: int(info["next_line"]) for s, info in infos.items()}
            # Units a previous degraded run quarantined sit *below* the
            # watermark with no persisted .npz — the failed-unit manifest
            # is what re-includes them, so a fault-free resume repairs the
            # hole (and clears the manifest below).
            failed_prev = {s: persist.failed_lines(s) for s in requested}
            for s, mark in marks.items():
                if mark > 0:
                    persist.restore_windows(s, mark, ppl, outs[s])
            units = [
                u for u in units
                if u.window.line_start >= marks[u.window.slice_i]
                or u.window.line_start in failed_prev[u.window.slice_i]
            ]

        # retry/speculation threads bump these via _note_fault under the
        # same lock; an unlocked reset here raced a concurrent bump (the
        # LOCK rule's first true positive)
        with self._fault_lock:
            self._fault_counts = {}
        quarantined: dict[int, list[dict]] = {s: [] for s in requested}
        load_total = wait_total = compute_total = 0.0
        wall0 = time.perf_counter()
        prefetcher = None
        if self.exec_config.prefetch and units:
            prefetcher = WindowPrefetcher(
                units, self._load_guarded, depth=self.exec_config.prefetch_depth
            )
            stream = iter(prefetcher)
        else:
            stream = (self._load_guarded(u) for u in units)

        try:
            while True:
                w0 = time.perf_counter()
                try:
                    item = next(stream, None)
                except PrefetchError as pe:
                    # Shard death must surface as itself: the scheduler's
                    # re-deal catches ShardLostError, not the prefetch
                    # wrapper it crossed the thread boundary in.
                    if isinstance(pe.__cause__, ShardLostError):
                        raise pe.__cause__
                    raise
                if item is None:
                    break
                # wait_s: the only load-stage time the device was blocked on
                # (serial mode does the whole load inline here, so wait ==
                # load by construction; with prefetch it is the shortfall).
                wait_s = time.perf_counter() - w0

                if not isinstance(item, _FailedUnit):
                    item = self._compute_with_retry(item)
                if isinstance(item, _FailedUnit):
                    if not self.exec_config.degraded_mode:
                        raise RuntimeError(
                            f"work unit {item.unit.unit_id} failed after "
                            f"{item.attempts} attempts: {item.error}")
                    self._quarantine(item, outs, ppl, quarantined)
                    continue

                (w, t, p, e, mom_np, sample_idx, fitted, hits,
                 comp_s, _load_s) = item
                o = outs[w.slice_i]
                lo, hi = w.line_start * ppl, w.line_end * ppl
                o["type_idx"][lo:hi], o["params"][lo:hi], o["error"][lo:hi] = t, p, e
                if sample_idx is None:
                    for name, col in zip(("mean", "std", "skew", "kurt"), mom_np):
                        o[name][lo:hi] = col
                else:
                    # random sampling computed moments for the sampled rows
                    # only; unsampled rows stay zero (their type_idx is -1)
                    for name, col in zip(("mean", "std", "skew", "kurt"), mom_np):
                        o[name][lo:hi][sample_idx] = col

                ws = WindowStats(w, hi - lo, fitted, item.load_seconds,
                                 comp_s, hits, wait_s)
                stats[w.slice_i].append(ws)
                load_total += item.load_seconds
                wait_total += wait_s
                compute_total += comp_s

                persist.submit(
                    w.slice_i, w, {name: o[name][lo:hi] for name in _FIELDS}
                )
                if on_window:
                    on_window(ws)
        finally:
            if prefetcher is not None:
                prefetcher.close()
            persist.close()  # flushes: the watermark is durable before any re-raise
            if self._spec_pool is not None:
                self._spec_pool.shutdown(wait=False, cancel_futures=True)
                self._spec_pool = None

        persist.raise_if_failed()
        if self.out_dir is not None:
            for s in requested:
                persist.write_failed_manifest(s, quarantined[s])
        wall = time.perf_counter() - wall0
        counts = self._fault_counts
        self.last_report = ExecutorReport(
            wall_seconds=wall,
            units=sum(len(v) for v in stats.values()),
            load_seconds=load_total,
            wait_seconds=wait_total,
            compute_seconds=compute_total,
            persist_seconds=persist.seconds,
            retries=sum(c["retries"] for c in counts.values()),
            speculations=sum(c["speculations"] for c in counts.values()),
            speculation_wins=sum(
                c["speculation_wins"] for c in counts.values()),
            quarantined=sum(len(v) for v in quarantined.values()),
        )

        results: dict[int, SliceResult] = {}
        for s in requested:
            o = outs[s]
            avg_err = float(o["error"].mean())
            c = counts.get(s, {})
            r = SliceResult(o["type_idx"], o["params"], o["error"], o["mean"],
                            o["std"], o["skew"], o["kurt"], avg_err, stats[s],
                            slice_i=s, spec_hash=self.spec_hash,
                            retries=c.get("retries", 0),
                            speculations=c.get("speculations", 0),
                            quarantined=tuple(quarantined[s]))
            if self.config.error_bound is not None:
                r.error_bound_satisfied = avg_err <= self.config.error_bound
            results[s] = r
        return results

    class _ComputedWindow(NamedTuple):
        """One computed window: everything the run loop scatters/persists."""

        window: regions.Window
        type_idx: np.ndarray
        params: np.ndarray
        error: np.ndarray
        mom_np: tuple
        sample_idx: np.ndarray | None
        fitted: int
        cache_hits: int
        compute_seconds: float
        load_seconds: float = 0.0

    def _compute_window(self, item: _StagedWindow,
                        attempt: int = 0) -> "_ComputedWindow":
        """The compute-stage body for one staged window (moments + Select &
        fit) — factored out of the run loop so it can be retried as a unit."""
        cmon = self.monitors["compute"]
        unit = item.unit
        w = unit.window
        uid = unit.unit_id if attempt == 0 else f"{unit.unit_id}#c{attempt}"
        values = item.values
        total_points = values.shape[0]
        sample_idx = None
        if (self.config.method == "sampling"
                and self.config.sampler == "random"):
            # §5.4's entire point: only the sampled fraction is touched —
            # subset the window on device *before* the moments pass, so
            # per-window device work (and the figure-15 cost curve) scales
            # with the rate. k-means keeps the full pass: it clusters on
            # every point's (mu, sigma) by construction.
            sample_idx = self._draw_sample(total_points, w)
            values = values[jnp.asarray(sample_idx)]
        moments = jax.block_until_ready(self._moments(values))
        if self.stats_recorder is not None and sample_idx is None:
            # Must run before _select_and_fit: the fit executables donate
            # ``values``. Sampled windows are skipped — their stats describe
            # a draw, not the window, and cannot merge with append data.
            self.stats_recorder(w, values, dists.Moments(*moments))
        t1 = time.perf_counter()
        cmon.start(uid, now=t1)
        try:
            t, p, e, fitted, hits = self._select_and_fit(
                values, dists.Moments(*moments), w,
                sample_idx=sample_idx, total_points=total_points,
            )
        except BaseException:
            cmon.abandon(uid)
            raise
        t2 = time.perf_counter()
        cmon.finish(uid, now=t2)
        mom_np = (np.asarray(moments[0]),
                  np.sqrt(np.maximum(np.asarray(moments[1]), 0)),
                  np.asarray(moments[2]), np.asarray(moments[3]))
        return self._ComputedWindow(w, t, p, e, mom_np, sample_idx, fitted,
                                    hits, t2 - t1, item.load_seconds)

    def _compute_with_retry(self, item: _StagedWindow):
        """Compute one staged window, retrying transient failures with a
        *fresh load* each time — the fit executables donate the staged
        buffer, so after any fit dispatch the old device array must be
        treated as consumed. Returns a ``_ComputedWindow``, or a
        ``_FailedUnit`` after exhaustion (the run loop quarantines it in
        degraded mode, or raises a per-unit error outside it)."""
        ec = self.exec_config
        unit = item.unit
        last: BaseException | None = None
        for attempt in range(ec.max_retries + 1):
            try:
                if item is None:
                    item = self._load_unit(unit, uid=f"{unit.unit_id}#c{attempt}")
                return self._compute_window(item, attempt)
            except Exception as e:  # noqa: BLE001 — classified below
                if not is_transient(e):
                    raise
                last = e
                item = None  # possibly-donated buffer: reload next attempt
                if attempt < ec.max_retries:
                    self._note_fault(unit.window.slice_i, "retries")
                    time.sleep(self._backoff(unit, attempt))
        return _FailedUnit(unit, _errstr(last), ec.max_retries + 1)

    def _quarantine(self, failed: _FailedUnit, outs: dict, ppl: int,
                    quarantined: dict[int, list[dict]]):
        """Degraded mode's terminal state for a unit: its points carry
        ``type_idx = -1`` (the established unclassified marker) and zero
        params/moments, nothing is persisted for the window (the manifest —
        not a fabricated .npz — records the hole), and the run continues."""
        w = failed.unit.window
        o = outs[w.slice_i]
        lo, hi = w.line_start * ppl, w.line_end * ppl
        o["type_idx"][lo:hi] = -1
        for name in ("params", "error", "mean", "std", "skew", "kurt"):
            o[name][lo:hi] = 0
        quarantined[w.slice_i].append({
            "unit_id": failed.unit.unit_id,
            "line_start": int(w.line_start),
            "line_end": int(w.line_end),
            "attempts": int(failed.attempts),
            "error": failed.error,
        })

    def run_slice(
        self,
        slice_i: int,
        resume: bool = False,
        on_window: Callable[[WindowStats], None] | None = None,
    ) -> SliceResult:
        plan = regions.build_plan(
            self.data.geometry, [slice_i], self.config.window_lines
        )
        return self.run(plan, resume=resume, on_window=on_window)[slice_i]

    # -- externally-batched work units (the serving layer's entry points) ------

    def run_window_batch(
        self, windows: list[regions.Window]
    ) -> list[WindowResult]:
        """Compute many windows with shared device launches — the warm
        executor's entry point for externally-batched work (the serving
        layer's coalesced tick; ``windows`` must be distinct, in any order,
        possibly spanning slices).

        Per-point results are **bitwise-identical** to running each window
        through ``run_window``, by construction: every launch the batch
        issues has the exact shape the serial path would compile for, so
        both paths execute the same XLA executables — and within one
        executable per-row results are position- and content-independent
        (moments and fits are row-pure; padding rows and neighbours cannot
        perturb a row's bits). Concretely:

        * moments run per window at the window's own shape — only their
          *dispatch* is shared (all launched asynchronously, one barrier),
          which removes the serial path's per-window sync.
        * the grouped methods' representative fits are packed: each
          window's Select (quantize → group → representative choice) is
          made per window exactly as serially, then whole windows whose
          serial fit shape class (``grp.padded_size(groups, rep_bucket)``)
          matches are packed into one gather + fit launch of that shape —
          many windows' representatives per dispatch, same executable as
          each window's solo fit.

        Naively concatenating windows into one big launch is ~2x fewer
        dispatches still, but a different-shaped executable vectorizes
        reductions differently and drifts results by ~1 ulp — the serving
        layer's equivalence contract (DESIGN.md §13) forbids exactly that.

        Three methods fall back to per-window ``run_window`` dispatch, by
        design: ``sampling`` (its cost is host-side classification; there
        is no device fit to share), the ``reuse`` variants (cache-hit
        values depend on insertion order, so batching lookups would serve
        different — not just differently-counted — fits), and any method
        under ``select_backend='device'`` (its gather→fit→scatter is fused
        into one per-window executable there)."""
        if not windows:
            return []
        if len({(w.slice_i, w.line_start) for w in windows}) != len(windows):
            raise ValueError("run_window_batch windows must be distinct")
        method = self.config.method
        if (method == "sampling" or method.startswith("reuse")
                or self._sel_fns is not None):
            return [self.run_window(w) for w in windows]

        lmon = self.monitors["load"]
        raws = []
        for w in windows:
            uid = f"batch:s{w.slice_i}/l{w.line_start:05d}"
            lmon.start(uid, now=time.perf_counter())
            raws.append(self.data.load_window(w))
            lmon.finish(uid, now=time.perf_counter())
        if self.sharding is None and len(raws) > 1:
            # one H2D for the whole batch, sliced back into window-shaped
            # device arrays (same f32 bits; slicing is pure data movement)
            bounds = np.cumsum([0] + [r.shape[0] for r in raws])
            cat = self._stage(np.concatenate(raws, axis=0))
            staged = [cat[a:b] for a, b in zip(bounds[:-1], bounds[1:])]
        else:
            staged = [self._stage(r) for r in raws]
        pending = [self._moments(v) for v in staged]  # async; barrier below
        moments = [dists.Moments(*jax.block_until_ready(m)) for m in pending]

        cmon = self.monitors["compute"]
        uid = (f"batch:s{windows[0].slice_i}/l{windows[0].line_start:05d}"
               f"x{len(windows)}")
        cmon.start(uid, now=time.perf_counter())
        if method in ("baseline", "ml"):
            # per-window fit launches (the serial shape), dispatched without
            # intermediate syncs; host conversion after the last dispatch
            if self._tree_arrays is not None and "ml" in method:
                fits = [self._fit_pred(v, m, self._tree_arrays)
                        for v, m in zip(staged, moments)]
            else:
                fits = [self._fit_all(v, m) for v, m in zip(staged, moments)]
            per = [tuple(np.asarray(x) for x in f) for f in fits]
        else:
            per = self._select_and_fit_packed(staged, moments)
        cmon.finish(uid, now=time.perf_counter())

        out = []
        for w, m, (t, p, e) in zip(windows, moments, per):
            out.append(WindowResult(
                w, t, p, e,
                np.asarray(m.mean),
                np.sqrt(np.maximum(np.asarray(m.var), 0)),
                np.asarray(m.skew), np.asarray(m.kurt)))
        return out

    def _select_and_fit_packed(self, staged: list, moments: list):
        """Grouped Select over a window batch: quantize + dedup per window
        on host (grouping scope = the window, as Algorithm 3 defines it),
        then pack whole windows of the same serial fit-shape class into
        shared gather + fit launches of exactly that shape. Returns
        per-window per-point ``(t, p, e)`` in window order."""
        bucket = self.config.rep_bucket
        infos = [grp.group_host(self._quantized_keys(m)) for m in moments]

        # pack: greedy fill within each shape class, preserving window order
        classes: dict[int, list[int]] = {}
        for i, g in enumerate(infos):
            classes.setdefault(grp.padded_size(g.num_groups, bucket),
                               []).append(i)
        launches: list[tuple[int, list[int]]] = []
        for size, idxs in sorted(classes.items()):
            cur: list[int] = []
            cur_n = 0
            for i in idxs:
                n = infos[i].num_groups
                if cur and cur_n + n > size:
                    launches.append((size, cur))
                    cur, cur_n = [], 0
                cur.append(i)
                cur_n += n
            if cur:
                launches.append((size, cur))

        offsets = np.cumsum([0] + [v.shape[0] for v in staged])
        cat_vals = jnp.concatenate(staged, axis=0)
        cat_mom = dists.Moments(
            *(jnp.concatenate(f, axis=0) for f in zip(*moments)))

        results: list = [None] * len(staged)
        for size, idxs in launches:
            # padding slots repeat the first representative — discarded by
            # the inverse maps, and row-pure kernels make their content moot
            idx = np.full(
                (size,),
                int(infos[idxs[0]].rep_indices[0]) + int(offsets[idxs[0]]),
                dtype=np.int64)
            pos = 0
            for i in idxs:
                n = infos[i].num_groups
                idx[pos:pos + n] = infos[i].rep_indices + offsets[i]
                pos += n
            sub_vals, sub_mom = self._gather(cat_vals, cat_mom,
                                             jnp.asarray(idx))
            t, p, e = self._fit(sub_vals, sub_mom)
            pos = 0
            for i in idxs:
                g = infos[i]
                n = g.num_groups
                inv = g.inverse
                results[i] = (t[pos:pos + n][inv], p[pos:pos + n][inv],
                              e[pos:pos + n][inv])
                pos += n
        return results

    def run_window(self, w: regions.Window) -> WindowResult:
        """ONE window through exactly the serial run-loop computation (load
        → moments → Select & fit), without persist: the per-window fallback
        of ``run_window_batch`` (method='sampling') and the serving layer's
        naive one-launch-per-query baseline."""
        item = self._load_unit(regions.WorkUnit(w, 0))
        values = item.values
        total_points = values.shape[0]
        sample_idx = None
        if (self.config.method == "sampling"
                and self.config.sampler == "random"):
            sample_idx = self._draw_sample(total_points, w)
            values = values[jnp.asarray(sample_idx)]
        moments = jax.block_until_ready(self._moments(values))
        cmon = self.monitors["compute"]
        uid = f"one:s{w.slice_i}/l{w.line_start:05d}"
        cmon.start(uid, now=time.perf_counter())
        t, p, e, _fitted, _hits = self._select_and_fit(
            values, dists.Moments(*moments), w,
            sample_idx=sample_idx, total_points=total_points,
        )
        cmon.finish(uid, now=time.perf_counter())
        mom_np = (np.asarray(moments[0]),
                  np.sqrt(np.maximum(np.asarray(moments[1]), 0)),
                  np.asarray(moments[2]), np.asarray(moments[3]))
        if sample_idx is None:
            mean, std, skew, kurt = mom_np
        else:
            # like the serial loop: unsampled rows stay zero (type_idx -1)
            mean, std, skew, kurt = (
                np.zeros((total_points,), dtype=np.float32) for _ in range(4))
            for dst, col in zip((mean, std, skew, kurt), mom_np):
                dst[sample_idx] = col
        return WindowResult(w, np.asarray(t), np.asarray(p), np.asarray(e),
                            mean, std, skew, kurt)

    # -- resume helpers (also used by the PDFComputer facade) ------------------

    def watermark(self, slice_i: int) -> int:
        return PersistStage(self.out_dir, async_writes=False).watermark(slice_i)
