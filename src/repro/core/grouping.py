"""Data grouping (§5.2): points sharing (quantized) mean/std fit once.

Three layers, mirroring how the paper's Spark shuffle decomposes on a TPU
mesh (DESIGN.md §2):

* ``quantize_keys``       — device: (mu, sigma) -> integer key pair.
* ``group_host``          — host: np.unique over a window's keys; returns the
  representative indices + inverse map. This is the honest analog of the
  paper's Aggregate: grouping is *data movement + dedup*, then the expensive
  fit runs only on representatives (real compute savings, since the host
  re-dispatches a smaller padded batch to the device).
* ``group_device_global`` — device: all_gather over the mesh + sort-based
  dedup, used by the dry-run to expose the *collective* cost of global
  grouping (the paper's "shuffle kills grouping at scale" finding shows up
  in the roofline's collective term).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_TOL = 1e-6


def quantize_keys(mean: jax.Array, std: jax.Array, tol: float = DEFAULT_TOL) -> jax.Array:
    """(P,) mu/sigma -> (P, 2) int32 quantized keys. tol is the paper's
    'acceptable fluctuation' (§5.2); exact grouping is tol -> 0.

    Quotients are folded into int32 range (mod 2^31) before the cast:
    XLA's out-of-range f32 -> s32 conversion saturates, which used to
    collapse every realistic seismic mean (~3e3 / 1e-6 tol ~ 3e9) into one
    key and so one giant group on the device path. The fold keeps keys
    exact below 2^31 and hash-like above (pairwise collision odds ~2^-31);
    the host Select path (``executor._quantized_keys``) quantizes exactly
    in float64 instead — see ROADMAP for unifying the two."""
    two31 = jnp.float32(2**31)
    qm = (jnp.round(mean / tol) % two31).astype(jnp.int32)
    qs = (jnp.round(std / tol) % two31).astype(jnp.int32)
    return jnp.stack([qm, qs], axis=-1)


class HostGroups(NamedTuple):
    rep_indices: np.ndarray  # (G,) indices of one representative per group
    inverse: np.ndarray  # (P,) group id of every point
    num_groups: int


def group_host(keys: np.ndarray) -> HostGroups:
    """Window-level dedup on host (the shuffle boundary). keys: (P, 2) int."""
    keys = np.asarray(keys)
    _, rep_indices, inverse = np.unique(
        keys, axis=0, return_index=True, return_inverse=True
    )
    return HostGroups(rep_indices.astype(np.int64), inverse.reshape(-1).astype(np.int64), len(rep_indices))


def pad_representatives(rep_indices: np.ndarray, bucket: int = 256) -> np.ndarray:
    """Pad the representative list to ``bucket * 2^k`` so the fit step's jit
    cache stays small across windows (padded slots repeat rep 0; their results
    are discarded by the inverse map).

    Geometric buckets bound the distinct padded shapes — and thus fit
    recompiles — to O(log P) per method instead of O(P/bucket), at the cost
    of at most 2x padding. Linear buckets made windows whose group count
    straddled a bucket edge trigger fresh XLA compiles mid-run (the
    fig06/4types grouping-slower-than-baseline inversion)."""
    g = len(rep_indices)
    padded = bucket
    while padded < g:
        padded *= 2
    out = np.full((padded,), rep_indices[0] if g else 0, dtype=np.int64)
    out[:g] = rep_indices
    return out


class DeviceGroups(NamedTuple):
    """Static-shape device grouping: every point learns its group's
    representative (the first point, in (key, index) sort order, holding an
    identical key)."""

    rep_for_point: jax.Array  # (P,) index of the point's representative
    is_rep: jax.Array  # (P,) bool
    num_groups: jax.Array  # () int32


def group_device(keys: jax.Array) -> DeviceGroups:
    """Sort-based dedup with static shapes (single shard).

    Sorts by (key_mu, key_sigma, index), marks segment heads, and propagates
    each segment head's original index with a cumulative max — O(P log P),
    no dynamic shapes, fully jit-able.
    """
    p = keys.shape[0]
    idx = jnp.arange(p, dtype=jnp.int32)
    order = jnp.lexsort((idx, keys[:, 1], keys[:, 0]))
    sk = keys[order]
    same_as_prev = jnp.concatenate(
        [jnp.array([False]), jnp.all(sk[1:] == sk[:-1], axis=-1)]
    )
    sorted_orig = order.astype(jnp.int32)
    # Segment head keeps its own index; followers inherit via cumulative max
    # (valid because within a segment the head has the smallest index only if
    # we seed followers with -1 and take a running max of head indices).
    head_idx = jnp.where(same_as_prev, -1, sorted_orig)
    seg_id = jnp.cumsum(jnp.logical_not(same_as_prev).astype(jnp.int32)) - 1
    # For each segment, the head value; scatter-max into (P,) segment table.
    seg_head = jnp.full((p,), -1, dtype=jnp.int32).at[seg_id].max(head_idx)
    rep_sorted = seg_head[seg_id]
    rep_for_point = jnp.zeros((p,), jnp.int32).at[order].set(rep_sorted)
    is_rep = rep_for_point == idx
    return DeviceGroups(rep_for_point, is_rep, jnp.sum(is_rep).astype(jnp.int32))


def group_device_global(keys: jax.Array, axis_names: tuple[str, ...]) -> DeviceGroups:
    """Global grouping across mesh axes — the paper's cross-node shuffle.

    all_gathers every shard's keys (this is the collective the roofline's
    collective term prices), dedups the gathered table, and maps each local
    point to its *global* representative index (flattened across shards).
    Call inside shard_map with ``axis_names`` bound.
    """
    gathered = keys
    for ax in axis_names:
        gathered = jax.lax.all_gather(gathered, ax, tiled=True)
    groups = group_device(gathered)
    # Local shard's slice of the global table:
    shard_index = 0
    total = 1
    for ax in axis_names:
        size = jax.lax.psum(1, ax)  # axis size (jax.lax.axis_size is newer jax)
        shard_index = shard_index * size + jax.lax.axis_index(ax)
        total *= size
    p_local = keys.shape[0]
    start = shard_index * p_local
    local_rep = jax.lax.dynamic_slice_in_dim(groups.rep_for_point, start, p_local)
    local_is_rep = jax.lax.dynamic_slice_in_dim(groups.is_rep, start, p_local)
    return DeviceGroups(local_rep, local_is_rep, groups.num_groups)


def scatter_group_results(
    rep_results: jax.Array, inverse: jax.Array
) -> jax.Array:
    """Representative results (G, ...) + inverse (P,) -> per-point (P, ...)."""
    return rep_results[inverse]
