"""Data grouping (§5.2): points sharing (quantized) mean/std fit once.

Three layers, mirroring how the paper's Spark shuffle decomposes on a TPU
mesh (DESIGN.md §2):

* ``quantize_keys``       — device: (mu, sigma) -> hi/lo int32 key columns,
  bit-exact with the host float64 Select path (``quantize_keys_host``).
* ``group_host``          — host: np.unique over a window's keys; returns the
  representative indices + inverse map. This is the honest analog of the
  paper's Aggregate: grouping is *data movement + dedup*, then the expensive
  fit runs only on representatives (real compute savings, since the host
  re-dispatches a smaller padded batch to the device).
* ``group_device_global`` — device: all_gather over the mesh + sort-based
  dedup, used by the dry-run to expose the *collective* cost of global
  grouping (the paper's "shuffle kills grouping at scale" finding shows up
  in the roofline's collective term).

Key semantics are unified: every path computes ``rint(x / tol)`` in float64
(the paper's 'acceptable fluctuation', §5.2). The host packs the quotient
into int64 columns; the device packs the same integer into (hi, lo) int32
column pairs — ``keys_to_int64`` converts between the two losslessly, so
host dedup, device dedup and the reuse cache all agree on what "the same
point" means for |quotient| < 2^63 of finite moments.
"""

from __future__ import annotations

import struct
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_TOL = 1e-6


# -- exact float64 lanes inside (possibly x64-disabled) traces -----------------
#
# The executor's jitted fns — and the dry-run's lowered step — are compiled
# with jax_enable_x64 off, where any *concrete* 64-bit constant captured by
# the trace is canonicalized down to 32 bits at lowering time (lowering runs
# outside any enable_x64 context, so ``jnp.float64(tol)`` silently becomes an
# f32 operand and the build fails or, worse, rounds). Ops recorded in the
# jaxpr keep their stated dtypes, so the rule is: 64-bit values may only be
# *derived by traced ops* — here, by bitcasting u32 words that are XORed with
# a traced u32 zero to tie them into the graph. Called eagerly on concrete
# arrays the same code simply executes in real f64 under the context.


def _traced_zero_u32(x: jax.Array) -> jax.Array:
    """A u32 zero that is a function of ``x`` (traced whenever x is)."""
    b = jax.lax.bitcast_convert_type(x.reshape(-1)[:1].astype(jnp.float32), jnp.uint32)
    return (b ^ b)[0]


def _exact_f64(x: float, zero_u32: jax.Array) -> jax.Array:
    """Embed the exact f64 scalar ``x`` via two u32 words (see note above)."""
    lo, hi = struct.unpack("<II", struct.pack("<d", float(x)))
    words = jnp.stack([zero_u32 ^ np.uint32(lo), zero_u32 ^ np.uint32(hi)])
    return jax.lax.bitcast_convert_type(words, jnp.float64)


def _hi_lo_i32(q64: jax.Array, two32: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Integer-valued f64 -> (hi, lo) int32 matching int64 ``q >> 32`` /
    ``q & 0xFFFFFFFF``. Pure f64 math (power-of-two scaling is exact for any
    f64 integer), so no int64 constants ever enter the trace."""
    hi_f = jnp.floor(q64 / two32)
    lo_f = q64 - hi_f * two32  # in [0, 2^32)
    hi = hi_f.astype(jnp.int32)
    lo = jax.lax.bitcast_convert_type(lo_f.astype(jnp.uint32), jnp.int32)
    return hi, lo


def quantize_keys(mean: jax.Array, std: jax.Array, tol: float = DEFAULT_TOL) -> jax.Array:
    """(P,) mu/sigma -> (P, 4) int32 keys ``[mu_hi, mu_lo, sig_hi, sig_lo]``.

    Bit-exact with the host Select path: the quotient ``rint(x / tol)`` is
    computed in true float64 (x64 lanes inside the surrounding trace) and
    split into hi/lo int32 words of its int64 value. This replaces the old
    mod-2^31 f32 fold, which aliased realistic seismic means (~3e3 at
    tol=1e-6 -> quotients ~3e9, past f32's 2^24 integer grid) into ~256-step
    buckets and went hash-like above int32 range — silently merging points
    whose statistics differ by far more than ``tol``. Exact for |quotient|
    < 2^63 of finite inputs (the same domain as the host int64 path).

    ``std`` is quantized as given; use :func:`quantize_keys_from_var` when
    only the variance is at hand (it reproduces the host's f64 sqrt).
    """
    with jax.experimental.enable_x64():
        # asarray inside the context: a float64 numpy input must stay f64
        # (outside, canonicalization would round it to f32 before the
        # widening — the aliasing class this function exists to eliminate).
        mean = jnp.asarray(mean)
        std = jnp.asarray(std)
        z = _traced_zero_u32(mean)
        t = _exact_f64(tol, z)
        two32 = _exact_f64(2.0**32, z)
        cols: list[jax.Array] = []
        for v in (mean, std):
            q = jnp.rint(v.astype(jnp.float64) / t)
            cols.extend(_hi_lo_i32(q, two32))
    return jnp.stack(cols, axis=-1)


def quantize_keys_from_var(
    mean: jax.Array, var: jax.Array, tol: float = DEFAULT_TOL
) -> jax.Array:
    """Quantize from (mean, var) exactly as the host Select path does:
    clamp, then sqrt in float64 (clamping commutes with the exact widening
    cast, and both paths' sqrt is correctly rounded f64)."""
    with jax.experimental.enable_x64():
        var = jnp.asarray(var)  # inside the context: f64 inputs stay f64
        # dtype-preserving zero built from a 32-bit literal (a 64-bit zero
        # constant would be canonicalized at an x64-off lowering)
        zero = jnp.asarray(0, jnp.int32).astype(var.dtype)
        std64 = jnp.sqrt(jnp.maximum(var, zero).astype(jnp.float64))
    return quantize_keys(mean, std64, tol)


def quantize_keys_host(
    mean: np.ndarray,
    var: np.ndarray,
    tol: float = DEFAULT_TOL,
    out: np.ndarray | None = None,
    tmp: np.ndarray | None = None,
) -> np.ndarray:
    """Host Select-path quantization: (P,) mean/var -> (P, 2) int64 keys.

    The promotion into the f64 scratch happens *before* the divide: numpy's
    NEP-50 loop selection computes ``np.divide(mean_f32, tol, out=f64)`` in
    float32 (the Python-float tol is weak), which silently re-introduced the
    f32-grid aliasing this path exists to avoid — casting first makes every
    op a genuine f64 loop. ``out``/``tmp`` let callers reuse buffers
    (one allocation per window size on the executor hot path)."""
    mean = np.asarray(mean)
    var = np.asarray(var)
    p = mean.shape[0]
    if out is None:
        out = np.empty((p, 2), dtype=np.int64)
    if tmp is None:
        tmp = np.empty((p,), dtype=np.float64)
    tmp[:] = mean  # exact f32 -> f64 widening
    np.divide(tmp, tol, out=tmp)
    np.rint(tmp, out=tmp)
    out[:, 0] = tmp
    tmp[:] = var
    np.maximum(tmp, 0.0, out=tmp)
    np.sqrt(tmp, out=tmp)
    np.divide(tmp, tol, out=tmp)
    np.rint(tmp, out=tmp)
    out[:, 1] = tmp
    return out


def quantize_features_host(
    mean: np.ndarray, std: np.ndarray, tol: float = DEFAULT_TOL
) -> np.ndarray:
    """(P,) mean/std -> (P, 2) int64 keys, for callers that already hold the
    standard deviation (the sampling path, Alg. 5 line 16). Same semantics
    as ``quantize_keys_host`` minus the var -> std derivation: widen to f64
    *before* the divide — the NEP-50 f32-loop trap applies here identically
    (``np.round(mean_f32 / tol)`` aliased on f32's 2^24 grid)."""
    mean = np.asarray(mean)
    std = np.asarray(std)
    out = np.empty((mean.shape[0], 2), dtype=np.int64)
    out[:, 0] = np.rint(mean.astype(np.float64) / tol)
    out[:, 1] = np.rint(std.astype(np.float64) / tol)
    return out


def keys_to_int64(keys: np.ndarray) -> np.ndarray:
    """(..., 2k) hi/lo int32 device keys -> (..., k) int64 host keys
    (the exact inverse of the hi/lo split; used for reuse-cache interop)."""
    k = np.asarray(keys)
    hi = k[..., 0::2].astype(np.int64)
    lo = k[..., 1::2].astype(np.int64) & 0xFFFFFFFF
    return (hi << 32) | lo


class HostGroups(NamedTuple):
    rep_indices: np.ndarray  # (G,) indices of one representative per group
    inverse: np.ndarray  # (P,) group id of every point
    num_groups: int


def group_host(keys: np.ndarray) -> HostGroups:
    """Window-level dedup on host (the shuffle boundary). keys: (P, C) int."""
    keys = np.asarray(keys)
    _, rep_indices, inverse = np.unique(
        keys, axis=0, return_index=True, return_inverse=True
    )
    return HostGroups(rep_indices.astype(np.int64), inverse.reshape(-1).astype(np.int64), len(rep_indices))


def padded_size(num: int, bucket: int = 256) -> int:
    """Smallest ``bucket * 2^k`` >= num (geometric jit-cache buckets)."""
    padded = bucket
    while padded < num:
        padded *= 2
    return padded


def pad_representatives(rep_indices: np.ndarray, bucket: int = 256) -> np.ndarray:
    """Pad the representative list to ``bucket * 2^k`` so the fit step's jit
    cache stays small across windows (padded slots repeat rep 0; their results
    are discarded by the inverse map).

    Geometric buckets bound the distinct padded shapes — and thus fit
    recompiles — to O(log P) per method instead of O(P/bucket), at the cost
    of at most 2x padding. Linear buckets made windows whose group count
    straddled a bucket edge trigger fresh XLA compiles mid-run (the
    fig06/4types grouping-slower-than-baseline inversion)."""
    g = len(rep_indices)
    out = np.full((padded_size(g, bucket),), rep_indices[0] if g else 0, dtype=np.int64)
    out[:g] = rep_indices
    return out


class DeviceGroups(NamedTuple):
    """Static-shape device grouping: every point learns its group's
    representative (the first point, in (key, index) sort order, holding an
    identical key).

    Contract for the sharded path (``group_device_global``): ``rep_for_point``
    and ``is_rep`` are *local-shard* slices (indices flattened across the
    shard-major gathered table), while ``num_groups`` is the *global* group
    count — summing ``is_rep`` on one shard counts only the groups whose
    representative lives there, and generally disagrees with ``num_groups``.
    ``num_groups_local`` is that per-shard count (sums to ``num_groups``
    across shards). For the single-shard ``group_device`` the two counts are
    equal by construction."""

    rep_for_point: jax.Array  # (P,) index of the point's representative
    is_rep: jax.Array  # (P,) bool
    num_groups: jax.Array  # () int32 — global group count
    num_groups_local: jax.Array  # () int32 — groups whose rep is on this shard


def group_device(keys: jax.Array) -> DeviceGroups:
    """Sort-based dedup with static shapes (single shard).

    Sorts by (*key columns, index), marks segment heads, and propagates
    each segment head's original index with a cumulative max — O(P log P),
    no dynamic shapes, fully jit-able. ``keys`` may have any number of
    integer columns; the exact path uses the (P, 4) hi/lo int32 pairs of
    ``quantize_keys``."""
    p = keys.shape[0]
    idx = jnp.arange(p, dtype=jnp.int32)
    # lexsort: last key is primary — index last so ties break by position.
    cols = tuple(keys[:, c] for c in reversed(range(keys.shape[-1])))
    order = jnp.lexsort((idx,) + cols)
    sk = keys[order]
    same_as_prev = jnp.concatenate(
        [jnp.array([False]), jnp.all(sk[1:] == sk[:-1], axis=-1)]
    )
    sorted_orig = order.astype(jnp.int32)
    # Segment head keeps its own index; followers inherit via cumulative max
    # (valid because within a segment the head has the smallest index only if
    # we seed followers with -1 and take a running max of head indices).
    head_idx = jnp.where(same_as_prev, -1, sorted_orig)
    seg_id = jnp.cumsum(jnp.logical_not(same_as_prev).astype(jnp.int32)) - 1
    # For each segment, the head value; scatter-max into (P,) segment table.
    seg_head = jnp.full((p,), -1, dtype=jnp.int32).at[seg_id].max(head_idx)
    rep_sorted = seg_head[seg_id]
    rep_for_point = jnp.zeros((p,), jnp.int32).at[order].set(rep_sorted)
    is_rep = rep_for_point == idx
    num = jnp.sum(is_rep).astype(jnp.int32)
    return DeviceGroups(rep_for_point, is_rep, num, num)


def group_device_global(keys: jax.Array, axis_names: tuple[str, ...]) -> DeviceGroups:
    """Global grouping across mesh axes — the paper's cross-node shuffle.

    all_gathers every shard's keys (this is the collective the roofline's
    collective term prices), dedups the gathered table, and maps each local
    point to its *global* representative index (flattened across shards).
    Call inside shard_map with ``axis_names`` bound.

    Returned counts follow the DeviceGroups contract: ``num_groups`` is the
    global count over the gathered table; ``num_groups_local`` counts the
    groups represented on *this* shard (``sum(is_rep)`` of the local slice),
    so per-shard callers tallying representatives agree with what they see.
    """
    gathered = keys
    for ax in axis_names:
        gathered = jax.lax.all_gather(gathered, ax, tiled=True)
    groups = group_device(gathered)
    # Local shard's slice of the global table:
    shard_index = 0
    total = 1
    for ax in axis_names:
        size = jax.lax.psum(1, ax)  # axis size (jax.lax.axis_size is newer jax)
        shard_index = shard_index * size + jax.lax.axis_index(ax)
        total *= size
    p_local = keys.shape[0]
    start = shard_index * p_local
    local_rep = jax.lax.dynamic_slice_in_dim(groups.rep_for_point, start, p_local)
    local_is_rep = jax.lax.dynamic_slice_in_dim(groups.is_rep, start, p_local)
    return DeviceGroups(
        local_rep,
        local_is_rep,
        groups.num_groups,
        jnp.sum(local_is_rep).astype(jnp.int32),
    )


def compact_representatives(
    rep_for_point: jax.Array, is_rep: jax.Array, padded_g: int
) -> tuple[jax.Array, jax.Array]:
    """Static-shape compaction of a DeviceGroups partition.

    Returns ``(gather_idx (padded_g,), point_slot (P,))``: ``gather_idx[:G]``
    are the representatives' original row indices in first-occurrence order
    (slots >= G fall back to row 0, discarded downstream) and ``point_slot``
    maps every point to its representative's slot — the device-side
    ``(rep_indices, inverse)`` pair, usable as gather/scatter indices inside
    one jitted launch. ``padded_g`` must be >= the partition's group count
    (out-of-range reps are silently dropped by the bounded scatter).
    """
    p = rep_for_point.shape[0]
    idx = jnp.arange(p, dtype=jnp.int32)
    rep_rank = jnp.cumsum(is_rep.astype(jnp.int32)) - 1  # slot of each rep
    slots = jnp.where(is_rep, rep_rank, padded_g)  # non-reps park in the sentinel
    gather_idx = jnp.zeros((padded_g + 1,), jnp.int32).at[slots].set(idx)[:padded_g]
    point_slot = rep_rank[rep_for_point]
    return gather_idx, point_slot


def scatter_group_results(
    rep_results: jax.Array, inverse: jax.Array
) -> jax.Array:
    """Representative results (G, ...) + inverse (P,) -> per-point (P, ...)."""
    return rep_results[inverse]
