"""Reuse optimization (§5.2.1): cache fitted PDFs across windows.

The paper stores every computed (mu, sigma) -> PDF result and, for each new
window, searches the store before fitting; it observes the search can cost
more than it saves (a list scan in their implementation). Our store is a host
dict keyed by the quantized key pair — O(1) amortized — but we keep the
paper's accounting: lookups/hits/misses and time spent searching are surfaced
so fig10's "Reuse can lose to Grouping" effect remains measurable.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np


@dataclass
class ReuseCache:
    """Cross-window PDF result cache. Keys: (q_mu, q_sigma) int tuples.
    Values: (type_idx, params[3], error) packed as a small np array."""

    max_entries: int = 50_000_000
    _store: dict = field(default_factory=dict)
    lookups: int = 0
    hits: int = 0
    search_seconds: float = 0.0

    def lookup_window(self, keys: np.ndarray):
        """keys (G, 2) for a window's representatives -> (mask_hit (G,),
        results (G, 5)) where results rows for misses are zero."""
        t0 = time.perf_counter()
        g = len(keys)
        hit = np.zeros((g,), dtype=bool)
        out = np.zeros((g, 5), dtype=np.float64)
        for i in range(g):
            self.lookups += 1
            rec = self._store.get((int(keys[i, 0]), int(keys[i, 1])))
            if rec is not None:
                hit[i] = True
                out[i] = rec
                self.hits += 1
        self.search_seconds += time.perf_counter() - t0
        return hit, out

    def insert_window(self, keys: np.ndarray, results: np.ndarray) -> None:
        """Store newly computed representative results (G, 5)."""
        if len(self._store) >= self.max_entries:
            return
        for i in range(len(keys)):
            self._store[(int(keys[i, 0]), int(keys[i, 1]))] = results[i]

    @property
    def size(self) -> int:
        return len(self._store)

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0
