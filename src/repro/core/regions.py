"""Spatial cube geometry (§3): cube -> slices -> lines -> points, windows.

A cube is (num_slices, lines_per_slice, points_per_line); a point's integer
identification (the paper's RDD key) is its flattened index. A window is a
contiguous run of lines within a slice (§4.2 principle 4: windows are
disjoint, fixed size once configured).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, NamedTuple


@dataclass(frozen=True)
class CubeGeometry:
    num_slices: int
    lines_per_slice: int
    points_per_line: int

    @property
    def points_per_slice(self) -> int:
        return self.lines_per_slice * self.points_per_line

    @property
    def total_points(self) -> int:
        return self.num_slices * self.points_per_slice

    def point_id(self, slice_i: int, line: int, point: int) -> int:
        return (slice_i * self.lines_per_slice + line) * self.points_per_line + point


class Window(NamedTuple):
    slice_i: int
    line_start: int
    line_end: int  # exclusive

    @property
    def num_lines(self) -> int:
        return self.line_end - self.line_start


def iter_windows(
    geom: CubeGeometry, slice_i: int, window_lines: int, start_line: int = 0
) -> Iterator[Window]:
    """Disjoint sliding windows over a slice; ``start_line`` supports
    restart-from-watermark (checkpointed window progress)."""
    line = start_line
    while line < geom.lines_per_slice:
        end = min(line + window_lines, geom.lines_per_slice)
        yield Window(slice_i, line, end)
        line = end


def num_windows(geom: CubeGeometry, window_lines: int) -> int:
    return -(-geom.lines_per_slice // window_lines)
