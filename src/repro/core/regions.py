"""Spatial cube geometry (§3): cube -> slices -> lines -> points, windows.

A cube is (num_slices, lines_per_slice, points_per_line); a point's integer
identification (the paper's RDD key) is its flattened index. A window is a
contiguous run of lines within a slice (§4.2 principle 4: windows are
disjoint, fixed size once configured).

The ``WorkUnit``/``Plan`` layer turns (slice, window) pairs into a
schedulable queue spanning multiple slices — the unit of the staged
executor (core/executor.py) and of per-node slice assignment
(runtime/scheduler.py), mirroring the paper's RDD-partition scheduling.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Mapping, NamedTuple, Sequence


@dataclass(frozen=True)
class CubeGeometry:
    num_slices: int
    lines_per_slice: int
    points_per_line: int

    @property
    def points_per_slice(self) -> int:
        return self.lines_per_slice * self.points_per_line

    @property
    def total_points(self) -> int:
        return self.num_slices * self.points_per_slice

    def point_id(self, slice_i: int, line: int, point: int) -> int:
        return (slice_i * self.lines_per_slice + line) * self.points_per_line + point


class Window(NamedTuple):
    slice_i: int
    line_start: int
    line_end: int  # exclusive

    @property
    def num_lines(self) -> int:
        return self.line_end - self.line_start


def iter_windows(
    geom: CubeGeometry, slice_i: int, window_lines: int, start_line: int = 0
) -> Iterator[Window]:
    """Disjoint sliding windows over a slice; ``start_line`` supports
    restart-from-watermark (checkpointed window progress)."""
    line = start_line
    while line < geom.lines_per_slice:
        end = min(line + window_lines, geom.lines_per_slice)
        yield Window(slice_i, line, end)
        line = end


def num_windows(geom: CubeGeometry, window_lines: int) -> int:
    return -(-geom.lines_per_slice // window_lines)


# -- work units / plans --------------------------------------------------------


class WorkUnit(NamedTuple):
    """One schedulable unit of PDF computation: a window plus its position in
    the plan. ``seq`` orders units globally; within a slice the order equals
    line order, which the reuse cache and the resume watermark rely on."""

    window: Window
    seq: int

    @property
    def unit_id(self) -> str:
        """Stable id for heartbeat monitoring (runtime/monitor.py)."""
        return f"s{self.window.slice_i}/l{self.window.line_start:05d}"


@dataclass(frozen=True)
class Plan:
    """An ordered queue of WorkUnits, possibly spanning multiple slices.

    Slices appear as contiguous runs (slice-major order): the executor
    processes a slice's windows in line order before moving to the next
    slice, which keeps reuse-cache behaviour identical to running the
    slices back-to-back through the serial loop.
    """

    geometry: CubeGeometry
    window_lines: int
    units: tuple[WorkUnit, ...]

    @property
    def slices(self) -> tuple[int, ...]:
        out: list[int] = []
        for u in self.units:
            if not out or out[-1] != u.window.slice_i:
                out.append(u.window.slice_i)
        return tuple(out)

    def units_for_slice(self, slice_i: int) -> tuple[WorkUnit, ...]:
        return tuple(u for u in self.units if u.window.slice_i == slice_i)

    def __len__(self) -> int:
        return len(self.units)


def build_plan(
    geom: CubeGeometry,
    slices: Sequence[int],
    window_lines: int,
    start_lines: Mapping[int, int] | None = None,
) -> Plan:
    """Expand ``slices`` into a slice-major WorkUnit queue.

    ``start_lines`` maps slice -> first line still to do (resume from a
    watermark); omitted slices start at line 0. A slice whose watermark is
    already past the end contributes no units.
    """
    units: list[WorkUnit] = []
    for s in slices:
        if not 0 <= s < geom.num_slices:
            raise ValueError(f"slice {s} outside cube with {geom.num_slices} slices")
        start = start_lines.get(s, 0) if start_lines else 0
        for w in iter_windows(geom, s, window_lines, start):
            units.append(WorkUnit(w, len(units)))
    return Plan(geom, window_lines, tuple(units))
