"""ML prediction (§5.3): decision-tree classification of distribution types.

The paper trains an MLlib decision tree on previously generated output data
(features: mean and standard deviation; labels: distribution type) and uses
it to skip Algorithm 3's try-all-types loop. Here:

* ``train_tree``        — host-side exact CART/Gini trainer over maxBins
  histogram candidate splits (the same hyper-parameters MLlib exposes:
  ``depth`` and ``maxBins``). Training is seconds even in the paper (1-20 s),
  so host training changes nothing material (DESIGN.md §8.4).
* ``DecisionTree``      — a *complete-binary-tree array layout* (feature,
  threshold per internal node; label per leaf) so prediction is a fixed
  ``depth``-step vectorized descent: branch-free, jit-able, broadcastable to
  millions of points. Early leaves are expanded downward (children repeat the
  leaf), keeping the descent static.
* ``tune_hyperparameters`` — §5.3.1 grid search on a validation split.

The trained arrays are tiny (2^depth nodes) and fully replicated across the
mesh — the analog of the paper broadcasting the model to all Spark workers.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DecisionTree:
    """Complete binary tree of given depth in array form.

    feature[i], threshold[i] for internal nodes i in [0, 2^depth - 1);
    leaf_label[j] for leaves j in [0, 2^depth). Descent: go left iff
    x[feature] <= threshold.
    """

    depth: int
    feature: np.ndarray  # (2^depth - 1,) int32
    threshold: np.ndarray  # (2^depth - 1,) float32
    leaf_label: np.ndarray  # (2^depth,) int32

    def as_device(self):
        return (
            jnp.asarray(self.feature),
            jnp.asarray(self.threshold),
            jnp.asarray(self.leaf_label),
        )


def predict(tree_arrays, features: jax.Array) -> jax.Array:
    """features (..., F) -> (...,) predicted class. Fixed-depth descent."""
    feat, thr, leaf = tree_arrays
    depth = int(np.log2(leaf.shape[0]) + 0.5)
    node = jnp.zeros(features.shape[:-1], dtype=jnp.int32)
    for _ in range(depth):
        f = feat[node]
        t = thr[node]
        x = jnp.take_along_axis(features, f[..., None], axis=-1)[..., 0]
        go_left = x <= t
        node = jnp.where(go_left, 2 * node + 1, 2 * node + 2)
    leaf_idx = node - (leaf.shape[0] - 1)
    return leaf[leaf_idx]


def _gini_split(labels: np.ndarray, num_classes: int, left_mask: np.ndarray) -> float:
    def gini(sub):
        if len(sub) == 0:
            return 0.0
        counts = np.bincount(sub, minlength=num_classes).astype(np.float64)
        p = counts / len(sub)
        return 1.0 - np.sum(p * p)

    n = len(labels)
    nl = left_mask.sum()
    return (nl / n) * gini(labels[left_mask]) + ((n - nl) / n) * gini(labels[~left_mask])


def train_tree(
    features: np.ndarray,
    labels: np.ndarray,
    num_classes: int,
    depth: int = 4,
    max_bins: int = 32,
) -> DecisionTree:
    """Greedy CART with Gini impurity over maxBins quantile candidate splits."""
    features = np.asarray(features, dtype=np.float32)
    labels = np.asarray(labels, dtype=np.int32)
    n, num_feat = features.shape

    n_internal = 2**depth - 1
    feat_arr = np.zeros((n_internal,), dtype=np.int32)
    thr_arr = np.full((n_internal,), np.inf, dtype=np.float32)  # inf => always left
    leaf_arr = np.zeros((2**depth,), dtype=np.int32)

    def majority(idx):
        if len(idx) == 0:
            return 0
        return int(np.bincount(labels[idx], minlength=num_classes).argmax())

    # node -> sample indices, built level by level.
    assignments = {0: np.arange(n)}
    for node in range(n_internal):
        idx = assignments.pop(node, np.empty((0,), dtype=np.int64))
        left_child, right_child = 2 * node + 1, 2 * node + 2
        best = None
        if len(idx) > 1 and len(np.unique(labels[idx])) > 1:
            sub_x, sub_y = features[idx], labels[idx]
            for f in range(num_feat):
                col = sub_x[:, f]
                qs = np.unique(
                    np.quantile(col, np.linspace(0, 1, min(max_bins, len(col)) + 1)[1:-1])
                )
                for t in qs:
                    lm = col <= t
                    if lm.all() or not lm.any():
                        continue
                    g = _gini_split(sub_y, num_classes, lm)
                    if best is None or g < best[0]:
                        best = (g, f, t, lm)
        if best is None:
            # Early leaf: expand downward (always-left path carries the label).
            feat_arr[node] = 0
            thr_arr[node] = np.inf
            assignments[left_child] = idx
            assignments[right_child] = np.empty((0,), dtype=np.int64)
        else:
            _, f, t, lm = best
            feat_arr[node] = f
            thr_arr[node] = t
            assignments[left_child] = idx[lm]
            assignments[right_child] = idx[~lm]

    # Leaves: majority label; empty leaves inherit from sibling/parent path.
    first_leaf = n_internal
    global_major = majority(np.arange(n))
    for j in range(2**depth):
        idx = assignments.get(first_leaf + j, np.empty((0,), dtype=np.int64))
        leaf_arr[j] = majority(idx) if len(idx) else global_major

    # Fix empty leaves under early-leaf chains: propagate the left sibling.
    for j in range(2**depth):
        node_idx = first_leaf + j
        if len(assignments.get(node_idx, ())) == 0 and j % 2 == 1:
            leaf_arr[j] = leaf_arr[j - 1]

    return DecisionTree(depth, feat_arr, thr_arr, leaf_arr)


def model_error(tree: DecisionTree, features: np.ndarray, labels: np.ndarray) -> float:
    """Wrong-prediction rate (the paper's 'model error')."""
    pred = np.asarray(predict(tree.as_device(), jnp.asarray(features)))
    return float(np.mean(pred != labels))


def tune_hyperparameters(
    features: np.ndarray,
    labels: np.ndarray,
    num_classes: int,
    depths: Sequence[int] = (2, 3, 4, 5, 6),
    bins: Sequence[int] = (8, 16, 32, 64),
    val_fraction: float = 0.3,
    seed: int = 0,
) -> tuple[int, int, float]:
    """§5.3.1: pick the smallest (depth, maxBins) past which validation error
    stops decreasing. Returns (depth, max_bins, best_val_error)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(labels))
    n_val = int(len(labels) * val_fraction)
    va, tr = perm[:n_val], perm[n_val:]

    best = (depths[0], bins[0], 1.0)
    for d in depths:
        for b in bins:
            tree = train_tree(features[tr], labels[tr], num_classes, d, b)
            err = model_error(tree, features[va], labels[va])
            # Strict improvement keeps the minimal hyper-parameters (paper:
            # "choose the minimum values from which the error does not
            # decrease when they increase").
            if err < best[2] - 1e-9:
                best = (d, b, err)
    return best
