# The paper's primary contribution: parallel PDF computation on big spatial
# data — distribution fitting (Algorithm 3/4), Eq.-5 error, grouping (§5.2),
# reuse (§5.2.1), decision-tree ML prediction (§5.3), sampling (§5.4), and
# the windowed pipeline (Algorithms 1-2), run by a staged executor that
# overlaps load / compute / persist (executor.py) — all as fused JAX
# computations.
from repro.core import distributions, fitting, grouping, ml_predict, pdf_error
from repro.core import executor, pipeline, regions, reuse, sampling
from repro.core.distributions import TYPES_4, TYPES_10, Moments, moments_from_values
from repro.core.fitting import FitResult, compute_pdf_and_error, compute_pdf_with_predicted_type
from repro.core.executor import (
    ExecutorConfig,
    ExecutorReport,
    StagedExecutor,
)
from repro.core.pipeline import PDFComputer, PDFConfig, SliceResult
from repro.core.regions import CubeGeometry, Plan, Window, WorkUnit, build_plan, iter_windows

__all__ = [
    "TYPES_4", "TYPES_10", "Moments", "moments_from_values",
    "FitResult", "compute_pdf_and_error", "compute_pdf_with_predicted_type",
    "PDFComputer", "PDFConfig", "SliceResult",
    "StagedExecutor", "ExecutorConfig", "ExecutorReport",
    "CubeGeometry", "Window", "WorkUnit", "Plan", "build_plan", "iter_windows",
    "distributions", "executor", "fitting", "grouping", "ml_predict",
    "pdf_error", "pipeline", "regions", "reuse", "sampling",
]
