"""PDF error (Eq. 5) and slice-average error (Eq. 6).

Eq. 5 compares the empirical interval frequencies of the observation values
against the fitted distribution's CDF mass over the same L intervals, where
the intervals evenly split [min(V), max(V)]:

    e = sum_k | Freq_k / n  -  (F(edge_{k+1}) - F(edge_k)) |

Two implementation modes exist (see DESIGN.md §8.2 and fitting.py):

* ``faithful`` — the histogram is recomputed per candidate type, matching the
  paper's cost structure (its R subprocess re-reads the data for every type).
* ``fused``   — the histogram is computed once and shared across all T types
  (it only depends on the data); this is the beyond-paper optimization.

Both produce bit-identical errors; only the compute cost differs.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import distributions as dists

_EPS = 1e-12


def interval_edges(vmin: jax.Array, vmax: jax.Array, num_bins: int) -> jax.Array:
    """(...,) min/max -> (..., L+1) evenly spaced edges (Eq. 5's intervals)."""
    span = jnp.maximum(vmax - vmin, _EPS)
    k = jnp.arange(num_bins + 1, dtype=vmin.dtype)
    return vmin[..., None] + span[..., None] * k / num_bins


def histogram(values: jax.Array, vmin: jax.Array, vmax: jax.Array, num_bins: int) -> jax.Array:
    """(..., n) values -> (..., L) counts over the Eq.-5 intervals.

    Pure-jnp reference; kernels/hist computes the same thing tiled in VMEM.
    The last interval is closed (values == vmax land in bin L-1), matching
    the usual histogram convention and the KS-style construction.
    """
    span = jnp.maximum(vmax - vmin, _EPS)
    idx = jnp.floor((values - vmin[..., None]) / span[..., None] * num_bins)
    idx = jnp.clip(idx, 0, num_bins - 1).astype(jnp.int32)
    one_hot = jax.nn.one_hot(idx, num_bins, dtype=values.dtype)
    return jnp.sum(one_hot, axis=-2)


def histogram_scatter(
    values: jax.Array, vmin: jax.Array, vmax: jax.Array, num_bins: int
) -> jax.Array:
    """Scatter-add histogram: one O(P*n) streaming pass instead of the
    (P, n, L) one-hot intermediate (§Perf pdf-seismic iteration 2 — the
    one-hot costs L x the data volume in HBM traffic)."""
    p = values.shape[:-1]
    flat = values.reshape(-1, values.shape[-1])
    lo = vmin.reshape(-1, 1)
    hi = vmax.reshape(-1, 1)
    span = jnp.maximum(hi - lo, _EPS)
    idx = jnp.clip(
        jnp.floor((flat - lo) / span * num_bins), 0, num_bins - 1
    ).astype(jnp.int32)
    rows = jax.lax.broadcasted_iota(jnp.int32, idx.shape, 0)
    out = jnp.zeros((flat.shape[0], num_bins), values.dtype)
    out = out.at[rows.reshape(-1), idx.reshape(-1)].add(1.0)
    return out.reshape(p + (num_bins,))


def cdf_masses(
    types: Sequence[str], params: jax.Array, edges: jax.Array
) -> jax.Array:
    """params (..., T, 3), edges (..., L+1) -> (..., T, L) interval masses.

    The paper treats mass outside [min, max] as negligible; we follow that
    (no renormalization), so a badly fitted type pays for its tail mass via a
    larger Eq.-5 error — which is exactly the selection signal Algorithm 3
    relies on.
    """
    cdf_at_edges = dists.cdf_all(types, params, edges)  # (..., T, L+1)
    return cdf_at_edges[..., 1:] - cdf_at_edges[..., :-1]


def pdf_error_from_freq(freq: jax.Array, masses: jax.Array) -> jax.Array:
    """freq (..., L) counts, masses (..., [T,] L) -> (..., [T]) Eq.-5 error."""
    n = jnp.sum(freq, axis=-1)
    rel = freq / jnp.maximum(n, 1.0)[..., None]
    if masses.ndim == rel.ndim + 1:
        rel = rel[..., None, :]
    return jnp.sum(jnp.abs(rel - masses), axis=-1)


def pdf_error(
    values: jax.Array,
    params: jax.Array,
    types: Sequence[str],
    num_bins: int,
    moments: dists.Moments | None = None,
) -> jax.Array:
    """End-to-end Eq. 5 for all types: values (..., n), params (..., T, 3)
    -> (..., T). Reference path used by tests and the faithful mode."""
    if moments is None:
        vmin = jnp.min(values, axis=-1)
        vmax = jnp.max(values, axis=-1)
    else:
        vmin, vmax = moments.vmin, moments.vmax
    edges = interval_edges(vmin, vmax, num_bins)
    freq = histogram(values, vmin, vmax, num_bins)
    masses = cdf_masses(types, params, edges)
    return pdf_error_from_freq(freq, masses)


def slice_average_error(errors: jax.Array, valid: jax.Array | None = None) -> jax.Array:
    """Eq. 6: average per-point error over a slice (optionally masked)."""
    if valid is None:
        return jnp.mean(errors)
    w = valid.astype(errors.dtype)
    return jnp.sum(errors * w) / jnp.maximum(jnp.sum(w), 1.0)
