"""Candidate distribution types (§6.1 of the paper): fitters, PDFs, CDFs.

The paper fits each candidate type with an external R program per point.
On TPU we replace that with *closed-form method-of-moments fitters* that are
pure jnp functions of the per-point moment vector, so the whole fit for a
window of points is one fused, vectorized XLA computation (see DESIGN.md §2).

Every distribution is parameterized by a fixed-width ``(..., 3)`` parameter
slot so that all types stack into a single ``(..., T, 3)`` array — this keeps
the fit-all-types path (Algorithm 3) a dense batched computation with an
``argmin`` over the type axis, and the ML-predicted path (Algorithm 4) a
``take_along_axis`` on the same array.

Moment conventions: ``mean``, ``var`` (unbiased, n-1), ``skew`` (g1 =
m3/sigma^3), ``kurt`` (excess, m4/sigma^4 - 3), ``vmin``, ``vmax``.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.scipy import special as jsp

# The paper's two candidate sets (§6.1).
TYPES_4: tuple[str, ...] = ("normal", "uniform", "exponential", "lognormal")
TYPES_10: tuple[str, ...] = TYPES_4 + (
    "cauchy",
    "gamma",
    "geometric",
    "logistic",
    "student_t",
    "weibull",
)

_EPS = 1e-12
_BIG = 1e30


class Moments(NamedTuple):
    """Per-point summary statistics; every field has the same leading shape."""

    mean: jax.Array
    var: jax.Array
    skew: jax.Array
    kurt: jax.Array
    vmin: jax.Array
    vmax: jax.Array

    @property
    def std(self) -> jax.Array:
        return jnp.sqrt(jnp.maximum(self.var, 0.0))


def moments_from_values(values: jax.Array, axis: int = -1) -> Moments:
    """Reference moment computation (the Pallas kernel in kernels/moments
    computes the same thing tiled; tests assert allclose against this)."""
    n = values.shape[axis]
    mean = jnp.mean(values, axis=axis)
    centered = values - jnp.expand_dims(mean, axis)
    m2 = jnp.mean(centered**2, axis=axis)
    m3 = jnp.mean(centered**3, axis=axis)
    m4 = jnp.mean(centered**4, axis=axis)
    var = m2 * n / max(n - 1, 1)  # unbiased, Eq. 2 of the paper
    sig = jnp.sqrt(jnp.maximum(m2, _EPS))
    skew = m3 / sig**3
    kurt = m4 / jnp.maximum(m2, _EPS) ** 2 - 3.0
    return Moments(mean, var, skew, kurt, jnp.min(values, axis=axis), jnp.max(values, axis=axis))


# ---------------------------------------------------------------------------
# Per-type method-of-moments fitters. Each returns (..., 3) params.
# Parameter slot layout is documented per function; unused slots are zero.
# ---------------------------------------------------------------------------


def _pack(*ps: jax.Array) -> jax.Array:
    ps = ps + (jnp.zeros_like(ps[0]),) * (3 - len(ps))
    return jnp.stack(ps, axis=-1)


def fit_normal(m: Moments) -> jax.Array:
    """[mu, sigma, 0]"""
    return _pack(m.mean, jnp.maximum(m.std, _EPS))


def fit_uniform(m: Moments) -> jax.Array:
    """[a, b, 0] — observed support, as the paper's R fitter uses the data range."""
    return _pack(m.vmin, jnp.maximum(m.vmax, m.vmin + _EPS))


def fit_exponential(m: Moments) -> jax.Array:
    """[rate, 0, 0] — rate = 1/mean (the paper names `rate` explicitly)."""
    return _pack(1.0 / jnp.maximum(m.mean, _EPS))


def fit_lognormal(m: Moments) -> jax.Array:
    """[mu, sigma, 0] of log-space."""
    mean = jnp.maximum(m.mean, _EPS)
    sigma2 = jnp.log1p(jnp.maximum(m.var, 0.0) / mean**2)
    mu = jnp.log(mean) - 0.5 * sigma2
    return _pack(mu, jnp.sqrt(jnp.maximum(sigma2, _EPS)))


def fit_cauchy(m: Moments) -> jax.Array:
    """[loc, scale, 0]. Cauchy has no moments; the standard quantile fit needs
    the median/IQR which the moment pipeline doesn't carry, so we use the
    common robust fallback loc=mean, scale=std/2 — a deliberately weak fit
    whose Eq.-5 error deselects it unless the data really is heavy-tailed."""
    return _pack(m.mean, jnp.maximum(0.5 * m.std, _EPS))


def fit_gamma(m: Moments) -> jax.Array:
    """[k (shape), theta (scale), 0]."""
    mean = jnp.maximum(m.mean, _EPS)
    var = jnp.maximum(m.var, _EPS)
    k = mean**2 / var
    theta = var / mean
    return _pack(jnp.maximum(k, _EPS), jnp.maximum(theta, _EPS))


def fit_geometric(m: Moments) -> jax.Array:
    """[p, 0, 0] on support {0,1,2,...}: p = 1/(1+mean)."""
    p = 1.0 / (1.0 + jnp.maximum(m.mean, 0.0))
    return _pack(jnp.clip(p, _EPS, 1.0))


def fit_logistic(m: Moments) -> jax.Array:
    """[loc, s, 0]: s = std*sqrt(3)/pi."""
    s = m.std * jnp.sqrt(3.0) / jnp.pi
    return _pack(m.mean, jnp.maximum(s, _EPS))


def fit_student_t(m: Moments) -> jax.Array:
    """[loc, scale, nu] — location-scale t; nu from excess kurtosis
    (gamma2 = 6/(nu-4) => nu = 4 + 6/gamma2), clamped to (4.5, 50)."""
    g2 = jnp.maximum(m.kurt, _EPS)
    nu = jnp.clip(4.0 + 6.0 / g2, 4.5, 50.0)
    scale = jnp.sqrt(jnp.maximum(m.var, _EPS) * (nu - 2.0) / nu)
    return _pack(m.mean, jnp.maximum(scale, _EPS), nu)


def _weibull_cv2(k: jax.Array) -> jax.Array:
    """Squared coefficient of variation of Weibull(k, 1)."""
    lg1 = jsp.gammaln(1.0 + 1.0 / k)
    lg2 = jsp.gammaln(1.0 + 2.0 / k)
    return jnp.exp(lg2 - 2.0 * lg1) - 1.0


def fit_weibull(m: Moments, iters: int = 20) -> jax.Array:
    """[k (shape), lam (scale), 0] — solve CV^2(k) = var/mean^2 by bisection
    (fixed iteration count keeps the graph static; 20 halvings of (0.2, 50)
    give k to ~1e-4 relative)."""
    mean = jnp.maximum(m.mean, _EPS)
    target = jnp.clip(jnp.maximum(m.var, _EPS) / mean**2, 1e-6, 1e4)

    lo = jnp.full_like(mean, 0.2)
    hi = jnp.full_like(mean, 50.0)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        # CV^2 is decreasing in k.
        too_small_k = _weibull_cv2(mid) < target  # need smaller k
        hi = jnp.where(too_small_k, mid, hi)
        lo = jnp.where(too_small_k, lo, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    k = 0.5 * (lo + hi)
    lam = mean / jnp.exp(jsp.gammaln(1.0 + 1.0 / k))
    return _pack(k, lam)


_FITTERS = {
    "normal": fit_normal,
    "uniform": fit_uniform,
    "exponential": fit_exponential,
    "lognormal": fit_lognormal,
    "cauchy": fit_cauchy,
    "gamma": fit_gamma,
    "geometric": fit_geometric,
    "logistic": fit_logistic,
    "student_t": fit_student_t,
    "weibull": fit_weibull,
}


def fit_all(types: Sequence[str], m: Moments) -> jax.Array:
    """Algorithm 3 line 3 for every candidate type: (..., T, 3) params."""
    return jnp.stack([_FITTERS[t](m) for t in types], axis=-2)


# ---------------------------------------------------------------------------
# CDFs. cdf_<type>(params (...,3), x (...)) -> (...). Broadcasting applies.
# ---------------------------------------------------------------------------


def _phi(z: jax.Array) -> jax.Array:
    return 0.5 * (1.0 + jax.lax.erf(z / jnp.sqrt(2.0)))


def cdf_normal(p: jax.Array, x: jax.Array) -> jax.Array:
    return _phi((x - p[..., 0]) / p[..., 1])


def cdf_uniform(p: jax.Array, x: jax.Array) -> jax.Array:
    return jnp.clip((x - p[..., 0]) / (p[..., 1] - p[..., 0]), 0.0, 1.0)


def cdf_exponential(p: jax.Array, x: jax.Array) -> jax.Array:
    return jnp.where(x <= 0, 0.0, 1.0 - jnp.exp(-p[..., 0] * jnp.maximum(x, 0.0)))


def cdf_lognormal(p: jax.Array, x: jax.Array) -> jax.Array:
    safe_x = jnp.maximum(x, _EPS)
    return jnp.where(x <= 0, 0.0, _phi((jnp.log(safe_x) - p[..., 0]) / p[..., 1]))


def cdf_cauchy(p: jax.Array, x: jax.Array) -> jax.Array:
    return 0.5 + jnp.arctan((x - p[..., 0]) / p[..., 1]) / jnp.pi


# Above this shape parameter the f32 incomplete gamma is both slow (its
# iteration count grows with k — ~80 ms per (256, 65) eval at k ~ 1e5, the
# regime the moment fitter reaches on near-normal windows) and unstable
# (1 ulp of x moves the CDF by ~1e-2). The Wilson-Hilferty cube-root normal
# approximation is sub-1e-4 accurate there and pure elementwise math.
_GAMMA_WH_K = 1e4


def cdf_gamma(p: jax.Array, x: jax.Array) -> jax.Array:
    k, theta = p[..., 0], p[..., 1]
    xs = jnp.maximum(x, 0.0) / theta
    # Clamp the exact branch's inputs: jnp.where evaluates both branches, and
    # igamma at huge k would still pay its full iteration cost. For k <=
    # _GAMMA_WH_K the clamp of xs is inert (gammainc(k, 2e4) == 1 there).
    exact = jsp.gammainc(
        jnp.minimum(k, _GAMMA_WH_K), jnp.minimum(xs, 2.0 * _GAMMA_WH_K)
    )
    kk = jnp.maximum(k, _EPS)
    z = (jnp.cbrt(xs / kk) - (1.0 - 1.0 / (9.0 * kk))) * jnp.sqrt(9.0 * kk)
    return jnp.where(x <= 0, 0.0, jnp.where(k > _GAMMA_WH_K, _phi(z), exact))


def cdf_geometric(p: jax.Array, x: jax.Array) -> jax.Array:
    # Support {0,1,...}: F(x) = 1 - (1-p)^(floor(x)+1) for x >= 0.
    k = jnp.floor(jnp.maximum(x, 0.0))
    return jnp.where(x < 0, 0.0, 1.0 - jnp.exp((k + 1.0) * jnp.log1p(-jnp.minimum(p[..., 0], 1 - _EPS))))


def cdf_logistic(p: jax.Array, x: jax.Array) -> jax.Array:
    return jax.nn.sigmoid((x - p[..., 0]) / p[..., 1])


def cdf_student_t(p: jax.Array, x: jax.Array) -> jax.Array:
    loc, scale, nu = p[..., 0], p[..., 1], p[..., 2]
    t = (x - loc) / scale
    ib = jsp.betainc(0.5 * nu, 0.5, nu / (nu + t**2))
    return jnp.where(t >= 0, 1.0 - 0.5 * ib, 0.5 * ib)


def cdf_weibull(p: jax.Array, x: jax.Array) -> jax.Array:
    k, lam = p[..., 0], p[..., 1]
    z = jnp.maximum(x, 0.0) / lam
    return jnp.where(x <= 0, 0.0, -jnp.expm1(-(z**k)))


_CDFS = {
    "normal": cdf_normal,
    "uniform": cdf_uniform,
    "exponential": cdf_exponential,
    "lognormal": cdf_lognormal,
    "cauchy": cdf_cauchy,
    "gamma": cdf_gamma,
    "geometric": cdf_geometric,
    "logistic": cdf_logistic,
    "student_t": cdf_student_t,
    "weibull": cdf_weibull,
}


def cdf(type_name: str, params: jax.Array, x: jax.Array) -> jax.Array:
    return _CDFS[type_name](params, x)


def cdf_all(types: Sequence[str], params: jax.Array, x: jax.Array) -> jax.Array:
    """params (..., T, 3), x (..., K) -> (..., T, K): every type's CDF at x.

    Used by the fit-all path: T is small and static so evaluating all types
    densely is cheaper than any gather on TPU.
    """
    # params[..., t, None, :] is (..., 1, 3); its param columns broadcast
    # (..., 1) against x (..., K) -> (..., K). Stack over the T types.
    return jnp.stack(
        [_CDFS[t](params[..., i, None, :], x) for i, t in enumerate(types)], axis=-2
    )


# Samplers (for the data substrate + tests) -----------------------------------


def sample(type_name: str, params, key: jax.Array, shape) -> jax.Array:
    """Draw samples; used by data/simulation.py and property tests."""
    u = jax.random.uniform(key, shape, minval=1e-6, maxval=1.0 - 1e-6)
    p = jnp.asarray(params, dtype=jnp.float32)
    if type_name == "normal":
        return p[0] + p[1] * jax.random.normal(key, shape)
    if type_name == "uniform":
        return p[0] + (p[1] - p[0]) * u
    if type_name == "exponential":
        return -jnp.log1p(-u) / p[0]
    if type_name == "lognormal":
        return jnp.exp(p[0] + p[1] * jax.random.normal(key, shape))
    if type_name == "cauchy":
        return p[0] + p[1] * jnp.tan(jnp.pi * (u - 0.5))
    if type_name == "gamma":
        return p[1] * jax.random.gamma(key, p[0], shape)
    if type_name == "geometric":
        return jnp.floor(jnp.log1p(-u) / jnp.log1p(-p[0]))
    if type_name == "logistic":
        return p[0] + p[1] * (jnp.log(u) - jnp.log1p(-u))
    if type_name == "student_t":
        return p[0] + p[1] * jax.random.t(key, p[2], shape)
    if type_name == "weibull":
        return p[1] * (-jnp.log1p(-u)) ** (1.0 / p[0])
    raise ValueError(f"unknown distribution type {type_name!r}")


def type_index(types: Sequence[str], name: str) -> int:
    return list(types).index(name)
