"""SHAPE — launch-shape discipline at the jit boundary (the PR 6 drift class).

XLA specializes executables per input shape, and two executables that
compute "the same" reduction at different shapes may differ by ~1 ulp —
PR 6 measured exactly that when a batched concatenate produced a
differently-shaped fused launch than the serial path. The repo's defense is
the *shape-class* discipline: device inputs are padded to a small set of
blessed bucket sizes (``grouping.padded_size``) so batched and serial runs
hit the same executable.

This rule guards the two files that build device launches — the executor
and the serving batcher: any ``jnp.concatenate``/``stack``/``reshape``/
``pad``-family call inside a function that never consults ``padded_size``
is flagged as a potential unblessed shape seam. Fixed-shape assemblies that
are provably not batch seams (e.g. a per-point feature triple) carry an
inline ``# repro: allow[SHAPE]`` with the argument.

Host-side ``np.*`` assembly is exempt: NumPy never feeds a jit boundary
directly here, and host concatenation is bitwise-associative-free by
construction (no re-tiling).
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Finding, Rule, import_aliases, qualname

SCOPE_FILES = ("core/executor.py", "serve/server.py")

SHAPE_FNS = {"concatenate", "stack", "hstack", "vstack", "dstack",
             "column_stack", "reshape", "pad", "tile", "repeat", "resize",
             "broadcast_to", "atleast_1d", "atleast_2d", "atleast_3d"}


def _blessed_functions(tree: ast.Module) -> set[ast.AST]:
    """Function nodes whose subtree calls ``padded_size`` — the shape-class
    helper blesses every device assembly in that function."""
    blessed: set[ast.AST] = set()
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                f = node.func
                name = f.attr if isinstance(f, ast.Attribute) else (
                    f.id if isinstance(f, ast.Name) else None)
                if name == "padded_size":
                    blessed.add(fn)
                    break
    return blessed


class ShapeRule(Rule):
    name = "SHAPE"
    description = ("jnp concatenate/stack/reshape feeding a jit boundary "
                   "outside the padded_size shape-class helpers")

    def applies(self, relpath: str) -> bool:
        return relpath in SCOPE_FILES

    def check(self, tree, lines, relpath):
        aliases = import_aliases(tree)
        blessed = _blessed_functions(tree)
        out: list[Finding] = []

        def visit(node: ast.AST, fn_stack: tuple[ast.AST, ...]) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fn_stack = fn_stack + (node,)
            elif isinstance(node, ast.Call):
                q = qualname(node.func, aliases)
                if q and q.startswith(("jax.numpy.", "jax.lax.")):
                    attr = q.rsplit(".", 1)[1]
                    if attr in SHAPE_FNS and not any(
                            fn in blessed for fn in fn_stack):
                        out.append(self.finding(
                            relpath, node,
                            f"{attr} builds a device-array shape outside a "
                            "padded_size shape class — a differently-shaped "
                            "executable can drift ~1 ulp (DESIGN.md §13)",
                            lines))
            for child in ast.iter_child_nodes(node):
                visit(child, fn_stack)

        visit(tree, ())
        return out
