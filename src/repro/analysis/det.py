"""DET — determinism sources inside result-defining modules.

The bitwise contracts (staged-executor equivalence §9, fault recovery §14,
coalescing equivalence) all assume a window's bytes depend on the spec
alone. This rule flags the four ways ambient state leaks into that path:

* wall-clock reads (``time.time`` / ``datetime.now``) — timing-only uses
  (staleness checks, backoff) carry a justified ``# repro: allow[DET]``;
* unseeded randomness: NumPy's global RNG (``np.random.rand`` et al.), a
  seed-less ``default_rng()`` / ``RandomState()``, the stdlib ``random``
  module, ``os.urandom`` / ``secrets`` / ``uuid.uuid4``;
* environment reads (``os.environ`` / ``os.getenv``) — config must arrive
  through the spec, never ambiently;
* iteration over a ``set`` literal / comprehension / call — string hashing
  is salted per process (PYTHONHASHSEED), so set order is run-dependent;
  ordered consumers (``sorted``, ``min``/``max``), membership tests, and
  aggregations (``len``/``sum``/``any``/``all``) are fine.

Scope: ``core/``, ``kernels/``, ``data/``, ``serve/``, ``api/`` — the
modules whose outputs are result-defining. ``runtime/`` (monitor, backoff,
fault clocks) and ``launch/`` are timing/UX layers and exempt by design.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Finding, Rule, import_aliases, qualname

SCOPE = ("core/", "kernels/", "data/", "serve/", "api/")

WALL_CLOCK = {
    "time.time": "wall-clock read (time.time)",
    "time.time_ns": "wall-clock read (time.time_ns)",
    "datetime.datetime.now": "wall-clock read (datetime.now)",
    "datetime.datetime.utcnow": "wall-clock read (datetime.utcnow)",
    "datetime.datetime.today": "wall-clock read (datetime.today)",
    "datetime.date.today": "wall-clock read (date.today)",
}

# numpy.random attributes that are NOT the seeded-generator API: anything
# else on numpy.random is the shared global RNG.
NP_RANDOM_OK = {"default_rng", "Generator", "RandomState", "SeedSequence",
                "PCG64", "Philox", "SFC64", "MT19937", "BitGenerator"}

ENTROPY = {
    "os.urandom": "os.urandom is non-deterministic entropy",
    "uuid.uuid4": "uuid.uuid4 is non-deterministic entropy",
}

# builtins that materialize their argument's iteration order
ORDER_SINKS = {"list", "tuple", "iter", "enumerate"}


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset"))


class DetRule(Rule):
    name = "DET"
    description = ("no unseeded randomness, wall-clock, env reads, or "
                   "set-iteration-order leakage in result-defining modules")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith(SCOPE)

    def check(self, tree, lines, relpath):
        aliases = import_aliases(tree)
        out: list[Finding] = []

        def emit(node, msg):
            out.append(self.finding(relpath, node, msg, lines))

        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                q = qualname(node.func, aliases)
                if q in WALL_CLOCK:
                    emit(node, WALL_CLOCK[q]
                         + " — results must not depend on when they ran")
                elif q in ENTROPY:
                    emit(node, ENTROPY[q])
                elif q and q.startswith("numpy.random."):
                    attr = q.rsplit(".", 1)[1]
                    if attr not in NP_RANDOM_OK:
                        emit(node, f"numpy global-RNG call ({attr}) — use "
                                   "np.random.default_rng(seed)")
                    elif attr in ("default_rng", "RandomState") and not (
                            node.args or node.keywords):
                        emit(node, f"{attr}() without a seed draws OS entropy")
                elif q and (q.startswith("random.") or q.startswith("secrets.")):
                    emit(node, f"{q} is unseeded process-global randomness")
                elif q == "os.getenv" or (q or "").startswith("os.environ."):
                    emit(node, "environment read — configuration must come "
                               "from the spec, not ambient state")
                elif (isinstance(node.func, ast.Name)
                        and node.func.id in ORDER_SINKS and node.args
                        and _is_set_expr(node.args[0])):
                    emit(node, f"{node.func.id}() over a set materializes "
                               "hash-salted iteration order")
            elif isinstance(node, ast.Subscript):
                if qualname(node.value, aliases) == "os.environ":
                    emit(node, "environment read — configuration must come "
                               "from the spec, not ambient state")
            elif isinstance(node, ast.For) and _is_set_expr(node.iter):
                emit(node, "for-loop over a set leaks hash-salted iteration "
                           "order into results (sort it)")
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp)):
                for gen in node.generators:
                    if _is_set_expr(gen.iter):
                        emit(gen.iter, "comprehension over a set leaks "
                                       "hash-salted iteration order (sort it)")
        return out
