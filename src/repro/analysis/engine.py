"""The analysis engine: findings, suppression, baselines, and the tree walk.

A :class:`Rule` is a pure function from one module's AST to a list of
:class:`Finding`\\ s; the engine owns everything around that — which files a
rule sees, the ``# repro: allow[RULE]`` inline-suppression syntax, and the
checked-in baseline that lets pre-existing findings ride while new ones
fail. Rules import nothing from the package under analysis (stdlib ``ast``
only), so ``python -m repro.analysis`` runs without JAX or NumPy present.

Finding identity is ``(rule, path, snippet)`` — the *stripped source line*,
not the line number — so a baseline survives unrelated edits above the
finding but goes stale the moment the offending line itself changes, which
is exactly when a human should re-justify it.
"""

from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass
from pathlib import Path

#: ``# repro: allow[DET]`` or ``# repro: allow[DET,LOCK]: reason`` on the
#: finding's own line suppresses it. Justification text after ``:`` is for
#: the reader; the engine only matches the rule list.
SUPPRESS_RE = re.compile(r"#\s*repro:\s*allow\[([A-Za-z_*,\s]+)\]")

#: Subtrees of the package root the walker never descends into: the
#: analyzer must not lint itself (its fixtures are *deliberate* violations).
EXCLUDE_PREFIXES = ("analysis/",)


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source line."""

    rule: str
    path: str  # posix path relative to the package root (e.g. "core/executor.py")
    line: int  # 1-based physical line of the offending node
    message: str
    snippet: str = ""  # stripped source text of that line (baseline identity)

    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.snippet)

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message, "snippet": self.snippet}

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class Rule:
    """Base class: subclasses set ``name``, scope via ``applies`` and emit
    findings from ``check``. One instance is stateless and reusable."""

    name = "RULE"
    description = ""

    def applies(self, relpath: str) -> bool:
        raise NotImplementedError

    def check(self, tree: ast.Module, lines: list[str],
              relpath: str) -> list[Finding]:
        raise NotImplementedError

    def finding(self, relpath: str, node: ast.AST | int, message: str,
                lines: list[str]) -> Finding:
        line = node if isinstance(node, int) else node.lineno
        snippet = lines[line - 1].strip() if 0 < line <= len(lines) else ""
        return Finding(self.name, relpath, line, message, snippet)


# -- shared AST helpers ---------------------------------------------------------


def import_aliases(tree: ast.Module) -> dict[str, str]:
    """Map local names to the dotted import they stand for, so
    ``np.random.rand`` resolves to ``numpy.random.rand`` and a
    ``from time import time`` call resolves to ``time.time``."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    out[a.asname] = a.name
                else:
                    head = a.name.split(".")[0]
                    out[head] = head
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def qualname(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Dotted name of a ``Name``/``Attribute`` chain with the leading alias
    expanded, or None when the chain roots in anything else (a call result,
    a subscript, ...)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    parts.reverse()
    return ".".join([aliases.get(parts[0], parts[0])] + parts[1:])


def self_attr(node: ast.AST) -> str | None:
    """Attribute name X when ``node`` is ``self.X`` — possibly behind
    subscripts, so ``self._counts["hits"]`` also resolves to ``_counts``."""
    while isinstance(node, ast.Subscript):
        node = node.value
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name) and node.value.id == "self"):
        return node.attr
    return None


# -- suppression ----------------------------------------------------------------


def parse_suppressions(lines: list[str]) -> dict[int, set[str]]:
    """Per-line allow sets: line number -> {rule names} (``*`` = all)."""
    allow: dict[int, set[str]] = {}
    for i, text in enumerate(lines, 1):
        m = SUPPRESS_RE.search(text)
        if m:
            allow[i] = {tok.strip() for tok in m.group(1).split(",") if tok.strip()}
    return allow


# -- per-file / per-tree analysis -----------------------------------------------


def analyze_source(src: str, relpath: str,
                   rules: list[Rule]) -> tuple[list[Finding], int]:
    """Run every applicable rule over one module; returns the surviving
    findings and how many were suppressed inline."""
    tree = ast.parse(src, filename=relpath)
    lines = src.splitlines()
    allow = parse_suppressions(lines)
    findings: list[Finding] = []
    suppressed = 0
    for rule in rules:
        if not rule.applies(relpath):
            continue
        for f in rule.check(tree, lines, relpath):
            marked = allow.get(f.line, ())
            if "*" in marked or f.rule in marked:
                suppressed += 1
            else:
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, suppressed


@dataclass
class TreeReport:
    findings: list[Finding]
    suppressed: int  # inline ``# repro: allow[...]`` hits
    files: int


def analyze_tree(root: Path, rules: list[Rule]) -> TreeReport:
    """Walk every ``.py`` under ``root`` (the ``repro`` package directory),
    skipping the analyzer's own subtree, and run the rule battery."""
    findings: list[Finding] = []
    suppressed = 0
    files = 0
    for path in sorted(root.rglob("*.py")):
        relpath = path.relative_to(root).as_posix()
        if relpath.startswith(EXCLUDE_PREFIXES) or "__pycache__" in relpath:
            continue
        files += 1
        got, supp = analyze_source(path.read_text(), relpath, rules)
        findings.extend(got)
        suppressed += supp
    return TreeReport(findings=findings, suppressed=suppressed, files=files)


# -- baseline -------------------------------------------------------------------


class BaselineError(ValueError):
    """Malformed baseline file (bad JSON, missing keys, no justification)."""


def load_baseline(path: Path) -> list[dict]:
    """Parse and validate the baseline. Every entry must carry a one-line
    ``justification`` — an unexplained suppression is a config error, not a
    finding to tolerate."""
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise BaselineError(f"cannot read baseline {path}: {e}") from e
    entries = data.get("findings") if isinstance(data, dict) else None
    if not isinstance(entries, list):
        raise BaselineError(
            f"baseline {path} must be {{\"findings\": [...]}}")
    for i, e in enumerate(entries):
        missing = [k for k in ("rule", "path", "snippet", "justification")
                   if not (isinstance(e, dict) and e.get(k))]
        if missing:
            raise BaselineError(
                f"baseline entry #{i} is missing {missing} "
                f"(every entry needs rule/path/snippet and a justification)")
    return entries


def apply_baseline(
    findings: list[Finding], entries: list[dict],
) -> tuple[list[Finding], list[Finding], list[dict]]:
    """Split findings into (new, baselined) and report stale entries —
    baseline rows whose (rule, path, snippet) no longer matches anything,
    i.e. the violation was fixed (or edited: re-justify it)."""
    keys = {(e["rule"], e["path"], e["snippet"]): e for e in entries}
    new = [f for f in findings if f.key() not in keys]
    baselined = [f for f in findings if f.key() in keys]
    matched = {f.key() for f in baselined}
    stale = [e for k, e in keys.items() if k not in matched]
    return new, baselined, stale
