"""``python -m repro.analysis`` — run the invariant checker (exit-code
contract: 0 clean, 1 new findings / stale baseline / self-check failure,
2 usage or internal error)."""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import ALL_RULES
from repro.analysis.engine import (
    BaselineError,
    analyze_tree,
    apply_baseline,
    load_baseline,
)
from repro.analysis.selfcheck import run_self_check


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-invariant static checker: determinism (DET), "
                    "spec-hash coverage (HASH), launch-shape discipline "
                    "(SHAPE), lock consistency (LOCK), error taxonomy "
                    "(ERR). See DESIGN.md §15.")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset (default: all)")
    ap.add_argument("--baseline", default=None,
                    help="JSON baseline of justified pre-existing findings; "
                         "stale entries (fixed findings) fail the run")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable report on stdout")
    ap.add_argument("--root", default=None,
                    help="package root to analyze (default: the repro "
                         "package this module ships in)")
    ap.add_argument("--self-check", action="store_true",
                    help="run every rule against its seeded fixture and "
                         "fail on any delta (guards the checker itself)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.name:6} {rule.description}")
        return 0

    if args.self_check:
        problems = run_self_check()
        for p in problems:
            print(p)
        print(f"self-check: {len(problems)} problem(s) across "
              f"{len(ALL_RULES)} rules")
        return 1 if problems else 0

    rules = list(ALL_RULES)
    if args.rules:
        want = {tok.strip().upper() for tok in args.rules.split(",")
                if tok.strip()}
        known = {r.name for r in ALL_RULES}
        if want - known:
            print(f"error: unknown rule(s) {sorted(want - known)} "
                  f"(known: {sorted(known)})", file=sys.stderr)
            return 2
        rules = [r for r in rules if r.name in want]

    root = (Path(args.root) if args.root
            else Path(__file__).resolve().parent.parent)
    if not root.is_dir():
        print(f"error: --root {root} is not a directory", file=sys.stderr)
        return 2

    try:
        report = analyze_tree(root, rules)
    except SyntaxError as e:
        print(f"error: cannot parse {e.filename}:{e.lineno}: {e.msg}",
              file=sys.stderr)
        return 2

    entries: list[dict] = []
    if args.baseline:
        try:
            entries = load_baseline(Path(args.baseline))
        except BaselineError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
    new, baselined, stale = apply_baseline(report.findings, entries)

    if args.as_json:
        print(json.dumps({
            "files": report.files,
            "rules": [r.name for r in rules],
            "new": [f.to_dict() for f in new],
            "baselined": [f.to_dict() for f in baselined],
            "stale_baseline": stale,
            "suppressed_inline": report.suppressed,
        }, indent=1))
    else:
        for f in new:
            print(f.render())
        for e in stale:
            print(f"stale baseline entry: [{e['rule']}] {e['path']}: "
                  f"{e['snippet']!r} — the finding is gone; remove the "
                  "entry (or re-justify it if the line merely changed)")
        print(f"{len(new)} new finding(s) over {report.files} files "
              f"({len(baselined)} baselined, {report.suppressed} "
              f"suppressed inline, {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'})")
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
