"""repro.analysis — the repo-invariant static checker (DESIGN.md §15).

Every hard-won contract in this reproduction is one careless edit away
from silently breaking: determinism (bitwise results under faults), spec
content-hash coverage (cache keys), the serial-shape launch discipline
(PR 6's ~1 ulp drift), and the lock/error taxonomy around shared state.
This package walks ``src/repro`` with ``ast`` and fails CI when a change
violates one — the same role a race detector or sanitizer plays for a
training stack.

Usage::

    python -m repro.analysis                      # human output, exit code
    python -m repro.analysis --rules DET,LOCK     # subset
    python -m repro.analysis --baseline analysis_baseline.json
    python -m repro.analysis --json               # machine-readable
    python -m repro.analysis --self-check         # rules vs their fixtures

Exit codes: 0 clean, 1 new findings / stale baseline / self-check failure,
2 usage or internal error. Suppress a single line with
``# repro: allow[RULE]: reason``; park pre-existing findings in the
baseline file (every entry needs a one-line justification).

The rule battery lives in sibling modules (``det``, ``hashes``, ``shape``,
``locks``, ``errors``); ``engine`` owns findings, suppression, baselines,
and the tree walk. Rules never import the code under analysis — the
checker runs on a bare Python without JAX installed.
"""

from repro.analysis.det import DetRule
from repro.analysis.engine import (
    Finding,
    Rule,
    TreeReport,
    analyze_source,
    analyze_tree,
    apply_baseline,
    load_baseline,
)
from repro.analysis.errors import ErrRule
from repro.analysis.hashes import HashRule
from repro.analysis.locks import LockRule
from repro.analysis.shape import ShapeRule

#: The battery, in reporting order.
ALL_RULES = (DetRule(), HashRule(), ShapeRule(), LockRule(), ErrRule())

__all__ = [
    "ALL_RULES", "Finding", "Rule", "TreeReport", "analyze_source",
    "analyze_tree", "apply_baseline", "load_baseline",
]
