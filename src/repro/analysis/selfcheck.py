"""``--self-check``: run every rule against its fixture file.

Each fixture under ``fixtures/`` seeds deliberate violations, one per
line, marked with a trailing ``# expect[RULE]`` comment; clean idioms and
one ``# repro: allow[RULE]`` suppression ride along as negative cases. The
self-check fails on any delta in either direction — a rule that stops
firing on its own fixtures would otherwise turn the CI gate vacuously
green, and a rule that over-fires would bury real findings in noise.

The fixtures are parsed, never imported, and each is presented to the
engine under a scope path its rule applies to (the SHAPE fixture plays
``core/executor.py``, the HASH fixture plays ``api/spec.py``).
"""

from __future__ import annotations

import re
from pathlib import Path

from repro.analysis.det import DetRule
from repro.analysis.engine import analyze_source
from repro.analysis.errors import ErrRule
from repro.analysis.hashes import HashRule
from repro.analysis.locks import LockRule
from repro.analysis.shape import ShapeRule

FIXTURE_DIR = Path(__file__).resolve().parent / "fixtures"

EXPECT_RE = re.compile(r"#\s*expect\[([A-Za-z_,\s]+)\]")

#: (fixture file, relpath it impersonates, rules to run)
FIXTURES = (
    ("det_case.py", "core/det_case.py", (DetRule(),)),
    ("shape_case.py", "core/executor.py", (ShapeRule(),)),
    ("lock_case.py", "serve/lock_case.py", (LockRule(),)),
    ("err_case.py", "core/err_case.py", (ErrRule(),)),
    ("hash_case.py", "api/spec.py", (HashRule(),)),
)


def expected_in(src: str) -> set[tuple[str, int]]:
    """(rule, line) pairs declared by ``# expect[RULE]`` markers."""
    want: set[tuple[str, int]] = set()
    for i, line in enumerate(src.splitlines(), 1):
        m = EXPECT_RE.search(line)
        if m:
            for rule in m.group(1).split(","):
                want.add((rule.strip(), i))
    return want


def run_self_check() -> list[str]:
    """Empty list when every rule reports exactly its fixture's expected
    findings (and its allow-line suppresses); problem strings otherwise."""
    problems: list[str] = []
    for fname, relpath, rules in FIXTURES:
        src = (FIXTURE_DIR / fname).read_text()
        findings, suppressed = analyze_source(src, relpath, list(rules))
        got = {(f.rule, f.line) for f in findings}
        want = expected_in(src)
        for rule, line in sorted(want - got):
            problems.append(
                f"{fname}:{line}: expected a {rule} finding, rule reported "
                "none — the checker has gone blind to this violation class")
        for rule, line in sorted(got - want):
            problems.append(f"{fname}:{line}: unexpected {rule} finding")
        if "repro: allow[" in src and not suppressed:
            problems.append(
                f"{fname}: the fixture's allow[...] line suppressed "
                "nothing — inline suppression is broken")
    return problems
