"""LOCK fixture: guarded-attribute inference cases (parsed, never
imported). ``_hits``/``_tags`` become guarded via ``locked_bump``; every
unlocked mutation of them must be flagged, while the never-locked
``_fresh`` counter and plain reads stay silent."""

import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self._hits = 0
        self._tags = {}
        self._fresh = 0

    def locked_bump(self):
        with self._lock:
            self._hits += 1
            self._tags.setdefault("seen", 0)

    def racy_bump(self):
        self._hits += 1  # expect[LOCK]

    def racy_reset(self):
        self._tags = {}  # expect[LOCK]

    def racy_item_write(self):
        self._tags["seen"] = 0  # expect[LOCK]

    def unguarded_counter_ok(self):
        self._fresh += 1

    def snapshot_read_ok(self):
        return self._hits

    def closure_does_not_hold(self):
        with self._lock:
            def later():
                self._hits += 1  # expect[LOCK]
            return later

    def allowed_racy(self):
        self._hits += 1  # repro: allow[LOCK]: fixture — suppression must hold
