"""ERR fixture: exception-taxonomy violations (parsed, never imported)."""

import time

from repro.runtime.faults import is_transient


def swallow_everything(work):
    try:
        return work()
    except Exception:  # expect[ERR]
        return None


def classify_ok(work):
    try:
        return work()
    except Exception as e:
        if not is_transient(e):
            raise
        return None


def reraise_ok(work):
    try:
        return work()
    except BaseException:
        raise


def retry_foreign_type(work):
    for _ in range(3):
        try:
            return work()
        except ValueError:  # expect[ERR]
            time.sleep(0.01)
    return None


def retry_taxonomy_ok(work):
    for _ in range(3):
        try:
            return work()
        except (OSError, TimeoutError):
            continue
    return None


def narrow_no_retry_ok(path):
    try:
        return open(path).read()
    except KeyError:
        return None


def allowed_swallow(work):
    try:
        return work()
    except Exception:  # repro: allow[ERR]: fixture — suppression must hold
        return None
