"""HASH fixture: a miniature spec module with deliberate tag mismatches
(parsed as if it were ``api/spec.py``; never imported)."""

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field

HASHED_SECTIONS = ("source",)
HASH_EXCLUDED_FIELDS = {"source": ("throttle",)}


def _meta(help_, *, hashed=None, **kw):
    return {"help": help_, "hashed": hashed, **kw}


@dataclass(frozen=True)
class SourceSpec:
    seed: int = field(default=0, metadata=_meta("tagged right", hashed=True))
    untagged: int = field(default=1, metadata=_meta("missing the tag"))  # expect[HASH]
    mis_tagged: int = field(default=2, metadata=_meta("wrong tag", hashed=False))  # expect[HASH]
    throttle: float = field(default=0.0, metadata=_meta("carved out", hashed=True))  # expect[HASH]
    bare: int = 3  # expect[HASH]
    quirk: int = field(default=4, metadata=_meta("wrong", hashed=False))  # repro: allow[HASH]: fixture — suppression must hold

    def hash_payload(self):  # expect[HASH]
        d = dataclasses.asdict(self)
        d.pop("throttle")  # hand-listed — must consult HASH_EXCLUDED_FIELDS
        return d


@dataclass(frozen=True)
class ExecSpec:
    retries: int = field(default=2, metadata=_meta("staging leak", hashed=True))  # expect[HASH]
    out_dir: str = field(default="", metadata=_meta("staging", hashed=False))


_GROUPS = (
    ("source", SourceSpec, ""),
    ("execution", ExecSpec, ""),
)


@dataclass(frozen=True)
class PipelineSpec:
    source: SourceSpec = SourceSpec()
    execution: ExecSpec = ExecSpec()

    def content_hash(self):  # expect[HASH]
        payload = {"source": self.source.hash_payload()}
        blob = json.dumps(payload, sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]
