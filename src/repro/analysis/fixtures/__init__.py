"""Seeded-violation fixtures for ``repro.analysis --self-check``.

Every ``*_case.py`` here is *parsed, never imported*: each marked line is a
deliberate invariant violation its rule must report (``# expect[RULE]``),
next to clean idioms the rule must stay silent on and one
``# repro: allow[RULE]`` line proving suppression works. The engine's tree
walk excludes this whole package, so the fixtures never pollute a real run.
"""
