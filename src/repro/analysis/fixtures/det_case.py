"""DET fixture: ambient-state leaks the rule must catch (parsed, never
imported — see fixtures/__init__)."""

import os
import random
import time

import numpy as np


def wall_clock_stamp():
    return time.time()  # expect[DET]


def global_rng_draw():
    return np.random.rand(3)  # expect[DET]


def unseeded_generator():
    return np.random.default_rng()  # expect[DET]


def seeded_generator_ok(seed):
    return np.random.default_rng(seed)


def stdlib_random():
    return random.random()  # expect[DET]


def env_read():
    return os.environ["REPRO_MODE"]  # expect[DET]


def env_get():
    return os.getenv("REPRO_MODE")  # expect[DET]


def set_comprehension_leak(items):
    return [x * 2 for x in {i % 7 for i in items}]  # expect[DET]


def set_loop_leak(tags):
    out = []
    for t in set(tags):  # expect[DET]
        out.append(t)
    return out


def set_materialize_leak(tags):
    return list({t.lower() for t in tags})  # expect[DET]


def sorted_set_ok(tags):
    return sorted({t.lower() for t in tags})


def membership_ok(tag):
    return tag in {"mean", "std", "skew"}


def perf_counter_ok():
    return time.perf_counter()


def allowed_wall_clock():
    return time.time()  # repro: allow[DET]: fixture — suppression must hold
