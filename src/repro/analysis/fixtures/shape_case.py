"""SHAPE fixture: device-shape assembly outside the blessed shape-class
helpers (parsed as if it were ``core/executor.py``; never imported)."""

import jax.numpy as jnp
import numpy as np


def unblessed_batch(parts):
    flat = jnp.concatenate(parts)  # expect[SHAPE]
    return flat.sum()


def unblessed_stack(a, b):
    return jnp.stack([a, b])  # expect[SHAPE]


def unblessed_reshape(x, n):
    return jnp.reshape(x, (n, -1))  # expect[SHAPE]


def blessed_batch(parts, grp):
    padded = grp.padded_size(sum(p.shape[0] for p in parts))
    flat = jnp.concatenate(parts)
    return flat, padded


def host_assembly_ok(parts):
    return np.concatenate(parts)


def allowed_fixed_triple(a, b, c):
    return jnp.stack([a, b, c], axis=-1)  # repro: allow[SHAPE]: fixed triple, not a batch seam
