"""ERR — exception handling must speak the transient-fault taxonomy.

DESIGN.md §14 classifies failures once, in ``runtime.faults``: transient
(``TransientError``, OSError/TimeoutError families — retry with backoff)
vs fatal (``ShardLostError``, programming errors — re-raise immediately).
A handler that retries outside that taxonomy, or swallows broadly without
consulting it, silently converts bugs into "transients" and retries them
into the quarantine path — the exact failure mode the taxonomy exists to
prevent.

Two checks per ``except`` handler in the runtime-facing packages:

* **broad swallow** — a bare / ``Exception`` / ``BaseException`` handler
  must either re-``raise`` on some path or classify via ``is_transient``;
  one that does neither swallows fatals;
* **foreign retry** — a handler that retries (a ``continue``, or a
  backoff ``sleep`` in its body) may only catch taxonomy types; retrying
  a ``ValueError`` is a loop around a bug.

Deliberate swallow-and-surface-later sites (a worker thread that parks the
exception for the main thread to re-raise) carry ``# repro: allow[ERR]``
with the surfacing path named.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Finding, Rule, qualname

SCOPE = ("core/", "data/", "serve/", "api/", "runtime/")

BROAD = {"Exception", "BaseException"}

# The transient taxonomy: runtime.faults.TransientError and the stdlib
# families is_transient() honors (OSError and subclasses, timeouts).
TRANSIENT_TYPES = {
    "TransientError", "InjectedFault", "PrefetchError",
    "OSError", "IOError", "EnvironmentError", "TimeoutError",
    "ConnectionError", "ConnectionResetError", "BrokenPipeError",
    "FileExistsError", "FileNotFoundError", "PermissionError",
    "InterruptedError", "BlockingIOError",
    # queue backpressure is flow control, not failure — retrying it is the
    # whole point of a bounded queue
    "queue.Empty", "Empty", "queue.Full", "Full",
}


def _caught_names(handler: ast.ExceptHandler) -> list[str]:
    t = handler.type
    if t is None:
        return ["<bare>"]
    nodes = t.elts if isinstance(t, ast.Tuple) else [t]
    names = []
    for n in nodes:
        q = qualname(n, {})
        names.append(q if q else "<expr>")
    return names


def _body_has(handler: ast.ExceptHandler, *, raises=False, classifies=False,
              retries=False) -> bool:
    for node in ast.walk(handler):
        if raises and isinstance(node, ast.Raise):
            return True
        if classifies and isinstance(node, ast.Call):
            q = qualname(node.func, {}) or ""
            if q.split(".")[-1] == "is_transient":
                return True
        if retries:
            if isinstance(node, ast.Continue):
                return True
            if isinstance(node, ast.Call):
                q = qualname(node.func, {}) or ""
                if q.split(".")[-1] == "sleep":
                    return True
    return False


class ErrRule(Rule):
    name = "ERR"
    description = ("broad excepts must re-raise or classify via "
                   "is_transient; retrying handlers must catch taxonomy "
                   "types only")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith(SCOPE)

    def check(self, tree, lines, relpath):
        out: list[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            names = _caught_names(node)
            broad = any(n in BROAD or n == "<bare>" for n in names)
            raises = _body_has(node, raises=True)
            classifies = _body_has(node, classifies=True)
            retries = _body_has(node, retries=True)
            if broad and not (raises or classifies):
                out.append(self.finding(
                    relpath, node,
                    f"broad except ({', '.join(names)}) neither re-raises "
                    "nor classifies via is_transient — fatal errors are "
                    "swallowed outside the taxonomy (runtime.faults)",
                    lines))
            elif retries and not broad:
                foreign = [n for n in names
                           if n.split(".")[-1] not in TRANSIENT_TYPES
                           and n != "<expr>"]
                if foreign:
                    out.append(self.finding(
                        relpath, node,
                        f"retrying handler catches {', '.join(foreign)} — "
                        "outside the TransientError taxonomy; retrying a "
                        "non-transient loops around a bug",
                        lines))
        return out
