"""HASH — spec-field metadata must agree with the content-hash subtree.

``content_hash()`` is the cache key for every persisted result (the
``ResultCache``, ``.npz`` watermarks, resume). A result-defining field that
silently stays out of the hash is a cache-poisoning incident waiting for
its first collision; a staging field that sneaks *in* shatters cache reuse
for runs that compute identical bytes. So the hash subtree is declared
three times on purpose — and this rule cross-checks the declarations:

* ``HASHED_SECTIONS`` — which top-level spec sections are hashed;
* ``HASH_EXCLUDED_FIELDS`` — per-section fields carved out of the hash
  (``source.throttle_mb_s``/``path``/``layout``: location and bandwidth do
  not change the bytes read);
* per-field ``hashed=`` tags in every ``_meta(...)`` — the machine-readable
  truth ``api.cli`` renders into docs and the runtime test exercises.

Checks: every field of every ``_GROUPS`` dataclass carries ``_meta`` with a
literal ``hashed=`` that matches its section's hashedness and exclusions;
``content_hash`` builds its payload from ``HASHED_SECTIONS`` (not a
hand-maintained dict); any ``hash_payload`` of a section with exclusions
consults ``HASH_EXCLUDED_FIELDS``. The rule is purely structural (AST), so
it also runs on the fixture spec in ``--self-check``.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Finding, Rule

SPEC_PATH = "api/spec.py"


def _assign_value(tree: ast.Module, name: str) -> ast.expr | None:
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    return node.value
        elif isinstance(node, ast.AnnAssign):
            if isinstance(node.target, ast.Name) and node.target.id == name:
                return node.value
    return None


def _const_strs(node: ast.expr | None) -> list[str]:
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)]
    return []


def _excluded_map(node: ast.expr | None) -> dict[str, list[str]]:
    out: dict[str, list[str]] = {}
    if isinstance(node, ast.Dict):
        for k, v in zip(node.keys, node.values):
            if isinstance(k, ast.Constant) and isinstance(k.value, str):
                out[k.value] = _const_strs(v)
    return out


def _groups(node: ast.expr | None) -> list[tuple[str, str]]:
    """(section path, class name) pairs from the ``_GROUPS`` literal."""
    out: list[tuple[str, str]] = []
    if isinstance(node, (ast.Tuple, ast.List)):
        for e in node.elts:
            if (isinstance(e, ast.Tuple) and len(e.elts) >= 2
                    and isinstance(e.elts[0], ast.Constant)
                    and isinstance(e.elts[1], ast.Name)):
                out.append((e.elts[0].value, e.elts[1].id))
    return out


def _kwarg(call: ast.Call, name: str) -> ast.expr | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _meta_call(field_call: ast.Call) -> ast.Call | None:
    meta = _kwarg(field_call, "metadata")
    if (isinstance(meta, ast.Call) and isinstance(meta.func, ast.Name)
            and meta.func.id == "_meta"):
        return meta
    return None


def _uses_name(fn: ast.AST, name: str) -> bool:
    return any(isinstance(n, ast.Name) and n.id == name for n in ast.walk(fn))


class HashRule(Rule):
    name = "HASH"
    description = ("spec field hashed= tags must match the declared "
                   "content_hash subtree (sections + exclusions)")

    def applies(self, relpath: str) -> bool:
        return relpath == SPEC_PATH

    def check(self, tree, lines, relpath):
        out: list[Finding] = []

        def emit(node, msg):
            out.append(self.finding(relpath, node, msg, lines))

        sections = _const_strs(_assign_value(tree, "HASHED_SECTIONS"))
        excluded = _excluded_map(_assign_value(tree, "HASH_EXCLUDED_FIELDS"))
        groups = _groups(_assign_value(tree, "_GROUPS"))
        if not sections or not groups:
            emit(1, "spec module must declare HASHED_SECTIONS and _GROUPS "
                    "as module-level literals — the hash subtree is checked "
                    "against them")
            return out

        # class name -> section top segments it serves under
        owners: dict[str, list[str]] = {}
        for path, cls_name in groups:
            owners.setdefault(cls_name, []).append(path.split(".")[0])

        classes = {n.name: n for n in tree.body if isinstance(n, ast.ClassDef)}

        for cls_name, tops in owners.items():
            cls = classes.get(cls_name)
            if cls is None:
                continue
            in_hash = {t in sections for t in tops}
            if len(in_hash) > 1:
                emit(cls, f"{cls_name} serves both hashed and unhashed "
                          "sections — per-field hashed= tags are ambiguous")
                continue
            section_hashed = in_hash.pop()
            carved = {f for t in tops for f in excluded.get(t, ())}
            for stmt in cls.body:
                if not (isinstance(stmt, ast.AnnAssign)
                        and isinstance(stmt.target, ast.Name)):
                    continue
                fname = stmt.target.id
                if not (isinstance(stmt.value, ast.Call)
                        and isinstance(stmt.value.func, ast.Name)
                        and stmt.value.func.id == "field"):
                    emit(stmt, f"{cls_name}.{fname} is not declared via "
                               "field(metadata=_meta(...)) — it has no "
                               "hashed= tag for the cross-check")
                    continue
                meta = _meta_call(stmt.value)
                if meta is None:
                    emit(stmt, f"{cls_name}.{fname} has no _meta metadata "
                               "— every spec field declares its CLI surface "
                               "and hashed= tag")
                    continue
                hashed = _kwarg(meta, "hashed")
                if hashed is None:
                    emit(stmt, f"{cls_name}.{fname} is missing hashed= — "
                               "tag whether this field feeds content_hash")
                    continue
                if not (isinstance(hashed, ast.Constant)
                        and isinstance(hashed.value, bool)):
                    emit(stmt, f"{cls_name}.{fname}: hashed= must be a "
                               "literal True/False (machine-checkable)")
                    continue
                expected = section_hashed and fname not in carved
                if hashed.value != expected:
                    why = ("its section is excluded from content_hash"
                           if not section_hashed else
                           f"HASH_EXCLUDED_FIELDS carves it out"
                           if fname in carved else
                           "its section is hashed and it is not excluded")
                    emit(stmt, f"{cls_name}.{fname}: hashed="
                               f"{hashed.value} but {why}")
            # sections with exclusions must consult the constant, so the
            # carve-out list cannot drift from the actual pops
            if section_hashed and carved:
                payload_fn = next(
                    (n for n in cls.body
                     if isinstance(n, ast.FunctionDef)
                     and n.name == "hash_payload"), None)
                if payload_fn is None or not _uses_name(
                        payload_fn, "HASH_EXCLUDED_FIELDS"):
                    emit(payload_fn or cls,
                         f"{cls_name}.hash_payload must drop exactly "
                         "HASH_EXCLUDED_FIELDS — hand-listed exclusions "
                         "drift from the declared carve-outs")

        # content_hash must be driven by HASHED_SECTIONS, not a literal dict
        content_fn = next(
            (n for c in classes.values() for n in c.body
             if isinstance(n, ast.FunctionDef) and n.name == "content_hash"),
            None)
        if content_fn is None:
            emit(1, "no content_hash() method found — the spec module must "
                    "define the provenance hash")
        elif not _uses_name(content_fn, "HASHED_SECTIONS"):
            emit(content_fn,
                 "content_hash() does not build its payload from "
                 "HASHED_SECTIONS — a new section (or a tag change) would "
                 "not reach the hash")
        return out
