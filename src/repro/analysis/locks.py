"""LOCK — lock-consistency inference for shared mutable state.

The PR 7 store-vs-evict cache race motivated this rule: a counter or map
that is *sometimes* mutated under a lock is a cross-thread contract, and
every other mutation site is a race until proven otherwise.

The analysis is RacerD-style inference, per class, with no annotations:

1. a class owns a lock when a method assigns ``self.X = threading.Lock()``
   (or ``RLock``);
2. pass 1 — every ``self.Y`` mutated inside ``with self.X:`` becomes
   *guarded* (assignment, augmented assignment, subscript store, deletion,
   or a known mutator-method call like ``.append``/``.setdefault``);
3. pass 2 — a mutation of a guarded attribute *outside* any lock is a
   finding. ``__init__``/``__post_init__`` are exempt (no concurrent
   aliases exist yet), and a nested function's body resets the held-lock
   depth: defining a closure under ``with`` does not mean it *runs* there.

Reads are deliberately not flagged: ``stats()``-style snapshots are racy
but benign by documented contract ("may lag"), and flagging them would
drown the mutation signal that actually corrupts state.
"""

from __future__ import annotations

import ast

from repro.analysis.engine import Finding, Rule, qualname, self_attr

SCOPE = ("core/", "data/", "serve/", "api/", "runtime/")

LOCK_FACTORIES = {"threading.Lock", "threading.RLock"}

MUTATORS = {"append", "appendleft", "add", "extend", "insert", "remove",
            "discard", "pop", "popitem", "popleft", "clear", "update",
            "setdefault", "move_to_end", "sort", "reverse"}

INIT_METHODS = {"__init__", "__post_init__", "__new__"}


def _lock_attrs(cls: ast.ClassDef, aliases: dict[str, str]) -> set[str]:
    locks: set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if qualname(node.value.func, aliases) in LOCK_FACTORIES:
                for t in node.targets:
                    attr = self_attr(t)
                    if attr:
                        locks.add(attr)
    return locks


def _mutations(node: ast.AST) -> list[tuple[str, ast.AST]]:
    """(attr, node) pairs for every ``self.X`` mutation rooted at ``node``
    itself (non-recursive — the walker drives traversal)."""
    out: list[tuple[str, ast.AST]] = []

    def targets_of(t: ast.AST):
        if isinstance(t, (ast.Tuple, ast.List)):
            for elt in t.elts:
                targets_of(elt)
        else:
            attr = self_attr(t)
            if attr:
                out.append((attr, t))

    if isinstance(node, ast.Assign):
        for t in node.targets:
            targets_of(t)
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        if node.value is not None or isinstance(node, ast.AugAssign):
            targets_of(node.target)
    elif isinstance(node, ast.Delete):
        for t in node.targets:
            targets_of(t)
    elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        if node.func.attr in MUTATORS:
            attr = self_attr(node.func.value)
            if attr:
                out.append((attr, node))
    return out


class LockRule(Rule):
    name = "LOCK"
    description = ("attributes mutated under a class's lock must always be "
                   "mutated under it (inferred guarded-by sets)")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith(SCOPE)

    def check(self, tree, lines, relpath):
        from repro.analysis.engine import import_aliases

        aliases = import_aliases(tree)
        out: list[Finding] = []
        for cls in ast.walk(tree):
            if isinstance(cls, ast.ClassDef):
                out.extend(self._check_class(cls, aliases, lines, relpath))
        return out

    def _check_class(self, cls, aliases, lines, relpath):
        locks = _lock_attrs(cls, aliases)
        if not locks:
            return []

        guarded: dict[str, str] = {}  # attr -> lock it was seen held under
        findings: list[tuple[str, ast.AST]] = []

        def walk(node: ast.AST, depth: int, collect: bool) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                # a nested def/lambda runs later, not under the current lock
                body = node.body if isinstance(node.body, list) else [node.body]
                for child in body:
                    walk(child, 0, collect)
                return
            held = depth
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    attr = self_attr(item.context_expr)
                    if attr in locks:
                        held += 1
            for attr, at in _mutations(node):
                if attr in locks:
                    continue
                if held and collect:
                    guarded.setdefault(attr, "lock")
                elif not held and not collect and attr in guarded:
                    findings.append((attr, at))
            for child in ast.iter_child_nodes(node):
                walk(child, held, collect)

        methods = [n for n in cls.body
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for phase_collect in (True, False):
            for m in methods:
                if m.name in INIT_METHODS:
                    continue
                for stmt in m.body:
                    walk(stmt, 0, phase_collect)

        return [self.finding(
            relpath, at,
            f"{cls.name}.{attr} is mutated under a lock elsewhere but "
            "unlocked here — cross-thread mutation must hold the lock",
            lines) for attr, at in findings]
