"""Streaming ingestion: append-able cubes, merge-able statistics,
chunk-granular incremental recompute (DESIGN.md §16).

The subsystem spans four layers:

* data — ``append_realizations`` extends an exported cube with a versioned
  manifest delta; ``FileCubeSource`` opens any version and
  ``file_source.chunk_diff`` reports what an append touched;
* core — ``moments`` carries the Chan/Pébay sufficient-statistic merges and
  exact histogram merges, wired through the ``fit_backend`` registry;
  ``stats.StatsRecorder`` persists per-window statistics sidecars;
* api — ``PDFSession`` adopts cached slices whose chunk fingerprints are
  unchanged (``ResultCache.adopt``) and routes appended slices through
  ``incremental.merge_slice`` (or a strict full recompute);
* serve/launch — ``PDFServer.invalidate`` and ``run_pdf --watch`` pick up
  appends without a restart.
"""

from repro.streaming.append import append_realizations
from repro.streaming.incremental import merge_slice, refit_from_stats
from repro.streaming.moments import (
    MERGE_ULP_BUDGET,
    SuffStats,
    empty_suffstats,
    merge_counts,
    merge_counts_jnp,
    merge_suffstats,
    merge_suffstats_jnp,
    moments_from_suffstats,
    suffstats_from_moments,
    suffstats_from_values,
    ulp_diff,
)
from repro.streaming.stats import StatsRecorder, load_stats

__all__ = [
    "MERGE_ULP_BUDGET",
    "StatsRecorder",
    "SuffStats",
    "append_realizations",
    "empty_suffstats",
    "load_stats",
    "merge_counts",
    "merge_counts_jnp",
    "merge_slice",
    "merge_suffstats",
    "merge_suffstats_jnp",
    "moments_from_suffstats",
    "refit_from_stats",
    "suffstats_from_moments",
    "suffstats_from_values",
    "ulp_diff",
]
