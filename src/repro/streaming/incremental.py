"""Incremental recompute across appends: merge-mode window updates.

Two update modes (``StreamSpec.update_mode``) govern what happens to a
slice whose chunks changed in an append:

* ``"strict"`` — the session recomputes affected slices in full through the
  normal executor: bitwise-identical to a from-scratch run on the appended
  cube, by construction. This module is not involved.
* ``"merge"`` (the default) — each affected window re-fits from *merged*
  sufficient statistics: the persisted sidecar (streaming/stats.py) carries
  the old partition's stats and Eq.-5 counts, the append's new realizations
  are read alone (O(new data)), and the Chan/Pébay merge plus an exact
  integer histogram merge reconstruct the appended window without re-reading
  its history. Merged histograms are bitwise-equal to a full recompute;
  merged moments are within ``MERGE_ULP_BUDGET`` float32 ulps of it — the
  updated watermark records that tolerance (``merge_ulp_budget``), which is
  exactly why merge-mode results never enter the ``ResultCache`` (the cache
  serves only bitwise-reproducible entries).

The merge is refused — per slice, falling back to a full recompute — when
its preconditions do not hold: a missing/foreign sidecar, a bin-count
mismatch, no new observations, or new values outside a point's old
``[vmin, vmax]`` (the Eq.-5 edges move, so old counts are not reusable;
moments would still merge, but a half-merged slice is not worth the
asymmetry). The fallback is the safety valve that keeps ``"merge"`` a pure
optimization.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core import distributions as dists
from repro.core import fitting
from repro.core import pdf_error as pe
from repro.core import regions
from repro.core.executor import _FIELDS, PersistStage, SliceResult
from repro.streaming import stats as sstats
from repro.streaming.moments import (
    MERGE_ULP_BUDGET,
    merge_counts,
    merge_suffstats,
    moments_from_suffstats,
    suffstats_from_values,
)


@dataclass
class MergedWindow:
    window: regions.Window
    arrays: dict  # _FIELDS name -> (P,) / (P, 3) float32
    stats: "sstats.SuffStats"
    freq: np.ndarray  # int64 (P, L)


def refit_from_stats(types, num_bins: int, moments: dists.Moments,
                     freq: np.ndarray):
    """Algorithm 3 (fit every candidate, select by Eq.-5 error) driven from
    statistics alone — no raw values. Equivalent to the fused-mode chain:
    the histogram that would be computed from values is replaced by the
    merged counts, everything downstream is the same code."""
    mom = dists.Moments(*(jnp.asarray(np.asarray(f, np.float32))
                          for f in moments))
    params_all = dists.fit_all(types, mom)
    edges = pe.interval_edges(mom.vmin, mom.vmax, num_bins)
    masses = pe.cdf_masses(types, params_all, edges)
    errs = pe.pdf_error_from_freq(jnp.asarray(freq, jnp.float32), masses)
    res = fitting.select_best(params_all, errs)
    return (np.asarray(res.type_idx), np.asarray(res.params),
            np.asarray(res.error))


def merge_window(spec, source, w: regions.Window,
                 old: dict) -> MergedWindow | None:
    """Merge one window forward over an append, or None when a merge
    precondition fails (see module docstring). ``old`` is the window's
    sidecar dict from ``stats.load_stats``."""
    num_bins = spec.compute.num_bins
    if old["num_bins"] != num_bins:
        return None
    n_old = int(old["stats"].n)
    n_now = source.slice_observations(w.slice_i)
    if n_now <= n_old:
        return None  # nothing appended since the sidecar was recorded
    new_vals = source.load_window_obs(w, n_old, n_now)  # (P, k) float32
    new_stats = suffstats_from_values(new_vals)
    merged = merge_suffstats(old["stats"], new_stats)
    if not (np.array_equal(merged.vmin, old["stats"].vmin)
            and np.array_equal(merged.vmax, old["stats"].vmax)):
        return None  # edges moved: old Eq.-5 counts are not reusable
    # Exact histogram merge: bin the new partition over the OLD edges (f32,
    # the pipeline's own scatter path) and add integers. Bitwise-equal to a
    # one-pass histogram of the full window because scatter counts are
    # order-independent integer sums (< 2**24).
    vmin32 = jnp.asarray(np.asarray(old["stats"].vmin, np.float32))
    vmax32 = jnp.asarray(np.asarray(old["stats"].vmax, np.float32))
    new_freq = np.rint(np.asarray(pe.histogram_scatter(
        jnp.asarray(new_vals), vmin32, vmax32, num_bins))).astype(np.int64)
    freq = merge_counts(old["freq"], new_freq)
    mom = moments_from_suffstats(merged, np.float32)
    type_idx, params, error = refit_from_stats(
        tuple(spec.compute.types), num_bins, mom, freq)
    arrays = {
        "type_idx": type_idx.astype(np.int32),
        "params": params,
        "error": error,
        "mean": np.asarray(mom.mean, np.float32),
        "std": np.sqrt(np.maximum(np.asarray(mom.var, np.float32), 0)),
        "skew": np.asarray(mom.skew, np.float32),
        "kurt": np.asarray(mom.kurt, np.float32),
    }
    return MergedWindow(w, arrays, merged, freq)


def merge_slice(spec, source, slice_i: int, new_hash: str,
                lineage: tuple[str, ...] = ()) -> SliceResult | None:
    """Merge every window of one appended slice forward, atomically from the
    caller's point of view: windows/sidecars/watermark are rewritten only
    after ALL windows merged (any failure returns None with the out_dir
    untouched, and the caller falls back to a full recompute).

    The out_dir must hold the previous run's windows + stats sidecars; the
    watermark's recorded spec hash identifies that run, and sidecars are
    validated against it OR against ``lineage`` — the spec's hashes at
    archived manifest versions. A cache-hit persist re-stamps the watermark
    at the session's current hash without touching the sidecars (it has no
    SuffStats to rewrite them with), so an adopted slice's sidecars keep an
    ancestor version's stamp; any lineage hash proves the same compute
    knobs over an ancestor of the same append-only cube, and the merge
    reads everything past the sidecar's own ``n``, so an older stamp is
    still sound merge input. The updated watermark carries ``new_hash``
    plus the merge tolerance: ``{"merge_ulp_budget": MERGE_ULP_BUDGET,
    "merged_from": <old hash>}``."""
    out_dir = spec.execution.out_dir
    if out_dir is None:
        return None
    geom = source.geometry
    persist = PersistStage(out_dir, async_writes=False, spec_hash=new_hash)
    info = persist.watermark_info(slice_i)
    old_hash = info.get("spec_hash")
    if not old_hash or int(info.get("next_line", 0)) < geom.lines_per_slice:
        return None  # no complete prior run to merge forward
    merged: list[MergedWindow] = []
    for w in regions.iter_windows(geom, slice_i, spec.compute.window_lines):
        old = sstats.load_stats(out_dir, slice_i, w.line_start,
                                spec_hash=(old_hash, *lineage))
        if old is None or (old["line_start"], old["line_end"]) != \
                (w.line_start, w.line_end):
            return None  # sidecar missing/foreign/mis-windowed
        mw = merge_window(spec, source, w, old)
        if mw is None:
            return None
        merged.append(mw)
    # Commit: window .npz + sidecars first, tolerance-stamped watermark last
    # (the same durable-then-advance order the persist stage uses).
    for mw in merged:
        persist.submit(slice_i, mw.window, mw.arrays)
        sstats.write_stats(out_dir, slice_i, mw.window.line_start,
                           mw.window.line_end, mw.stats, mw.freq,
                           spec.compute.num_bins, new_hash)
    persist.close()
    persist.raise_if_failed()
    mark = Path(out_dir) / f"slice{slice_i}_watermark.json"
    mark.write_text(json.dumps({
        "next_line": geom.lines_per_slice,
        "spec_hash": new_hash,
        "merge_ulp_budget": MERGE_ULP_BUDGET,
        "merged_from": old_hash,
    }))
    outs = {name: np.concatenate([mw.arrays[name] for mw in merged])
            for name in _FIELDS}
    return SliceResult(
        *(outs[name] for name in _FIELDS),
        avg_error=float(outs["error"].mean()),
        stats=[], slice_i=slice_i, spec_hash=new_hash,
    )
