"""Merge-able sufficient statistics: parallel Welford/Chan moment merges.

The Random Sample Partition view of the cube (PAPERS.md) treats every chunk
as a self-contained partition of the observations; an *append* adds a new
partition of realizations to a window the pipeline has already fitted. The
Eq.-2 moments and the Eq.-5 histogram are both decomposable over that
partition structure:

* moments — a window's (mean, var, skew, kurt, min, max) finalize from the
  sufficient statistics ``(n, mean, S2, S3, S4, vmin, vmax)`` where
  ``Sk = sum((x - mean)**k)``; two partitions' statistics merge exactly with
  the Chan/Golub/LeVeque + Pébay update formulas — no re-read of the old
  observations;
* histogram — Eq.-5 bin counts over FIXED edges are integers, and integer
  addition is exact: merged counts are bitwise-equal to a full recompute
  whenever the merged (vmin, vmax) still equal the edges the old counts
  were binned with (otherwise the edges moved and the merge layer must
  fall back to a full recompute of that window — streaming/incremental.py).

Both a host (numpy, float64 accumulation) and a jnp path are provided and
wired through the ``fit_backend`` registry (core/fitting.py): ``reference``
carries the host pair, ``kernels``/``fused`` the jnp pair. The formulas are
identical; only the array module and accumulation dtype differ.

Merged moments are NOT bitwise-equal to a from-scratch recompute — float
rounding differs along the merge tree — but they are provably close:
``MERGE_ULP_BUDGET`` pins the float32 ulp tolerance the property tests
(merge associativity, partition-permutation invariance, empty/degenerate
partitions) and the merge-mode watermark both use. The budget is a
declared constant, never recomputed from an observed run.

This module is deliberately free of repro imports beyond the ``Moments``
container — the merge math must stay importable from the fit-backend
registry and the data layer without cycles.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.distributions import Moments

# Matches distributions._EPS / pdf_error._EPS: the finalization guards must
# be the same as moments_from_values' or a merged refit would diverge from
# the full recompute for reasons other than merge rounding.
_EPS = 1e-12

# The pinned tolerance (float32 ulps, per moment field) between merged and
# from-scratch moments. tests/test_streaming*.py assert merges stay inside
# it; streaming/incremental.py records it in every merge-mode watermark.
# Sized from the two regimes it must cover: same-precision merge
# associativity/permutation is exact to a few ulps, while a float64 merge
# against the float32 single-pass pipeline recompute differs by the
# *pipeline's* own cancellation noise in skew/kurt (~300 ulps measured on
# cube data) — 2048 bounds both with headroom, and stays a meaningful
# ~2e-4 relative bound.
MERGE_ULP_BUDGET = 2048


class SuffStats(NamedTuple):
    """Merge-able per-point statistics of one observation partition.

    ``n`` is the partition's observation count (scalar — every point of a
    window sees the same number of realizations); the array fields share
    one leading shape (the window's points). ``s2``/``s3``/``s4`` are the
    *central sums* ``sum((x - mean)**k)``, not the normalized moments —
    sums are what the Chan/Pébay updates merge. An empty partition is
    ``n=0`` with zero sums and ``vmin=+inf``/``vmax=-inf`` (the min/max
    identities), which the merge formulas absorb without branching."""

    n: float
    mean: np.ndarray
    s2: np.ndarray
    s3: np.ndarray
    s4: np.ndarray
    vmin: np.ndarray
    vmax: np.ndarray


def empty_suffstats(shape, dtype=np.float64) -> SuffStats:
    """The merge identity: ``merge(empty, s) == s`` field-for-field."""
    z = np.zeros(shape, dtype)
    return SuffStats(0.0, z.copy(), z.copy(), z.copy(), z.copy(),
                     np.full(shape, np.inf, dtype),
                     np.full(shape, -np.inf, dtype))


def suffstats_from_values(values, axis: int = -1) -> SuffStats:
    """Direct (host, float64) statistics of one partition's raw values —
    the from-scratch side of every merge test, and what the append path
    computes over the new realizations it just wrote."""
    v = np.asarray(values, np.float64)
    n = v.shape[axis]
    mean = v.mean(axis=axis)
    c = v - np.expand_dims(mean, axis)
    return SuffStats(
        float(n), mean,
        (c**2).sum(axis=axis), (c**3).sum(axis=axis), (c**4).sum(axis=axis),
        v.min(axis=axis), v.max(axis=axis))


def suffstats_from_moments(m: Moments, n: int) -> SuffStats:
    """Invert ``moments_from_values``' finalization (exactly, modulo float
    rounding: the same ``_EPS`` guards are un-applied that finalization
    applies) — how persisted window moments become merge-able statistics
    without touching the raw observations again."""
    n = float(n)
    var = np.asarray(m.var, np.float64)
    m2 = var * max(n - 1.0, 1.0) / n
    sig = np.sqrt(np.maximum(m2, _EPS))
    m3 = np.asarray(m.skew, np.float64) * sig**3
    m4 = (np.asarray(m.kurt, np.float64) + 3.0) * np.maximum(m2, _EPS) ** 2
    return SuffStats(
        n, np.asarray(m.mean, np.float64),
        n * m2, n * m3, n * m4,
        np.asarray(m.vmin, np.float64), np.asarray(m.vmax, np.float64))


def moments_from_suffstats(s: SuffStats, dtype=np.float32) -> Moments:
    """Finalize merged statistics with the *same* formulas (and ``_EPS``
    guards) as ``distributions.moments_from_values``, so a merged window
    differs from a full recompute only by merge-tree rounding — the
    difference MERGE_ULP_BUDGET bounds."""
    n = max(float(s.n), 1.0)
    m2 = np.asarray(s.s2, np.float64) / n
    var = np.asarray(s.s2, np.float64) / max(float(s.n) - 1.0, 1.0)
    sig = np.sqrt(np.maximum(m2, _EPS))
    skew = (np.asarray(s.s3, np.float64) / n) / sig**3
    kurt = (np.asarray(s.s4, np.float64) / n) / np.maximum(m2, _EPS) ** 2 - 3.0
    return Moments(*(np.asarray(f, dtype) for f in
                     (s.mean, var, skew, kurt, s.vmin, s.vmax)))


def _merge(a: SuffStats, b: SuffStats, xp) -> SuffStats:
    """Chan/Golub/LeVeque (S2) + Pébay (S3, S4) pairwise update, array
    module ``xp`` ∈ {numpy, jax.numpy}. Branch-free: an ``n=0`` side
    contributes nothing because every cross term carries an ``na*nb`` or
    ``Sk`` factor of zero, and ``n`` is clamped in denominators only."""
    na, nb = float(a.n), float(b.n)
    n = na + nb
    nn = n if n > 0 else 1.0  # counts are host scalars in both paths
    delta = b.mean - a.mean
    mean = a.mean + delta * (nb / nn)
    s2 = a.s2 + b.s2 + delta**2 * (na * nb / nn)
    s3 = (a.s3 + b.s3
          + delta**3 * (na * nb * (na - nb) / nn**2)
          + 3.0 * delta * (na * b.s2 - nb * a.s2) / nn)
    s4 = (a.s4 + b.s4
          + delta**4 * (na * nb * (na * na - na * nb + nb * nb) / nn**3)
          + 6.0 * delta**2 * (na * na * b.s2 + nb * nb * a.s2) / nn**2
          + 4.0 * delta * (na * b.s3 - nb * a.s3) / nn)
    return SuffStats(n, mean, s2, s3, s4,
                     xp.minimum(a.vmin, b.vmin), xp.maximum(a.vmax, b.vmax))


def merge_suffstats(a: SuffStats, b: SuffStats) -> SuffStats:
    """Host (numpy, float64) merge — the ``reference`` backend's path and
    the one streaming/incremental.py uses for persisted sidecar stats."""
    if a.n == 0:
        return b
    if b.n == 0:
        return a
    return _merge(a, b, np)


def merge_suffstats_jnp(a: SuffStats, b: SuffStats) -> SuffStats:
    """Device (jnp) merge with identical formulas — the ``kernels`` and
    ``fused`` backends' path. Works in the arrays' own dtype (float32 on
    default configs); the host path remains the accuracy reference."""
    return _merge(SuffStats(a.n, *map(jnp.asarray, a[1:])),
                  SuffStats(b.n, *map(jnp.asarray, b[1:])), jnp)


# -- exact integer histogram merges --------------------------------------------


def merge_counts(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Bitwise-exact Eq.-5 histogram merge over FIXED edges: counts are
    integers, so addition in int64 is exact and the result is bitwise-equal
    to histogramming the concatenated observations (same edges). Raises if
    either input is not integral — a count array that drifted off the
    integers is corrupt, not mergeable."""
    ia = np.asarray(np.rint(a), np.int64)
    ib = np.asarray(np.rint(b), np.int64)
    if not (np.array_equal(ia, np.asarray(a)) and
            np.array_equal(ib, np.asarray(b))):
        raise ValueError("histogram merge requires integral bin counts")
    return (ia + ib).astype(np.asarray(a).dtype)


def merge_counts_jnp(a, b):
    """jnp histogram merge: float32 integer adds are exact below 2**24
    counts per bin — far above any window's observation count — so plain
    addition preserves the bitwise-equality contract."""
    return jnp.asarray(a) + jnp.asarray(b)


# -- ulp distance (the budget's measuring stick) -------------------------------


def ulp_diff(a, b) -> np.ndarray:
    """Element-wise distance in float32 ulps between two arrays: the
    monotone integer reinterpretation of IEEE-754 makes |key(a) - key(b)|
    exactly the number of representable floats between them."""
    fa = np.asarray(a, np.float32)
    fb = np.asarray(b, np.float32)

    def key(x):
        i = x.view(np.int32).astype(np.int64)
        return np.where(i < 0, (1 << 31) - i, i)

    return np.abs(key(fa) - key(fb))
