"""``append_realizations``: extend an exported cube with new observations.

An append adds ``k`` new Monte-Carlo realizations to every point of a
*subset* of slices — the streaming-ingestion shape of the paper's cube
(sensors and simulation campaigns keep producing realizations; the spatial
geometry never changes). On disk an append is purely additive:

* new chunk files named ``s{slice:05d}_l{line:05d}.v{version:06d}.npy`` —
  version-stamped so a delta chunk can never collide with the base export
  or any earlier append;
* new manifest chunk entries carrying the observation range
  ``obs_start``/``obs_end`` the layer covers (base chunks keep their
  implicit ``[0, num_observations)`` range);
* the previous manifest body archived as ``manifest.vNNNNNN.json`` and a
  new ``manifest.json`` with a monotonically bumped ``version`` written
  via the repo's tmp + atomic-rename discipline.

Write order is chunks → archive → manifest replace, so a crash at ANY
point leaves the previous version fully readable (orphaned delta chunks
and a pre-archived body are inert until a manifest references them, and a
retried append overwrites them idempotently). ``FileCubeSource`` opens any
archived version, and ``chunk_diff`` reports exactly which slices an
append touched — the unit of chunk-granular cache invalidation.
"""

from __future__ import annotations

import json
import os
from pathlib import Path

import numpy as np

from repro.core.regions import CubeGeometry, iter_windows
from repro.data.file_source import (
    APPEND_FORMAT_VERSION,
    MANIFEST_NAME,
    _archive_name,
    _array_sha256,
    _manifest_content_sha,
    chunk_obs_range,
    read_manifest,
)


def _delta_chunk_name(slice_i: int, line_start: int, version: int) -> str:
    return f"s{slice_i:05d}_l{line_start:05d}.v{version:06d}.npy"


def _slice_obs_total(manifest: dict, slice_i: int) -> int:
    base = int(manifest["num_observations"])
    ends = [chunk_obs_range(c, base)[1]
            for c in manifest["chunks"] if c["slice"] == slice_i]
    return max(ends) if ends else 0


def append_realizations(cube_dir: str | Path,
                        new_data: dict[int, np.ndarray]) -> int:
    """Append new realizations to ``cube_dir`` and return the new manifest
    version.

    ``new_data`` maps ``slice_i -> (lines_per_slice, points_per_line, k)``
    float32 observations (``(points_per_slice, k)`` is accepted and
    reshaped); every point of a written slice gains the same ``k`` new
    observations, untouched slices keep their chunk set bit-for-bit — the
    property the chunk-diff invalidation layer relies on."""
    out = Path(cube_dir)
    manifest = read_manifest(out)
    geom = CubeGeometry(manifest["num_slices"], manifest["lines_per_slice"],
                        manifest["points_per_line"])
    lines_per_chunk = int(manifest["lines_per_chunk"])
    cur_version = int(manifest.get("version", 1))
    new_version = cur_version + 1

    if not new_data:
        raise ValueError("append_realizations: new_data is empty — nothing "
                         "to append")
    blocks: dict[int, np.ndarray] = {}
    for s, arr in sorted(new_data.items()):
        if not 0 <= int(s) < geom.num_slices:
            raise ValueError(
                f"append slice {s} outside the cube's {geom.num_slices} "
                "slices")
        a = np.asarray(arr, dtype=np.float32)
        if a.ndim == 2:
            a = a.reshape(geom.lines_per_slice, geom.points_per_line, -1)
        if (a.ndim != 3 or a.shape[:2] !=
                (geom.lines_per_slice, geom.points_per_line) or
                a.shape[2] < 1):
            raise ValueError(
                f"append data for slice {s} has shape {np.shape(arr)}; "
                f"expected ({geom.lines_per_slice}, {geom.points_per_line}, "
                "k>=1)")
        blocks[int(s)] = a

    # 1) delta chunks — additive files, inert until the manifest lands
    new_entries = []
    for s, a in blocks.items():
        o0 = _slice_obs_total(manifest, s)
        o1 = o0 + a.shape[2]
        for w in iter_windows(geom, s, lines_per_chunk):
            chunk = np.ascontiguousarray(a[w.line_start:w.line_end])
            name = _delta_chunk_name(s, w.line_start, new_version)
            np.save(out / name, chunk)
            new_entries.append({
                "file": name,
                "slice": s,
                "line_start": w.line_start,
                "line_end": w.line_end,
                "obs_start": o0,
                "obs_end": o1,
                "sha256": _array_sha256(chunk),
            })

    # 2) archive the current body under its own version (idempotent on a
    #    retried append — the body is identical)
    arch_tmp = out / (_archive_name(cur_version) + ".tmp")
    arch_tmp.write_text(json.dumps(manifest, indent=1, sort_keys=True))
    os.replace(arch_tmp, out / _archive_name(cur_version))

    # 3) the new manifest, atomically — the commit point of the append
    new_manifest = dict(manifest)
    new_manifest["format_version"] = APPEND_FORMAT_VERSION
    new_manifest["version"] = new_version
    new_manifest["chunks"] = list(manifest["chunks"]) + new_entries
    new_manifest.pop("content_sha256", None)
    new_manifest["content_sha256"] = _manifest_content_sha(new_manifest)
    tmp = out / (MANIFEST_NAME + ".tmp")
    tmp.write_text(json.dumps(new_manifest, indent=1, sort_keys=True))
    os.replace(tmp, out / MANIFEST_NAME)
    return new_version
