"""Per-window sufficient-statistic sidecars: the merge path's persistence.

``StatsRecorder`` is the ``StagedExecutor.stats_recorder`` hook: for every
full (non-sampled) window it snapshots the staged values *before* the fit
donates the device buffer and writes one sidecar next to the window's
persisted ``.npz``:

    out_dir/slice{N}_stats_{line:05d}.npz
        spec_hash, line_start, line_end, n, num_bins,
        mean, s2, s3, s4, vmin, vmax      # float64 SuffStats per point
        freq                              # int64 Eq.-5 counts per point

Statistics are computed from the raw float32 values in float64 on the host
(``suffstats_from_values``) — NOT inverted from the finalized float32
moments — so the old side of a later merge carries no finalization
round-trip error. The histogram counts are the pipeline's own
``histogram_scatter`` over the window's (vmin, vmax) edges, stored as exact
integers so ``merge_counts`` stays bitwise.

Writes are tmp + atomic rename (the repo-wide discipline): a crashed write
leaves no half-sidecar, and a missing/stale sidecar only costs the merge
path a full-recompute fallback for that window — never correctness.
"""

from __future__ import annotations

import functools
import os
import tempfile
import zipfile
from pathlib import Path

import jax
import numpy as np

from repro.core import pdf_error as pe
from repro.streaming.moments import SuffStats, suffstats_from_values

_STAT_FIELDS = ("mean", "s2", "s3", "s4", "vmin", "vmax")


def stats_path(out_dir: str | Path, slice_i: int, line_start: int) -> Path:
    return Path(out_dir) / f"slice{slice_i}_stats_{line_start:05d}.npz"


@functools.lru_cache(maxsize=8)
def _jitted_hist(num_bins: int):
    return jax.jit(functools.partial(pe.histogram_scatter, num_bins=num_bins))


class StatsRecorder:
    """Callable hook ``(window, values, moments) -> None`` writing one
    sidecar per window. Runs on the executor's compute thread; the write is
    synchronous but tiny (a few arrays of the window's point count)."""

    def __init__(self, out_dir: str | Path, num_bins: int,
                 spec_hash: str | None = None):
        self.out_dir = Path(out_dir)
        self.num_bins = int(num_bins)
        self.spec_hash = spec_hash
        self.windows_recorded = 0

    def __call__(self, w, values, moments) -> None:
        freq = _jitted_hist(self.num_bins)(values, moments.vmin, moments.vmax)
        # host copies before _select_and_fit donates the staged buffer
        host = np.asarray(values)
        freq = np.asarray(jax.block_until_ready(freq))
        s = suffstats_from_values(host)
        write_stats(self.out_dir, w.slice_i, w.line_start, w.line_end,
                    s, np.rint(freq).astype(np.int64), self.num_bins,
                    self.spec_hash)
        self.windows_recorded += 1


def write_stats(out_dir: str | Path, slice_i: int, line_start: int,
                line_end: int, s: SuffStats, freq: np.ndarray,
                num_bins: int, spec_hash: str | None) -> None:
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    f = stats_path(out, slice_i, line_start)
    fd, tmp = tempfile.mkstemp(dir=out, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as fh:
            np.savez(
                fh,
                spec_hash=spec_hash or "",
                line_start=line_start, line_end=line_end,
                n=float(s.n), num_bins=num_bins, freq=freq,
                **{name: np.asarray(getattr(s, name), np.float64)
                   for name in _STAT_FIELDS},
            )
        os.replace(tmp, f)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def load_stats(out_dir: str | Path, slice_i: int, line_start: int,
               spec_hash=None) -> dict | None:
    """One window's sidecar as ``{"stats": SuffStats, "freq": int64 array,
    "num_bins": int, "line_start"/"line_end": int}`` — or None when the
    sidecar is missing, unreadable, or (when ``spec_hash`` is given) was
    written under a different spec. ``spec_hash`` may be one hash or a
    collection of acceptable hashes (the spec's manifest-version lineage —
    see ``incremental.merge_slice``). None always means "fall back to a
    full recompute of this window"."""
    f = stats_path(out_dir, slice_i, line_start)
    accept = ({spec_hash} if isinstance(spec_hash, str)
              else set(spec_hash or ()))
    try:
        with np.load(f) as z:
            if accept and str(z["spec_hash"]) not in accept | {""}:
                return None
            return {
                "stats": SuffStats(float(z["n"]),
                                   *(z[name] for name in _STAT_FIELDS)),
                "freq": np.asarray(z["freq"], np.int64),
                "num_bins": int(z["num_bins"]),
                "line_start": int(z["line_start"]),
                "line_end": int(z["line_end"]),
            }
    except (OSError, ValueError, KeyError, EOFError, zipfile.BadZipFile):
        return None
