"""Aggregate dry-run JSON cells into the EXPERIMENTS.md roofline table.

  PYTHONPATH=src python -m repro.launch.report --dir results/dryrun [--pod2]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def fmt_b(x) -> str:
    if x is None:
        return "-"
    for unit, div in [("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)]:
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def load_cells(d: Path, pod: str):
    cells = {}
    for f in sorted(d.glob(f"*__{pod}.json")):
        rec = json.loads(f.read_text())
        cells[(rec["arch"], rec["shape"])] = rec
    return cells


ARCH_ORDER = [
    "granite-3-8b", "gemma3-12b", "command-r-35b", "mistral-nemo-12b",
    "seamless-m4t-medium", "llama-3.2-vision-90b", "arctic-480b",
    "kimi-k2-1t-a32b", "mamba2-780m", "hymba-1.5b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def markdown_table(cells, show_memory=False) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "HLO GFLOPs/dev | bytes/dev | coll/dev | useful | roofline frac | HBM/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = cells.get((arch, shape))
            if rec is None:
                continue
            if rec.get("skipped"):
                lines.append(f"| {arch} | {shape} | — | — | — | {rec['reason']} | — | — | — | — | — | — |")
                continue
            if not rec.get("ok"):
                lines.append(f"| {arch} | {shape} | FAIL | | | {rec.get('error','')[:60]} | | | | | | |")
                continue
            t = rec["terms_seconds"]
            mem = rec.get("memory_analysis", {})
            hbm = (mem.get("argument_size_in_bytes") or 0) + (
                mem.get("temp_size_in_bytes") or 0
            )
            lines.append(
                f"| {arch} | {shape} | {fmt_s(t['compute'])} | {fmt_s(t['memory'])} "
                f"| {fmt_s(t['collective'])} | **{rec['dominant']}** "
                f"| {rec['flops_per_device']/1e9:.0f} | {fmt_b(rec['bytes_per_device'])} "
                f"| {fmt_b(rec['collective_traffic_per_device'])} "
                f"| {rec['useful_ratio']:.2f} | {rec['roofline_fraction']:.3f} "
                f"| {fmt_b(hbm)} |"
            )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--pod2", action="store_true")
    args = ap.parse_args()
    cells = load_cells(Path(args.dir), "pod2" if args.pod2 else "pod1")
    print(markdown_table(cells))
    n_ok = sum(1 for r in cells.values() if r.get("ok"))
    n_skip = sum(1 for r in cells.values() if r.get("skipped"))
    n_fail = len(cells) - n_ok - n_skip
    print(f"\ncells: {n_ok} ok, {n_skip} skipped-by-design, {n_fail} failed")


if __name__ == "__main__":
    main()


def compare_tables(base_dir: Path, opt_dir: Path, pod: str = "pod1") -> str:
    """Baseline vs optimized dominant-term comparison (EXPERIMENTS.md §Perf
    optimized-sweep addendum)."""
    base = load_cells(base_dir, pod)
    # optimized cells carry a __opt suffix in the filename but the same
    # arch/shape keys inside the JSON.
    opt = {}
    for f in sorted(opt_dir.glob(f"*__{pod}__opt.json")):
        rec = json.loads(f.read_text())
        opt[(rec["arch"], rec["shape"])] = rec
    lines = [
        "| arch | shape | dominant (base) | base | opt | factor | useful base→opt |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            b = base.get((arch, shape))
            o = opt.get((arch, shape))
            if not b or not o or not b.get("ok") or not o.get("ok"):
                continue
            dom = b["dominant"]
            tb = b["terms_seconds"][dom]
            to = o["terms_seconds"][dom]
            factor = tb / to if to else float("inf")
            lines.append(
                f"| {arch} | {shape} | {dom} | {fmt_s(tb)} | {fmt_s(to)} "
                f"| {factor:.2f}× | {b['useful_ratio']:.2f}→{o['useful_ratio']:.2f} |"
            )
    return "\n".join(lines)


def main_compare():
    ap = argparse.ArgumentParser()
    ap.add_argument("--base", default="results/dryrun")
    ap.add_argument("--opt", default="results/dryrun_opt")
    args = ap.parse_args()
    print(compare_tables(Path(args.base), Path(args.opt)))
