"""Pipeline parallelism (GPipe-style) over a mesh axis via shard_map +
collective_permute.

Maps the classic microbatch pipeline onto jax-native constructs: each device
along the ``stage`` axis holds one stage's weights; activations flow
stage -> stage+1 with ``lax.ppermute`` once per tick; the schedule runs
``n_micro + n_stages - 1`` ticks (fill + steady-state + drain). In the
production meshes this is an optional mode mapping stages onto the ``pod``
axis (2 stages x 2 pods); correctness is asserted against the unpipelined
reference in tests/test_mesh_multidevice.py.

The stage compute here is a simple tanh(x @ w) layer — the scheduling
skeleton is the deliverable; swapping in transformer blocks is a matter of
replacing ``_stage_compute``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _stage_compute(w, x):
    return jnp.tanh(x @ w)


def pipelined_forward(mesh, axis: str, stage_weights, microbatches):
    """stage_weights: list of per-stage (d, d) mats (len == axis size);
    microbatches: (n_micro, b, d). Returns (n_micro, b, d) outputs of the
    final stage, replicated."""
    n_stages = mesh.shape[axis]
    assert len(stage_weights) == n_stages
    w_stacked = jnp.stack(stage_weights)  # (S, d, d)
    n_micro = microbatches.shape[0]

    def body(w_local, xs):
        w = w_local[0]  # this stage's weights
        s = jax.lax.axis_index(axis)
        outputs = jnp.zeros_like(xs)
        incoming = jnp.zeros_like(xs[0])
        fwd = [(i, i + 1) for i in range(n_stages - 1)]
        for t in range(n_micro + n_stages - 1):
            mb = t - s  # microbatch index this stage handles at tick t
            mb_c = jnp.clip(mb, 0, n_micro - 1)
            x_in = jnp.where(s == 0, xs[jnp.clip(t, 0, n_micro - 1)], incoming)
            y = _stage_compute(w, x_in)
            valid = (mb >= 0) & (mb < n_micro)
            is_last = s == n_stages - 1
            outputs = outputs.at[mb_c].set(
                jnp.where(valid & is_last, y, outputs[mb_c])
            )
            incoming = jax.lax.ppermute(y, axis, fwd)
        # only the last stage holds real outputs; replicate via psum.
        return jax.lax.psum(jnp.where(s == n_stages - 1, outputs, 0.0), axis)

    f = shard_map(
        body, mesh=mesh, in_specs=(P(axis), P()), out_specs=P(),
        check_rep=False,
    )
    return f(w_stacked, microbatches)
