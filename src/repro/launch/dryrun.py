import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), print memory/cost analysis, parse
collective traffic, and emit the roofline JSON that EXPERIMENTS.md reads.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first init, and the production meshes need 512
placeholder host devices. Do not import this module from tests/benches.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import ArchConfig
from repro.configs.shapes import SHAPES, ShapeDef, applicable, input_specs, ENCDEC_PROMPT
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import encdec as ED
from repro.models import sharding as sh
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


# -- step builders ---------------------------------------------------------------


def make_train_step(cfg: ArchConfig):
    opt_cfg = AdamWConfig(
        moment_dtype=jnp.bfloat16 if cfg.adam_moments_bf16 else jnp.float32
    )

    def step(params, opt, batch):
        def loss(p):
            if cfg.family == "encdec":
                return ED.loss_fn(p, batch["frames"], batch["tokens"], batch["targets"], cfg)
            extras = {"memory": batch["memory"]} if "memory" in batch else None
            return T.loss_fn(p, batch["tokens"], batch["targets"], cfg, extras)

        l, g = jax.value_and_grad(loss)(params)
        if cfg.use_adafactor:
            from repro.optim.adafactor import adafactor_update

            new_p, new_o = adafactor_update(g, opt, params)
            return new_p, new_o, l, jnp.zeros(())
        new_p, new_o, gnorm = adamw_update(g, opt, params, opt_cfg)
        return new_p, new_o, l, gnorm

    return step


def make_prefill(cfg: ArchConfig, shape: ShapeDef):
    def fn(params, batch):
        if cfg.family == "encdec":
            return ED.prefill(params, batch["frames"], batch["tokens"], cfg,
                              max_len=shape.seq_len)
        extras = {"memory": batch["memory"]} if "memory" in batch else None
        return T.prefill(params, batch["tokens"], cfg, extras, max_len=shape.seq_len)

    return fn


def make_decode_step(cfg: ArchConfig, shape: ShapeDef, mesh=None):
    pos = shape.seq_len - 1  # one new token against a full cache

    def fn(params, batch):
        if cfg.family == "encdec":
            return ED.decode_step(params, batch["token"], batch["caches"], pos, cfg)
        extras = {"memory": batch["memory"]} if "memory" in batch else {}
        if cfg.flash_decode and mesh is not None:
            extras["mesh"] = mesh
            extras["batch_axes"] = tuple(
                a for a in mesh.axis_names if a != "model"
            )
        return T.decode_step(params, batch["token"], batch["caches"], pos, cfg, extras)

    return fn


# -- lower + compile + analyse ------------------------------------------------------


def param_structs(cfg: ArchConfig):
    init = ED.init_params if cfg.family == "encdec" else T.init_params
    return jax.eval_shape(lambda: init(cfg, jax.random.PRNGKey(0)))


def lower_cell(cfg: ArchConfig, shape: ShapeDef, mesh):
    """Build + lower the cell's step function. Returns the Lowered object."""
    args, specs = input_specs(cfg, shape, mesh)
    p_struct = param_structs(cfg)
    p_shard = sh.make_shardings(cfg, mesh, p_struct)
    p_spec = sh.make_pspecs(cfg, mesh, p_struct)
    from jax.sharding import NamedSharding, PartitionSpec as P

    def ns(spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        step = make_train_step(cfg)
        if cfg.use_adafactor:
            from repro.optim.adafactor import FactoredState, adafactor_init

            o_struct = jax.eval_shape(adafactor_init, p_struct)
            is_spec = lambda x: isinstance(x, P)

            def vr_spec(ps, leaf):
                return P(*ps[:-1]) if leaf.ndim >= 2 else ps

            def vc_spec(ps, leaf):
                if leaf.ndim >= 2:
                    return P(*(list(ps[:-2]) + [ps[-1]]))
                return P(None)

            o_spec = FactoredState(
                step=P(),
                vr=jax.tree.map(vr_spec, p_spec, p_struct, is_leaf=is_spec),
                vc=jax.tree.map(vc_spec, p_spec, p_struct, is_leaf=is_spec),
            )
        else:
            ocfg = AdamWConfig(
                moment_dtype=jnp.bfloat16 if cfg.adam_moments_bf16 else jnp.float32
            )
            o_struct = jax.eval_shape(lambda p: adamw_init(p, ocfg), p_struct)
            # OptState is a NamedTuple: moments inherit each param's spec.
            from repro.optim.adamw import OptState
            o_spec = OptState(step=P(), mu=p_spec, nu=p_spec)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, ns(o_spec), ns(specs)),
            out_shardings=(p_shard, ns(o_spec), None, None),
            donate_argnums=(0, 1),
        )
        return jitted.lower(p_struct, o_struct, args)
    if shape.kind == "prefill":
        fn = make_prefill(cfg, shape)
        jitted = jax.jit(fn, in_shardings=(p_shard, ns(specs)))
        return jitted.lower(p_struct, args)
    # decode
    fn = make_decode_step(cfg, shape, mesh)
    cache_shardings = ns(specs["caches"])
    in_shardings = (p_shard, {**{k: ns(v) for k, v in specs.items() if k != "caches"},
                              "caches": cache_shardings})
    jitted = jax.jit(
        fn,
        in_shardings=in_shardings,
        out_shardings=(None, cache_shardings),
        donate_argnums=(1,),
    )
    return jitted.lower(p_struct, args)


def truncate_cfg(cfg: ArchConfig, r: int) -> ArchConfig:
    """Same architecture with r pattern repeats (fully unrolled) — the
    analysis lowering. Affine in r, so two points extrapolate exactly."""
    if cfg.family == "encdec":
        return cfg.replace(enc_layers=r, dec_layers=r, num_layers=2 * r, scan_unroll=0)
    return cfg.replace(
        num_layers=len(cfg.prefix) + len(cfg.pattern) * r, scan_unroll=0
    )


def _repeats(cfg: ArchConfig) -> int:
    return cfg.enc_layers if cfg.family == "encdec" else cfg.num_repeats


def _compile_costs(cfg: ArchConfig, shape: ShapeDef, mesh) -> dict:
    """flops/bytes per device + per-op collective traffic for one lowering."""
    compiled = lower_cell(cfg, shape, mesh).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = rl.parse_collectives(compiled.as_text(), mesh.devices.size)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_traffic": coll.per_device_traffic_bytes,
        "coll_by_op": dict(coll.op_traffic),
        "coll_counts": dict(coll.op_counts),
    }


def analysis_costs(cfg: ArchConfig, shape: ShapeDef, mesh) -> dict:
    """Exact per-step costs: XLA's HloCostAnalysis counts while bodies once,
    so the analysis lowering unrolls the layer scan. For R > 4 repeats, two
    truncated unrolled compiles (r=2, r=4) are extrapolated affinely in r —
    exact because every repeat is structurally identical."""
    r_full = _repeats(cfg)
    if r_full <= 4:
        c = _compile_costs(cfg.replace(scan_unroll=0), shape, mesh)
        c["extrapolated"] = False
        return c
    c2 = _compile_costs(truncate_cfg(cfg, 2), shape, mesh)
    c4 = _compile_costs(truncate_cfg(cfg, 4), shape, mesh)

    def extra(a2, a4):
        slope = (a4 - a2) / 2.0
        return a2 + slope * (r_full - 2)

    ops = set(c2["coll_by_op"]) | set(c4["coll_by_op"])
    by_op = {
        op: max(extra(c2["coll_by_op"].get(op, 0.0), c4["coll_by_op"].get(op, 0.0)), 0.0)
        for op in ops
    }
    counts = {
        op: int(round(extra(c2["coll_counts"].get(op, 0), c4["coll_counts"].get(op, 0))))
        for op in (set(c2["coll_counts"]) | set(c4["coll_counts"]))
    }
    return {
        "flops": extra(c2["flops"], c4["flops"]),
        "bytes": extra(c2["bytes"], c4["bytes"]),
        "coll_traffic": sum(by_op.values()),
        "coll_by_op": by_op,
        "coll_counts": counts,
        "extrapolated": True,
    }


def run_cell(cfg: ArchConfig, shape: ShapeDef, mesh, verbose: bool = True) -> dict:
    chips = mesh.devices.size
    t0 = time.perf_counter()

    # 1) production lowering (rolled scan): memory analysis + compile proof.
    lowered = lower_cell(cfg, shape, mesh)
    t_lower = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter()
    mem = compiled.memory_analysis()

    # 2) analysis lowering (unrolled / extrapolated): roofline terms.
    costs = analysis_costs(cfg, shape, mesh)
    t_analysis = time.perf_counter()

    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = rl.model_flops_train(cfg, tokens)
    elif shape.kind == "prefill":
        model_flops = rl.model_flops_prefill(cfg, shape.global_batch, shape.seq_len)
    else:
        model_flops = rl.model_flops_decode(cfg, shape.global_batch, shape.seq_len)

    flops_dev = costs["flops"]
    bytes_dev = costs["bytes"]
    coll = rl.CollectiveStats(
        per_device_traffic_bytes=costs["coll_traffic"],
        op_counts=costs["coll_counts"],
        op_traffic=costs["coll_by_op"],
    )
    roof = rl.make_roofline(flops_dev, bytes_dev, coll, chips, model_flops)

    mem_fields = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem_fields[f] = getattr(mem, f, None)

    rec = {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": list(mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "chips": chips,
        "ok": True,
        "lower_seconds": round(t_lower - t0, 2),
        "compile_seconds": round(t_compile - t_lower, 2),
        "analysis_seconds": round(t_analysis - t_compile, 2),
        "costs_extrapolated": costs.get("extrapolated", False),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_traffic_per_device": coll.per_device_traffic_bytes,
        "collective_ops": coll.op_counts,
        "collective_traffic_by_op": coll.op_traffic,
        "memory_analysis": mem_fields,
        "model_flops": model_flops,
        "total_params": rl.total_param_count(cfg),
        "active_params": rl.active_param_count(cfg),
        "terms_seconds": {
            "compute": roof.compute_s,
            "memory": roof.memory_s,
            "collective": roof.collective_s,
        },
        "dominant": roof.dominant,
        "useful_ratio": roof.useful_ratio,
        "roofline_fraction": roof.roofline_fraction,
    }
    if verbose:
        print(f"[{cfg.name} x {shape.name} x {'x'.join(map(str, mesh.devices.shape))}]")
        print(f"  lower {rec['lower_seconds']}s compile {rec['compile_seconds']}s")
        print(f"  memory_analysis: {mem}")
        print(f"  flops/dev {flops_dev:.3e}  bytes/dev {bytes_dev:.3e}  "
              f"coll/dev {coll.per_device_traffic_bytes:.3e}B {coll.op_counts}")
        t = rec["terms_seconds"]
        print(f"  terms: compute {t['compute']:.4f}s  memory {t['memory']:.4f}s  "
              f"collective {t['collective']:.4f}s  -> dominant {rec['dominant']}")
        print(f"  useful_ratio {roof.useful_ratio:.3f}  roofline_fraction "
              f"{roof.roofline_fraction:.3f}")
    return rec


def cell_id(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument(
        "--override", default="",
        help="comma-separated ArchConfig overrides, e.g. "
        "'block_local_attn=True,ssm_chunk=128' (python literals)",
    )
    ap.add_argument("--tag", default="", help="suffix for result filenames")
    args = ap.parse_args()

    import ast

    _DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32, "f16": jnp.float16}
    overrides = {}
    if args.override:
        for kv in args.override.split(","):
            k, v = kv.split("=", 1)
            v = ast.literal_eval(v)
            if isinstance(v, str) and v in _DTYPES:
                v = _DTYPES[v]
            overrides[k.strip()] = v

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    archs = registry.names() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for multi_pod in pods:
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in archs:
            cfg = registry.get(arch)
            if overrides:
                cfg = cfg.replace(**overrides)
            for shape_name in shapes:
                shape = SHAPES[shape_name]
                ok, reason = applicable(cfg, shape)
                cid = cell_id(arch, shape_name, multi_pod) + (
                    f"__{args.tag}" if args.tag else ""
                )
                path = out / f"{cid}.json"
                if not ok:
                    path.write_text(json.dumps({
                        "arch": arch, "shape": shape_name, "ok": False,
                        "skipped": True, "reason": reason,
                        "mesh": list(mesh.devices.shape),
                    }))
                    print(f"[{arch} x {shape_name}] {reason}")
                    continue
                if path.exists() and not args.force:
                    rec = json.loads(path.read_text())
                    if rec.get("ok"):
                        print(f"[{arch} x {shape_name}] cached")
                        continue
                try:
                    rec = run_cell(cfg, shape, mesh)
                except Exception as e:  # noqa: BLE001 - record & continue
                    traceback.print_exc()
                    rec = {
                        "arch": arch, "shape": shape_name, "ok": False,
                        "error": f"{type(e).__name__}: {e}",
                        "mesh": list(mesh.devices.shape),
                    }
                    failures.append(cid)
                path.write_text(json.dumps(rec, indent=1))

    if failures:
        print(f"FAILED cells: {failures}")
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
