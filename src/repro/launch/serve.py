"""Deprecated alias: ``repro.launch.serve`` is now ``serve_decode``.

"serve" here used to mean the batched LM prefill+decode demo; that module
lives at ``repro.launch.serve_decode`` now that the pipeline has a real
serving surface (``repro.launch.serve_pdf`` driving
``repro.serve.PDFServer``). This shim keeps old imports and
``python -m repro.launch.serve`` invocations working.
"""

from __future__ import annotations

import warnings

from repro.launch.serve_decode import *  # noqa: F401,F403
from repro.launch.serve_decode import generate, main  # noqa: F401

warnings.warn(
    "repro.launch.serve has been renamed to repro.launch.serve_decode "
    "(the LM decode demo); 'serve' now refers to the PDF query server — "
    "see repro.launch.serve_pdf and repro.serve.PDFServer",
    DeprecationWarning,
    stacklevel=2,
)

if __name__ == "__main__":
    main()
