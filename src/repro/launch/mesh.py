"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module constant) so importing
this module never touches jax device state — required because the dry-run
sets XLA_FLAGS before any jax initialization, while tests/benches must see
the real single CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods =
    512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests, elastic restarts)."""
    return jax.make_mesh(shape, axes)
