"""Model-decode demo driver: batched LM prefill + decode loop.

``python -m repro.launch.serve_decode --arch mamba2-780m --reduced --tokens 32``

Runs real token generation on the reduced model configs (CPU container);
the full-size decode/prefill paths are exercised per-shape by the dry-run.
Demonstrates the production decode loop: one jitted prefill, one jitted
decode step reused across positions with donated caches (no per-step
re-layout).

Formerly ``repro.launch.serve`` — renamed because "serve" now means the
paper pipeline's PDF *query* server (``repro.launch.serve_pdf`` /
``repro.serve.PDFServer``); the old module name remains as a deprecation
alias.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import encdec as ED
from repro.models import transformer as T


def generate(cfg, params, prompt: jax.Array, num_tokens: int, extras=None, max_len=None):
    b, s = prompt.shape
    max_len = max_len or (s + num_tokens)
    if cfg.family == "encdec":
        frames = extras["frames"]
        logits, caches = ED.prefill(params, frames, prompt, cfg, max_len=max_len)
        step = jax.jit(
            lambda p, t, c, pos: ED.decode_step(p, t, c, pos, cfg),
            donate_argnums=(2,), static_argnums=(),
        )
    else:
        logits, caches = T.prefill(params, prompt, cfg, extras, max_len=max_len)
        step = jax.jit(
            lambda p, t, c, pos: T.decode_step(p, t, c, pos, cfg, extras),
            donate_argnums=(2,),
        )
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(num_tokens):
        out.append(tok)
        logits, caches = step(params, tok, caches, s + i)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.names())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    init = ED.init_params if cfg.family == "encdec" else T.init_params
    params = init(cfg, key)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)

    extras = None
    if cfg.family == "vlm":
        extras = {"memory": jax.random.normal(key, (args.batch, cfg.num_patches, cfg.d_model))}
    if cfg.family == "encdec":
        extras = {"frames": jax.random.normal(key, (args.batch, args.prompt_len, cfg.d_model))}

    t0 = time.perf_counter()
    out = generate(cfg, params, prompt, args.tokens, extras)
    out = np.asarray(out)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s) sample: {out[0, :12]}")
    assert np.isfinite(out).all()
    return out


if __name__ == "__main__":
    main()
