"""Roofline accounting from compiled dry-run artifacts.

Hardware constants (TPU v5e, per the assignment):
  197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.

Terms (seconds, per-step):
  compute    = FLOPs / (chips x peak)        [global FLOPs]
  memory     = bytes / (chips x hbm_bw)      [global HBM bytes accessed]
  collective = per-device link traffic / link_bw
               (ring model: all_gather (n-1)x shard, all_reduce 2(n-1)/n x,
                reduce_scatter/all_to_all (n-1)/n x, permute 1x)

collective bytes are NOT in cost_analysis — they are parsed out of the
post-SPMD optimized HLO text (every *-start op counted once).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # [G,S]<=[N]: G groups of S participants.
        return int(m.group(2))
    return total_devices


@dataclass
class CollectiveStats:
    per_device_traffic_bytes: float = 0.0
    op_counts: dict = field(default_factory=dict)
    op_traffic: dict = field(default_factory=dict)

    def add(self, op: str, traffic: float):
        self.op_counts[op] = self.op_counts.get(op, 0) + 1
        self.op_traffic[op] = self.op_traffic.get(op, 0.0) + traffic
        self.per_device_traffic_bytes += traffic


def parse_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    """Sum per-device link traffic over every collective in the optimized HLO.

    Post-SPMD HLO prints (per-partition) shapes on the *result* side only
    (operands are bare names), so traffic is derived from result bytes with
    ring-model factors:

      all-gather       result x (n-1)/n   (result is the gathered buffer)
      all-reduce       2 x result x (n-1)/n
      reduce-scatter   result x (n-1)     (result is the scattered shard)
      all-to-all       result x (n-1)/n
      collective-permute  result

    ``-done`` ops are skipped (their ``-start`` was counted)."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        # result shapes: everything left of the opcode occurrence.
        left = line[: m.start()]
        results = _SHAPE_RE.findall(left)
        op_bytes = sum(_shape_bytes(dt, dims) for dt, dims in results)
        n = max(_group_size(line, total_devices), 1)
        if n == 1 or op_bytes == 0:
            stats.add(op, 0.0)
            continue
        if op == "all-gather":
            traffic = op_bytes * (n - 1) / n
        elif op == "all-reduce":
            traffic = 2.0 * op_bytes * (n - 1) / n
        elif op == "reduce-scatter":
            traffic = float(op_bytes) * (n - 1)
        elif op == "all-to-all":
            traffic = op_bytes * (n - 1) / n
        else:  # collective-permute
            traffic = float(op_bytes)
        stats.add(op, traffic)
    return stats


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_global: float
    bytes_global: float
    collective_traffic_per_device: float
    model_flops: float
    useful_ratio: float  # MODEL_FLOPS / HLO_FLOPs

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful compute time / achievable step time: how close the step is
        to the compute roofline if perfectly overlapped."""
        if self.bound_s <= 0:
            return 0.0
        ideal = self.model_flops / (PEAK_FLOPS * self._chips)
        return ideal / self.bound_s

    _chips: int = 1


def make_roofline(
    flops_per_device: float,
    bytes_per_device: float,
    coll: CollectiveStats,
    chips: int,
    model_flops: float,
) -> Roofline:
    flops_global = flops_per_device * chips
    bytes_global = bytes_per_device * chips
    r = Roofline(
        compute_s=flops_global / (chips * PEAK_FLOPS),
        memory_s=bytes_global / (chips * HBM_BW),
        collective_s=coll.per_device_traffic_bytes / LINK_BW,
        flops_global=flops_global,
        bytes_global=bytes_global,
        collective_traffic_per_device=coll.per_device_traffic_bytes,
        model_flops=model_flops,
        useful_ratio=model_flops / flops_global if flops_global else 0.0,
    )
    r._chips = chips
    return r


def model_flops_train(cfg, tokens: int) -> float:
    """6*N_active*D for one training step (fwd+bwd)."""
    n = active_param_count(cfg)
    return 6.0 * n * tokens


def model_flops_decode(cfg, batch: int, context: int) -> float:
    """2*N_active per token forward + attention reads over the context."""
    n = active_param_count(cfg)
    flops = 2.0 * n * batch
    # attention over cached context (full-attn layers only)
    attn_layers = _full_attn_layers(cfg)
    flops += 4.0 * attn_layers * batch * context * cfg.kv_heads * cfg.head_dim
    return flops


def model_flops_prefill(cfg, batch: int, seq: int) -> float:
    n = active_param_count(cfg)
    return 2.0 * n * batch * seq


def _full_attn_layers(cfg) -> int:
    if cfg.family == "encdec":
        return cfg.dec_layers * 2
    total = 0
    for bd in cfg.prefix:
        if bd.mixer in ("attn", "hybrid", "cross_attn") and bd.window is None:
            total += 1
    reps = cfg.num_repeats
    for bd in cfg.pattern:
        if bd.mixer in ("attn", "hybrid", "cross_attn") and bd.window is None:
            total += reps
    return total


def active_param_count(cfg) -> float:
    """Active (per-token) parameter count: MoE counts top_k of num_experts."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    attn = d * (cfg.q_heads + 2 * cfg.kv_heads) * cfg.head_dim + cfg.q_heads * cfg.head_dim * d

    def ssm_params():
        d_inner = cfg.ssm_expand * d
        h = d_inner // cfg.ssm_head_dim if cfg.ssm_head_dim else 0
        gn = cfg.ssm_groups * cfg.ssm_state
        return d * (2 * d_inner + 2 * gn + h) + d_inner * d

    def block_params(bd) -> float:
        p = 0.0
        if bd.mixer in ("attn", "cross_attn"):
            p += attn
        elif bd.mixer == "ssm":
            p += ssm_params()
        elif bd.mixer == "hybrid":
            p += attn + ssm_params()
        if bd.ffn == "dense":
            p += 3 * d * ff
        elif bd.ffn == "moe":
            p += cfg.moe_top_k * 3 * d * ff + d * cfg.num_experts
            p += 3 * d * cfg.moe_shared_ff
        elif bd.ffn == "moe_dense":
            p += cfg.moe_top_k * 3 * d * ff + d * cfg.num_experts + 3 * d * ff
        return p

    if cfg.family == "encdec":
        enc = cfg.enc_layers * (attn + 3 * d * ff)
        dec = cfg.dec_layers * (2 * attn + 3 * d * ff)
        return enc + dec + 2 * d * v
    total = sum(block_params(bd) for bd in cfg.prefix)
    total += cfg.num_repeats * sum(block_params(bd) for bd in cfg.pattern)
    return total + 2 * d * v


def total_param_count(cfg) -> float:
    """Total stored parameters (MoE counts all experts)."""
    if not cfg.num_experts:
        return active_param_count(cfg)
    extra = (cfg.num_experts - cfg.moe_top_k) * 3 * cfg.d_model * cfg.d_ff
    per_moe_layer_extra = extra
    moe_layers = sum(1 for bd in cfg.pattern if bd.ffn in ("moe", "moe_dense"))
    return active_param_count(cfg) + cfg.num_repeats * moe_layers * per_moe_layer_extra
