import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the PAPER's own workload: one PDF-computation window step on
the production mesh (the analog of launch/dryrun.py for the LM cells).

The step is the fused device part of Algorithms 1-3 for a window of points:
moments -> fit all candidate types -> Eq.-5 error -> argmin (plus, in the
``grouping_global`` variant, the §5.2 cross-device shuffle via all_gather,
whose collective term is exactly the paper's "grouping stops scaling"
effect).

Variants (--variant):
  faithful        baseline per-type histogram passes (paper cost model)
  fused           shared histogram across types (beyond-paper optimization)
  grouping_global faithful + global grouping shuffle (collective exposure)

Shapes (--pdf-shape):
  window_small    6,275 pts x 1,000 obs   (Set1: 25 lines x 251 points)
  window_prod     262,144 pts x 1,000 obs (Set2-scale, mesh-sized window)
  window_obs10k   65,536 pts x 10,000 obs (Set3 regime: 10x observations)

  PYTHONPATH=src python -m repro.launch.dryrun_pdf --all --out results/dryrun_pdf
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributions as d
from repro.core import fitting
from repro.core import grouping as grp
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh

PDF_SHAPES = {
    # Set1 window (25 lines x 251 points = 6,275) padded to the 512-device
    # mesh divisor, as the loader does (data/loader.ShardedStager).
    "window_small": (6_656, 1_000),
    "window_prod": (262_144, 1_000),
    "window_obs10k": (65_536, 10_000),
}

VARIANTS = ("faithful", "fused", "fused_scatter", "fused_scatter_shmap", "grouping_global")
NUM_BINS = 20
TYPES = d.TYPES_4

# Pipeline knobs (types / num_bins / group_tol) come from the shared
# PipelineSpec surface — the dry-run declares only its own defaults here
# (the paper's 20-bin histogram) and no flags of its own for them, so it can
# never again drift from the launchers (PR 3 had to fix this file silently
# dropping --group-tol).
def _base_spec():
    from repro.api import ComputeSpec, PipelineSpec

    return PipelineSpec(compute=ComputeSpec(num_bins=NUM_BINS, types=TYPES))


def make_window_step(variant: str, mesh, types=TYPES, num_bins=NUM_BINS,
                     group_tol: float = grp.DEFAULT_TOL):
    axes = tuple(mesh.axis_names)

    def core(values):
        from repro.core import pdf_error as pe

        m = d.moments_from_values(values)
        mode = "faithful" if variant in ("faithful", "grouping_global") else "fused"
        hist = (
            pe.histogram_scatter
            if variant.startswith("fused_scatter")
            else pe.histogram
        )
        r = fitting.compute_pdf_and_error(
            values, m, types, num_bins, mode=mode, histogram_fn=hist
        )
        return (r.type_idx, r.params, r.error, m.mean, m.var)

    if variant == "fused_scatter_shmap":
        # The per-point fit is embarrassingly parallel (the paper's Map):
        # shard_map makes that explicit, so the partitioner cannot introduce
        # data gathers (§Perf pdf-seismic iteration 3).
        from jax.experimental.shard_map import shard_map

        return shard_map(
            core, mesh=mesh,
            in_specs=P(axes, None),
            out_specs=(P(axes), P(axes, None), P(axes), P(axes), P(axes)),
        )

    def step(values):
        out = core(values)
        if variant == "grouping_global":
            # §5.2 global shuffle: quantized keys all_gathered + dedup'd.
            # quantize_keys_from_var matches the host Select path bit-exactly
            # (f64 sqrt + hi/lo int32 key pairs) at the *configured* tol —
            # this used to drop the tolerance and always group at DEFAULT_TOL.
            from jax.experimental.shard_map import shard_map

            mean, var = out[3], out[4]
            keys = grp.quantize_keys_from_var(mean, var, tol=group_tol)
            rep = shard_map(
                lambda k: grp.group_device_global(k, axes).rep_for_point,
                mesh=mesh, in_specs=P(axes), out_specs=P(axes),
            )(keys)
            out = out + (rep,)
        return out

    return step


def run_pdf_cell(variant: str, shape_name: str, mesh, verbose=True,
                 group_tol: float = grp.DEFAULT_TOL, types=TYPES,
                 num_bins: int = NUM_BINS, spec_hash: str | None = None) -> dict:
    points, obs = PDF_SHAPES[shape_name]
    chips = mesh.devices.size
    axes = tuple(mesh.axis_names)
    values = jax.ShapeDtypeStruct((points, obs), jnp.float32)
    in_sh = NamedSharding(mesh, P(axes, None))

    step = make_window_step(variant, mesh, types=types, num_bins=num_bins,
                            group_tol=group_tol)
    t0 = time.perf_counter()
    lowered = jax.jit(step, in_shardings=(in_sh,)).lower(values)
    compiled = lowered.compile()
    t1 = time.perf_counter()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = rl.parse_collectives(compiled.as_text(), chips)
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))

    # "model flops" for the PDF step: the minimum useful work = one moments
    # pass (5 flops/value) + one histogram pass (2) + T x O(L) CDF math.
    t_types = len(types)
    model_flops = points * obs * (5.0 + 2.0) + points * t_types * num_bins * 25.0
    roof = rl.make_roofline(flops_dev, bytes_dev, coll, chips, model_flops)

    rec = {
        "workload": "pdf-seismic",
        "variant": variant,
        "spec_hash": spec_hash,
        "shape": shape_name,
        "points": points,
        "obs": obs,
        "mesh": list(mesh.devices.shape),
        "chips": chips,
        "ok": True,
        "compile_seconds": round(t1 - t0, 2),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_traffic_per_device": coll.per_device_traffic_bytes,
        "collective_ops": coll.op_counts,
        "memory_analysis": {
            "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", None),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
        },
        "model_flops": model_flops,
        "terms_seconds": {
            "compute": roof.compute_s,
            "memory": roof.memory_s,
            "collective": roof.collective_s,
        },
        "dominant": roof.dominant,
        "useful_ratio": roof.useful_ratio,
        "roofline_fraction": roof.roofline_fraction,
    }
    if verbose:
        t = rec["terms_seconds"]
        print(f"[pdf {variant} x {shape_name} x {'x'.join(map(str, mesh.devices.shape))}] "
              f"compile {rec['compile_seconds']}s")
        print(f"  flops/dev {flops_dev:.3e} bytes/dev {bytes_dev:.3e} "
              f"coll/dev {coll.per_device_traffic_bytes:.3e} {coll.op_counts}")
        print(f"  compute {t['compute']*1e3:.2f}ms memory {t['memory']*1e3:.2f}ms "
              f"collective {t['collective']*1e3:.2f}ms -> {rec['dominant']} "
              f"(useful {roof.useful_ratio:.3f})")
    return rec


def main():
    from repro.api import add_spec_args, spec_from_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", choices=VARIANTS, default=None)
    ap.add_argument("--pdf-shape", choices=list(PDF_SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun_pdf",
                    help="directory for per-cell roofline records")
    # every pipeline knob (--group-tol, --types, --num-bins, --spec ...)
    # comes from the shared spec surface
    add_spec_args(ap)
    args = ap.parse_args()
    spec = spec_from_args(args, base=_base_spec())
    print(f"[spec] hash={spec.content_hash()} source={spec.source.kind} "
          f"types={len(spec.compute.types)} bins={spec.compute.num_bins} "
          f"group_tol={spec.method.group_tol}")

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    variants = VARIANTS if (args.all or not args.variant) else [args.variant]
    shapes = list(PDF_SHAPES) if (args.all or not args.pdf_shape) else [args.pdf_shape]

    failures = []
    for v in variants:
        for s in shapes:
            cid = f"pdf__{v}__{s}__{'pod2' if args.multi_pod else 'pod1'}"
            try:
                rec = run_pdf_cell(
                    v, s, mesh,
                    group_tol=spec.method.group_tol,
                    types=tuple(spec.compute.types),
                    num_bins=spec.compute.num_bins,
                    spec_hash=spec.content_hash(),
                )
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                rec = {"ok": False, "variant": v, "shape": s, "error": str(e)}
                failures.append(cid)
            (out / f"{cid}.json").write_text(json.dumps(rec, indent=1))
    if failures:
        raise SystemExit(f"failed: {failures}")
    print("pdf dry-run complete")


if __name__ == "__main__":
    main()
