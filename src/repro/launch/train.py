"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

End-to-end: config -> mesh -> sharded init -> jitted train step (AdamW,
remat, grad accumulation) -> checkpointing (async, restartable) ->
straggler monitoring. On this CPU container it runs the reduced configs
(--reduced) for real; full configs are exercised by the dry-run.

Multi-pod path: gradients are averaged across the ``pod`` axis with int8
compression (optim/compression.py) inside shard_map — the DCI is the thin
pipe (DESIGN.md §4); within-pod averaging stays in XLA's native psum.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import registry
from repro.configs.base import ArchConfig
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import make_mesh
from repro.models import encdec as ED
from repro.models import sharding as sh
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.runtime import StepMonitor


def build_step(cfg: ArchConfig, opt_cfg: AdamWConfig, total_steps: int, accum: int = 1):
    def loss_of(p, batch):
        if cfg.family == "encdec":
            return ED.loss_fn(p, batch["frames"], batch["tokens"], batch["targets"], cfg)
        extras = {"memory": batch["memory"]} if "memory" in batch else None
        return T.loss_fn(p, batch["tokens"], batch["targets"], cfg, extras)

    def step(params, opt, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            # micro-batch accumulation: batch leaves lead with (accum, ...).
            def body(carry, micro):
                acc_l, acc_g = carry
                l, g = jax.value_and_grad(loss_of)(params, micro)
                return (acc_l + l, jax.tree.map(jnp.add, acc_g, g)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), batch)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        lr_scale = cosine_schedule(opt.step, warmup=max(total_steps // 20, 1), total=total_steps)
        params, opt, gnorm = adamw_update(grads, opt, params, opt_cfg, lr_scale)
        return params, opt, loss, gnorm

    return step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.names())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1x1", help="data x model, e.g. 2x4")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dm, mm = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((dm, mm), ("data", "model"))

    init = ED.init_params if cfg.family == "encdec" else T.init_params
    params = init(cfg, jax.random.PRNGKey(0))
    shardings = sh.make_shardings(cfg, mesh, params)
    params = jax.tree.map(jax.device_put, params, shardings)
    opt = adamw_init(params)
    opt_cfg = AdamWConfig(lr=args.lr)

    pipe = TokenPipeline(cfg.vocab, args.seq, args.batch)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if mgr and args.resume:
        restored, manifest = mgr.restore_latest({"params": params, "opt": opt})
        if restored is not None:
            params, opt = restored["params"], restored["opt"]
            start_step = manifest["step"]
            pipe.restore(manifest["extra"]["pipeline"])
            print(f"resumed from step {start_step}")

    step_fn = jax.jit(build_step(cfg, opt_cfg, args.steps, args.accum), donate_argnums=(0, 1))
    batch_sharding = NamedSharding(mesh, sh.batch_pspec(mesh))
    monitor = StepMonitor()

    for step in range(start_step, args.steps):
        tokens, targets = pipe.next_batch()
        batch = {
            "tokens": jax.device_put(tokens, batch_sharding),
            "targets": jax.device_put(targets, batch_sharding),
        }
        if cfg.family == "vlm":
            batch["memory"] = jnp.zeros((args.batch, cfg.num_patches, cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.batch, args.seq, cfg.d_model)
            )
        monitor.start(f"step{step}")
        params, opt, loss, gnorm = step_fn(params, opt, batch)
        loss = float(loss)
        dur = monitor.finish(f"step{step}")
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {loss:.4f}  gnorm {float(gnorm):.3f}  {dur*1e3:.0f}ms")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt},
                     extra={"pipeline": pipe.state()}, async_=True)
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt},
                 extra={"pipeline": pipe.state()})
        mgr.wait()
    print("training done; final loss", loss)
    return loss


if __name__ == "__main__":
    main()
