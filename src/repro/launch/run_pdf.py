"""Multi-slice PDF run — the production launcher over the ``repro.api``
surface.

Every pipeline knob comes from the declarative ``PipelineSpec``: flags are
auto-generated from the spec fields (``api.cli``), ``--spec FILE`` loads a
JSON spec (explicit flags override), and the run streams slice results from
a ``PDFSession``. Whole slices are dealt to shards of the mesh data axis
(the paper's per-node slice assignment); ``--shard`` restricts execution to
one shard — on a cluster, each node runs this script with its own shard
index against the shared filesystem. Watermark files are per-slice and
stamped with the spec's content hash, so ``--resume`` refuses to mix
windows persisted by a *different* computation (DESIGN.md §API).

  PYTHONPATH=src python -m repro.launch.run_pdf --slices 0 1 2 3 --shards 2
  PYTHONPATH=src python -m repro.launch.run_pdf --method grouping_ml --serial
  PYTHONPATH=src python -m repro.launch.run_pdf --spec run.json --resume
"""

from __future__ import annotations

import argparse
import time

from repro.api import (
    ExecSpec,
    MethodSpec,
    PDFSession,
    PipelineSpec,
    add_spec_args,
    spec_from_args,
)

# The launcher's only defaults that differ from the spec's own: the paper's
# headline method and a 4-slice demo run. Everything else — geometry,
# backends, staging — is the spec's single declaration.
BASE_SPEC = PipelineSpec(
    method=MethodSpec(name="grouping"),
    execution=ExecSpec(slices=(0, 1, 2, 3)),
)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    add_spec_args(ap)
    args = ap.parse_args()
    spec = spec_from_args(args, base=BASE_SPEC)

    session = PDFSession(spec)
    # the session's memoized hash: one manifest read for kind='file', and
    # the banner can never disagree with the hash keying the run/cache
    print(f"[spec] hash={session.spec_hash} source={spec.source.kind} "
          f"method={spec.method.name} "
          f"mode={spec.compute.mode} fit={spec.compute.fit_backend} "
          f"select={spec.compute.select_backend}")
    from repro.runtime.scheduler import assign_slices

    slices = session.resolve_slices(None)
    for a in assign_slices(slices, spec.execution.shards):
        print(f"[assign] shard {a.shard}: slices {list(a.slices)}")

    window_durations: list[float] = []

    def on_window(ws):
        window_durations.append(ws.load_seconds + ws.compute_seconds)

    t0 = time.perf_counter()
    for r in session.run(on_window=on_window):
        if r.cached:
            print(f"[slice {r.slice_i}] E={r.avg_error:.4f} served from "
                  f"result cache (spec {r.spec_hash})")
            continue
        print(f"[slice {r.slice_i}] E={r.avg_error:.4f} windows={len(r.stats)} "
              f"fitted={sum(w.num_fitted for w in r.stats)}"
              f"/{session.geometry.points_per_slice}")
        if r.degraded:
            print(f"[degraded] slice {r.slice_i}: {len(r.quarantined)} "
                  f"window(s) quarantined — see the failed-unit manifest "
                  f"next to the watermark")
    wall = time.perf_counter() - t0

    rep = session.report()
    for shard, reports in sorted(rep.shard_reports.items()):
        load = sum(r.load_seconds for r in reports)
        wait = sum(r.wait_seconds for r in reports)
        comp = sum(r.compute_seconds for r in reports)
        pers = sum(r.persist_seconds for r in reports)
        swall = sum(r.wall_seconds for r in reports)
        hidden = max(0.0, load - wait) / load if load > 0 else 0.0
        print(f"[shard {shard}] wall={swall:.3f}s load={load:.3f}s "
              f"wait={wait:.3f}s compute={comp:.3f}s persist={pers:.3f}s "
              f"load_hidden={hidden:.0%}")
    if spec.execution.cache_dir:
        print(f"[cache] hits={rep.cache_hits} misses={rep.cache_misses} "
              f"dir={spec.execution.cache_dir}")
    if (rep.retries or rep.speculations or rep.quarantined_units
            or rep.shards_lost or spec.execution.fault_plan):
        print(f"[faults] retries={rep.retries} "
              f"speculations={rep.speculations} "
              f"quarantined={rep.quarantined_units} "
              f"shards_lost={len(rep.shards_lost)}")
    if window_durations:
        med = sorted(window_durations)[len(window_durations) // 2]
        print(f"[total] wall={wall:.3f}s windows={rep.windows} "
              f"median_window={med * 1e3:.1f}ms spec={rep.spec_hash}")
    else:
        print(f"[total] wall={wall:.3f}s windows={rep.windows} "
              f"spec={rep.spec_hash}")


if __name__ == "__main__":
    main()
