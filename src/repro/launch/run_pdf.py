"""Multi-slice PDF run — the production launcher over the ``repro.api``
surface.

Every pipeline knob comes from the declarative ``PipelineSpec``: flags are
auto-generated from the spec fields (``api.cli``), ``--spec FILE`` loads a
JSON spec (explicit flags override), and the run streams slice results from
a ``PDFSession``. Whole slices are dealt to shards of the mesh data axis
(the paper's per-node slice assignment); ``--shard`` restricts execution to
one shard — on a cluster, each node runs this script with its own shard
index against the shared filesystem. Watermark files are per-slice and
stamped with the spec's content hash, so ``--resume`` refuses to mix
windows persisted by a *different* computation (DESIGN.md §API).

``--watch`` (kind='file' sources) keeps the process alive after the first
run, polling the cube's manifest version every ``stream.poll_interval_s``
seconds: when an append lands, the session re-opens the cube at the new
version and applies the update incrementally — unchanged slices are adopted
in the result cache and served as hits, appended slices merge forward or
recompute per ``stream.update_mode`` (DESIGN.md §16). ``--stream-max-updates
N`` exits after N applied appends (how the CI smoke job bounds the loop).

  PYTHONPATH=src python -m repro.launch.run_pdf --slices 0 1 2 3 --shards 2
  PYTHONPATH=src python -m repro.launch.run_pdf --method grouping_ml --serial
  PYTHONPATH=src python -m repro.launch.run_pdf --spec run.json --resume
  PYTHONPATH=src python -m repro.launch.run_pdf --source-path cube/ --watch
"""

from __future__ import annotations

import argparse
import time

from repro.api import (
    ExecSpec,
    MethodSpec,
    PDFSession,
    PipelineSpec,
    add_spec_args,
    spec_from_args,
)

# The launcher's only defaults that differ from the spec's own: the paper's
# headline method and a 4-slice demo run. Everything else — geometry,
# backends, staging — is the spec's single declaration.
BASE_SPEC = PipelineSpec(
    method=MethodSpec(name="grouping"),
    execution=ExecSpec(slices=(0, 1, 2, 3)),
)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    add_spec_args(ap)
    ap.add_argument("--watch", action="store_true", help=(
        "after the first run, poll the file cube's manifest version and "
        "apply appends incrementally as they land (stream.* knobs govern "
        "polling and update mode)"))
    args = ap.parse_args()
    spec = spec_from_args(args, base=BASE_SPEC)
    if args.watch and spec.source.kind != "file":
        ap.error("--watch requires a file source (--source-path)")

    from repro.runtime import cluster

    spec = cluster.apply_placement(spec)
    pl = spec.execution.placement
    if cluster.init_distributed(pl):
        print(f"[cluster] jax.distributed process {pl.process_id}/"
              f"{pl.num_processes} coordinator={pl.coordinator}")
    elif pl.process_id is not None and pl.process_id >= pl.num_processes:
        print(f"[cluster] join-only worker {pl.process_id} "
              f"(world of {pl.num_processes}) — redeal pickup only")

    session = PDFSession(spec)
    # the session's memoized hash: one manifest read for kind='file', and
    # the banner can never disagree with the hash keying the run/cache
    print(f"[spec] hash={session.spec_hash} source={spec.source.kind} "
          f"method={spec.method.name} "
          f"mode={spec.compute.mode} fit={spec.compute.fit_backend} "
          f"select={spec.compute.select_backend}")
    from repro.runtime.scheduler import assign_slices

    slices = session.resolve_slices(None)
    for a in assign_slices(slices, spec.execution.shards):
        print(f"[assign] shard {a.shard}: slices {list(a.slices)}")

    _run_once(session, spec)
    if args.watch:
        _watch(session, spec)


def _watch(session: PDFSession, spec: PipelineSpec) -> None:
    """Poll the manifest version; on a bump, re-open the cube and run the
    session again — adoption + merge/strict updates make the re-run cost
    O(appended data) for cached/persisted slices."""
    from repro.data.file_source import manifest_version

    last_v = manifest_version(spec.source.path)
    applied = 0
    limit = spec.stream.max_updates
    print(f"[watch] cube at version {last_v}; polling every "
          f"{spec.stream.poll_interval_s}s"
          + (f" (max {limit} update(s))" if limit else ""))
    try:
        while limit is None or applied < limit:
            time.sleep(spec.stream.poll_interval_s)
            try:
                v = manifest_version(spec.source.path)
            except (OSError, ValueError):
                continue  # manifest mid-replace: next poll sees it whole
            if v == last_v:
                continue
            print(f"[watch] manifest version {last_v} -> {v}: updating")
            session.refresh_source()
            print(f"[spec] hash={session.spec_hash} (version {v})")
            _run_once(session, spec)
            last_v = v
            applied += 1
    except KeyboardInterrupt:
        print(f"[watch] stopped after {applied} update(s)")


def _run_once(session: PDFSession, spec: PipelineSpec) -> None:
    window_durations: list[float] = []

    def on_window(ws):
        window_durations.append(ws.load_seconds + ws.compute_seconds)

    pl = spec.execution.placement
    cluster_mode = pl.num_processes > 1 or (
        pl.process_id is not None and pl.process_id >= pl.num_processes)
    if cluster_mode:
        from repro.runtime import cluster

        results = cluster.run_worker(session, on_window=on_window, log=print)
    else:
        results = session.run(on_window=on_window)

    t0 = time.perf_counter()
    for r in results:
        if r.cached:
            print(f"[slice {r.slice_i}] E={r.avg_error:.4f} served from "
                  f"result cache (spec {r.spec_hash})")
            continue
        print(f"[slice {r.slice_i}] E={r.avg_error:.4f} windows={len(r.stats)} "
              f"fitted={sum(w.num_fitted for w in r.stats)}"
              f"/{session.geometry.points_per_slice}")
        if r.degraded:
            print(f"[degraded] slice {r.slice_i}: {len(r.quarantined)} "
                  f"window(s) quarantined — see the failed-unit manifest "
                  f"next to the watermark")
    wall = time.perf_counter() - t0

    rep = session.report()
    for shard, reports in sorted(rep.shard_reports.items()):
        load = sum(r.load_seconds for r in reports)
        wait = sum(r.wait_seconds for r in reports)
        comp = sum(r.compute_seconds for r in reports)
        pers = sum(r.persist_seconds for r in reports)
        swall = sum(r.wall_seconds for r in reports)
        hidden = max(0.0, load - wait) / load if load > 0 else 0.0
        print(f"[shard {shard}] wall={swall:.3f}s load={load:.3f}s "
              f"wait={wait:.3f}s compute={comp:.3f}s persist={pers:.3f}s "
              f"load_hidden={hidden:.0%}")
    if spec.execution.cache_dir:
        print(f"[cache] hits={rep.cache_hits} misses={rep.cache_misses} "
              f"dir={spec.execution.cache_dir}")
    if rep.cache_adopted or rep.slices_merged:
        print(f"[stream] adopted={rep.cache_adopted} "
              f"merged={rep.slices_merged} "
              f"mode={spec.stream.update_mode}")
    if (rep.retries or rep.speculations or rep.quarantined_units
            or rep.shards_lost or spec.execution.fault_plan):
        print(f"[faults] retries={rep.retries} "
              f"speculations={rep.speculations} "
              f"quarantined={rep.quarantined_units} "
              f"shards_lost={len(rep.shards_lost)}")
    # cold-start visibility: with --compile-cache-dir, "new_compilations"
    # counts persistent-cache misses (executables built fresh) — a warm
    # relaunch of an identical spec reports new_compilations=0; without the
    # cache it counts backend compiles outright
    new_compilations = (rep.compile_cache_misses
                        if spec.execution.compile_cache_dir else rep.compiles)
    print(f"[compile] traces={rep.traces} compiled={rep.compiles} "
          f"cache_hits={rep.compile_cache_hits} "
          f"cache_misses={rep.compile_cache_misses} "
          f"new_compilations={new_compilations}")
    if window_durations:
        med = sorted(window_durations)[len(window_durations) // 2]
        print(f"[total] wall={wall:.3f}s windows={rep.windows} "
              f"median_window={med * 1e3:.1f}ms spec={rep.spec_hash}")
    else:
        print(f"[total] wall={wall:.3f}s windows={rep.windows} "
              f"spec={rep.spec_hash}")


if __name__ == "__main__":
    main()
