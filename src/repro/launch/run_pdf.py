"""Multi-slice PDF run through the staged executor + slice scheduler.

The production entry point for the paper's workload shape: whole slices are
assigned to shards of the mesh data axis (runtime/scheduler.py — the
paper's per-node slice assignment), each shard's plan runs through the
staged executor (core/executor.py) with window prefetch and async persist,
and the per-stage report shows how much load time was hidden behind
compute. ``--shard`` restricts execution to one shard — on a cluster, each
node runs this script with its own shard index against the shared
filesystem; watermark files are per-slice, and slices never span shards,
so restart (``--resume``) stays per-node.

  PYTHONPATH=src python -m repro.launch.run_pdf --slices 0 1 2 3 --shards 2
  PYTHONPATH=src python -m repro.launch.run_pdf --method grouping_ml --serial
"""

from __future__ import annotations

import argparse
import time

from repro.core import distributions as d
from repro.core import fitting
from repro.core import grouping as grp
from repro.core.executor import (
    METHODS,
    SELECT_BACKENDS,
    ExecutorConfig,
    PDFConfig,
    StagedExecutor,
)
from repro.core.pipeline import train_type_tree
from repro.core.regions import CubeGeometry
from repro.data.simulation import SeismicSimulation, SimulationConfig
from repro.runtime.scheduler import SliceScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slices", type=int, nargs="+", default=[0, 1, 2, 3])
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--shard", type=int, default=None,
                    help="run only this shard's assignment (per-node mode)")
    ap.add_argument("--method", default="grouping", choices=list(METHODS))
    ap.add_argument("--fit-backend", default="fused",
                    choices=list(fitting.FIT_BACKENDS),
                    help="device-work implementation (DESIGN.md §2.1)")
    ap.add_argument("--select-backend", default="host",
                    choices=list(SELECT_BACKENDS),
                    help="where Select's grouping dedup runs: 'host' "
                         "(np.unique bounce) or 'device' (quantize + sort + "
                         "gather + fit + scatter on the accelerator)")
    ap.add_argument("--group-tol", type=float, default=grp.DEFAULT_TOL,
                    help="grouping tolerance (paper §5.2 'acceptable "
                         "fluctuation') for the grouping/reuse methods")
    ap.add_argument("--rep-bucket", type=int, default=64,
                    help="geometric padding bucket for representative "
                         "batches (was hard-coded; 64 suits the reduced "
                         "default workload, use 256 at paper scale)")
    ap.add_argument("--mode", default="fused", choices=["faithful", "fused"],
                    help="shared-histogram fit (default; the fused backend's "
                         "single-launch kernel path) vs paper-faithful "
                         "per-type passes (always the chained path — a "
                         "single launch cannot model the paper's cost)")
    ap.add_argument("--window-lines", type=int, default=6)
    ap.add_argument("--lines", type=int, default=24)
    ap.add_argument("--ppl", type=int, default=60)
    ap.add_argument("--obs", type=int, default=300)
    ap.add_argument("--num-slices", type=int, default=8)
    ap.add_argument("--serial", action="store_true",
                    help="disable prefetch + async persist (reference path)")
    ap.add_argument("--prefetch-depth", type=int, default=2)
    ap.add_argument("--out", default=None, help="persist .npz watermarks here")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    if args.shard is not None and not 0 <= args.shard < args.shards:
        ap.error(f"--shard {args.shard} outside range 0..{args.shards - 1}")

    sim = SeismicSimulation(SimulationConfig(
        geometry=CubeGeometry(args.num_slices, args.lines, args.ppl),
        num_simulations=args.obs,
    ))
    # training slices clamped to the cube (the default 4 cover all types)
    tree = train_type_tree(sim, slices=tuple(range(min(4, args.num_slices))),
                           window_lines=args.window_lines) \
        if "ml" in args.method else None
    cfg = PDFConfig(window_lines=args.window_lines, method=args.method,
                    mode=args.mode, fit_backend=args.fit_backend,
                    select_backend=args.select_backend,
                    group_tol=args.group_tol, rep_bucket=args.rep_bucket)
    exec_cfg = ExecutorConfig(
        prefetch=not args.serial,
        prefetch_depth=args.prefetch_depth,
        async_persist=not args.serial,
    )

    sched = SliceScheduler(num_shards=args.shards)
    for a in sched.assignments(args.slices):
        print(f"[assign] shard {a.shard}: slices {list(a.slices)}")

    def make_executor(shard: int) -> StagedExecutor:
        # On a cluster each node builds its executor over its NFS view;
        # here every shard sees the same simulation source.
        return StagedExecutor(cfg, sim, tree=tree, out_dir=args.out,
                              exec_config=exec_cfg)

    t0 = time.perf_counter()
    results = sched.run(make_executor, args.slices,
                        window_lines=args.window_lines,
                        shard=args.shard, resume=args.resume)
    wall = time.perf_counter() - t0

    for s in sorted(results):
        r = results[s]
        print(f"[slice {s}] E={r.avg_error:.4f} windows={len(r.stats)} "
              f"fitted={sum(w.num_fitted for w in r.stats)}"
              f"/{sim.geometry.points_per_slice}")
    for shard, rep in sorted(sched.last_reports.items()):
        if rep is None:
            continue
        print(f"[shard {shard}] wall={rep.wall_seconds:.3f}s "
              f"load={rep.load_seconds:.3f}s wait={rep.wait_seconds:.3f}s "
              f"compute={rep.compute_seconds:.3f}s persist={rep.persist_seconds:.3f}s "
              f"load_hidden={rep.load_hidden_fraction:.0%}")
    med = sched.window_monitor.median()
    print(f"[total] wall={wall:.3f}s windows={sched.window_monitor.completed} "
          f"median_window={med * 1e3:.1f}ms" if med is not None else
          f"[total] wall={wall:.3f}s windows={sched.window_monitor.completed}")
    if sched.shard_monitor.flagged:
        print(f"[stragglers] {sched.shard_monitor.flagged}")


if __name__ == "__main__":
    main()
