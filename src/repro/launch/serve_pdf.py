"""PDF query server launcher — stand up a ``PDFServer`` for one spec.

Every pipeline *and serving* knob comes from the declarative
``PipelineSpec``: flags are auto-generated from the spec fields
(``api.cli``, including the ``serve.*`` group — tick length, batch cap,
coalescing on/off, hot-window LRU size), ``--spec FILE`` loads a JSON spec
(explicit flags override). The launcher starts the server, fires a demo
query mix from ``--clients`` concurrent threads (point + window + region
queries over ``--slices``, each client re-asking its point queries so the
hot path shows up), then prints the server's counters: launches vs windows
requested (the coalescing win), memory/disk hit rates, and request/launch
p50/p99.

  PYTHONPATH=src python -m repro.launch.serve_pdf --clients 8
  PYTHONPATH=src python -m repro.launch.serve_pdf --cache-dir /tmp/pdfcache \\
      --cache-max-bytes 50000000 --serve-max-batch-windows 16
  PYTHONPATH=src python -m repro.launch.serve_pdf --no-serve-coalesce  # naive

With ``--cache-dir`` the server answers straight from the ``ResultCache``
when a stored slice covers the query (no executor, no tree), and stores
back every slice it fully computes — run twice with the same cache dir and
the second run is all disk hits.
"""

from __future__ import annotations

import argparse
import threading
import time

from repro.api import (
    ExecSpec,
    MethodSpec,
    PipelineSpec,
    add_spec_args,
    spec_from_args,
)
from repro.serve import PDFServer, PointQuery, RegionQuery, WindowQuery

BASE_SPEC = PipelineSpec(
    method=MethodSpec(name="grouping"),
    execution=ExecSpec(slices=(0, 1)),
)


def _client(server: PDFServer, cid: int, slices: list[int], repeats: int,
            errors: list[BaseException]) -> None:
    """One closed-loop client: a small point/window/region mix, point
    queries re-asked ``repeats`` times (the hot path)."""
    try:
        geom = server.session.geometry
        s = slices[cid % len(slices)]
        line = (3 * cid + 1) % geom.lines_per_slice
        point = (7 * cid + 2) % geom.points_per_line
        for _ in range(repeats):
            server.query(PointQuery(s, line, point))
        hi = min(geom.lines_per_slice, line + 4)
        server.query(WindowQuery(s, max(0, line - 1), hi))
        if cid % 4 == 0:
            server.query(RegionQuery(s))
    except BaseException as e:  # noqa: BLE001 — surface on the main thread
        errors.append(e)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    add_spec_args(ap)
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent demo query threads (default 4)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="times each client re-asks its point query")
    args = ap.parse_args(argv)
    spec = spec_from_args(args, base=BASE_SPEC)
    slices = list(spec.execution.slices
                  or range(spec.source.num_slices))

    server = PDFServer(spec)
    print(f"[serve] hash={server.session.spec_hash} "
          f"method={spec.method.name} coalesce={spec.serve.coalesce} "
          f"tick={spec.serve.tick_seconds * 1e3:.1f}ms "
          f"max_batch={spec.serve.max_batch_windows} "
          f"lru={spec.serve.window_cache_entries}")

    errors: list[BaseException] = []
    t0 = time.perf_counter()
    with server:
        threads = [
            threading.Thread(target=_client,
                             args=(server, c, slices, args.repeats, errors))
            for c in range(args.clients)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        st = server.stats()
    wall = time.perf_counter() - t0

    print(f"[queries] total={st.queries} by_kind={st.queries_by_kind} "
          f"wall={wall:.3f}s qps={st.queries / wall:.1f}")
    print(f"[coalesce] ticks={st.ticks} launches={st.launches} "
          f"requested={st.windows_requested} unique={st.windows_unique} "
          f"computed={st.windows_computed} "
          f"ratio={st.coalesce_ratio:.2f} occupancy={st.batch_occupancy:.2f}")
    print(f"[cache] memory={st.windows_from_memory} disk={st.windows_from_disk} "
          f"hit_rate={st.window_hit_rate:.0%} stored_slices={st.slices_stored} "
          f"max_queue_depth={st.max_queue_depth}")
    print(f"[latency] request p50={st.latency['p50'] * 1e3:.2f}ms "
          f"p99={st.latency['p99'] * 1e3:.2f}ms | launch "
          f"p50={st.launch_latency['p50'] * 1e3:.2f}ms "
          f"p99={st.launch_latency['p99'] * 1e3:.2f}ms")
    for stage, pct in sorted(st.stage_percentiles.items()):
        print(f"[stage {stage}] p50={pct['p50'] * 1e3:.2f}ms "
              f"p99={pct['p99'] * 1e3:.2f}ms")
    return st


if __name__ == "__main__":
    main()
