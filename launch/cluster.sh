#!/usr/bin/env bash
# Multi-process cluster launcher (DESIGN.md §17): spawn NPROCS run_pdf
# workers on this host, each pinned to one seat of the placement
# (--num-processes/--process-id), sharing one jax.distributed coordinator
# and one --out-dir. Usage:
#
#   launch/cluster.sh NPROCS [run_pdf flags...]
#
# Every flag after NPROCS is passed through to every worker — give them a
# shared --out-dir (required in cluster mode) and optionally a shared
# --compile-cache-dir so only the first launch ever compiles. Environment:
#
#   COORD_PORT          coordinator port (default 12723)
#   CLUSTER_REF         a reference out_dir: after the run, verify this
#                       run's --out-dir is bitwise-identical to it and
#                       print the invariant line CI greps for
#   CPU_DEVICES_PER_PROC  host-platform device count per worker (default 1)
#
# Env hardening per the SNIPPETS run.sh recipes: tcmalloc preload (when
# present), silenced TF/absl logging, a pinned host device count, and
# explicit x64 settings (the pipeline's f64 work goes through its own
# "x64 lanes" emulation — JAX_ENABLE_X64 stays off so traces match the
# single-process/test configuration bit for bit).
set -euo pipefail

if [ "$#" -lt 1 ]; then
    echo "usage: launch/cluster.sh NPROCS [run_pdf flags...]" >&2
    exit 2
fi
NPROCS="$1"; shift

# -- env hardening (SNIPPETS: HomebrewNLP-Jax/olmax run.sh) -------------------
TCMALLOC=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4
if [ -f "$TCMALLOC" ]; then
    export LD_PRELOAD="$TCMALLOC"                          # faster malloc
    export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000  # no numpy spam
fi
export TF_CPP_MIN_LOG_LEVEL=4                              # no XLA chatter
export JAX_ENABLE_X64=0           # f64 runs through the x64-lanes emulation
export JAX_DEFAULT_DTYPE_BITS=32
export JAX_NUM_CPU_DEVICES="${CPU_DEVICES_PER_PROC:-1}"
export XLA_FLAGS="--xla_force_host_platform_device_count=${CPU_DEVICES_PER_PROC:-1} ${XLA_FLAGS:-}"
REPO_ROOT="$(cd "$(dirname "$0")/.." && pwd)"
export PYTHONPATH="$REPO_ROOT/src${PYTHONPATH:+:$PYTHONPATH}"

COORD="127.0.0.1:${COORD_PORT:-12723}"

# The shared out_dir is also where the marker protocol lives — find it in
# the pass-through flags so the optional CLUSTER_REF verification knows
# what to compare.
OUT_DIR=""
prev=""
for arg in "$@"; do
    if [ "$prev" = "--out-dir" ]; then OUT_DIR="$arg"; fi
    prev="$arg"
done

echo "[cluster.sh] launching $NPROCS worker(s), coordinator $COORD"
pids=()
for i in $(seq 0 $((NPROCS - 1))); do
    python -m repro.launch.run_pdf \
        --num-processes "$NPROCS" --process-id "$i" --coordinator "$COORD" \
        "$@" 2>&1 | sed "s/^/[proc $i] /" &
    pids+=($!)
done
status=0
for pid in "${pids[@]}"; do
    wait "$pid" || status=$?
done
if [ "$status" -ne 0 ]; then
    echo "[cluster.sh] a worker failed (exit $status)" >&2
    exit "$status"
fi

if [ -n "${CLUSTER_REF:-}" ]; then
    if [ -z "$OUT_DIR" ]; then
        echo "[cluster.sh] CLUSTER_REF set but no --out-dir flag found" >&2
        exit 2
    fi
    python -m repro.runtime.cluster --compare "$CLUSTER_REF" "$OUT_DIR"
fi
echo "[cluster.sh] done"
