"""Batched serving example: prefill a batch of prompts, decode with donated
caches. Demonstrates the O(1)-state decode of the SSM family vs the KV-cache
decode of the attention family.

  PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch import serve


def main():
    for arch in ["mamba2-780m", "granite-3-8b", "hymba-1.5b"]:
        print(f"=== {arch} (reduced) ===")
        serve.main(["--arch", arch, "--batch", "4", "--prompt-len", "24",
                    "--tokens", "16"])


if __name__ == "__main__":
    main()
