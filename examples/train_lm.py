"""Train a reduced LM config end to end on CPU (loss goes down), with
checkpoint/resume. Any of the 10 assigned archs works via --arch.

  PYTHONPATH=src python examples/train_lm.py --arch mamba2-780m --steps 60
"""

import argparse
import tempfile

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        loss = train.main([
            "--arch", args.arch, "--reduced",
            "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--ckpt-dir", ckpt, "--ckpt-every", str(max(args.steps // 2, 1)),
            "--lr", "1e-3",
        ])
        print(f"final loss {loss:.4f}")
        # resume from the checkpoint for a few more steps (restart path)
        train.main([
            "--arch", args.arch, "--reduced",
            "--steps", str(args.steps + 10),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--ckpt-dir", ckpt, "--resume",
            "--lr", "1e-3",
        ])


if __name__ == "__main__":
    main()
