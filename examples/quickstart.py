"""Quickstart: compute PDFs of a spatial slice in ~30 seconds on CPU.

One declarative ``PipelineSpec`` describes the whole run — the synthetic
seismic cube (the paper's Monte-Carlo structure), the paper's winning
method (Grouping + ML prediction), and the execution strategy — and a
``PDFSession`` executes it. The spec JSON printed below is a complete,
replayable description of this run: save it to a file and
``python -m repro.launch.run_pdf --spec FILE`` reproduces it (same
content hash, same results).

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.api import (
    ComputeSpec,
    ExecSpec,
    MethodSpec,
    PDFSession,
    PipelineSpec,
    SourceSpec,
)
from repro.core import distributions as d


def main():
    spec = PipelineSpec(
        # a small cube: 16 slices x 12 lines x 40 points, 400 observations
        source=SourceSpec(num_slices=16, lines_per_slice=12,
                          points_per_line=40, observations=400),
        # the paper's winner (§6): group identical (mu, sigma) points, let
        # the decision tree skip the per-type Eq.-5 search
        method=MethodSpec(name="grouping_ml", error_bound=0.5),
        compute=ComputeSpec(window_lines=4, num_bins=20),
        execution=ExecSpec(slices=(6,)),
    )
    print(f"spec {spec.content_hash()}:")
    print(spec.to_json())

    session = PDFSession(spec)
    sim = session.source
    print(f"cube: {sim.geometry}, {sim.config.num_simulations} observations/point "
          f"({sim.nominal_bytes() / 1e6:.0f} MB if materialized)")

    # The session trains the (mu, sigma) -> type decision tree on first use
    # (§5.3.1: baseline over the spec's training slices) and streams one
    # SliceResult per requested slice.
    for res in session.run():
        fitted = sum(s.num_fitted for s in res.stats)
        pct = np.bincount(res.type_idx, minlength=4) / len(res.type_idx)
        print(f"slice {res.slice_i} grouping+ml: E={res.avg_error:.4f} "
              f"(bound satisfied: {res.error_bound_satisfied})")
        print(f"  fitted {fitted}/{len(res.type_idx)} points "
              f"({res.total_compute_seconds:.2f}s compute, "
              f"{res.total_load_seconds:.2f}s load)")
        for t, p in zip(d.TYPES_4, pct):
            print(f"  {t:12s} {p:6.1%}")


if __name__ == "__main__":
    main()
