"""Quickstart: compute PDFs of a spatial slice in ~30 seconds on CPU.

Generates a small seismic cube (the paper's Monte-Carlo structure), runs the
paper's winning method (Grouping + ML prediction), and prints the per-type
percentages + average Eq.-6 error.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import distributions as d
from repro.core import ml_predict as mlp
from repro.core.pipeline import PDFComputer, PDFConfig
from repro.core.regions import CubeGeometry
from repro.data.simulation import SeismicSimulation, SimulationConfig


def main():
    sim = SeismicSimulation(
        SimulationConfig(geometry=CubeGeometry(16, 12, 40), num_simulations=400)
    )
    print(f"cube: {sim.geometry}, {sim.config.num_simulations} observations/point "
          f"({sim.nominal_bytes() / 1e6:.0f} MB if materialized)")

    # 1-2) 'previously generated output data' (baseline over slices 0-3)
    #      -> decision tree (§5.3.1).
    from repro.core.pipeline import train_type_tree
    tree = train_type_tree(sim)
    print("trained (mu, sigma) -> type decision tree on slices 0-3")

    # 3) run the paper's winner (Grouping + ML) on the slice of interest.
    comp = PDFComputer(
        PDFConfig(window_lines=4, method="grouping_ml", num_bins=20, error_bound=0.5),
        sim, tree=tree,
    )
    res = comp.run_slice(6)
    fitted = sum(s.num_fitted for s in res.stats)
    pct = np.bincount(res.type_idx, minlength=4) / len(res.type_idx)
    print(f"slice 6 grouping+ml: E={res.avg_error:.4f} "
          f"(bound satisfied: {res.error_bound_satisfied})")
    print(f"  fitted {fitted}/{len(res.type_idx)} points "
          f"({res.total_compute_seconds:.2f}s compute, "
          f"{res.total_load_seconds:.2f}s load)")
    for t, p in zip(d.TYPES_4, pct):
        print(f"  {t:12s} {p:6.1%}")


if __name__ == "__main__":
    main()
