"""The paper's technique applied to the LM substrate: uncertainty
quantification of an *ensemble* of model outputs.

Each "point" is one logit coordinate; each "observation" is that logit under
one ensemble member (different init seeds — a stand-in for checkpoint
ensembles / MC-dropout in production). The same core engine (moments ->
grouping -> fit/ML -> Eq.-5 error) that processes the seismic cube processes
the logit tensor. See DESIGN.md §5 (Arch-applicability).

  PYTHONPATH=src python examples/uq_ensemble.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import distributions as d
from repro.core import fitting
from repro.core.grouping import group_host
from repro.kernels.moments import moments
from repro.models import transformer as T


def main():
    cfg = registry.get("granite-3-8b").reduced()
    ensemble = 64
    toks = jax.random.randint(jax.random.PRNGKey(9), (1, 16), 0, cfg.vocab)

    # ensemble of logits at the last position: (points=vocab, obs=ensemble)
    outs = []
    for seed in range(ensemble):
        p = T.init_params(cfg, jax.random.PRNGKey(seed))
        outs.append(np.asarray(T.forward(p, toks, cfg)[0, -1]))
    obs = np.stack(outs, axis=1).astype(np.float32)  # (vocab, ensemble)
    print(f"ensemble logit matrix: {obs.shape}")

    m = moments(jnp.asarray(obs))
    keys = np.stack(
        [np.round(np.asarray(m.mean) / 1e-3), np.round(np.asarray(m.std) / 1e-3)], 1
    ).astype(np.int64)
    g = group_host(keys)
    print(f"grouping: {g.num_groups} groups for {len(keys)} logits "
          f"({len(keys) / g.num_groups:.1f}x dedup)")

    r = fitting.compute_pdf_and_error(jnp.asarray(obs), m, d.TYPES_4, 16)
    pct = np.bincount(np.asarray(r.type_idx), minlength=4) / obs.shape[0]
    print("logit distribution types across the vocab:")
    for t, p_ in zip(d.TYPES_4, pct):
        print(f"  {t:12s} {p_:6.1%}")
    print(f"avg Eq.-5 error: {float(np.asarray(r.error).mean()):.4f}")
    # the classic CLT sanity check: sums of many random features -> normal
    assert pct[0] > 0.5, "ensemble logits should be predominantly normal"
    print("OK: ensemble logits are predominantly normal (CLT), "
          "with per-coordinate PDFs + errors available for UQ.")


if __name__ == "__main__":
    main()
