"""End-to-end driver (the paper's kind of workload): a full slice through
the production pipeline — slice-feature sampling, method comparison, crash +
restart — every stage declared as a ``PipelineSpec`` and run by a
``PDFSession``. The specs differ only in their ``MethodSpec``; everything
else (cube, windowing, backends) is declared once and shared.

  PYTHONPATH=src python examples/pdf_full_slice.py [--obs 500] [--method grouping]
"""

import argparse
import dataclasses
import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.api import (
    ComputeSpec,
    MethodSpec,
    PDFSession,
    PipelineSpec,
    add_spec_args,
    explicit_fields,
    spec_from_args,
)

METHODS = ["baseline", "grouping", "reuse", "ml", "grouping_ml"]
SLICE = 6


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    add_spec_args(ap)  # every pipeline knob, incl. --method/--types/--obs
    args = ap.parse_args()
    base = PipelineSpec(
        source=dataclasses.replace(
            PipelineSpec().source, num_slices=8, observations=400),
        compute=ComputeSpec(window_lines=6, mode="faithful"),
    )
    spec = spec_from_args(args, base=base)
    # default: compare all methods; an explicit --method narrows to one
    methods = [spec.method.name] if "method.name" in explicit_fields(args) \
        else METHODS

    def with_method(**method_kw) -> PipelineSpec:
        return dataclasses.replace(
            spec, method=dataclasses.replace(spec.method, **method_kw))

    # --- sampling first (Algorithm 5): choose the slice cheaply -------------
    # method='sampling' classifies a fraction of points with the decision
    # tree — no Eq.-5 fitting — through the same executor as every method.
    t0 = time.perf_counter()
    s_spec = with_method(name="sampling", sample_frac=0.25)
    s_session = PDFSession(s_spec)
    tree = s_session.tree  # trained once (§5.3.1), shared by every run below
    res = s_session.run_all([SLICE])[SLICE]
    f = res.features(spec.compute.types)
    print(f"[sampling] slice {SLICE} features in {time.perf_counter()-t0:.2f}s: "
          f"avg_mu={f.avg_mean:.1f} avg_sigma={f.avg_std:.2f} "
          f"pct={np.round(f.type_percentage, 3)} "
          f"({f.num_sampled} points, spec {s_spec.content_hash()})")

    # --- full methods comparison on the chosen slice ------------------------
    sim = s_session.source  # share the generator across sessions
    base_time = None
    for method in methods:
        m_spec = with_method(name=method)
        # warm the jit cache on another slice so timings exclude compilation
        PDFSession(m_spec, data_source=sim, tree=tree).run_all([1])
        session = PDFSession(m_spec, data_source=sim, tree=tree)
        res = session.run_all([SLICE])[SLICE]
        c = res.total_compute_seconds
        base_time = c if method == "baseline" else base_time
        rep = session.report()  # per-stage totals (staged executor)
        cache = session.executor(0).cache
        print(f"[{method:12s}] compute {c:7.2f}s  "
              f"speedup {(base_time or c)/max(c,1e-9):5.2f}x  "
              f"E={res.avg_error:.4f}  fitted {sum(s.num_fitted for s in res.stats)}"
              f"/{session.geometry.points_per_slice}"
              f"  load_hidden={rep.load_hidden_fraction:.0%}"
              + (f"  cache_hits={cache.hits}" if method.startswith("reuse") else ""))

    # --- fault tolerance: crash after 1 window, restart from watermark ------
    # The watermark carries the spec's content hash, so resume refuses to
    # mix windows persisted by a different computation.
    out = Path(tempfile.mkdtemp(prefix="pdf_ckpt_"))
    try:
        c_spec = dataclasses.replace(
            with_method(name="grouping_ml"),
            execution=dataclasses.replace(spec.execution, out_dir=str(out)))
        count = 0

        class Crash(Exception):
            pass

        def crash(ws):
            nonlocal count
            count += 1
            if count == 1:
                raise Crash()

        session = PDFSession(c_spec, data_source=sim, tree=tree)
        try:
            session.run_all([SLICE], on_window=crash)
        except Crash:
            mark = session.executor(0).watermark(SLICE)
            print(f"[restart] simulated crash after 1 window "
                  f"(watermark at line {mark}, spec {c_spec.content_hash()})")
        resumed = PDFSession(c_spec, data_source=sim, tree=tree).run_all(
            [SLICE], resume=True)[SLICE]
        print(f"[restart] resumed: {len(resumed.stats)} windows re-run, "
              f"E={resumed.avg_error:.4f} (matches full run)")
    finally:
        shutil.rmtree(out, ignore_errors=True)


if __name__ == "__main__":
    main()
