"""End-to-end driver (the paper's kind of workload): a full slice through
the production pipeline — windowed loading, method comparison, per-window
persistence, crash + restart, and slice-feature sampling.

  PYTHONPATH=src python examples/pdf_full_slice.py [--obs 500] [--method all]
"""

import argparse
import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import distributions as d
from repro.core import ml_predict as mlp
from repro.core import sampling as smp
from repro.core.pipeline import PDFComputer, PDFConfig
from repro.core.regions import CubeGeometry, Window
from repro.data.simulation import SeismicSimulation, SimulationConfig
from repro.kernels.moments import moments

import jax.numpy as jnp

METHODS = ["baseline", "grouping", "reuse", "ml", "grouping_ml"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--obs", type=int, default=400)
    ap.add_argument("--lines", type=int, default=24)
    ap.add_argument("--ppl", type=int, default=60)
    ap.add_argument("--method", default="all")
    ap.add_argument("--types", default="4", choices=["4", "10"])
    args = ap.parse_args()

    types = d.TYPES_4 if args.types == "4" else d.TYPES_10
    sim = SeismicSimulation(
        SimulationConfig(
            geometry=CubeGeometry(8, args.lines, args.ppl),
            num_simulations=args.obs,
        )
    )
    slice_i = 6

    # --- sampling first (Algorithm 5): choose the slice cheaply -------------
    t0 = time.perf_counter()
    from repro.core.pipeline import train_type_tree
    tree = train_type_tree(sim, types=types, window_lines=6)
    vals = sim.load_window(Window(slice_i, 0, 2))
    m = moments(jnp.asarray(vals))
    f = smp.slice_features_from_moments(
        np.asarray(m.mean), np.asarray(m.std), tree, types,
        skew=np.asarray(m.skew), kurt=np.asarray(m.kurt)
    )
    print(f"[sampling] slice {slice_i} features in {time.perf_counter()-t0:.2f}s: "
          f"avg_mu={f.avg_mean:.1f} avg_sigma={f.avg_std:.2f} "
          f"pct={np.round(f.type_percentage, 3)}")

    # --- full methods comparison on the chosen slice ------------------------
    methods = METHODS if args.method == "all" else [args.method]
    base_time = None
    for method in methods:
        cfg = PDFConfig(types=types, window_lines=6, method=method,
                        mode="faithful", rep_bucket=64)
        # warm the jit cache on another slice so timings exclude compilation
        PDFComputer(cfg, sim, tree=tree if "ml" in method else None).run_slice(1)
        comp = PDFComputer(cfg, sim, tree=tree if "ml" in method else None)
        res = comp.run_slice(slice_i)
        c = res.total_compute_seconds
        base_time = c if method == "baseline" else base_time
        rep = comp.last_report  # staged-executor per-stage totals
        print(f"[{method:12s}] compute {c:7.2f}s  speedup {base_time/max(c,1e-9):5.2f}x  "
              f"E={res.avg_error:.4f}  fitted {sum(s.num_fitted for s in res.stats)}"
              f"/{sim.geometry.points_per_slice}"
              f"  load_hidden={rep.load_hidden_fraction:.0%}"
              + (f"  cache_hits={comp.cache.hits}" if method.startswith("reuse") else ""))

    # --- fault tolerance: crash after 2 windows, restart from watermark -----
    out = Path(tempfile.mkdtemp(prefix="pdf_ckpt_"))
    try:
        cfg = PDFConfig(types=types, window_lines=6, method="grouping_ml", rep_bucket=64)
        comp = PDFComputer(cfg, sim, tree=tree, out_dir=out)
        count = 0

        class Crash(Exception):
            pass

        def crash(ws):
            nonlocal count
            count += 1
            if count == 1:
                raise Crash()

        try:
            comp.run_slice(slice_i, on_window=crash)
        except Crash:
            print(f"[restart] simulated crash after 1 window "
                  f"(watermark at line {comp._watermark(slice_i)})")
        resumed = PDFComputer(cfg, sim, tree=tree, out_dir=out).run_slice(
            slice_i, resume=True
        )
        print(f"[restart] resumed: {len(resumed.stats)} windows re-run, "
              f"E={resumed.avg_error:.4f} (matches full run)")
    finally:
        shutil.rmtree(out, ignore_errors=True)


if __name__ == "__main__":
    main()
