"""Result-cache speedup: one slice computed cold vs served from the
spec-hash-keyed ``ResultCache`` (api/cache.py).

The pair of rows records what repeated benchmark sweeps gain from
``--cache-dir``: ``cache/grouping_cold`` is a normal grouped slice run that
misses and stores; ``cache/grouping_hit`` reruns the *identical spec* in a
fresh session and is served bitwise-identical results from disk — no
loading, no Select, no device work. The derived column carries the speedup
and asserts the hit really was a hit (and bitwise-equal, so the row can
never quietly measure a silent recompute).

Rows are tracked, not gated (the hit path is a file read — its absolute
time is all filesystem noise at this workload size).
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks import common  # noqa: E402 — run via benchmarks/run.py
from repro.api import PDFSession
from repro.core import distributions as d
from repro.core.executor import RESULT_FIELDS


def run(quick: bool = True, cache_dir: str | None = None):
    sim = common.small_sim(num_simulations=200 if quick else 1000)
    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        cdir = cache_dir or tmp
        spec = common.method_spec(sim, "grouping", d.TYPES_4, window_lines=6,
                                  cache_dir=cdir)

        # jit warmup on another slice (also stored — irrelevant to slice 2)
        PDFSession(spec, data_source=sim).run_all([3])

        cold_session = PDFSession(spec, data_source=sim)
        t0 = time.perf_counter()
        cold = cold_session.run_all([2])[2]
        t_cold = time.perf_counter() - t0
        # With a persistent --cache-dir a rerun's "cold" pass is itself
        # served from cache (that being the feature); the derived column
        # records which measurement this row actually is.
        cold_kind = "hit (persistent cache)" if cold.cached else "miss+store"

        hit_session = PDFSession(spec, data_source=sim)
        t0 = time.perf_counter()
        hit = hit_session.run_all([2])[2]
        t_hit = time.perf_counter() - t0
        rep = hit_session.report()
        assert rep.cache_hits == 1 and rep.cache_misses == 0 and hit.cached
        for f in RESULT_FIELDS:
            np.testing.assert_array_equal(getattr(cold, f), getattr(hit, f))
        assert cold.avg_error == hit.avg_error

        rows.append(common.Row(
            "cache/grouping_cold", t_cold * 1e6,
            derived=cold_kind, spec_hash=cold.spec_hash or ""))
        rows.append(common.Row(
            "cache/grouping_hit", t_hit * 1e6,
            derived=f"speedup={t_cold / max(t_hit, 1e-9):.1f}x bitwise-equal",
            spec_hash=hit.spec_hash or ""))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
