"""Fig. 8/9 analog: average per-line PDF-computation time vs window size
(Grouping). The paper finds a U-curve with the optimum at 25 lines; our
reduced cube reproduces the shape: bigger windows amortize grouping until
the per-window dedup/transfer overhead wins."""

from __future__ import annotations

from benchmarks.common import Row, run_method, small_sim
from repro.core import distributions as d


def run(quick: bool = True):
    sim = small_sim(lines=24, ppl=30, num_simulations=200 if quick else 1000)
    rows = []
    best = (None, float("inf"))
    for wl in [1, 2, 4, 8, 12, 24]:
        res, wall = run_method(sim, "grouping", d.TYPES_4, wl, 2)
        per_line = res.total_compute_seconds / 24
        if per_line < best[1]:
            best = (wl, per_line)
        rows.append(
            Row(f"fig08/window_{wl:02d}_lines", per_line * 1e6,
                f"fitted={sum(s.num_fitted for s in res.stats)}",
                spec_hash=res.spec_hash or "")
        )
    rows.append(Row("fig08/optimal_window", best[1] * 1e6, f"lines={best[0]}"))
    return rows
