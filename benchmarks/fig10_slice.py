"""Fig. 10 analog: one full slice with the tuned window size, every method.
Paper (235 GB, Slice 201, window 25): Grouping ~10x, ML ~3x, Grouping+ML
~27x over Baseline; Reuse+ML can trail Grouping+ML (search overhead).

All methods run through the staged executor; the ``fig10/overlap/*`` rows
compare the strictly serial reference path against the prefetching pipeline
on the same workload — wall time must drop and the per-stage stats must
show the load time hidden behind compute (wait << load)."""

from __future__ import annotations

from repro.core import distributions as d
from repro.data.loader import ThrottledSource
from benchmarks.common import SERIAL, Row, run_method, small_sim, train_type_tree

METHODS = ["baseline", "grouping", "reuse", "ml", "grouping_ml", "reuse_ml"]

# Modeled NFS bandwidth for the overlap rows: windows of this reduced config
# then cost roughly as much to load as to fit, the paper's regime (its
# loading stage dominates the 235 GB baseline runs).
NFS_BYTES_PER_S = 50e6


def run(quick: bool = True):
    sim = small_sim(lines=20, ppl=50, num_simulations=250 if quick else 1000)
    tree = train_type_tree(sim)
    rows = []
    base = None
    for method in METHODS:
        res, wall = run_method(
            sim, method, d.TYPES_4, 8, 3, tree=tree if "ml" in method else None
        )
        c = res.total_compute_seconds
        base = c if method == "baseline" else base
        rows.append(
            Row(
                f"fig10/{method}",
                c * 1e6,
                f"speedup={base / max(c, 1e-9):.2f}x E={res.avg_error:.4f} "
                f"fitted={sum(s.num_fitted for s in res.stats)}",
                spec_hash=res.spec_hash or "",
            )
        )

    # -- executor overlap: serial reference vs prefetching pipeline ----------
    # The paper's loading stage is NFS-bound (a large share of baseline wall
    # time); the synthetic generator is far cheaper, so the overlap rows read
    # through ThrottledSource at a modeled NFS bandwidth to reproduce the
    # paper's load/compute ratio. Median-of-5 walls (shared-container
    # jitter); per-stage stats from the median prefetch run show the device
    # blocked on only ``wait`` of the ``load`` seconds the loader spent.
    nfs = ThrottledSource(sim, NFS_BYTES_PER_S)

    def median_run(exec_config):
        runs = sorted(
            (run_method(nfs, "baseline", d.TYPES_4, 8, 3, exec_config=exec_config,
                        warmup=False) for _ in range(5)),
            key=lambda rw: rw[1],
        )
        return runs[len(runs) // 2]

    run_method(nfs, "baseline", d.TYPES_4, 8, 3)  # shared jit warmup
    _, serial_wall = median_run(SERIAL)
    pre_res, pre_wall = median_run(None)
    hidden = max(0.0, pre_res.total_load_seconds - pre_res.total_wait_seconds)
    rows.append(
        Row("fig10/overlap/serial_wall", serial_wall * 1e6,
            f"nfs_model={NFS_BYTES_PER_S / 1e6:.0f}MB/s")
    )
    rows.append(
        Row(
            "fig10/overlap/prefetch_wall",
            pre_wall * 1e6,
            f"speedup={serial_wall / max(pre_wall, 1e-9):.2f}x "
            f"load={pre_res.total_load_seconds * 1e3:.1f}ms "
            f"wait={pre_res.total_wait_seconds * 1e3:.1f}ms "
            f"hidden={hidden / max(pre_res.total_load_seconds, 1e-9):.0%}",
        )
    )
    return rows
