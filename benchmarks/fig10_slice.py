"""Fig. 10 analog: one full slice with the tuned window size, every method.
Paper (235 GB, Slice 201, window 25): Grouping ~10x, ML ~3x, Grouping+ML
~27x over Baseline; Reuse+ML can trail Grouping+ML (search overhead)."""

from __future__ import annotations

from repro.core import distributions as d
from benchmarks.common import Row, run_method, small_sim, train_type_tree

METHODS = ["baseline", "grouping", "reuse", "ml", "grouping_ml", "reuse_ml"]


def run(quick: bool = True):
    sim = small_sim(lines=20, ppl=50, num_simulations=250 if quick else 1000)
    tree = train_type_tree(sim)
    rows = []
    base = None
    for method in METHODS:
        res, wall = run_method(
            sim, method, d.TYPES_4, 8, 3, tree=tree if "ml" in method else None
        )
        c = res.total_compute_seconds
        base = c if method == "baseline" else base
        rows.append(
            Row(
                f"fig10/{method}",
                c * 1e6,
                f"speedup={base / max(c, 1e-9):.2f}x E={res.avg_error:.4f} "
                f"fitted={sum(s.num_fitted for s in res.stats)}",
            )
        )
    return rows
