# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV
# and writes BENCH_pipeline.json (name -> us_per_call) so future PRs can
# track the perf trajectory. ``--check`` turns the run into a regression
# gate against the committed json (used by the CI workflow).
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time
from pathlib import Path

# Support `python benchmarks/run.py` as well as `python -m benchmarks.run`.
_ROOT = Path(__file__).resolve().parent.parent
if str(_ROOT) not in sys.path:
    sys.path.insert(0, str(_ROOT))

# Rows the --check gate enforces: kernel timings, the per-method pipeline
# rows, and the serving-layer rows. Other figures (overlap walls,
# projections) are tracked but too environment-dependent to gate on.
GATE_PREFIXES = ("kernel/", "fig06/", "serve/")
GATE_MAX_REGRESSION = 1.25  # fail if fresh > committed * 1.25 (post-drift)
GATE_MIN_US = 5000.0  # sub-5ms rows are dispatch-latency noise, not signal
# Serving rows sit below the generic floor by design (per-query walls over
# a 96-query closed loop / best-of-passes), but they are amortized
# aggregates, not single dispatches — stable enough to gate. Only the
# microsecond memory-hit row stays excluded.
GATE_MIN_US_BY_PREFIX = {"serve/": 500.0}


def check_regressions(
    fresh: dict[str, float],
    committed: dict[str, float],
    report: set[str] | None = None,
    tag: str = "",
) -> list[str] | None:
    """Compare fresh timings against the committed map; returns the names of
    gated rows that regressed by more than GATE_MAX_REGRESSION (``None``
    when no gated row was measured at all — a vacuous gate).

    Ratios are normalized by the run-wide median drift first: on shared
    runners the whole machine drifts 1.3-1.5x between runs (bandwidth
    contention), which moves every row together — a code regression moves
    one row against the fleet. Only the normalized per-row excess fails.

    ``report`` restricts which rows may be *reported* (printed/failed) —
    the retry pass scopes itself to the first-pass breaches this way, so a
    drift median shifted by re-measurement can neither fail rows that never
    breached nor spam phantom REGRESSION lines into the log. All measured
    rows still feed the drift estimate. ``tag`` prefixes the stderr lines of
    that pass."""
    ratios: dict[str, float] = {}
    for name, old in committed.items():
        if not isinstance(old, (int, float)):
            continue  # side maps (e.g. __specs__) are not timing rows
        floor = next((v for p, v in GATE_MIN_US_BY_PREFIX.items()
                      if name.startswith(p)), GATE_MIN_US)
        if not name.startswith(GATE_PREFIXES) or old <= floor:
            continue
        new = fresh.get(name)
        if new is not None and new > 0:
            ratios[name] = new / old
    if not ratios:
        # A filter typo or row rename must not turn the gate silently green.
        print(f"# --check: {tag}no gated rows measured — gate is vacuous",
              file=sys.stderr)
        return None
    drift = sorted(ratios.values())[len(ratios) // 2]
    print(f"# {tag}machine drift (median over {len(ratios)} gated rows): "
          f"{drift:.2f}x", file=sys.stderr)

    failures: list[str] = []
    for name, ratio in sorted(ratios.items()):
        if report is not None and name not in report:
            continue
        normalized = ratio / drift
        if normalized > GATE_MAX_REGRESSION:
            failures.append(name)
            print(f"# {tag}REGRESSION {name}: {committed[name]:.1f} -> "
                  f"{fresh[name]:.1f} us ({ratio:.2f}x raw, "
                  f"{normalized:.2f}x vs drift)", file=sys.stderr)
        else:
            print(f"# {tag}ok {name}: {normalized:.2f}x vs drift", file=sys.stderr)
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale observation counts")
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filter on module names")
    ap.add_argument("--json-out", default="BENCH_pipeline.json",
                    help="where to write the name -> us_per_call map ('' disables)")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent ResultCache dir for cache-aware modules "
                         "(default: each run uses a throwaway temp dir)")
    ap.add_argument("--check", action="store_true",
                    help="regression gate: compare fresh timings against the "
                         "committed --json-out file instead of rewriting it; "
                         f"exit 1 when a kernel or method row is more than "
                         f"{GATE_MAX_REGRESSION:.2f}x slower after the "
                         "run-wide median drift is normalized out")
    args = ap.parse_args()

    from benchmarks import (
        analysis_bench,
        cache_bench,
        fault_bench,
        fig06_methods_small,
        fig07_errors,
        fig08_window_size,
        fig10_slice,
        fig13_scalability,
        fig15_sampling,
        fig18_bigdata,
        kernel_bench,
        serve_bench,
        streaming_bench,
    )

    modules = [
        fig06_methods_small, fig07_errors, fig08_window_size, fig10_slice,
        fig13_scalability, fig15_sampling, fig18_bigdata, kernel_bench,
        cache_bench, serve_bench, fault_bench, analysis_bench,
        streaming_bench,
    ]
    only = [tok for tok in (args.only or "").split(",") if tok]
    results: dict[str, float] = {}
    specs: dict[str, str] = {}  # row name -> PipelineSpec content hash
    row_module: dict[str, object] = {}  # row name -> module that measured it

    def measure(mod, quiet: bool = False) -> None:
        t0 = time.perf_counter()
        kwargs = {}
        if args.cache_dir and "cache_dir" in inspect.signature(mod.run).parameters:
            kwargs["cache_dir"] = args.cache_dir
        rows = mod.run(quick=not args.full, **kwargs)
        for r in rows:
            if not quiet:  # retry passes must not duplicate CSV rows
                print(r.csv())
            results[r.name] = round(r.us_per_call, 1)
            row_module[r.name] = mod
            if getattr(r, "spec_hash", ""):
                specs[r.name] = r.spec_hash
        print(f"# {mod.__name__} total {time.perf_counter() - t0:.1f}s", file=sys.stderr)

    print("name,us_per_call,derived")
    for mod in modules:
        if only and not any(tok in mod.__name__ for tok in only):
            continue
        measure(mod)

    if args.check:
        out_path = Path(args.json_out or "BENCH_pipeline.json")
        if not out_path.exists():
            print(f"# --check: no committed {out_path} to gate against",
                  file=sys.stderr)
            sys.exit(2)
        committed = json.loads(out_path.read_text())
        failures = check_regressions(results, committed)
        if failures is None:
            sys.exit(2)
        if failures:
            # Flake hardening: a single shared-runner tail spike (CPU phase,
            # bandwidth contention) can push one row past the 25% threshold
            # even after drift normalization. Re-measure just the modules
            # that own the breaching rows once; only a regression that
            # reproduces fails the gate.
            retry_mods = {id(row_module[n]): row_module[n]
                          for n in failures if n in row_module}
            print(f"# --check: {len(failures)} breach(es) — retrying "
                  f"{len(retry_mods)} module(s) once: "
                  f"{sorted(m.__name__ for m in retry_mods.values())}",
                  file=sys.stderr)
            for mod in retry_mods.values():
                measure(mod, quiet=True)
            # Only first-pass breaches may fail (report=...): the retry
            # shifts the drift median, which could otherwise push — or at
            # least loudly report — never-breaching rows of modules that
            # were never re-measured.
            failures = check_regressions(
                results, committed, report=set(failures), tag="retry: "
            )
            if failures is None:
                sys.exit(2)
        print(f"# --check: {len(failures)} regression(s)", file=sys.stderr)
        sys.exit(1 if failures else 0)

    if args.json_out and results:
        # merge into any existing map so a --only run refreshes its rows
        # without clobbering the other figures' tracked numbers; the
        # "__specs__" side map (row -> PipelineSpec content hash) merges the
        # same way so every tracked number stays traceable to its spec
        out_path = Path(args.json_out)
        merged_specs: dict[str, str] = {}
        if out_path.exists():
            try:
                merged = json.loads(out_path.read_text())
            except (json.JSONDecodeError, OSError):
                merged = {}  # corrupt/truncated previous file: overwrite
            merged_specs = merged.pop("__specs__", {})
            merged.update(results)
            results = merged
        merged_specs.update(specs)
        if merged_specs:
            results["__specs__"] = merged_specs
        out_path.write_text(json.dumps(results, indent=1, sort_keys=True))
        print(f"# wrote {args.json_out} ({len(results)} entries)", file=sys.stderr)


if __name__ == "__main__":
    main()
