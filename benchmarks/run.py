# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV
# and writes BENCH_pipeline.json (name -> us_per_call) so future PRs can
# track the perf trajectory.
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale observation counts")
    ap.add_argument("--only", default=None, help="substring filter on module name")
    ap.add_argument("--json-out", default="BENCH_pipeline.json",
                    help="where to write the name -> us_per_call map ('' disables)")
    args = ap.parse_args()

    from benchmarks import (
        fig06_methods_small,
        fig07_errors,
        fig08_window_size,
        fig10_slice,
        fig13_scalability,
        fig15_sampling,
        fig18_bigdata,
        kernel_bench,
    )

    modules = [
        fig06_methods_small, fig07_errors, fig08_window_size, fig10_slice,
        fig13_scalability, fig15_sampling, fig18_bigdata, kernel_bench,
    ]
    results: dict[str, float] = {}
    print("name,us_per_call,derived")
    for mod in modules:
        if args.only and args.only not in mod.__name__:
            continue
        t0 = time.perf_counter()
        rows = mod.run(quick=not args.full)
        for r in rows:
            print(r.csv())
            results[r.name] = round(r.us_per_call, 1)
        print(f"# {mod.__name__} total {time.perf_counter() - t0:.1f}s", file=sys.stderr)

    if args.json_out and results:
        # merge into any existing map so a --only run refreshes its rows
        # without clobbering the other figures' tracked numbers
        out_path = Path(args.json_out)
        if out_path.exists():
            try:
                merged = json.loads(out_path.read_text())
            except (json.JSONDecodeError, OSError):
                merged = {}  # corrupt/truncated previous file: overwrite
            merged.update(results)
            results = merged
        out_path.write_text(json.dumps(results, indent=1, sort_keys=True))
        print(f"# wrote {args.json_out} ({len(results)} entries)", file=sys.stderr)


if __name__ == "__main__":
    main()
