# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale observation counts")
    ap.add_argument("--only", default=None, help="substring filter on module name")
    args = ap.parse_args()

    from benchmarks import (
        fig06_methods_small,
        fig07_errors,
        fig08_window_size,
        fig10_slice,
        fig13_scalability,
        fig15_sampling,
        fig18_bigdata,
        kernel_bench,
    )

    modules = [
        fig06_methods_small, fig07_errors, fig08_window_size, fig10_slice,
        fig13_scalability, fig15_sampling, fig18_bigdata, kernel_bench,
    ]
    print("name,us_per_call,derived")
    for mod in modules:
        if args.only and args.only not in mod.__name__:
            continue
        t0 = time.perf_counter()
        rows = mod.run(quick=not args.full)
        for r in rows:
            print(r.csv())
        print(f"# {mod.__name__} total {time.perf_counter() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
