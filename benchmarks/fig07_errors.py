"""Fig. 7/11 analog: average Eq.-6 error, NoML vs WithML, 4-types vs
10-types. Paper: WithML error exceeds NoML by <= 0.017; 10-types+ML can
beat 4-types NoML."""

from __future__ import annotations

from repro.core import distributions as d
from benchmarks.common import Row, run_method, small_sim, train_type_tree


def run(quick: bool = True):
    sim = small_sim(num_simulations=300 if quick else 1000)
    rows = []
    errs = {}
    for types, tag in [(d.TYPES_4, "4types"), (d.TYPES_10, "10types")]:
        tree = train_type_tree(sim, types)
        for label, method in [("NoML", "baseline"), ("WithML", "ml")]:
            res, wall = run_method(
                sim, method, types, 4, 3, tree=tree if method == "ml" else None
            )
            errs[(tag, label)] = res.avg_error
            rows.append(Row(f"fig07/{tag}/{label}", wall * 1e6,
                            f"E={res.avg_error:.4f}",
                            spec_hash=res.spec_hash or ""))
    delta4 = errs[("4types", "WithML")] - errs[("4types", "NoML")]
    rows.append(Row("fig07/ml_error_penalty_4types", 0.0, f"delta={delta4:.4f}"))
    return rows
