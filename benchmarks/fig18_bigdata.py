"""Fig. 18/19/20 analog: the TB-scale regimes.

Set2 (1.9 TB): more points, 1000 obs — ML scales, Grouping hurt by shuffle.
Set3 (2.4 TB): 10x observations per point — Grouping's shuffle payload is
9x bigger (the paper drops Grouping entirely); ML keeps its advantage.

Reduced here: 'obs_1x' ~ Set1/2 regime vs 'obs_10x' ~ Set3 regime, same
points. Derived: grouping's advantage collapsing when the per-point payload
grows 10x while ML's advantage persists."""

from __future__ import annotations

from repro.core import distributions as d
from benchmarks.common import Row, run_method, small_sim, train_type_tree


def run(quick: bool = True):
    rows = []
    summary = {}
    for obs, tag in [(150 if quick else 1000, "obs_1x"), (1500 if quick else 10000, "obs_10x")]:
        sim = small_sim(lines=8, ppl=30, num_simulations=obs)
        tree = train_type_tree(sim, window_lines=4)
        res_b, _ = run_method(sim, "baseline", d.TYPES_4, 4, 2)
        res_g, _ = run_method(sim, "grouping", d.TYPES_4, 4, 2)
        res_m, _ = run_method(sim, "ml", d.TYPES_4, 4, 2, tree=tree)
        cb, cg, cm = (
            r.total_compute_seconds for r in (res_b, res_g, res_m)
        )
        # grouping "shuffle" payload analog: bytes of observation data moved
        # for representative re-dispatch (the host->device second pass)
        payload = sum(s.num_fitted for s in res_g.stats) * obs * 4
        summary[tag] = (cb / cg, cb / cm)
        rows.append(Row(f"fig18/{tag}/baseline", cb * 1e6, "",
                        spec_hash=res_b.spec_hash or ""))
        rows.append(Row(f"fig18/{tag}/grouping", cg * 1e6,
                        f"speedup={cb/cg:.2f}x payload={payload/1e6:.1f}MB",
                        spec_hash=res_g.spec_hash or ""))
        rows.append(Row(f"fig18/{tag}/ml", cm * 1e6, f"speedup={cb/cm:.2f}x",
                        spec_hash=res_m.spec_hash or ""))
    g1, m1 = summary["obs_1x"]
    g10, m10 = summary["obs_10x"]
    rows.append(
        Row("fig18/grouping_vs_obs_scale", 0.0,
            f"grouping {g1:.2f}x->{g10:.2f}x ml {m1:.2f}x->{m10:.2f}x "
            "(paper: grouping COLLAPSES at 10x obs because Spark shuffles "
            "whole observation vectors; our shuffle moves (mu,sigma) keys + "
            "representative rows only, so grouping survives Set3 — an "
            "intentional substrate improvement, see EXPERIMENTS.md)")
    )
    return rows
