"""Fig. 18/19/20 analog: the TB-scale regimes.

Set2 (1.9 TB): more points, 1000 obs — ML scales, Grouping hurt by shuffle.
Set3 (2.4 TB): 10x observations per point — Grouping's shuffle payload is
9x bigger (the paper drops Grouping entirely); ML keeps its advantage.

Reduced here: 'obs_1x' ~ Set1/2 regime vs 'obs_10x' ~ Set3 regime, same
points. Derived: grouping's advantage collapsing when the per-point payload
grows 10x while ML's advantage persists."""

from __future__ import annotations

import os
import re
import socket
import subprocess
import sys
import tempfile
import time
from pathlib import Path

from repro.core import distributions as d
from benchmarks.common import Row, run_method, small_sim, train_type_tree


def run(quick: bool = True):
    rows = []
    summary = {}
    for obs, tag in [(150 if quick else 1000, "obs_1x"), (1500 if quick else 10000, "obs_10x")]:
        sim = small_sim(lines=8, ppl=30, num_simulations=obs)
        tree = train_type_tree(sim, window_lines=4)
        res_b, _ = run_method(sim, "baseline", d.TYPES_4, 4, 2)
        res_g, _ = run_method(sim, "grouping", d.TYPES_4, 4, 2)
        res_m, _ = run_method(sim, "ml", d.TYPES_4, 4, 2, tree=tree)
        cb, cg, cm = (
            r.total_compute_seconds for r in (res_b, res_g, res_m)
        )
        # grouping "shuffle" payload analog: bytes of observation data moved
        # for representative re-dispatch (the host->device second pass)
        payload = sum(s.num_fitted for s in res_g.stats) * obs * 4
        summary[tag] = (cb / cg, cb / cm)
        rows.append(Row(f"fig18/{tag}/baseline", cb * 1e6, "",
                        spec_hash=res_b.spec_hash or ""))
        rows.append(Row(f"fig18/{tag}/grouping", cg * 1e6,
                        f"speedup={cb/cg:.2f}x payload={payload/1e6:.1f}MB",
                        spec_hash=res_g.spec_hash or ""))
        rows.append(Row(f"fig18/{tag}/ml", cm * 1e6, f"speedup={cb/cm:.2f}x",
                        spec_hash=res_m.spec_hash or ""))
    g1, m1 = summary["obs_1x"]
    g10, m10 = summary["obs_10x"]
    rows.append(
        Row("fig18/grouping_vs_obs_scale", 0.0,
            f"grouping {g1:.2f}x->{g10:.2f}x ml {m1:.2f}x->{m10:.2f}x "
            "(paper: grouping COLLAPSES at 10x obs because Spark shuffles "
            "whole observation vectors; our shuffle moves (mu,sigma) keys + "
            "representative rows only, so grouping survives Set3 — an "
            "intentional substrate improvement, see EXPERIMENTS.md)")
    )
    rows.extend(weak_scaling_rows())
    return rows


def weak_scaling_rows() -> list[Row]:
    """``cluster/weak_scaling_{N}proc``: N real ``run_pdf`` worker processes
    (one ``jax.distributed`` seat each, 1 CPU device each) over N slices —
    fixed work per process, wall clock per whole launch. The paper's
    weak-scaling shape (Fig. 13 at cluster granularity). Tracked, NOT gated:
    interpreter startup dominates at this reduced scale, so the row's value
    is trend visibility — a topology regression (workers serializing on a
    peer's shard, the marker protocol blocking the exit path) shows up as a
    wall-time jump against the per-process baseline."""
    repo = Path(__file__).resolve().parent.parent
    env = dict(os.environ, PYTHONPATH=str(repo / "src"),
               JAX_PLATFORMS="cpu", JAX_NUM_CPU_DEVICES="1",
               XLA_FLAGS="--xla_force_host_platform_device_count=1")
    rows: list[Row] = []
    base_wall = None
    with tempfile.TemporaryDirectory() as tmp:
        cache = Path(tmp) / "compile-cache"  # shared: measure run, not XLA
        for nprocs in (1, 2, 4):
            with socket.socket() as s:
                s.bind(("127.0.0.1", 0))
                coord = f"127.0.0.1:{s.getsockname()[1]}"
            flags = [
                "--num-slices", str(nprocs), "--lines", "6", "--ppl", "10",
                "--obs", "80", "--method", "grouping", "--window-lines", "3",
                "--num-bins", "20", "--slices",
                *[str(i) for i in range(nprocs)],
                "--out-dir", str(Path(tmp) / f"out{nprocs}"),
                "--compile-cache-dir", str(cache),
                "--num-processes", str(nprocs), "--coordinator", coord,
            ]
            t0 = time.perf_counter()
            procs = [subprocess.Popen(
                [sys.executable, "-m", "repro.launch.run_pdf", *flags,
                 "--process-id", str(i)],
                env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                text=True) for i in range(nprocs)]
            outs = [p.communicate()[0] for p in procs]
            wall = time.perf_counter() - t0
            name = f"cluster/weak_scaling_{nprocs}proc"
            if any(p.returncode != 0 for p in procs):
                rows.append(Row(name, 0.0,
                                "SKIPPED: worker failed (platform cannot "
                                "run a jax.distributed coordinator)"))
                continue
            if base_wall is None:
                base_wall = wall
            eff = base_wall / wall if wall > 0 else 0.0
            m = re.search(r"hash=([0-9a-f]{16})", outs[0])
            rows.append(Row(
                name, wall * 1e6,
                f"efficiency={eff:.2f} (1.0 = perfect weak scaling)",
                spec_hash=m.group(1) if m else ""))
    return rows
