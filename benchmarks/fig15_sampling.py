"""Fig. 15/16/17 analog: sampling — random vs k-means, rate sweep.

Paper: data-loading time falls ~linearly with rate; PDF-computation stays
~constant (tree prediction only); k-means costs more than random at the same
rate; the type-percentage distance to the full population shrinks with rate
(random) while k-means is better at tiny rates.

The population mixes two slices of different dominant types so the
type-percentage vector is non-trivial (our synthetic slices are type-pure).
Moment computation per rate is warmed up before timing (jit compile excluded,
as for every other figure).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributions as d
from repro.core import sampling as smp
from repro.core.regions import Window
from benchmarks.common import Row, small_sim, train_type_tree
from repro.kernels.moments import moments


def run(quick: bool = True):
    sim = small_sim(lines=16, ppl=40, num_simulations=250 if quick else 1000)
    tree = train_type_tree(sim)
    geom = sim.geometry
    # mixed population: slice 2 (exponential) + slice 3 (uniform)
    vals = np.concatenate(
        [
            sim.load_window(Window(s, 0, geom.lines_per_slice))
            for s in (2, 3)
        ]
    )
    m_all = moments(jnp.asarray(vals))
    mean_all = np.asarray(m_all.mean)
    std_all = np.asarray(m_all.std)
    sk_all = np.asarray(m_all.skew)
    ku_all = np.asarray(m_all.kurt)
    full = smp.slice_features_from_moments(
        mean_all, std_all, tree, d.TYPES_4, skew=sk_all, kurt=ku_all
    )

    rows = []
    for rate in [0.001, 0.01, 0.1, 0.5, 1.0]:
        idx = smp.sample_indices_random(len(mean_all), rate, seed=1)
        sub = jnp.asarray(vals[idx])
        jax.block_until_ready(moments(sub))  # warm the (len(idx), n) shape
        t0 = time.perf_counter()
        m = jax.block_until_ready(moments(sub))
        t_load = time.perf_counter() - t0
        t1 = time.perf_counter()
        f = smp.slice_features_from_moments(
            np.asarray(m.mean), np.asarray(m.std), tree, d.TYPES_4,
            skew=np.asarray(m.skew), kurt=np.asarray(m.kurt),
        )
        t_pdf = time.perf_counter() - t1
        dist = smp.type_percentage_distance(f.type_percentage, full.type_percentage)
        rows.append(
            Row(f"fig15/random_rate_{rate}", (t_load + t_pdf) * 1e6,
                f"load={t_load*1e3:.1f}ms pdf={t_pdf*1e3:.1f}ms dist={dist:.4f} "
                f"pts={len(idx)}")
        )
    # k-means sampling (fig 16/17)
    feats = np.stack([mean_all, std_all], 1)
    for rate in [0.01, 0.1, 0.2]:
        t0 = time.perf_counter()
        idx = smp.sample_indices_kmeans(feats, rate, iters=5, seed=1)
        t_kmeans = time.perf_counter() - t0
        f = smp.slice_features_from_moments(
            mean_all[idx], std_all[idx], tree, d.TYPES_4,
            skew=sk_all[idx], kurt=ku_all[idx],
        )
        dist = smp.type_percentage_distance(f.type_percentage, full.type_percentage)
        rows.append(
            Row(f"fig16/kmeans_rate_{rate}", t_kmeans * 1e6, f"dist={dist:.4f}")
        )
    return rows
