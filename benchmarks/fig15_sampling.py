"""Fig. 15/16/17 analog: sampling — random vs k-means, rate sweep.

Paper: data-loading time falls ~linearly with rate; PDF-computation stays
~constant (tree prediction only); k-means costs more than random at the same
rate; the type-percentage distance to the full population shrinks with rate
(random) while k-means is better at tiny rates.

Sampling is a first-class ``MethodSpec`` entry now: every row here runs
``method='sampling'`` through the same staged executor as the fitting
methods (PipelineSpec + PDFSession — no hand-wired moments/classify glue).
The population mixes two slices of different dominant types so the
type-percentage vector is non-trivial (our synthetic slices are type-pure);
per-slice sampled counts combine into the population percentage. Rate 1.0
with the random sampler classifies every point — the full-population
reference the distances are measured against.
"""

from __future__ import annotations

import time

import numpy as np

from repro.api import ComputeSpec, MethodSpec, PDFSession, PipelineSpec, source_spec_for
from repro.core import distributions as d
from repro.core import sampling as smp
from benchmarks.common import Row, small_sim, train_type_tree

SLICES = (2, 3)  # exponential + uniform dominant layers


def _sampling_spec(sim, rate: float, sampler: str, iters: int = 10) -> PipelineSpec:
    return PipelineSpec(
        source=source_spec_for(sim),
        method=MethodSpec(name="sampling", sample_frac=rate, sampler=sampler,
                          kmeans_iters=iters),
        # one window per slice: the sampler's scope matches the paper's
        # slice-level Algorithm 5
        compute=ComputeSpec(window_lines=sim.geometry.lines_per_slice),
    )


def _population_pct(results, num_types: int):
    """Combine per-slice sampled classifications into population-level type
    percentages (weighted by each slice's sampled count)."""
    counts = np.zeros(num_types, dtype=np.float64)
    sampled = 0
    for r in results.values():
        m = r.type_idx >= 0
        counts += np.bincount(r.type_idx[m], minlength=num_types)
        sampled += int(m.sum())
    return counts / max(sampled, 1), sampled


def run(quick: bool = True):
    sim = small_sim(lines=16, ppl=40, num_simulations=250 if quick else 1000)
    tree = train_type_tree(sim)
    t_count = len(d.TYPES_4)

    def measure(rate: float, sampler: str, iters: int = 10):
        spec = _sampling_spec(sim, rate, sampler, iters)
        # warm this rate's sampled-subset shapes (moments + tree predict jit
        # compile per distinct sample size) off the clock, like every figure
        PDFSession(spec, data_source=sim, tree=tree).run_all(SLICES)
        session = PDFSession(spec, data_source=sim, tree=tree)
        t0 = time.perf_counter()
        results = session.run_all(SLICES)
        wall = time.perf_counter() - t0
        pct, sampled = _population_pct(results, t_count)
        return spec, wall, pct, sampled

    # the full-population reference (rate 1.0 == classify everything;
    # Fig. 17's baseline the distances are measured against)
    _, _, full_pct, _ = measure(1.0, "random")

    rows = []
    for rate in [0.001, 0.01, 0.1, 0.5, 1.0]:
        spec, wall, pct, sampled = measure(rate, "random")
        dist = smp.type_percentage_distance(pct, full_pct)
        rows.append(
            Row(f"fig15/random_rate_{rate}", wall * 1e6,
                f"dist={dist:.4f} pts={sampled}",
                spec_hash=spec.content_hash())
        )
    # k-means "double sampling" (fig 16/17): costs more at the same rate,
    # buys accuracy at tiny rates
    for rate in [0.01, 0.1, 0.2]:
        spec, wall, pct, sampled = measure(rate, "kmeans", iters=5)
        dist = smp.type_percentage_distance(pct, full_pct)
        rows.append(
            Row(f"fig16/kmeans_rate_{rate}", wall * 1e6,
                f"dist={dist:.4f} pts={sampled}",
                spec_hash=spec.content_hash())
        )
    return rows
