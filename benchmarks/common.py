"""Shared benchmark scaffolding: workload construction + CSV rows.

All benchmarks run REDUCED workloads sized for this single-CPU container but
keep the paper's structure (same method code paths, same ratios of
points/observations). Rows: ``name,us_per_call,derived`` — and every row
that measured a pipeline run carries the run's ``PipelineSpec`` content
hash (``spec_hash``), which ``run.py`` persists alongside the timing in
``BENCH_pipeline.json`` (``__specs__``) so a tracked number can always be
traced back to the exact declarative spec that produced it.

Runs are constructed through the public API (``PipelineSpec`` +
``PDFSession``): no benchmark declares a pipeline knob outside the spec.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.api import (
    ComputeSpec,
    ExecSpec,
    MethodSpec,
    PDFSession,
    PipelineSpec,
    source_spec_for,
)
from repro.core import distributions as d
from repro.core import ml_predict as mlp
from repro.core.regions import CubeGeometry
from repro.data.simulation import SeismicSimulation, SimulationConfig

# the pre-refactor strictly serial loop (no prefetch, sync persist): the
# reference path the staged executor's overlap is measured against
SERIAL = ExecSpec(prefetch=False, async_persist=False)


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str = ""
    spec_hash: str = ""  # PipelineSpec.content_hash() of the measured run

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def small_sim(num_simulations: int = 300, lines: int = 12, ppl: int = 40,
              slices: int = 8, **kw) -> SeismicSimulation:
    return SeismicSimulation(
        SimulationConfig(
            geometry=CubeGeometry(slices, lines, ppl),
            num_simulations=num_simulations, **kw,
        )
    )


def train_type_tree(sim, types=d.TYPES_4, slices=(0, 1, 2, 3),
                    window_lines: int = 4) -> mlp.DecisionTree:
    """§5.3.1 flow via the shared pipeline helper (slices cover all types)."""
    from repro.core.pipeline import train_type_tree as _ttt

    return _ttt(sim, types=types, slices=slices, window_lines=window_lines)


def method_spec(sim, method: str, types, window_lines: int,
                mode: str = "faithful",
                exec_config: ExecSpec | None = None,
                cache_dir: str | None = None, **method_kw) -> PipelineSpec:
    """The one place benchmarks turn knobs into a spec. ``rep_bucket=32``
    is sized for the reduced workloads (the default 64+ would pad grouped
    batches past the baseline's size on these small windows).
    ``cache_dir`` threads the spec-hash-keyed ``ResultCache`` into the run's
    ``ExecSpec`` — repeated sweeps of an identical spec skip recomputation."""
    import dataclasses

    execution = exec_config if exec_config is not None else ExecSpec()
    if cache_dir is not None:
        execution = dataclasses.replace(execution, cache_dir=cache_dir)
    return PipelineSpec(
        source=source_spec_for(sim),
        method=MethodSpec(name=method, rep_bucket=32, **method_kw),
        compute=ComputeSpec(types=tuple(types), window_lines=window_lines,
                            mode=mode),
        execution=execution,
    )


def run_method(sim, method: str, types, window_lines: int, slice_i: int,
               tree=None, mode: str = "faithful", warmup: bool = True,
               exec_config: ExecSpec | None = None, reps: int = 1,
               cache_dir: str | None = None):
    """Runs one slice through a ``PDFSession`` (default overlapped config;
    pass ``exec_config=SERIAL`` for the reference serial path). Returns
    (SliceResult, wall_seconds); per-stage totals are on ``res`` stats /
    the session's ``report()``, and ``res.spec_hash`` identifies the spec.
    ``reps > 1`` repeats the measured slice and keeps the best-compute run —
    container noise is strictly additive, so the min is the estimator stable
    enough for the ``run.py --check`` gate to diff across runs. With
    ``cache_dir`` the run goes through a ``ResultCache``: the first rep of a
    fresh cache is the cold measurement and any repeat is a hit, so the
    best-of selection below considers only non-cached reps when any exist —
    a cached rep's compute time is 0 and would otherwise always win,
    silently turning a method measurement into a file-read measurement
    (cache_bench measures the cold/hit pair explicitly)."""
    spec = method_spec(sim, method, types, window_lines, mode=mode,
                       exec_config=exec_config, cache_dir=cache_dir)
    if warmup:
        # trigger jit compilation for this method's shapes on another slice
        PDFSession(spec, data_source=sim, tree=tree).run_all(
            [(slice_i + 1) % sim.geometry.num_slices]
        )
    runs = []
    for _ in range(max(reps, 1)):
        session = PDFSession(spec, data_source=sim, tree=tree)
        t0 = time.perf_counter()
        res = session.run_all([slice_i])[slice_i]
        runs.append((time.perf_counter() - t0, res))
    # Keep the best-compute run's own wall so (res, wall) stay consistent
    # (overlap stats derive from their difference).
    computed = [r for r in runs if not r[1].cached] or runs
    wall, res = min(computed, key=lambda r: r[1].total_compute_seconds)
    return res, wall
