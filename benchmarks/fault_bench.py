"""Fault-tolerance costs (DESIGN.md §14): what the retry/speculation
machinery costs when nothing fails, and what recovery costs when faults hit.

``fault/clean_retry_path`` is the headline: a normal grouped run with the
full guarded load path (retry wrapper + speculation arm + degraded-mode
bookkeeping) against the same run with all of it disabled — the derived
column records the overhead, which must stay in the noise (the guard code
is a try/except and two counters per unit; speculation only spawns work
when a straggler trips the threshold).

``fault/transient_recovery`` injects a transient read error on every
window's first load and measures the recovered run — asserting in-bench
that the result is bitwise-identical to the fault-free pass (the layer's
invariant; a bench that quietly measured different answers would be
meaningless). ``fault/degraded_manifest`` measures a run that quarantines
one unrecoverable unit and completes degraded, manifest and all.

Rows are tracked, not gated: injected sleeps/backoffs are configured
constants, not code-speed signals.
"""

from __future__ import annotations

import json
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks import common  # noqa: E402 — run via benchmarks/run.py
from repro.api import ExecSpec, PDFSession
from repro.core import distributions as d
from repro.core.executor import RESULT_FIELDS
from repro.runtime.faults import FaultInjector, FaultPlan, FaultRule

FAST = dict(retry_backoff_s=0.001, speculate=False)


def _timed(spec, sim, slices, injector=None):
    sess = PDFSession(spec, data_source=sim, fault_injector=injector)
    t0 = time.perf_counter()
    results = sess.run_all(slices)
    return sess, results, time.perf_counter() - t0


def run(quick: bool = True):
    sim = common.small_sim(num_simulations=200 if quick else 1000)
    slices = [2, 3]
    rows = []

    guarded = common.method_spec(
        sim, "grouping", d.TYPES_4, window_lines=6,
        exec_config=ExecSpec(max_retries=2, speculate=True))
    bare = common.method_spec(
        sim, "grouping", d.TYPES_4, window_lines=6,
        exec_config=ExecSpec(max_retries=0, speculate=False,
                             degraded_mode=False))

    # jit warmup (both specs share executables shapes; one pass suffices)
    PDFSession(guarded, data_source=sim).run_all([0])

    _, ref, t_guarded = _timed(guarded, sim, slices)
    _, ref_bare, t_bare = _timed(bare, sim, slices)
    for s in slices:  # the guard path must not change a single bit
        for f in RESULT_FIELDS:
            np.testing.assert_array_equal(
                getattr(ref[s], f), getattr(ref_bare[s], f))
    overhead = (t_guarded - t_bare) / t_bare if t_bare > 0 else 0.0
    rows.append(common.Row(
        "fault/clean_retry_path", t_guarded * 1e6,
        f"overhead vs bare {overhead * 100:+.1f}%",
        spec_hash=guarded.content_hash()))

    # -- transient recovery: every window's first read fails ------------------
    spec = common.method_spec(
        sim, "grouping", d.TYPES_4, window_lines=6,
        exec_config=ExecSpec(max_retries=2, **FAST))
    inj = FaultInjector(FaultPlan(rules=(FaultRule("read_error", times=1),)))
    sess, faulty, t_recover = _timed(spec, sim, slices, injector=inj)
    rep = sess.report()
    assert rep.retries > 0 and rep.quarantined_units == 0
    for s in slices:
        for f in RESULT_FIELDS:
            np.testing.assert_array_equal(
                getattr(faulty[s], f), getattr(ref[s], f))
    rows.append(common.Row(
        "fault/transient_recovery", t_recover * 1e6,
        f"retries={rep.retries} bitwise=ok",
        spec_hash=spec.content_hash()))

    # -- degraded completion: one unit never loads ----------------------------
    with tempfile.TemporaryDirectory() as tmp:
        spec = common.method_spec(
            sim, "grouping", d.TYPES_4, window_lines=6,
            exec_config=ExecSpec(max_retries=1, out_dir=tmp, **FAST))
        inj = FaultInjector(FaultPlan(rules=(
            FaultRule("read_error", slice_i=2, line_start=0, times=10_000),
        )))
        sess, results, t_degraded = _timed(spec, sim, slices, injector=inj)
        rep = sess.report()
        assert results[2].degraded and not results[3].degraded
        manifest = Path(tmp) / "slice2_failed_units.json"
        failed = json.loads(manifest.read_text())["failed"]
        assert [e["line_start"] for e in failed] == [0]
        rows.append(common.Row(
            "fault/degraded_manifest", t_degraded * 1e6,
            f"quarantined={rep.quarantined_units} manifest=ok",
            spec_hash=spec.content_hash()))

    return rows
