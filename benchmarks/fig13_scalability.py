"""Fig. 12/13/14 analog: scalability with node count — structural.

This container has one CPU, so instead of wall-clock multi-node timing we
reproduce the paper's scaling *analytically* from measured single-shard
constants + the roofline collective model (the same model the dry-run uses):

  t_baseline(n)    = W_fit * P / n                        (perfectly parallel)
  t_ml(n)          = W_fit_ml * P / n
  t_grouping(n)    = W_fit * G / n + shuffle(n)           (G = #groups)
  shuffle(n)       = keys_bytes * (n-1)/n / link_bw + t_dedup(n)

The paper's finding — Grouping wins at small n, ML wins past ~10 nodes
because the shuffle term stops shrinking — falls out of the measured
constants. Derived column reports the crossover node count.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import distributions as d
from repro.runtime.scheduler import assign_slices
from benchmarks.common import SERIAL, Row, run_method, small_sim, train_type_tree

LINK_BW = 50e9  # consistent with launch/roofline.py
SET1_SLICES = 501  # the paper's Set1 cube: one slice per node-queue entry


def run(quick: bool = True):
    sim = small_sim(lines=16, ppl=40, num_simulations=250 if quick else 1000)
    tree = train_type_tree(sim)
    geom = sim.geometry
    points = geom.points_per_slice

    # measured per-point fit costs (seconds) on this hardware — all through
    # the staged executor (run_method default)
    res_b, _ = run_method(sim, "baseline", d.TYPES_4, 8, 2)
    res_g, _ = run_method(sim, "grouping", d.TYPES_4, 8, 2)
    res_m, _ = run_method(sim, "ml", d.TYPES_4, 8, 2, tree=tree)
    w_fit = res_b.total_compute_seconds / points
    groups = sum(s.num_fitted for s in res_g.stats)
    w_fit_ml = res_m.total_compute_seconds / points

    # measured load overlap: the fraction of load time the prefetching
    # executor hides behind compute (Spark's pipelined-RDD term; serial
    # reference has hidden = 0 by construction)
    res_ser, wall_ser = run_method(sim, "baseline", d.TYPES_4, 8, 2,
                                   exec_config=SERIAL)
    hidden = max(0.0, res_b.total_load_seconds - res_b.total_wait_seconds)
    hidden_frac = hidden / max(res_b.total_load_seconds, 1e-12)

    # per-point key shuffle payload: (mu, sigma) + id ~ 16 bytes + dedup cost
    key_bytes = 16.0

    rows = [
        Row("fig13/measured/w_fit_per_point", w_fit * 1e6,
            f"groups={groups}/{points}", spec_hash=res_b.spec_hash or ""),
        Row("fig13/measured/w_fit_ml_per_point", w_fit_ml * 1e6, "",
            spec_hash=res_m.spec_hash or ""),
        Row("fig13/measured/load_hidden", hidden * 1e6,
            f"frac={hidden_frac:.0%} load={res_b.total_load_seconds * 1e3:.1f}ms "
            f"wait={res_b.total_wait_seconds * 1e3:.1f}ms "
            f"serial_wall={wall_ser * 1e3:.1f}ms"),
    ]
    crossover = None
    # project to the paper's Set1 slice (251*501 points) on n nodes
    big_points = 251 * 501
    big_groups = int(big_points * groups / points)
    for n in [1, 10, 20, 30, 40, 50, 60]:
        # whole-slice round-robin assignment (runtime/scheduler.py): the
        # slowest node carries ceil(S/n) of the S slices, so multi-slice
        # walls scale by the balance factor, not 1/n exactly.
        max_slices = max(len(a.slices) for a in assign_slices(range(SET1_SLICES), n))
        balance = max_slices * n / SET1_SLICES
        t_base = w_fit * big_points / n
        t_ml = w_fit_ml * big_points / n
        shuffle = key_bytes * big_points * (n - 1) / n / LINK_BW + 2e-3 * n
        t_grp = w_fit * big_groups / n + shuffle
        t_grp_ml = w_fit_ml * big_groups / n + shuffle
        if crossover is None and t_ml < t_grp_ml:
            crossover = n
        rows.append(
            Row(
                f"fig13/projected/n{n:02d}",
                t_base * 1e6,
                f"base={t_base:.2f}s grp={t_grp:.2f}s ml={t_ml:.2f}s "
                f"grp_ml={t_grp_ml:.2f}s balance={balance:.3f}",
            )
        )
    rows.append(
        Row("fig13/ml_beats_grouping_ml_at", 0.0,
            f"n>={crossover} (paper: >10 nodes)")
    )
    return rows
