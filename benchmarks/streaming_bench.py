"""Streaming-append speedup: incremental update vs full recompute.

One exported cube runs cold (populating the ResultCache, the persisted
windows, and the per-window stats sidecars), then an append lands on ONE
slice. The pair of rows measures the two ways a run can react:

* ``streaming/append_incremental`` — the same spec re-run through a fresh
  session: every untouched slice is *adopted* in the cache (chunk
  fingerprints unchanged) and served as a hit, the appended slice re-fits
  from merged sufficient statistics (streaming/incremental.py). No executor
  is ever built; the cost is O(appended data) file reads + one re-fit.
* ``streaming/append_full_recompute`` — the same appended cube computed
  from scratch (fresh cache/out dirs): what every run would cost without
  the streaming layer.

The derived column asserts the incremental run really was incremental
(adopted + merged counts, zero executors) and carries the measured speedup;
the bench itself asserts the speedup is real (>= 1.5x) so the row can never
quietly measure two equivalent full runs. Rows are tracked, not gated —
the incremental path is file IO, all filesystem noise at this size.
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks import common  # noqa: E402 — run via benchmarks/run.py
from repro.api import (
    ComputeSpec,
    ExecSpec,
    MethodSpec,
    PDFSession,
    PipelineSpec,
    SourceSpec,
    StreamSpec,
)
from repro.core import distributions as d
from repro.core.regions import Window
from repro.data.file_source import FileCubeSource, export_cube
from repro.streaming import append_realizations


def _in_range_block(cube_path, slice_i: int, k: int) -> np.ndarray:
    """Per-point midpoints of the existing [vmin, vmax], tiled k deep — an
    append that keeps the Eq.-5 edges fixed so the merge path engages."""
    src = FileCubeSource(cube_path)
    g = src.geometry
    vals = src.load_window(Window(slice_i, 0, g.lines_per_slice))
    mid = (vals.min(axis=1) + vals.max(axis=1)) / 2.0
    return np.repeat(mid[:, None], k, axis=1).astype(np.float32).reshape(
        g.lines_per_slice, g.points_per_line, k)


def _spec(file_src: SourceSpec, root: Path, tag: str) -> PipelineSpec:
    return PipelineSpec(
        source=file_src,
        method=MethodSpec(name="grouping", rep_bucket=32),
        compute=ComputeSpec(types=tuple(d.TYPES_4), window_lines=4),
        execution=ExecSpec(cache_dir=str(root / f"cache{tag}"),
                           out_dir=str(root / f"out{tag}")),
        stream=StreamSpec(persist_stats=True),
    )


def run(quick: bool = True):
    sim_spec = SourceSpec(num_slices=4, lines_per_slice=8,
                          points_per_line=24,
                          observations=120 if quick else 600)
    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        root = Path(tmp)
        file_src = export_cube(sim_spec, root / "cube", lines_per_chunk=4)
        cube = file_src.path
        spec = _spec(file_src, root, "")

        PDFSession(spec).run_all()  # populate (and warm the executor jit)
        # Steady state is repeated appends: the first one also warms the
        # merge path's own jit graphs (refit_from_stats traces a different
        # chain than the executor), so the measured pass times the work,
        # not one-time tracing.
        append_realizations(cube, {1: _in_range_block(cube, 1, k=8)})
        PDFSession(spec).run_all()
        append_realizations(cube, {1: _in_range_block(cube, 1, k=8)})

        inc_session = PDFSession(spec)
        t0 = time.perf_counter()
        inc_session.run_all()
        t_inc = time.perf_counter() - t0
        rep = inc_session.report()
        n = sim_spec.num_slices
        assert rep.cache_adopted == n - 1 and rep.slices_merged == 1, (
            f"incremental row measured a non-incremental run: {rep}")
        assert not inc_session._executors and rep.windows == 0

        full_session = PDFSession(_spec(file_src, root, "_full"))
        t0 = time.perf_counter()
        full_session.run_all()
        t_full = time.perf_counter() - t0
        assert full_session.report().cache_misses == n

        speedup = t_full / max(t_inc, 1e-9)
        assert speedup >= 1.5, (
            f"incremental update not faster than full recompute "
            f"({t_inc:.3f}s vs {t_full:.3f}s) — the streaming layer "
            "regressed into a recompute")
        rows.append(common.Row(
            "streaming/append_incremental", t_inc * 1e6,
            derived=f"adopted={rep.cache_adopted} merged={rep.slices_merged} "
                    f"executors=0",
            spec_hash=inc_session.spec_hash))
        rows.append(common.Row(
            "streaming/append_full_recompute", t_full * 1e6,
            derived=f"speedup={speedup:.1f}x over full recompute",
            spec_hash=full_session.spec_hash))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
