"""Wall time of the static invariant checker over the full package tree.

One row, ``analysis/wall_time_full_tree``: the time for a complete
``python -m repro.analysis`` pass (all five rules over every module of
``src/repro``), which is what the CI ``lint-invariants`` job and every
pre-commit run pay. Tracked, not gated — the checker is pure-Python AST
walking, so its absolute time swings with interpreter and filesystem noise
far more than with real regressions; the row exists so a rule that goes
accidentally quadratic in tree size shows up in BENCH history.
"""

from __future__ import annotations

import time
from pathlib import Path

from benchmarks import common  # noqa: E402 — run via benchmarks/run.py
import repro.analysis
from repro.analysis import ALL_RULES
from repro.analysis.engine import analyze_tree


def run(quick: bool = True):
    # the repro package root (repro itself is a namespace package, so it
    # has no __file__ — resolve via the analysis subpackage, like the CLI)
    root = Path(repro.analysis.__file__).resolve().parent.parent
    reps = 3 if quick else 10
    # warmup: touch every file once so the timed passes measure parsing
    # and rule evaluation, not cold page cache
    report = analyze_tree(root, list(ALL_RULES))
    t0 = time.perf_counter()
    for _ in range(reps):
        report = analyze_tree(root, list(ALL_RULES))
    us = (time.perf_counter() - t0) / reps * 1e6
    return [common.Row(
        "analysis/wall_time_full_tree", us,
        f"{report.files} files; {len(report.findings)} findings; "
        f"{len(ALL_RULES)} rules")]
