"""Fig. 6 analog: PDF-computation time per method on a small workload,
4-types vs 10-types (paper: 6 lines x 3006 points, 235 GB input; here a
proportionally reduced cube, faithful cost mode).

Derived metric: speedup over Baseline — the paper reports Grouping ~3.2x/3.5x,
ML ~1.9x/4.5x, Grouping+ML ~8x/17x on this workload.
"""

from __future__ import annotations

from repro.core import distributions as d
from benchmarks.common import SERIAL, Row, run_method, small_sim, train_type_tree

METHODS = ["baseline", "grouping", "reuse", "ml", "grouping_ml", "reuse_ml"]


def run(quick: bool = True):
    sim = small_sim(num_simulations=200 if quick else 1000)
    rows = []
    for types, tag in [(d.TYPES_4, "4types"), (d.TYPES_10, "10types")]:
        tree = train_type_tree(sim, types)
        base_wall = None
        for method in METHODS:
            # SERIAL: these rows compare per-method Select+fit compute, so
            # keep the prefetch thread's generation work off the measured
            # core (these rows feed the --check gate; overlap is fig10's).
            res, wall = run_method(
                sim, method, types, window_lines=3, slice_i=2,
                tree=tree if "ml" in method else None, exec_config=SERIAL,
                reps=7,
            )
            compute = res.total_compute_seconds
            if method == "baseline":
                base_wall = compute
            speedup = base_wall / max(compute, 1e-9)
            rows.append(
                Row(
                    f"fig06/{tag}/{method}",
                    compute * 1e6,
                    f"speedup={speedup:.2f}x err={res.avg_error:.4f}",
                    spec_hash=res.spec_hash or "",
                )
            )
    return rows
