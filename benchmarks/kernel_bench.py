"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference wall time +
the structural VMEM working-set check for the TPU BlockSpecs.

On CPU the interpret-mode kernel is *slower* than fused XLA jnp — the
deliverable here is correctness parity plus the VMEM footprint audit that
matters on the real target (block bytes must fit the ~16 MiB/core VMEM)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.core.pdf_error import histogram as hist_jnp
from repro.core.distributions import moments_from_values
from repro.kernels.hist import histogram as hist_kernel
from repro.kernels.moments import moments as moments_kernel


def _time(f, *args, reps=3):
    f(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def vmem_bytes(bp: int, bn: int, num_bins: int = 64) -> int:
    # values tile + accumulators + onehot intermediate (f32)
    return bp * bn * 4 + bp * 8 * 4 + bp * bn * num_bins * 4 // 16


def run(quick: bool = True):
    rows = []
    p, n = (256, 1000) if quick else (2048, 10000)
    v = jnp.asarray(np.random.default_rng(0).normal(3000, 10, (p, n)), jnp.float32)

    t_ref = _time(jax.jit(lambda x: moments_from_values(x)), v)
    t_ker = _time(lambda x: moments_kernel(x), v)
    rows.append(Row("kernel/moments_ref_jnp", t_ref * 1e6, f"P={p} n={n}"))
    rows.append(Row("kernel/moments_pallas_interpret", t_ker * 1e6,
                    "correctness: tests/test_kernels.py"))

    vmin, vmax = v.min(1), v.max(1)
    t_ref = _time(jax.jit(lambda x, a, b: hist_jnp(x, a, b, 64)), v, vmin, vmax)
    t_ker = _time(lambda x, a, b: hist_kernel(x, a, b, 64), v, vmin, vmax)
    rows.append(Row("kernel/hist_ref_jnp", t_ref * 1e6, ""))
    rows.append(Row("kernel/hist_pallas_interpret", t_ker * 1e6, ""))

    # banded attention kernel vs jnp band path (interpret mode on CPU)
    from repro.kernels.band_attn import banded_attention, banded_attention_ref
    b, s, h, kv, hd, w = (2, 256, 4, 2, 64, 64) if quick else (4, 2048, 8, 2, 128, 512)
    import jax as _jax
    q = _jax.random.normal(_jax.random.PRNGKey(1), (b, s, h, hd)) * 0.5
    kk = _jax.random.normal(_jax.random.PRNGKey(2), (b, s, kv, hd)) * 0.5
    vv = _jax.random.normal(_jax.random.PRNGKey(3), (b, s, kv, hd))
    t_ref = _time(jax.jit(lambda a, c, d: banded_attention_ref(a, c, d, w)), q, kk, vv)
    t_ker = _time(lambda a, c, d: banded_attention(a, c, d, w), q, kk, vv)
    rows.append(Row("kernel/band_attn_ref_jnp", t_ref * 1e6, f"S={s} W={w}"))
    rows.append(Row("kernel/band_attn_pallas_interpret", t_ker * 1e6,
                    "VMEM-resident scores; correctness: tests/test_band_attn_kernel.py"))
    sc_bytes = 2 * w * w * 4
    rows.append(Row("kernel/band_attn_vmem_scores", 0.0,
                    f"{sc_bytes/2**10:.0f}KiB scores tile (W={w}) stays in VMEM; "
                    f"{2*1024*1024*4/2**20:.0f}MiB at W=1024"))

    for bp, bn in [(8, 512), (8, 1024), (16, 512)]:
        b = vmem_bytes(bp, bn)
        rows.append(
            Row(f"kernel/vmem_block_{bp}x{bn}", 0.0,
                f"{b/1024:.0f}KiB of 16MiB VMEM ({'ok' if b < 16 * 2**20 else 'OVER'})")
        )
    return rows
