"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference wall time +
the structural VMEM working-set check for the TPU BlockSpecs.

On CPU the interpret-mode kernel is *slower* than fused XLA jnp — the
deliverable here is correctness parity plus the VMEM footprint audit that
matters on the real target (block bytes must fit the ~16 MiB/core VMEM)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.core import distributions as d
from repro.core import fitting
from repro.core.pdf_error import histogram as hist_jnp
from repro.core.regions import Window
from repro.core.distributions import moments_from_values
from repro.kernels.hist import histogram as hist_kernel
from repro.kernels.moments import moments as moments_kernel


def _time(f, *args, reps=11):
    """Best-of-reps: timing noise on a shared container is strictly additive
    (bandwidth contention hits the one-hot rows up to ~1.7x), so the min is
    the stable estimator the run.py --check gate can diff across runs."""
    jax.block_until_ready(f(*args))  # warmup/compile
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        samples.append(time.perf_counter() - t0)
    return min(samples)


def vmem_bytes(bp: int, bn: int, num_bins: int = 64) -> int:
    # values tile + accumulators + onehot intermediate (f32)
    return bp * bn * 4 + bp * 8 * 4 + bp * bn * num_bins * 4 // 16


def run(quick: bool = True):
    rows = []
    p, n = (256, 1000) if quick else (2048, 10000)
    v = jnp.asarray(np.random.default_rng(0).normal(3000, 10, (p, n)), jnp.float32)

    t_ref = _time(jax.jit(lambda x: moments_from_values(x)), v)
    t_ker = _time(lambda x: moments_kernel(x), v)
    rows.append(Row("kernel/moments_ref_jnp", t_ref * 1e6, f"P={p} n={n}"))
    rows.append(Row("kernel/moments_pallas_interpret", t_ker * 1e6,
                    "correctness: tests/test_kernels.py"))

    vmin, vmax = v.min(1), v.max(1)
    t_ref = _time(jax.jit(lambda x, a, b: hist_jnp(x, a, b, 64)), v, vmin, vmax)
    t_ker = _time(lambda x, a, b: hist_kernel(x, a, b, 64), v, vmin, vmax)
    rows.append(Row("kernel/hist_ref_jnp", t_ref * 1e6, ""))
    rows.append(Row("kernel/hist_pallas_interpret", t_ker * 1e6, ""))

    # End-to-end ComputePDF&Error: the fused single-launch path (kernels/
    # fitpdf) vs the chained two-pass kernel path (moments kernel + hist
    # kernel + XLA masses/error). Same moments->select semantics; the fused
    # rows must beat two-pass by >= 1.5x (fused-fit issue acceptance).
    def _fit_fn(backend_name, types):
        backend = fitting.get_fit_backend(backend_name, 64)

        @jax.jit
        def run_fit(x):
            m = backend.moments(x)
            r = backend.fit_all(x, m, types, 64, "fused")
            return r.type_idx, r.error

        return run_fit

    for types, tag in [(d.TYPES_4, "4types"), (d.TYPES_10, "10types")]:
        t_two = _time(_fit_fn("kernels", types), v)
        t_fused = _time(_fit_fn("fused", types), v)
        rows.append(Row(f"kernel/fit_twopass_{tag}", t_two * 1e6, f"P={p} n={n}"))
        rows.append(Row(
            f"kernel/fit_fused_{tag}", t_fused * 1e6,
            f"speedup={t_two / max(t_fused, 1e-9):.2f}x vs two-pass",
        ))

    # Select backends (DESIGN.md §6): device-side grouped dispatch
    # (quantize -> group_device -> gather -> fused fit -> scatter, one
    # launch + a scalar sync) vs the host Select path (np.unique bounce +
    # padded representative re-dispatch). Heavily-duplicated window so the
    # Select machinery, not the representative fit, dominates the row.
    from repro.core.executor import PDFConfig, StagedExecutor

    sp, sn, sg = (2048, 400, 48) if quick else (8192, 1000, 96)
    srng = np.random.default_rng(7)
    base = srng.normal(3000, 10, (sg, sn)).astype(np.float32)
    sel_np = base[srng.integers(0, sg, size=sp)]  # sp rows over sg distinct
    sel_times = {}
    for types, tag in [(d.TYPES_4, "4types"), (d.TYPES_10, "10types")]:
        for backend in ("host", "device"):
            cfg = PDFConfig(types=types, method="grouping",
                            select_backend=backend, rep_bucket=64)
            ex = StagedExecutor(cfg, None)
            win = Window(0, 0, 1)  # only feeds the sampling method's seed
            m = d.Moments(
                *jax.block_until_ready(ex._moments(jnp.asarray(sel_np)))
            )
            # fresh staged buffer per call: the device path donates the
            # window (as the executor does); staging cost is symmetric.
            ex._select_and_fit(jnp.asarray(sel_np), m, win)  # warmup/compile
            samples = []
            for _ in range(7):
                sv = jax.block_until_ready(jnp.asarray(sel_np))
                t0 = time.perf_counter()
                ex._select_and_fit(sv, m, win)  # returns np arrays (synchronous)
                samples.append(time.perf_counter() - t0)
            sel_times[(tag, backend)] = min(samples)
        t_host, t_dev = sel_times[(tag, "host")], sel_times[(tag, "device")]
        rows.append(Row(f"kernel/select_host_{tag}", t_host * 1e6,
                        f"P={sp} n={sn} G={sg} np.unique+re-dispatch"))
        rows.append(Row(
            f"kernel/select_device_{tag}", t_dev * 1e6,
            f"speedup={t_host / max(t_dev, 1e-9):.2f}x vs host Select",
        ))

    # banded attention kernel vs jnp band path (interpret mode on CPU)
    from repro.kernels.band_attn import banded_attention, banded_attention_ref
    b, s, h, kv, hd, w = (2, 256, 4, 2, 64, 64) if quick else (4, 2048, 8, 2, 128, 512)
    import jax as _jax
    q = _jax.random.normal(_jax.random.PRNGKey(1), (b, s, h, hd)) * 0.5
    kk = _jax.random.normal(_jax.random.PRNGKey(2), (b, s, kv, hd)) * 0.5
    vv = _jax.random.normal(_jax.random.PRNGKey(3), (b, s, kv, hd))
    t_ref = _time(jax.jit(lambda a, c, d: banded_attention_ref(a, c, d, w)), q, kk, vv)
    t_ker = _time(lambda a, c, d: banded_attention(a, c, d, w), q, kk, vv)
    rows.append(Row("kernel/band_attn_ref_jnp", t_ref * 1e6, f"S={s} W={w}"))
    rows.append(Row("kernel/band_attn_pallas_interpret", t_ker * 1e6,
                    "VMEM-resident scores; correctness: tests/test_band_attn_kernel.py"))
    sc_bytes = 2 * w * w * 4
    rows.append(Row("kernel/band_attn_vmem_scores", 0.0,
                    f"{sc_bytes/2**10:.0f}KiB scores tile (W={w}) stays in VMEM; "
                    f"{2*1024*1024*4/2**20:.0f}MiB at W=1024"))

    for bp, bn in [(8, 512), (8, 1024), (16, 512)]:
        b = vmem_bytes(bp, bn)
        rows.append(
            Row(f"kernel/vmem_block_{bp}x{bn}", 0.0,
                f"{b/1024:.0f}KiB of 16MiB VMEM ({'ok' if b < 16 * 2**20 else 'OVER'})")
        )
    # Fused fit kernel's TPU tile (one-hot accumulation path, 10 types):
    # values + freq scratch + edges + params + the strip-mined one-hot.
    bp, bn, L, T = 8, 512, 64, 10
    fb = bp * bn * 4 + bp * L * 4 + bp * (L + 1) * 4 + bp * 3 * T * 4 \
        + bp * bn * L * 4 // 16
    rows.append(
        Row(f"kernel/vmem_fitpdf_{bp}x{bn}", 0.0,
            f"{fb/1024:.0f}KiB of 16MiB VMEM ({'ok' if fb < 16 * 2**20 else 'OVER'})")
    )
    return rows
