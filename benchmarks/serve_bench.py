"""Serving-layer benchmark: the coalescing win and the hot-path latency.

Four tracked ``serve/*`` rows drive a closed-loop load generator (client
threads that wait for each answer before asking again) against a
``PDFServer``:

  serve/coalesced_8c   per-query wall with 8 concurrent clients asking
                       distinct-window point queries, coalescing ON — the
                       pending queue drains into shared
                       ``run_window_batch`` launches each tick.
  serve/naive_8c       the identical workload with ``serve.coalesce=false``
                       (one ``run_window`` launch per query window) — the
                       baseline the tentpole is measured against; derived
                       on the coalesced row records the speedup.
  serve/cold_p50       serial per-query p50 when every query computes its
                       window (first touch).
  serve/warm_p50       serial per-query p50 re-asking the same points — all
                       memory-LRU hits, no executor. Microseconds by
                       construction — below even the serve-family gate
                       floor (run.py GATE_MIN_US_BY_PREFIX), so
                       tracked-not-gated.

The throughput pair runs the paper's headline ``grouping`` method with the
hot-window LRU disabled: every query then costs real device work, and the
only difference between the rows is launch sharing — per query, the naive
path pays a synced moments dispatch plus a padded gather-and-fit dispatch
for ONE 80-row window, while the coalesced path dispatches the pending
windows' moments asynchronously behind one H2D/barrier and packs all their
representatives into a single shared fit launch of the same 256-slot shape
class the serial path compiles (grouping's per-window host dedup is
unchanged, and shape-identical launches keep answers bitwise-equal). Each
mode's wall is the best of ``reps`` passes — container noise is strictly
additive, same estimator as ``common.run_method``. Shapes are jit-warmed
for every power-of-two chunk the coalescer can form, so neither row pays
compiles.

``--smoke`` (CI): a seconds-scale pass asserting the serving contract
end-to-end — answers bitwise-equal to the batch pipeline, memory hits on
repeat, and a second server process-alike (fresh ``PDFServer``, same
``cache_dir``) served from disk with zero computed windows.
"""

from __future__ import annotations

import sys
import tempfile
import threading
import time
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
if str(_ROOT) not in sys.path:  # support `python benchmarks/serve_bench.py`
    sys.path.insert(0, str(_ROOT))

import numpy as np

from benchmarks import common  # noqa: E402 — run via benchmarks/run.py
from repro.api import ComputeSpec, ExecSpec, MethodSpec, PipelineSpec, source_spec_for
from repro.api.spec import ServeSpec
from repro.runtime.monitor import percentiles
from repro.serve import PDFServer, PointQuery, RegionQuery

CLIENTS = 8
QUERIES_PER_CLIENT = 12
# Serving's natural unit is small: a point query touches one 2-line window
# (80 rows here), where the fixed per-launch cost dominates per-row compute
# — the regime request coalescing exists for. (Batch-pipeline benchmarks
# keep their larger windows; this knob is serve_bench's workload, not a
# pipeline default.) max_batch_windows=8 keeps every launch in the
# measured-efficient batch range for these shapes.
WINDOW_LINES = 2
OBSERVATIONS = 100
MAX_BATCH = 16
# The executor-default representative bucket (ExecutorConfig.rep_bucket,
# also the pdf_seismic config's choice): every window's serial fit pads to
# the same 256-slot shape class, so the coalescer packs a whole chunk's
# representatives (~10 groups/window here) into ONE shared fit launch.
REP_BUCKET = 256


def _spec(sim, coalesce: bool, lru: int) -> PipelineSpec:
    return PipelineSpec(
        source=source_spec_for(sim),
        method=MethodSpec(name="grouping", rep_bucket=REP_BUCKET),
        compute=ComputeSpec(window_lines=WINDOW_LINES),
        serve=ServeSpec(coalesce=coalesce, window_cache_entries=lru,
                        max_batch_windows=MAX_BATCH),
    )


def _point_queries(geom, n: int) -> list[PointQuery]:
    """``n`` point queries, each in a DISTINCT window (round-robin over
    slices, then window rows) — no two queries share any work, so every
    answered query is one window of real compute."""
    wins_per_slice = -(-geom.lines_per_slice // WINDOW_LINES)
    total = geom.num_slices * wins_per_slice
    if n > total:
        raise ValueError(f"workload wants {n} distinct windows, cube has {total}")
    out = []
    for i in range(n):
        s, w = i % geom.num_slices, (i // geom.num_slices) % wins_per_slice
        out.append(PointQuery(s, w * WINDOW_LINES, (3 * i) % geom.points_per_line))
    return out


def _warm_shapes(sim, spec: PipelineSpec, max_batch: int) -> None:
    """Compile every fused shape the coalescer can form: chunk sizes pad to
    power-of-two row buckets, so batches of 1, 2, 4, ... max_batch windows
    cover them all (run_window == a batch of 1)."""
    from repro.api import PDFSession
    from repro.core import regions

    ex = PDFSession(spec, data_source=sim).executor(0)
    geom = sim.geometry
    wins = [w for s in range(geom.num_slices)
            for w in regions.iter_windows(geom, s, WINDOW_LINES)]
    k = 1
    while k <= max_batch:
        ex.run_window_batch(wins[:k])
        k *= 2


def _closed_loop(server: PDFServer, queries: list[PointQuery],
                 clients: int) -> float:
    """Fire the queries from ``clients`` closed-loop threads (client ``c``
    takes every ``c``-th query); returns total wall seconds."""
    errors: list[BaseException] = []

    def client(c: int) -> None:
        try:
            for q in queries[c::clients]:
                server.query(q)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=client, args=(c,)) for c in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return wall


def run(quick: bool = True):
    # --full adds measurement passes, not observations: more rows per window
    # would shift the workload out of the launch-bound serving regime this
    # module measures (figures 6-18 cover the compute-bound regimes).
    reps = 2 if quick else 4
    sim = common.small_sim(num_simulations=OBSERVATIONS, lines=24)
    geom = sim.geometry
    queries = _point_queries(geom, CLIENTS * QUERIES_PER_CLIENT)
    _warm_shapes(sim, _spec(sim, coalesce=True, lru=0), max_batch=MAX_BATCH)
    rows = []

    # -- throughput under concurrency: coalesced vs naive ----------------------
    walls = {}
    for mode, coalesce in (("coalesced", True), ("naive", False)):
        per_pass = []
        for _ in range(reps):
            with PDFServer(_spec(sim, coalesce, lru=0), data_source=sim) as srv:
                per_pass.append(_closed_loop(srv, queries, CLIENTS))
                st = srv.stats()
        walls[mode] = min(per_pass)
        assert st.windows_computed == len(queries), (
            f"{mode}: every query must compute its own window "
            f"({st.windows_computed} != {len(queries)})")
        if mode == "coalesced":
            derived = (f"qps={len(queries) / walls[mode]:.1f} "
                       f"launches={st.launches}/{len(queries)} "
                       f"occupancy={st.batch_occupancy:.1f}")
            coalesced_hash = st.spec_hash
        else:
            speed = walls["naive"] / walls["coalesced"]
            rows[-1].derived += f" speedup={speed:.1f}x vs naive"
            derived = f"qps={len(queries) / walls[mode]:.1f} launches={st.launches}"
        rows.append(common.Row(
            f"serve/{mode}_{CLIENTS}c",
            walls[mode] / len(queries) * 1e6,
            derived=derived, spec_hash=st.spec_hash))

    # -- cold vs warm serial latency -------------------------------------------
    with PDFServer(_spec(sim, coalesce=True, lru=256), data_source=sim) as srv:
        cold = [srv.query(q).latency_seconds for q in queries[:16]]
        warm = [srv.query(q).latency_seconds for q in queries[:16]]
        st = srv.stats()
    assert st.windows_from_memory == 16, "warm pass must be all memory hits"
    p_cold = percentiles(cold)["p50"]
    p_warm = percentiles(warm)["p50"]
    rows.append(common.Row("serve/cold_p50", p_cold * 1e6,
                           derived="first-touch compute",
                           spec_hash=coalesced_hash))
    rows.append(common.Row("serve/warm_p50", p_warm * 1e6,
                           derived=f"memory-hit, cold/warm="
                                   f"{p_cold / max(p_warm, 1e-9):.0f}x",
                           spec_hash=coalesced_hash))
    return rows


def smoke() -> None:
    """Seconds-scale CI gate: serve, verify bitwise vs the batch pipeline,
    then assert repeat queries hit memory and a fresh server over the same
    ``cache_dir`` is served entirely from disk."""
    from repro.api import PDFSession
    from repro.core.executor import RESULT_FIELDS

    sim = common.small_sim(num_simulations=120, lines=12, slices=4)
    with tempfile.TemporaryDirectory() as tmp:
        spec = PipelineSpec(
            source=source_spec_for(sim),
            method=MethodSpec(name="grouping", rep_bucket=32),
            compute=ComputeSpec(window_lines=WINDOW_LINES),
            execution=ExecSpec(cache_dir=tmp),
        )
        # reference via the batch pipeline, cache-less (same content hash —
        # execution is staging-only — but it must not pre-populate tmp, or
        # the server under test would never compute/store anything)
        import dataclasses

        ref_spec = dataclasses.replace(spec, execution=ExecSpec())
        ref = PDFSession(ref_spec, data_source=sim).run_all([0, 1])

        with PDFServer(spec, data_source=sim) as srv:
            a = srv.query(RegionQuery(0))
            for f in RESULT_FIELDS:
                np.testing.assert_array_equal(getattr(a, f), getattr(ref[0], f))
            b = srv.query(RegionQuery(0))  # repeat: memory LRU
            assert b.windows_from_memory > 0 and b.windows_computed == 0, (
                "repeat query did not hit the hot-window LRU")
            srv.query(RegionQuery(1))
            st = srv.stats()
        assert st.slices_stored == 2, f"slices_stored={st.slices_stored}"

        # a fresh server over the same cache dir: all disk, zero compute
        with PDFServer(spec, data_source=sim) as srv2:
            c = srv2.query(RegionQuery(0))
            for f in RESULT_FIELDS:
                np.testing.assert_array_equal(getattr(c, f), getattr(ref[0], f))
            st2 = srv2.stats()
        assert st2.windows_from_disk > 0 and st2.windows_computed == 0, (
            f"fresh server should serve from ResultCache, got "
            f"disk={st2.windows_from_disk} computed={st2.windows_computed}")
        print(f"[smoke] ok: memory_hits={st.windows_from_memory} "
              f"disk_hits={st2.windows_from_disk} computed_repeat=0 "
              f"stored_slices={st.slices_stored}")


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        smoke()
    else:
        for r in run(quick="--full" not in sys.argv):
            print(r.csv())
