"""Chaos suite (DESIGN.md §14): the fault-injection layer and everything
it exercises — work-unit retry, straggler speculation, degraded-mode
quarantine + failed-unit manifests, shard-death re-dealing, verified chunk
reads, cache-lock degradation, and the server's partial-failure /
load-shedding / deadline paths.

The load-bearing invariant everywhere: any COMPLETED result produced under
injected faults is bitwise-identical to the fault-free run. Work units are
independently recomputable partitions (re-loading a window yields the same
bytes, fits are row-pure), so retrying, speculating, or re-dealing a unit
can change wall time and placement — never the answer's bits."""

import json
import os
import threading
import time

import numpy as np
import pytest

from repro.api import (
    ComputeSpec,
    ExecSpec,
    MethodSpec,
    PDFSession,
    PipelineSpec,
    ResultCache,
    SourceSpec,
    build_source,
)
from repro.api.spec import ServeSpec
from repro.core.executor import RESULT_FIELDS, SliceResult
from repro.data import file_source
from repro.runtime.faults import (
    FaultInjector,
    FaultPlan,
    FaultRule,
    InjectedFault,
    ShardLostError,
    TransientError,
    is_transient,
)
from repro.runtime import elastic
from repro.serve import (
    PDFServer,
    PointQuery,
    ServerOverloadedError,
    WindowQuery,
)

SOURCE = SourceSpec(num_slices=3, lines_per_slice=10, points_per_line=8,
                    observations=150)
PPL = SOURCE.points_per_line
WINDOW_LINES = 3

# Chaos-test executor defaults: near-zero backoff (we inject the delays we
# want), no speculation unless the test is about speculation.
FAST_RETRY = dict(retry_backoff_s=0.001, speculate=False)


def make_spec(method="grouping", source=SOURCE, execution=None, serve=None):
    kw = {}
    if serve is not None:
        kw["serve"] = serve
    return PipelineSpec(
        source=source,
        method=MethodSpec(name=method),
        compute=ComputeSpec(window_lines=WINDOW_LINES, num_bins=20),
        execution=execution or ExecSpec(),
        **kw,
    )


def assert_bitwise(result, ref, what=""):
    for name in RESULT_FIELDS:
        np.testing.assert_array_equal(
            getattr(result, name), getattr(ref, name),
            err_msg=f"{what}{name}")


@pytest.fixture(scope="module")
def clean():
    """The fault-free reference arrays every bitwise assertion compares
    against (ExecSpec is hash-excluded, so one reference serves them all)."""
    return PDFSession(make_spec()).run_all([0, 1, 2])


# -- the plan / taxonomy -------------------------------------------------------


def test_plan_json_roundtrip():
    plan = FaultPlan(seed=7, rules=(
        FaultRule("read_error", slice_i=1, line_start=3, times=2),
        FaultRule("latency", seconds=0.5, rate=0.25),
        FaultRule("shard_death", shard=1, after_units=4),
    ))
    again = FaultPlan.from_json(plan.to_json())
    assert again == plan
    assert again.rules[2].shard == 1


def test_plan_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultRule("meteor_strike")
    with pytest.raises(ValueError, match="shard"):
        FaultRule("shard_death")  # no target shard
    with pytest.raises(ValueError, match="rate"):
        FaultRule("read_error", rate=0.0)
    with pytest.raises(ValueError, match="unknown fault plan keys"):
        FaultPlan.from_dict({"seed": 0, "rules": [], "extra": 1})


def test_is_transient_classification():
    assert is_transient(InjectedFault("hiccup"))
    assert is_transient(TransientError("retry me"))
    assert is_transient(OSError("nfs wobble"))
    assert is_transient(TimeoutError("slow"))
    assert not is_transient(ValueError("bad shape"))
    assert not is_transient(ShardLostError(3))
    # classification follows the __cause__ chain through wrappers
    wrapped = RuntimeError("prefetch stage failed")
    wrapped.__cause__ = OSError("root cause")
    assert is_transient(wrapped)
    fatal = RuntimeError("shard gone")
    fatal.__cause__ = ShardLostError(1)
    assert not is_transient(fatal)


def test_affliction_is_deterministic():
    plan = FaultPlan(seed=3, rules=(FaultRule("read_error", rate=0.5),))
    a = FaultInjector(plan)
    b = FaultInjector(plan)
    keys = [(s, line) for s in range(4) for line in range(0, 40, 3)]
    decide = lambda inj: [inj._afflicted(0, plan.rules[0], k) for k in keys]
    assert decide(a) == decide(b)
    assert 0 < sum(decide(a)) < len(keys)  # rate actually partitions


# -- executor: retry / speculation / quarantine --------------------------------


def test_transient_read_errors_recover_bitwise(clean):
    """Every window's first read fails; retries recover every unit and the
    completed results are bitwise-identical to the fault-free run."""
    spec = make_spec(execution=ExecSpec(**FAST_RETRY))
    inj = FaultInjector(FaultPlan(rules=(FaultRule("read_error", times=1),)))
    sess = PDFSession(spec, fault_injector=inj)
    results = sess.run_all([0, 1, 2])
    for s in (0, 1, 2):
        assert not results[s].degraded
        assert_bitwise(results[s], clean[s], f"slice{s}/")
    rep = sess.report()
    assert rep.retries > 0
    assert rep.quarantined_units == 0
    assert inj.events["read_error"] > 0


def test_straggler_speculation_wins_bitwise(clean):
    """An injected latency spike on a late window trips the straggler
    threshold; the speculative re-dispatch races it and the first success
    wins — with bitwise-identical results (loads are deterministic)."""
    spec = make_spec(execution=ExecSpec(
        retry_backoff_s=0.001, speculate=True, straggler_grace_s=0.05,
        prefetch=False))
    # slice 2 is the shard's 9th-12th unit: the trailing median exists
    # (min_samples=5) by the time the spike hits, so speculation can fire.
    inj = FaultInjector(FaultPlan(rules=(
        FaultRule("latency", slice_i=2, line_start=6, seconds=1.5, times=1),
    )))
    sess = PDFSession(spec, fault_injector=inj)
    results = sess.run_all([0, 1, 2])
    for s in (0, 1, 2):
        assert_bitwise(results[s], clean[s], f"slice{s}/")
    rep = sess.report()
    assert rep.speculations > 0
    assert inj.events["latency"] == 1


def test_unrecoverable_unit_quarantines_not_aborts(clean, tmp_path):
    """A unit whose reads NEVER succeed completes the run degraded: its
    window carries type_idx=-1, the failed-unit manifest sits next to the
    watermark, every other window is bitwise-correct, and the degraded
    slice is NOT stored in the result cache."""
    out = tmp_path / "out"
    cache = tmp_path / "cache"
    spec = make_spec(execution=ExecSpec(
        out_dir=str(out), cache_dir=str(cache), max_retries=1, **FAST_RETRY))
    inj = FaultInjector(FaultPlan(rules=(
        FaultRule("read_error", slice_i=1, line_start=3, times=10_000),
    )))
    sess = PDFSession(spec, fault_injector=inj)
    with pytest.warns(UserWarning, match="not stored"):
        results = sess.run_all([0, 1, 2])

    r1 = results[1]
    assert r1.degraded
    assert [q["line_start"] for q in r1.quarantined] == [3]
    assert r1.quarantined[0]["attempts"] == 2  # max_retries + 1
    assert "injected transient read error" in r1.quarantined[0]["error"]
    lo, hi = 3 * PPL, 6 * PPL
    assert (r1.type_idx[lo:hi] == -1).all()
    assert (r1.params[lo:hi] == 0).all()
    # everything OUTSIDE the quarantined window is bitwise the clean run
    for name in RESULT_FIELDS:
        got, want = getattr(r1, name), getattr(clean[1], name)
        np.testing.assert_array_equal(got[:lo], want[:lo], err_msg=name)
        np.testing.assert_array_equal(got[hi:], want[hi:], err_msg=name)
    for s in (0, 2):
        assert not results[s].degraded
        assert_bitwise(results[s], clean[s], f"slice{s}/")

    manifest = out / "slice1_failed_units.json"
    assert manifest.exists()
    m = json.loads(manifest.read_text())
    assert m["spec_hash"] == sess.spec_hash
    assert [e["line_start"] for e in m["failed"]] == [3]
    # degraded slice not cached; healthy neighbours are
    assert sess.cache.lookup(sess.spec_hash, 1) is None
    assert sess.cache.lookup(sess.spec_hash, 0) is not None
    assert rep_quarantined(sess) == 1

    # -- repair: a fault-free resume re-runs ONLY the manifest's units,
    # fills the hole bitwise, and clears the manifest.
    sess2 = PDFSession(spec)
    repaired = sess2.run_all([1], resume=True)[1]
    assert not repaired.degraded
    assert_bitwise(repaired, clean[1], "repaired/")
    assert not manifest.exists()


def rep_quarantined(sess):
    return sess.report().quarantined_units


def test_degraded_mode_off_raises():
    spec = make_spec(execution=ExecSpec(
        degraded_mode=False, max_retries=1, **FAST_RETRY))
    inj = FaultInjector(FaultPlan(rules=(
        FaultRule("read_error", slice_i=0, line_start=0, times=10_000),
    )))
    with pytest.raises(RuntimeError, match="failed after 2 attempts"):
        PDFSession(spec, fault_injector=inj).run_all([0])


# -- shard death / re-dealing --------------------------------------------------


def test_plan_redeal():
    plan = elastic.plan_redeal([4, 7, 9], healthy_shards=[0, 2], lost_shards=[1])
    assert plan.lost_shards == (1,)
    assert plan.slices_for(0) == (4, 9)
    assert plan.slices_for(2) == (7,)
    with pytest.raises(ValueError, match="no healthy shards"):
        elastic.plan_redeal([1], healthy_shards=[], lost_shards=[0])


def test_shard_death_redeals_and_completes_bitwise(clean, tmp_path):
    """Shard 1 dies mid-slice; its remaining work is re-dealt to shard 0
    with resume, so windows the dead shard persisted are restored (not
    recomputed) and every slice still completes bitwise-identical."""
    spec = make_spec(execution=ExecSpec(
        shards=2, out_dir=str(tmp_path / "out"), **FAST_RETRY))
    inj = FaultInjector(FaultPlan(rules=(
        FaultRule("shard_death", shard=1, after_units=2),
    )))
    sess = PDFSession(spec, fault_injector=inj)
    results = sess.run_all([0, 1, 2])
    assert set(results) == {0, 1, 2}
    for s in (0, 1, 2):
        assert not results[s].degraded
        assert_bitwise(results[s], clean[s], f"slice{s}/")
    assert sess.shards_lost == (1,)
    assert sess.report().shards_lost == (1,)
    assert inj.events["shard_death"] >= 1


def test_all_shards_lost_is_fatal():
    spec = make_spec(execution=ExecSpec(shards=1, **FAST_RETRY))
    inj = FaultInjector(FaultPlan(rules=(
        FaultRule("shard_death", shard=0, after_units=0),
    )))
    with pytest.raises((ShardLostError, ValueError)):
        PDFSession(spec, fault_injector=inj).run_all([0])


def test_plan_redeal_joined_grows_capacity():
    """The grow half of elastic execution: ``joined`` shards take redealt
    slices round-robin alongside survivors, and when every original shard
    died a joiner alone keeps the run alive."""
    plan = elastic.plan_redeal([4, 7, 9], healthy_shards=[0],
                               lost_shards=[1], joined=[5])
    assert plan.healthy_shards == (0, 5)
    assert plan.slices_for(0) == (4, 9)
    assert plan.slices_for(5) == (7,)
    solo = elastic.plan_redeal([1, 2], healthy_shards=[],
                               lost_shards=[0], joined=[9])
    assert solo.slices_for(9) == (1, 2)
    # duplicate join of an already-healthy shard is a no-op, not a double seat
    dup = elastic.plan_redeal([1, 2], healthy_shards=[0, 2],
                              lost_shards=[1], joined=[0])
    assert dup.healthy_shards == (0, 2)


def _cluster_spec(out_dir, pid, num_processes=2, peer_timeout_s=30.0):
    from repro.api.spec import PlacementSpec
    from repro.runtime import cluster

    return cluster.apply_placement(make_spec(execution=ExecSpec(
        out_dir=str(out_dir), **FAST_RETRY,
        placement=PlacementSpec(
            num_processes=num_processes, process_id=pid, distributed=False,
            peer_timeout_s=peer_timeout_s),
    )))


def test_cluster_redeal_survivor_completes_bitwise(clean, tmp_path):
    """The cross-process redeal protocol (runtime.cluster) driven
    in-process: worker 1's shard dies on its first window load and
    publishes a ``lost`` marker; worker 0 finishes its own deal, sees the
    marker, re-deals the dead shard's unfinished slices onto itself and
    completes them bitwise-identical, with ``shards_lost`` stamped in the
    report."""
    from repro.runtime import cluster

    out = tmp_path / "out"
    inj = FaultInjector(FaultPlan(rules=(
        FaultRule("shard_death", shard=1, after_units=0),
    )))
    s1 = PDFSession(_cluster_spec(out, pid=1), fault_injector=inj)
    died = list(cluster.run_worker(s1))
    assert died == []  # nothing completed before the death
    assert cluster.marker_path(out, 1, "lost").exists()
    assert inj.events["shard_death"] >= 1

    s0 = PDFSession(_cluster_spec(out, pid=0))
    results = {r.slice_i: r for r in cluster.run_worker(s0)}
    # shard 0's own deal (0, 2) plus the dead shard's (1,)
    assert set(results) == {0, 1, 2}
    for s in (0, 1, 2):
        assert not results[s].degraded
        assert_bitwise(results[s], clean[s], f"slice{s}/")
    assert s0.shards_lost == (1,)
    assert s0.report().shards_lost == (1,)
    assert cluster.marker_path(out, 0, "done").exists()


def test_cluster_joiner_completes_when_all_originals_die(clean, tmp_path):
    """A join-only worker (process_id >= num_processes) enters at the
    redeal step: with every original seat dead or silent past the peer
    timeout, ``plan_redeal(joined=...)`` hands it the whole pending set and
    it completes the run alone, bitwise-identical."""
    from repro.runtime import cluster

    out = tmp_path / "out"
    inj = FaultInjector(FaultPlan(rules=(
        FaultRule("shard_death", shard=0, after_units=0),
    )))
    s0 = PDFSession(_cluster_spec(out, pid=0), fault_injector=inj)
    assert list(cluster.run_worker(s0)) == []
    # shard 1 never starts — the joiner's peer timeout declares it lost
    joiner = PDFSession(_cluster_spec(out, pid=2, peer_timeout_s=0.3))
    results = {r.slice_i: r for r in cluster.run_worker(joiner)}
    assert set(results) == {0, 1, 2}
    for s in (0, 1, 2):
        assert_bitwise(results[s], clean[s], f"slice{s}/")
    assert joiner.shards_lost == (0, 1)


# -- corrupt chunk bytes / verified reads --------------------------------------


@pytest.fixture(scope="module")
def cube_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("cube")
    return file_source.export_cube(SOURCE, out), out


def test_corrupt_chunk_reread_recovers_bitwise(clean, cube_dir):
    """A torn first read of one chunk is detected by the manifest sha256
    and healed by the automatic re-read — no unit retry even needed, and
    the run is bitwise the fault-free one."""
    file_spec, _ = cube_dir
    spec = make_spec(source=file_spec, execution=ExecSpec(**FAST_RETRY))
    inj = FaultInjector(FaultPlan(rules=(
        FaultRule("corrupt", slice_i=0, line_start=0, times=1),
    )))
    sess = PDFSession(spec, fault_injector=inj)
    results = sess.run_all([0, 1, 2])
    for s in (0, 1, 2):
        assert_bitwise(results[s], clean[s], f"slice{s}/")
    assert inj.events["corrupt"] == 1
    assert sess.report().quarantined_units == 0


def test_corrupt_rules_require_file_source():
    inj = FaultInjector(FaultPlan(rules=(FaultRule("corrupt"),)))
    with pytest.raises(ValueError, match="file-backed source"):
        inj.wrap_source(build_source(SOURCE))


def test_persistent_corruption_raises_with_path_and_attempts(tmp_path):
    spec = file_source.export_cube(SOURCE, tmp_path / "cube2")
    src = file_source.FileCubeSource(spec.path)
    chunk = tmp_path / "cube2" / src.manifest["chunks"][0]["file"]
    arr = np.load(chunk)
    arr[0, 0, 0] += 1.0
    np.save(chunk, arr)
    with pytest.raises(ValueError, match="corrupt after 2 read attempts"):
        src.verify()
    with pytest.raises(ValueError, match=str(chunk)):
        src.verify()


# -- cache lock degradation ----------------------------------------------------


def _tiny_result(spec_hash="deadbeef", slice_i=0, n=8):
    return SliceResult(
        np.zeros(n, np.int32), np.zeros((n, 3), np.float32),
        np.zeros(n, np.float32), np.zeros(n, np.float32),
        np.zeros(n, np.float32), np.zeros(n, np.float32),
        np.zeros(n, np.float32), 0.0, [],
        slice_i=slice_i, spec_hash=spec_hash)


def test_cache_store_lock_contention_degrades_to_skip(tmp_path):
    cache = ResultCache(tmp_path, lock_timeout_s=0.05)
    result = _tiny_result()
    entry_dir = tmp_path / "deadbeef"
    entry_dir.mkdir()
    (entry_dir / ".lock").write_text("12345")  # held by "another process"
    t0 = time.monotonic()
    with pytest.warns(UserWarning, match="locked by another process"):
        cache.store(result)
    assert time.monotonic() - t0 < 5  # bounded: degraded, never a hang
    assert cache.lock_misses == 1
    assert not cache.path("deadbeef", 0).exists()
    # lock released -> the next store lands normally
    (entry_dir / ".lock").unlink()
    cache.store(result)
    assert cache.lookup("deadbeef", 0) is not None
    assert not (entry_dir / ".lock").exists()  # released after the store


def test_cache_stale_lock_is_broken(tmp_path):
    cache = ResultCache(tmp_path, lock_timeout_s=0.5)
    entry_dir = tmp_path / "deadbeef"
    entry_dir.mkdir()
    lock = entry_dir / ".lock"
    lock.write_text("999999")
    old = time.time() - 3600  # holder died an hour ago
    os.utime(lock, (old, old))
    cache.store(_tiny_result())  # breaks the stale lock, no warning
    assert cache.lock_misses == 0
    assert cache.lookup("deadbeef", 0) is not None


def test_injected_cache_faults_degrade_to_miss(tmp_path, clean):
    """cache_error faults ride the cache's existing OSError degradation:
    a failed lookup is a warned miss (slice recomputes), a failed store a
    warned skip — results stay bitwise-correct throughout."""
    spec = make_spec(execution=ExecSpec(
        cache_dir=str(tmp_path / "cache"), **FAST_RETRY))
    inj = FaultInjector(FaultPlan(rules=(
        FaultRule("cache_error", slice_i=0, times=10_000),
    )))
    sess = PDFSession(spec, fault_injector=inj)
    with pytest.warns(UserWarning, match="cache store failed"):
        results = sess.run_all([0, 1])
    assert_bitwise(results[0], clean[0], "slice0/")
    assert_bitwise(results[1], clean[1], "slice1/")
    assert sess.cache.lookup(sess.spec_hash, 1) is not None  # untargeted
    assert inj.events["cache_error"] > 0


# -- the server under faults ---------------------------------------------------


class _FlakyOnce:
    """Fails each window's FIRST load with a transient error."""

    def __init__(self, inner):
        self.inner = inner
        self.geometry = inner.geometry
        self._seen = set()
        self._lock = threading.Lock()

    def load_window(self, w):
        key = (w.slice_i, w.line_start)
        with self._lock:
            fresh = key not in self._seen
            self._seen.add(key)
        if fresh:
            raise InjectedFault(f"flaky first read of {key}")
        return self.inner.load_window(w)

    def __getattr__(self, name):
        return getattr(self.inner, name)


class _DeadSlice:
    """Every load of one slice fails transiently, forever."""

    def __init__(self, inner, dead_slice):
        self.inner = inner
        self.geometry = inner.geometry
        self.dead = dead_slice

    def load_window(self, w):
        if w.slice_i == self.dead:
            raise InjectedFault(f"slice {self.dead} unreachable")
        return self.inner.load_window(w)

    def __getattr__(self, name):
        return getattr(self.inner, name)


class _Gated:
    """Blocks every load until the event is set (for queue-shape tests)."""

    def __init__(self, inner, event):
        self.inner = inner
        self.geometry = inner.geometry
        self.event = event

    def load_window(self, w):
        self.event.wait()
        return self.inner.load_window(w)

    def __getattr__(self, name):
        return getattr(self.inner, name)


def test_server_transient_retry_recovers_bitwise(clean):
    # the 5-line query spans 3 windows and each fails its first load, so
    # the chunk launch needs up to 3 retries before a fully clean attempt
    spec = make_spec(serve=ServeSpec(retry_transient=3, tick_seconds=0.0))
    src = _FlakyOnce(build_source(SOURCE))
    with PDFServer(spec, data_source=src) as srv:
        a = srv.query(WindowQuery(0, 2, 7), timeout=120)
        for name in RESULT_FIELDS:
            np.testing.assert_array_equal(
                getattr(a, name), getattr(clean[0], name)[2 * PPL:7 * PPL],
                err_msg=name)
        stats = srv.stats()
    assert stats.launch_retries > 0
    assert stats.windows_failed == 0


def test_server_exhausted_transient_fails_only_affected_requests(clean):
    """A window whose launches keep failing transiently fails ITS futures
    with the underlying error — the server is not poisoned and keeps
    serving other slices bitwise-correctly."""
    spec = make_spec(serve=ServeSpec(retry_transient=1, tick_seconds=0.0))
    src = _DeadSlice(build_source(SOURCE), dead_slice=1)
    with PDFServer(spec, data_source=src) as srv:
        with pytest.raises(InjectedFault, match="unreachable"):
            srv.query(PointQuery(1, 0, 0), timeout=120)
        # still alive: an untouched slice serves fine afterwards
        a = srv.query(PointQuery(0, 4, 2), timeout=120)
        np.testing.assert_array_equal(
            a.type_idx, clean[0].type_idx[4 * PPL + 2:4 * PPL + 3])
        stats = srv.stats()
        assert stats.windows_failed >= 1
        assert srv._failure is None
    # close() after a partial failure is clean — nothing was poisoned


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_server_fatal_error_still_poisons():
    class _Fatal:
        def __init__(self, inner):
            self.inner = inner
            self.geometry = inner.geometry

        def load_window(self, w):
            raise ValueError("fatal: bad geometry")

        def __getattr__(self, name):
            return getattr(self.inner, name)

    spec = make_spec(serve=ServeSpec(retry_transient=3, tick_seconds=0.0))
    srv = PDFServer(spec, data_source=_Fatal(build_source(SOURCE))).start()
    fut = srv.submit(PointQuery(0, 0, 0))
    with pytest.raises(ValueError, match="fatal"):
        fut.result(timeout=120)
    srv._thread.join(timeout=60)
    with pytest.raises(RuntimeError, match="server thread failed"):
        srv.close()
    srv.close()  # second close: silent no-op (safe from finally blocks)


def test_server_close_is_idempotent():
    srv = PDFServer(make_spec()).start()
    srv.close(timeout=60)
    srv.close(timeout=60)  # no raise, no hang
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(PointQuery(0, 0, 0))


def test_server_load_shedding():
    """With the queue at max_queue_depth, submit sheds immediately with
    ServerOverloadedError; admitted requests still complete once the
    backlog drains."""
    gate = threading.Event()
    spec = make_spec(serve=ServeSpec(max_queue_depth=2, tick_seconds=0.0))
    src = _Gated(build_source(SOURCE), gate)
    with PDFServer(spec, data_source=src) as srv:
        first = srv.submit(PointQuery(0, 0, 0))  # drained, blocks on gate
        time.sleep(0.1)
        queued = [srv.submit(PointQuery(0, 3, 1)),
                  srv.submit(PointQuery(0, 6, 2))]  # depth now 2
        with pytest.raises(ServerOverloadedError, match="shed"):
            srv.submit(PointQuery(0, 9, 3))
        gate.set()
        for f in [first] + queued:
            assert f.result(timeout=120) is not None
        assert srv.stats().shed_requests == 1


def test_server_request_deadline_expires_queued_work():
    """A request that waited in the queue past serve.request_deadline_s
    fails with TimeoutError before any compute is spent on it."""
    gate = threading.Event()
    spec = make_spec(serve=ServeSpec(request_deadline_s=0.1, tick_seconds=0.0))
    src = _Gated(build_source(SOURCE), gate)
    with PDFServer(spec, data_source=src) as srv:
        first = srv.submit(PointQuery(0, 0, 0))  # in flight, blocks on gate
        time.sleep(0.05)
        stale = srv.submit(PointQuery(1, 0, 0))  # sits queued past deadline
        time.sleep(0.3)
        gate.set()
        assert first.result(timeout=120) is not None  # admitted before block
        with pytest.raises(TimeoutError, match="expired"):
            stale.result(timeout=120)
        assert srv.stats().deadline_expired == 1
