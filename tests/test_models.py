"""Model-family behaviour: decode==forward oracles, learnability, SSD math."""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, BlockDef
from repro.models import encdec as ED
from repro.models import ssm as S
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def tiny(**kw):
    base = dict(
        name="tiny", family="dense", num_layers=2, d_model=64, q_heads=4,
        kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none",
    )
    base.update(kw)
    return ArchConfig(**base)


def _decode_matches_forward(cfg, extras=None, steps=3):
    p = T.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    lg, cache = T.prefill(p, toks, cfg, extras, max_len=16 + steps + 1)
    full = T.forward(p, toks, cfg, extras)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full[:, -1]), rtol=1e-4, atol=1e-4
    )
    seq = toks
    for i in range(steps):
        nxt = jnp.argmax(lg, -1).astype(jnp.int32)
        lg, cache = T.decode_step(p, nxt, cache, 16 + i, cfg, extras)
        seq = jnp.concatenate([seq, nxt[:, None]], 1)
        oracle = T.forward(p, jnp.concatenate([seq, nxt[:, None]], 1)[:, :-1], cfg, extras)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(oracle[:, -1]), rtol=1e-3, atol=1e-3
        )


def test_dense_gqa_decode_oracle():
    _decode_matches_forward(tiny())


def test_sliding_window_decode_oracle():
    _decode_matches_forward(tiny(pattern=(BlockDef(window=8),), num_layers=2), steps=12)


def test_moe_decode_oracle():
    cfg = tiny(
        family="moe", pattern=(BlockDef(ffn="moe"),),
        num_experts=8, moe_top_k=2, num_layers=2,
    )
    _decode_matches_forward(cfg)


def test_ssm_decode_oracle():
    cfg = tiny(
        family="ssm", pattern=(BlockDef(mixer="ssm", ffn="none"),),
        ssm_state=8, ssm_head_dim=16, ssm_chunk=8, num_layers=2,
    )
    _decode_matches_forward(cfg)


def test_hybrid_decode_oracle():
    cfg = tiny(
        family="hybrid", pattern=(BlockDef(mixer="hybrid", window=8),),
        ssm_state=8, ssm_head_dim=16, ssm_chunk=8, num_layers=2,
    )
    _decode_matches_forward(cfg, steps=12)


def test_vlm_decode_oracle():
    cfg = tiny(
        family="vlm", num_layers=3,
        pattern=(BlockDef(), BlockDef(), BlockDef(mixer="cross_attn")),
        num_patches=12,
    )
    mem = jax.random.normal(KEY, (2, 12, cfg.d_model))
    _decode_matches_forward(cfg, extras={"memory": mem})


def test_encdec_decode_oracle():
    cfg = tiny(family="encdec", enc_layers=2, dec_layers=2)
    p = ED.init_params(cfg, KEY)
    frames = jax.random.normal(KEY, (2, 10, cfg.d_model))
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab)
    lg, cache = ED.prefill(p, frames, toks, cfg, max_len=16)
    full = ED.forward(p, frames, toks, cfg)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]), rtol=1e-4, atol=1e-4)
    nxt = jnp.argmax(lg, -1).astype(jnp.int32)
    lg2, cache = ED.decode_step(p, nxt, cache, 12, cfg)
    oracle = ED.forward(p, frames, jnp.concatenate([toks, nxt[:, None]], 1), cfg)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(oracle[:, -1]), rtol=1e-3, atol=1e-3)


def test_ssd_chunked_equals_sequential():
    cfg = types.SimpleNamespace(
        d_model=32, ssm_expand=2, ssm_head_dim=16, ssm_state=8, ssm_groups=1,
        ssm_conv=4, ssm_chunk=8, param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
    p = S.init_ssd(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32)) * 0.5
    y, (state, _) = S.ssd(p, x, cfg, return_final_state=True)
    cache = S.init_ssd_cache(cfg, 2, jnp.float32)
    ys = []
    for t in range(24):
        yt, cache = S.ssd_decode(p, x[:, t : t + 1], cache, cfg)
        ys.append(yt)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.concatenate(ys, 1)), atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(state), np.asarray(cache["state"]), atol=1e-4
    )


def test_ssd_nonmultiple_chunk_padding():
    cfg = types.SimpleNamespace(
        d_model=16, ssm_expand=2, ssm_head_dim=8, ssm_state=4, ssm_groups=1,
        ssm_conv=4, ssm_chunk=8, param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
    p = S.init_ssd(KEY, cfg)
    x = jax.random.normal(KEY, (1, 13, 16))
    y13 = S.ssd(p, x, cfg)
    y16 = S.ssd(p, jnp.pad(x, ((0, 0), (0, 3), (0, 0))), cfg)[:, :13]
    assert y13.shape == (1, 13, 16)
    np.testing.assert_allclose(np.asarray(y13), np.asarray(y16), atol=1e-4)


def test_tiny_model_learns():
    """A few Adam steps on a repeated sequence should cut the loss — the
    end-to-end learnability check."""
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = tiny(vocab=32)
    p = T.init_params(cfg, KEY)
    opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    opt = adamw_init(p, opt_cfg)
    toks = jnp.tile(jnp.arange(16, dtype=jnp.int32)[None], (4, 1)) % 32
    targets = jnp.roll(toks, -1, axis=1)

    @jax.jit
    def step(p, opt):
        l, g = jax.value_and_grad(lambda q: T.loss_fn(q, toks, targets, cfg))(p)
        p, opt, _ = adamw_update(g, opt, p, opt_cfg)
        return p, opt, l

    first = None
    for i in range(30):
        p, opt, l = step(p, opt)
        first = first if first is not None else float(l)
    assert float(l) < first * 0.7, (first, float(l))


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and balanced routing, most tokens survive;
    the layer must stay finite even when some drop."""
    from repro.models import layers as L

    cfg = tiny(num_experts=4, moe_top_k=2)
    pm = L.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    y = L.moe(pm, x, cfg, capacity_factor=1.0)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    aux = L.moe_aux_loss(pm, x, cfg)
    assert bool(jnp.isfinite(aux)) and float(aux) >= 1.0 - 1e-3  # >= 1 at balance


def test_rope_position_shift_invariance():
    """RoPE: scores depend only on relative positions."""
    from repro.models.layers import rope

    x = jax.random.normal(KEY, (1, 4, 2, 16))
    p0 = jnp.arange(4)[None]
    r0 = rope(x, p0, 10_000.0)
    r7 = rope(x, p0 + 7, 10_000.0)
    s0 = jnp.einsum("bshd,bthd->bhst", r0, r0)
    s7 = jnp.einsum("bshd,bthd->bhst", r7, r7)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s7), atol=1e-4)
