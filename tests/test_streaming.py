"""Streaming ingestion (DESIGN.md §16): append-able cubes, merge-able
moments, chunk-granular incremental recompute.

The tier-1 acceptance invariant lives here: after an append, an
incremental run recomputes ONLY the slices whose chunks changed — every
untouched slice is adopted in the result cache and served bitwise without
building a single executor. ``update_mode="strict"`` recomputes changed
slices bitwise-identical to a from-scratch run on the appended cube; the
default ``"merge"`` keeps histograms bitwise-exact and moments within the
pinned ``MERGE_ULP_BUDGET``, recording that tolerance in the watermark.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.api import (
    ComputeSpec,
    ExecSpec,
    PDFSession,
    PipelineSpec,
    ResultCache,
    SourceSpec,
    StreamSpec,
)
from repro.core import regions
from repro.core.executor import RESULT_FIELDS
from repro.data.file_source import (
    FileCubeSource,
    chunk_diff,
    export_cube,
    manifest_version,
    read_manifest,
    slice_chunk_shas,
)
from repro.streaming import (
    MERGE_ULP_BUDGET,
    append_realizations,
    empty_suffstats,
    merge_counts,
    merge_counts_jnp,
    merge_suffstats,
    moments_from_suffstats,
    suffstats_from_moments,
    suffstats_from_values,
    ulp_diff,
)
from repro.streaming.stats import load_stats

SIM = SourceSpec(num_slices=3, lines_per_slice=4, points_per_line=6,
                 observations=48)


def make_cube(tmp_path, name="cube"):
    return export_cube(SIM, tmp_path / name, lines_per_chunk=2)


def make_spec(file_src, tmp_path, tag="", **stream_kw):
    stream_kw.setdefault("persist_stats", True)
    return PipelineSpec(
        source=file_src,
        compute=ComputeSpec(window_lines=2, num_bins=16),
        execution=ExecSpec(cache_dir=str(tmp_path / f"cache{tag}"),
                           out_dir=str(tmp_path / f"out{tag}")),
        stream=StreamSpec(**stream_kw),
    )


def in_range_append(cube_path, slice_i, k=5):
    """Per-point data strictly inside each point's existing [vmin, vmax]
    (the midpoint, tiled k deep) — an append that cannot move the Eq.-5
    edges, so the merge path's edge precondition holds by construction."""
    src = FileCubeSource(cube_path)
    g = src.geometry
    w = regions.Window(slice_i, 0, g.lines_per_slice)
    vals = src.load_window(w)  # (points_per_slice, n_obs)
    mid = (vals.min(axis=1) + vals.max(axis=1)) / 2.0
    block = np.repeat(mid[:, None], k, axis=1).astype(np.float32)
    return block.reshape(g.lines_per_slice, g.points_per_line, k)


def assert_fields_equal(a, b):
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), err_msg=f)
    assert a.avg_error == b.avg_error


# -- merge math (deterministic unit tests; property tests with hypothesis
#    live in test_streaming_properties.py) ------------------------------------


def rand_parts(shape=(7,), counts=(12, 5, 9), seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return [rng.normal(3.0, scale, shape + (k,)).astype(np.float32)
            for k in counts]


def test_empty_is_merge_identity():
    (a,) = rand_parts(counts=(8,))
    s = suffstats_from_values(a)
    for merged in (merge_suffstats(empty_suffstats(s.mean.shape), s),
                   merge_suffstats(s, empty_suffstats(s.mean.shape))):
        for f_m, f_s in zip(merged, s):
            np.testing.assert_array_equal(f_m, f_s)


def test_merge_matches_from_scratch_within_budget():
    parts = rand_parts()
    merged = suffstats_from_values(parts[0])
    for p in parts[1:]:
        merged = merge_suffstats(merged, suffstats_from_values(p))
    direct = suffstats_from_values(np.concatenate(parts, axis=-1))
    assert merged.n == direct.n
    np.testing.assert_array_equal(merged.vmin, direct.vmin)  # min/max exact
    np.testing.assert_array_equal(merged.vmax, direct.vmax)
    m_m = moments_from_suffstats(merged)
    m_d = moments_from_suffstats(direct)
    for name in ("mean", "var", "skew", "kurt"):
        d = ulp_diff(getattr(m_m, name), getattr(m_d, name)).max()
        assert d <= MERGE_ULP_BUDGET, f"{name}: {d} ulps"


def test_merge_associativity_and_permutation():
    a, b, c = (suffstats_from_values(p) for p in rand_parts(seed=3))
    left = merge_suffstats(merge_suffstats(a, b), c)
    right = merge_suffstats(a, merge_suffstats(b, c))
    swapped = merge_suffstats(c, merge_suffstats(b, a))
    base = moments_from_suffstats(left)
    for other in (right, swapped):
        mo = moments_from_suffstats(other)
        for name in ("mean", "var", "skew", "kurt"):
            d = ulp_diff(getattr(base, name), getattr(mo, name)).max()
            assert d <= MERGE_ULP_BUDGET, f"{name}: {d} ulps"


def test_degenerate_constant_partition_merges_finite():
    const = np.full((4, 10), 2.5, np.float32)
    more = np.full((4, 6), 2.5, np.float32)
    merged = merge_suffstats(suffstats_from_values(const),
                             suffstats_from_values(more))
    m = moments_from_suffstats(merged)
    for f in m:
        assert np.isfinite(np.asarray(f)).all()
    np.testing.assert_allclose(np.asarray(m.mean), 2.5)
    np.testing.assert_allclose(np.asarray(m.var), 0.0)


def test_suffstats_from_moments_roundtrip():
    (a,) = rand_parts(counts=(40,), seed=7)
    from repro.core.distributions import moments_from_values

    m = moments_from_values(a)
    s = suffstats_from_moments(m, a.shape[-1])
    back = moments_from_suffstats(s)
    for name in ("mean", "var", "skew", "kurt", "vmin", "vmax"):
        d = ulp_diff(getattr(back, name), np.asarray(getattr(m, name))).max()
        assert d <= MERGE_ULP_BUDGET, f"{name}: {d} ulps"


def test_histogram_merge_is_exact_integer_addition():
    rng = np.random.default_rng(5)
    a = rng.integers(0, 1000, (6, 16)).astype(np.float32)
    b = rng.integers(0, 1000, (6, 16)).astype(np.float32)
    np.testing.assert_array_equal(merge_counts(a, b), a + b)
    np.testing.assert_array_equal(np.asarray(merge_counts_jnp(a, b)), a + b)
    with pytest.raises(ValueError, match="integral"):
        merge_counts(a + 0.5, b)


def test_split_histogram_bitwise_equals_one_pass():
    """Eq.-5 counts over FIXED edges: binning two partitions separately and
    adding is bitwise-equal to binning the concatenation — the exactness
    the merge path's bitwise-histogram contract rests on."""
    import jax.numpy as jnp

    from repro.core import pdf_error as pe

    rng = np.random.default_rng(11)
    parts = [rng.uniform(0.0, 10.0, (5, k)).astype(np.float32)
             for k in (30, 17, 4)]
    allv = np.concatenate(parts, axis=-1)
    vmin = jnp.asarray(allv.min(axis=1))
    vmax = jnp.asarray(allv.max(axis=1))

    def counts(v):
        return np.rint(np.asarray(
            pe.histogram_scatter(jnp.asarray(v), vmin, vmax, 16)
        )).astype(np.int64)

    summed = counts(parts[0])
    for p in parts[1:]:
        summed = merge_counts(summed, counts(p))
    np.testing.assert_array_equal(summed, counts(allv))


def test_fit_backends_carry_merge_callables():
    from repro.core.fitting import get_fit_backend
    from repro.streaming import moments as sm

    ref = get_fit_backend("reference")
    assert ref.merge_stats is sm.merge_suffstats
    assert ref.merge_hist is sm.merge_counts
    for name in ("kernels", "fused"):
        b = get_fit_backend(name)
        assert b.merge_stats is sm.merge_suffstats_jnp
        assert b.merge_hist is sm.merge_counts_jnp


# -- append-able cube format ---------------------------------------------------


def test_append_bumps_version_and_old_version_still_opens(tmp_path):
    src_spec = make_cube(tmp_path)
    cube = src_spec.path
    before = FileCubeSource(cube)
    w = regions.Window(1, 0, 2)
    old_window = before.load_window(w)

    v2 = append_realizations(cube, {1: in_range_append(cube, 1, k=5)})
    assert v2 == 2
    assert manifest_version(cube) == 2

    now = FileCubeSource(cube)
    assert now.version == 2
    assert now.slice_observations(1) == SIM.observations + 5
    assert now.slice_observations(0) == SIM.observations
    # appended observations are readable, and exactly the appended bytes
    appended = now.load_window_obs(w, SIM.observations, SIM.observations + 5)
    expected = in_range_append(cube, 1, k=5)  # deterministic midpoints
    np.testing.assert_array_equal(
        appended, expected[0:2].reshape(-1, 5))

    # the archived version opens and reads bit-identically to before
    old = FileCubeSource(cube, version=1)
    assert old.version == 1
    assert old.slice_observations(1) == SIM.observations
    np.testing.assert_array_equal(old.load_window(w), old_window)


def test_chunk_diff_reports_exactly_the_appended_slices(tmp_path):
    cube = make_cube(tmp_path).path
    m1 = read_manifest(cube)
    append_realizations(cube, {2: in_range_append(cube, 2)})
    diff = chunk_diff(cube, 1)
    assert diff["changed_slices"] == [2]
    assert all(c["slice"] == 2 for c in diff["new_chunks"])
    # untouched slices keep their chunk fingerprint bit-for-bit
    m2 = read_manifest(cube)
    for s in (0, 1):
        assert slice_chunk_shas(m1, s) == slice_chunk_shas(m2, s)
    assert slice_chunk_shas(m1, 2) != slice_chunk_shas(m2, 2)


def test_append_validates_inputs(tmp_path):
    cube = make_cube(tmp_path).path
    with pytest.raises(ValueError, match="empty"):
        append_realizations(cube, {})
    with pytest.raises(ValueError, match="outside"):
        append_realizations(cube, {99: in_range_append(cube, 0)})
    with pytest.raises(ValueError, match="shape"):
        append_realizations(cube, {0: np.zeros((2, 2, 3), np.float32)})
    assert manifest_version(cube) == 1  # failed appends commit nothing


def test_repeated_appends_stack_versions(tmp_path):
    cube = make_cube(tmp_path).path
    append_realizations(cube, {0: in_range_append(cube, 0, k=3)})
    append_realizations(cube, {0: in_range_append(cube, 0, k=2)})
    assert manifest_version(cube) == 3
    src = FileCubeSource(cube)
    assert src.slice_observations(0) == SIM.observations + 5
    # every archived version remains openable
    for v in (1, 2, 3):
        assert FileCubeSource(cube, version=v).version == v
    diff = chunk_diff(cube, 1, 3)
    assert diff["changed_slices"] == [0]


# -- the tier-1 e2e incremental invariant --------------------------------------


def test_incremental_run_recomputes_only_changed_slices(tmp_path):
    """The PR's acceptance invariant, merge mode: after an append to one
    slice, a second run adopts every untouched slice (served bitwise from
    the cache), merges the appended slice from its stats sidecars, and
    never builds an executor. Merged histograms are bitwise-equal to a
    from-scratch run on the appended cube; merged moments are within the
    pinned MERGE_ULP_BUDGET of it; the watermark records the tolerance."""
    file_src = make_cube(tmp_path)
    cube = file_src.path
    spec = make_spec(file_src, tmp_path)

    s1 = PDFSession(spec)
    first = s1.run_all()
    old_hash = s1.spec_hash
    rep1 = s1.report()
    assert rep1.cache_misses == 3 and rep1.cache_adopted == 0

    append_realizations(cube, {1: in_range_append(cube, 1)})

    s2 = PDFSession(spec)
    assert s2.spec_hash != old_hash  # the manifest sha keys the hash
    second = s2.run_all()
    rep2 = s2.report()
    # untouched slices 0/2 adopted then served as hits; slice 1 merged
    assert rep2.cache_adopted == 2
    assert rep2.cache_hits == 2
    assert rep2.slices_merged == 1
    assert rep2.cache_misses == 1  # slice 1 missed, then merged
    # zero executors: no window was recomputed anywhere
    assert not s2._executors
    assert rep2.windows == 0
    for s in (0, 2):
        assert second[s].cached
        assert_fields_equal(first[s], second[s])

    # reference: a from-scratch run on the appended cube
    fresh = PDFSession(make_spec(file_src, tmp_path, tag="_fresh"))
    full = fresh.run_all()
    merged, ref = second[1], full[1]
    np.testing.assert_array_equal(merged.mean == merged.mean,
                                  ref.mean == ref.mean)
    for name in ("mean", "std", "skew", "kurt"):
        d = ulp_diff(getattr(merged, name), getattr(ref, name)).max()
        assert d <= MERGE_ULP_BUDGET, f"{name}: {d} ulps"
    # merged sidecar histograms are bitwise-equal to the fresh run's
    g = s2.geometry
    for w in regions.iter_windows(g, 1, spec.compute.window_lines):
        a = load_stats(spec.execution.out_dir, 1, w.line_start)
        b = load_stats(fresh.spec.execution.out_dir, 1, w.line_start)
        np.testing.assert_array_equal(a["freq"], b["freq"])
        assert a["stats"].n == b["stats"].n == SIM.observations + 5

    # merge-mode watermark records the tolerance + provenance
    mark = json.loads(
        (tmp_path / "out" / "slice1_watermark.json").read_text())
    assert mark["spec_hash"] == s2.spec_hash
    assert mark["merge_ulp_budget"] == MERGE_ULP_BUDGET
    assert mark["merged_from"] == old_hash

    # merged results are path-dependent: they must NEVER enter the cache
    assert not ResultCache(spec.execution.cache_dir).path(
        s2.spec_hash, 1).exists()


def test_merge_survives_watermark_restamped_by_cache_hit(tmp_path):
    """Appends landing on DIFFERENT slices across versions: when slice 2 is
    adopted at v2, the cache-hit persist re-stamps its watermark at the v2
    hash but leaves its stats sidecars with the v1 stamp (a hit carries no
    SuffStats to rewrite them with). An append to slice 2 at v3 must still
    merge — the sidecar is accepted under the spec's manifest-version
    lineage, not just the watermark's own hash."""
    file_src = make_cube(tmp_path)
    cube = file_src.path
    spec = make_spec(file_src, tmp_path)
    PDFSession(spec).run_all()
    append_realizations(cube, {1: in_range_append(cube, 1)})
    PDFSession(spec).run_all()  # slice 2 adopted: watermark re-stamped at v2
    append_realizations(cube, {2: in_range_append(cube, 2)})

    s3 = PDFSession(spec)
    third = s3.run_all([2])
    rep = s3.report()
    assert rep.slices_merged == 1 and rep.windows == 0
    assert not s3._executors
    # numerically the same merge contract as a one-version-back merge
    fresh = PDFSession(make_spec(file_src, tmp_path, tag="_fresh"))
    ref = fresh.run_all([2])[2]
    for name in ("mean", "std", "skew", "kurt"):
        d = ulp_diff(getattr(third[2], name), getattr(ref, name)).max()
        assert d <= MERGE_ULP_BUDGET, f"{name}: {d} ulps"


def test_strict_mode_recompute_is_bitwise(tmp_path):
    """update_mode="strict": the changed slice goes back through the normal
    executor — bitwise-identical to a from-scratch run on the appended
    cube, and stored in the cache like any computed slice."""
    file_src = make_cube(tmp_path)
    cube = file_src.path
    spec = make_spec(file_src, tmp_path, update_mode="strict")
    PDFSession(spec).run_all()
    append_realizations(cube, {1: in_range_append(cube, 1)})

    s2 = PDFSession(spec)
    second = s2.run_all()
    rep2 = s2.report()
    assert rep2.cache_adopted == 2 and rep2.slices_merged == 0
    assert rep2.windows == 2  # exactly slice 1's windows recomputed

    fresh = PDFSession(make_spec(file_src, tmp_path, tag="_fresh",
                                 update_mode="strict"))
    full = fresh.run_all()
    assert_fields_equal(second[1], full[1])
    assert second[1].spec_hash == full[1].spec_hash
    # strict results are bitwise-reproducible, so they DO enter the cache
    assert ResultCache(spec.execution.cache_dir).path(
        s2.spec_hash, 1).exists()


def test_out_of_range_append_falls_back_to_full_recompute(tmp_path):
    """An append whose values move a point's (vmin, vmax) makes the old
    Eq.-5 counts unusable: the merge refuses and the slice recomputes in
    full — correctness never depends on the merge succeeding."""
    file_src = make_cube(tmp_path)
    cube = file_src.path
    spec = make_spec(file_src, tmp_path)
    PDFSession(spec).run_all()
    rng = np.random.default_rng(9)
    wild = rng.normal(100.0, 50.0,
                      (SIM.lines_per_slice, SIM.points_per_line, 5))
    append_realizations(cube, {1: wild.astype(np.float32)})

    s2 = PDFSession(spec)
    second = s2.run_all()
    rep2 = s2.report()
    assert rep2.cache_adopted == 2 and rep2.slices_merged == 0
    assert rep2.windows == 2  # full recompute of the changed slice

    fresh = PDFSession(make_spec(file_src, tmp_path, tag="_fresh"))
    assert_fields_equal(second[1], fresh.run_all()[1])


def test_incremental_disabled_skips_adoption(tmp_path):
    file_src = make_cube(tmp_path)
    cube = file_src.path
    spec = make_spec(file_src, tmp_path, incremental=False,
                     update_mode="strict")
    PDFSession(spec).run_all()
    append_realizations(cube, {1: in_range_append(cube, 1)})
    s2 = PDFSession(spec)
    s2.run_all()
    rep = s2.report()
    assert rep.cache_adopted == 0
    assert rep.cache_misses == 3  # everything recomputes


def test_refresh_source_follows_appends(tmp_path):
    """session.refresh_source() (the --watch / serve-invalidate hook)
    re-opens the cube at the new version and re-hashes the spec."""
    file_src = make_cube(tmp_path)
    cube = file_src.path
    spec = make_spec(file_src, tmp_path)
    s = PDFSession(spec)
    h1 = s.spec_hash
    s.run_all()
    append_realizations(cube, {0: in_range_append(cube, 0)})
    h2 = s.refresh_source()
    assert h2 != h1 and s.spec_hash == h2
    assert s._file_source().version == 2
    assert not s._executors  # old executors pinned the old version
    res = s.run_all()
    rep = s.report()
    assert rep.cache_adopted == 2 and rep.slices_merged == 1
    assert res[0].spec_hash == h2


# -- StreamSpec / spec versioning ----------------------------------------------


def test_stream_spec_validates():
    with pytest.raises(ValueError, match="update_mode"):
        StreamSpec(update_mode="yolo")
    with pytest.raises(ValueError, match="poll_interval_s"):
        StreamSpec(poll_interval_s=0.0)
    with pytest.raises(ValueError, match="max_updates"):
        StreamSpec(max_updates=0)


def test_stream_section_is_not_hashed():
    base = PipelineSpec()
    varied = dataclasses.replace(
        base, stream=StreamSpec(update_mode="strict", persist_stats=True,
                                incremental=False, poll_interval_s=9.0,
                                max_updates=3))
    assert varied.content_hash() == base.content_hash()


def test_spec_roundtrip_carries_stream_section():
    spec = PipelineSpec(stream=StreamSpec(update_mode="strict",
                                          poll_interval_s=2.5))
    back = PipelineSpec.from_json(spec.to_json())
    assert back == spec
    assert back.stream.update_mode == "strict"


def test_previous_spec_version_loads_with_stream_defaults():
    """Forward-compat shim: a version-2 JSON (pre-stream, pre-placement)
    loads with a warning and the missing sections at their defaults."""
    from repro.api.spec import PlacementSpec

    spec = PipelineSpec()
    d = json.loads(spec.to_json())
    d["version"] = 2
    del d["stream"]
    del d["execution"]["placement"]
    del d["execution"]["compile_cache_dir"]
    with pytest.warns(UserWarning, match="upgrading spec from version 2"):
        back = PipelineSpec.from_json(json.dumps(d))
    assert back.stream == StreamSpec()
    assert back.execution.placement == PlacementSpec()
    assert back.content_hash() == spec.content_hash()


def test_version_3_spec_loads_with_placement_defaults():
    """A version-3 JSON (has stream, pre-placement) upgrades in place."""
    from repro.api.spec import PlacementSpec

    spec = PipelineSpec()
    d = json.loads(spec.to_json())
    d["version"] = 3
    del d["execution"]["placement"]
    del d["execution"]["compile_cache_dir"]
    with pytest.warns(UserWarning, match="upgrading spec from version 3"):
        back = PipelineSpec.from_json(json.dumps(d))
    assert back.execution.placement == PlacementSpec()
    assert back.execution.compile_cache_dir is None
    assert back.content_hash() == spec.content_hash()
