"""The distributed correctness harness (DESIGN.md §17): real multi-process
cluster runs, verified bitwise against a serial reference.

Each test spawns ``launch/run_pdf`` worker subprocesses — one python process
per cluster seat, each seeing exactly 1 CPU device — sharing one
``jax.distributed`` coordinator and one ``--out-dir``, then asserts the
persisted window arrays are bitwise-identical to the single-process run
(``runtime.cluster.verify_outputs``). The cold-start tests drive the
persistent compilation cache the same way: only a subprocess relaunch
observes real cold-start cost (in-process, the executor's jitted-fn cache
would make the assertion vacuous).

Tests that need a ``jax.distributed`` world skip cleanly when the platform
cannot run a coordinator (sandboxes without localhost gRPC)."""

import os
import re
import socket
import subprocess
import sys
from pathlib import Path

import pytest

from repro.runtime.cluster import verify_outputs

REPO = Path(__file__).resolve().parent.parent

# The shared seismic spec every cluster test runs: 4 slices so a 4-process
# run still deals one slice per seat, small enough that a worker's life is
# dominated by startup, not compute.
SPEC_FLAGS = [
    "--num-slices", "4", "--lines", "6", "--ppl", "10", "--obs", "80",
    "--method", "grouping", "--window-lines", "3", "--num-bins", "20",
    "--slices", "0", "1", "2", "3",
]

# stderr fingerprints of "this platform cannot run a distributed
# coordinator" — anything else is a real failure and must fail the test
_COORD_FAIL = ("DEADLINE_EXCEEDED", "UNAVAILABLE", "failed to connect",
               "Barrier timed out", "coordination service")


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _worker_env() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["JAX_NUM_CPU_DEVICES"] = "1"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"
    return env


def _run_serial(out_dir, extra=()) -> str:
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.run_pdf", *SPEC_FLAGS,
         "--out-dir", str(out_dir), *extra],
        env=_worker_env(), capture_output=True, text=True, timeout=600)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    return p.stdout + p.stderr


def _run_cluster(nprocs, out_dir, extra=()) -> list[str]:
    """Spawn one run_pdf worker per seat against a shared out_dir; returns
    each worker's combined output. Skips the calling test when the failure
    is the platform refusing the coordinator, fails it otherwise."""
    coord = f"127.0.0.1:{_free_port()}"
    procs = [
        subprocess.Popen(
            [sys.executable, "-m", "repro.launch.run_pdf", *SPEC_FLAGS,
             "--out-dir", str(out_dir),
             "--num-processes", str(nprocs), "--process-id", str(i),
             "--coordinator", coord, *extra],
            env=_worker_env(), stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True)
        for i in range(nprocs)
    ]
    outs = []
    for p in procs:
        try:
            out, _ = p.communicate(timeout=600)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise
        outs.append((p.returncode, out))
    for rc, out in outs:
        if rc != 0:
            if nprocs > 1 and any(m in out for m in _COORD_FAIL):
                pytest.skip("platform cannot run a jax.distributed "
                            "coordinator here")
            raise AssertionError(f"worker failed (rc={rc}):\n{out}")
    return [out for _, out in outs]


@pytest.fixture(scope="module")
def serial_ref(tmp_path_factory):
    """The single-process reference out_dir every cluster run is compared
    against (plus its shared compile cache, so later launches skip XLA)."""
    base = tmp_path_factory.mktemp("serial")
    out, cache = base / "out", base / "compile-cache"
    log = _run_serial(out, ["--compile-cache-dir", str(cache)])
    assert "[total]" in log
    return out, cache


@pytest.mark.parametrize("nprocs", [1, 2, 4])
def test_cluster_matches_serial_reference(nprocs, serial_ref, tmp_path):
    """The acceptance invariant: N worker processes sharing one out_dir
    persist exactly the windows the serial run does, bitwise."""
    ref, cache = serial_ref
    out = tmp_path / f"out{nprocs}"
    logs = _run_cluster(nprocs, out, ["--compile-cache-dir", str(cache)])
    if nprocs > 1:
        assert any("[cluster] jax.distributed process" in l for l in logs)
    windows, arrays = verify_outputs(ref, out)
    assert windows == 8  # 4 slices x 2 windows (6 lines / 3 per window)
    assert arrays > 0


def test_worker_requires_seat_and_out_dir():
    """Placement misuse fails loudly at spec time: multi-process without a
    process id, and without a shared out_dir, both refuse to launch."""
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.run_pdf", *SPEC_FLAGS,
         "--num-processes", "2", "--out-dir", "/tmp/unused-seatless"],
        env=_worker_env(), capture_output=True, text=True, timeout=120)
    assert p.returncode != 0
    assert "process_id" in p.stderr or "process-id" in p.stderr
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.run_pdf", *SPEC_FLAGS,
         "--num-processes", "2", "--process-id", "0"],
        env=_worker_env(), capture_output=True, text=True, timeout=120)
    assert p.returncode != 0
    assert "out_dir" in p.stderr or "out-dir" in p.stderr


# -- cold-start elimination (the persistent compilation cache) ------------------


def _new_compilations(log: str) -> int:
    m = re.search(r"new_compilations=(\d+)", log)
    assert m, f"no [compile] line in:\n{log}"
    return int(m.group(1))


@pytest.fixture(scope="module")
def warm_cache(tmp_path_factory):
    """Two identical launches sharing one --compile-cache-dir; returns the
    cache dir and both logs for the cold-start assertions."""
    base = tmp_path_factory.mktemp("coldstart")
    cache = base / "compile-cache"
    log1 = _run_serial(base / "run1", ["--compile-cache-dir", str(cache)])
    log2 = _run_serial(base / "run2", ["--compile-cache-dir", str(cache)])
    return base, cache, log1, log2


def test_second_launch_reports_zero_new_compilations(warm_cache):
    """The cold-start acceptance criterion: a relaunched identical spec
    serves every executable from the persistent cache — the [compile] line
    reports zero new compilations (= zero persistent-cache misses; backend
    compile *calls* still fire on hits, which is why the indicator is the
    miss count)."""
    base, cache, log1, log2 = warm_cache
    assert _new_compilations(log1) > 0  # the first launch really compiled
    assert _new_compilations(log2) == 0
    assert re.search(r"cache_hits=[1-9]", log2)
    # the cache is keyed under the spec hash, next to every other artifact
    spec_hash = re.search(r"hash=([0-9a-f]{16})", log2).group(1)
    assert (cache / spec_hash).is_dir()
    assert any((cache / spec_hash).iterdir())
    # and the warm run's persisted windows are the cold run's, bitwise
    verify_outputs(base / "run1", base / "run2")


def test_corrupt_cache_entry_is_warned_miss_not_crash(warm_cache):
    """Cache-dir corruption degrades, never aborts: garbage bytes in every
    cache entry turn the next launch's hits into warned misses — JAX
    recompiles and the run completes with intact results."""
    base, cache, _, _ = warm_cache
    corrupted = 0
    for f in cache.rglob("*"):
        if f.is_file():
            f.write_bytes(b"not an xla executable")
            corrupted += 1
    assert corrupted > 0
    log3 = _run_serial(base / "run3", ["--compile-cache-dir", str(cache)])
    assert "[total]" in log3  # the run completed
    assert ("compilation cache" in log3 and "rror" in log3) \
        or _new_compilations(log3) > 0, log3
    verify_outputs(base / "run1", base / "run3")
