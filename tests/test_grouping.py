"""Grouping invariants (host + device paths) — property-based."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional 'test' extra")
from hypothesis import given, settings, strategies as st

from repro.core import grouping as grp


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 200),
    distinct=st.integers(1, 20),
    seed=st.integers(0, 1000),
)
def test_host_grouping_invariants(n, distinct, seed):
    rng = np.random.default_rng(seed)
    pool = rng.integers(-1000, 1000, size=(distinct, 2))
    keys = pool[rng.integers(0, distinct, size=n)]
    g = grp.group_host(keys)
    # every point maps to a representative with an identical key
    np.testing.assert_array_equal(keys[g.rep_indices][g.inverse], keys)
    # group count == distinct keys actually present
    assert g.num_groups == len(np.unique(keys, axis=0))
    # representatives are themselves members of their group
    assert (g.inverse[g.rep_indices] == np.arange(g.num_groups)).all()


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 128),
    distinct=st.integers(1, 10),
    seed=st.integers(0, 100),
)
def test_device_grouping_matches_host(n, distinct, seed):
    rng = np.random.default_rng(seed)
    pool = rng.integers(-50, 50, size=(distinct, 2))
    keys = pool[rng.integers(0, distinct, size=n)]
    host = grp.group_host(keys)
    dev = grp.group_device(jnp.asarray(keys, jnp.int32))
    assert int(dev.num_groups) == host.num_groups
    rep = np.asarray(dev.rep_for_point)
    # device rep index: first occurrence (smallest original index) of the key
    np.testing.assert_array_equal(keys[rep], keys)
    for i in range(n):
        same = np.nonzero((keys == keys[i]).all(1))[0]
        assert rep[i] == same.min()


def test_quantize_keys_tolerance():
    mean = jnp.asarray([1.0, 1.0000004, 1.1])
    std = jnp.asarray([0.5, 0.5, 0.5])
    k_tight = np.asarray(grp.quantize_keys(mean, std, tol=1e-7))
    k_loose = np.asarray(grp.quantize_keys(mean, std, tol=1e-2))
    assert not (k_tight[0] == k_tight[1]).all() or True  # may or may not merge
    assert (k_loose[0] == k_loose[1]).all()  # within tolerance -> same group
    assert not (k_loose[0] == k_loose[2]).all()


def test_pad_representatives_bucket():
    reps = np.arange(5)
    padded = grp.pad_representatives(reps, bucket=8)
    assert len(padded) == 8
    np.testing.assert_array_equal(padded[:5], reps)


def test_scatter_group_results_roundtrip():
    rep_results = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    inverse = jnp.asarray([0, 1, 0, 0, 1])
    out = np.asarray(grp.scatter_group_results(rep_results, inverse))
    np.testing.assert_array_equal(out[0], [1, 2])
    np.testing.assert_array_equal(out[1], [3, 4])
    np.testing.assert_array_equal(out[3], [1, 2])
