"""Grouping invariants (host + device paths) — property-based."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional 'test' extra")
from hypothesis import given, settings, strategies as st

from repro.core import grouping as grp


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 200),
    distinct=st.integers(1, 20),
    seed=st.integers(0, 1000),
)
def test_host_grouping_invariants(n, distinct, seed):
    rng = np.random.default_rng(seed)
    pool = rng.integers(-1000, 1000, size=(distinct, 2))
    keys = pool[rng.integers(0, distinct, size=n)]
    g = grp.group_host(keys)
    # every point maps to a representative with an identical key
    np.testing.assert_array_equal(keys[g.rep_indices][g.inverse], keys)
    # group count == distinct keys actually present
    assert g.num_groups == len(np.unique(keys, axis=0))
    # representatives are themselves members of their group
    assert (g.inverse[g.rep_indices] == np.arange(g.num_groups)).all()


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 128),
    distinct=st.integers(1, 10),
    seed=st.integers(0, 100),
)
def test_device_grouping_matches_host(n, distinct, seed):
    rng = np.random.default_rng(seed)
    pool = rng.integers(-50, 50, size=(distinct, 2))
    keys = pool[rng.integers(0, distinct, size=n)]
    host = grp.group_host(keys)
    dev = grp.group_device(jnp.asarray(keys, jnp.int32))
    assert int(dev.num_groups) == host.num_groups
    rep = np.asarray(dev.rep_for_point)
    # device rep index: first occurrence (smallest original index) of the key
    np.testing.assert_array_equal(keys[rep], keys)
    for i in range(n):
        same = np.nonzero((keys == keys[i]).all(1))[0]
        assert rep[i] == same.min()


def test_quantize_keys_tolerance():
    mean = jnp.asarray([1.0, 1.0000004, 1.1])
    std = jnp.asarray([0.5, 0.5, 0.5])
    k_tight = np.asarray(grp.quantize_keys(mean, std, tol=1e-7))
    k_loose = np.asarray(grp.quantize_keys(mean, std, tol=1e-2))
    assert not (k_tight[0] == k_tight[1]).all() or True  # may or may not merge
    assert (k_loose[0] == k_loose[1]).all()  # within tolerance -> same group
    assert not (k_loose[0] == k_loose[2]).all()


# magnitudes spanning the regimes the old mod-2^31 fold got wrong: f32-grid
# aliasing at seismic scale (~3e3 / 1e-6 tol ~ 3e9 quotients) and the
# hash-like fold above int32 range (1e9 means).
_MAGNITUDES = [1e-3, 1.0, 3e3, 1e6, 1e9]


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 150),
    mag_i=st.integers(0, len(_MAGNITUDES) - 1),
    negate=st.booleans(),
    tol=st.sampled_from([1e-6, 1e-2, 3.7e-5, grp.DEFAULT_TOL]),
    dup=st.integers(1, 6),
    seed=st.integers(0, 1000),
)
def test_device_keys_bitexact_with_host(n, mag_i, negate, tol, dup, seed):
    """The tentpole invariant: quantize_keys_from_var (device hi/lo int32
    pairs) and quantize_keys_host (f64 int64) are the SAME function, so the
    host and device group partitions are identical — across seismic-scale
    magnitudes, negative means, std=0 degenerates and non-default tols."""
    rng = np.random.default_rng(seed)
    mag = _MAGNITUDES[mag_i] * (-1 if negate else 1)
    mean = rng.normal(mag, abs(mag) * 0.1 + 1e-3, n).astype(np.float32)
    var = np.abs(rng.normal(100, 30, n)).astype(np.float32)
    var[::3] = 0.0  # degenerate windows
    # duplicated rows: the partitions must agree on real groups, not only
    # on all-singleton windows
    reps = rng.integers(0, n, size=n * (dup - 1)) if dup > 1 else np.array([], int)
    mean = np.concatenate([mean, mean[reps]])
    var = np.concatenate([var, var[reps]])

    host_keys = grp.quantize_keys_host(mean, var, tol)
    dev_keys = np.asarray(grp.quantize_keys_from_var(mean, var, tol))

    # keys are bit-exact (hi/lo pairs reassemble the host int64 exactly)
    np.testing.assert_array_equal(grp.keys_to_int64(dev_keys), host_keys)

    # and so are the partitions: host np.unique vs device sort-dedup.
    # np.unique's return_index is the first occurrence, group_device's rep
    # is the smallest index with the key — rep_indices[inverse] is therefore
    # directly comparable to rep_for_point.
    host = grp.group_host(host_keys)
    dev = grp.group_device(jnp.asarray(dev_keys))
    assert int(dev.num_groups) == host.num_groups
    np.testing.assert_array_equal(
        host.rep_indices[host.inverse], np.asarray(dev.rep_for_point)
    )


def test_quantize_keys_jit_matches_eager():
    """The x64 lanes survive being traced into an x64-disabled jit (the
    executor / dry-run scenario): no constant canonicalization drift."""
    rng = np.random.default_rng(5)
    mean = rng.normal(3e3, 300, 64).astype(np.float32)
    var = np.abs(rng.normal(100, 30, 64)).astype(np.float32)
    eager = np.asarray(grp.quantize_keys_from_var(mean, var, 1e-6))
    jitted = np.asarray(
        jax.jit(lambda m, v: grp.quantize_keys_from_var(m, v, 1e-6))(mean, var)
    )
    np.testing.assert_array_equal(eager, jitted)


def test_compact_representatives_roundtrip():
    """gather_idx/point_slot are a device-side (rep_indices, inverse) pair."""
    keys = jnp.asarray([[1, 1], [2, 2], [1, 1], [3, 3], [2, 2]], jnp.int32)
    g = grp.group_device(keys)
    gather_idx, point_slot = jax.jit(
        grp.compact_representatives, static_argnums=(2,)
    )(g.rep_for_point, g.is_rep, 8)
    gather_idx, point_slot = np.asarray(gather_idx), np.asarray(point_slot)
    assert list(gather_idx[:3]) == [0, 1, 3]  # first-occurrence order
    np.testing.assert_array_equal(point_slot, [0, 1, 0, 2, 1])
    # scatter path: every point receives its representative's row
    np.testing.assert_array_equal(
        np.asarray(keys)[gather_idx[point_slot]], np.asarray(keys)
    )


def test_pad_representatives_bucket():
    reps = np.arange(5)
    padded = grp.pad_representatives(reps, bucket=8)
    assert len(padded) == 8
    np.testing.assert_array_equal(padded[:5], reps)


def test_scatter_group_results_roundtrip():
    rep_results = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    inverse = jnp.asarray([0, 1, 0, 0, 1])
    out = np.asarray(grp.scatter_group_results(rep_results, inverse))
    np.testing.assert_array_equal(out[0], [1, 2])
    np.testing.assert_array_equal(out[1], [3, 4])
    np.testing.assert_array_equal(out[3], [1, 2])
