"""Distribution fitters/CDFs: recovery, bounds, monotonicity (property)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional 'test' extra")
from hypothesis import given, settings, strategies as st

from repro.core import distributions as d
from repro.core import fitting

KEY = jax.random.PRNGKey(0)

RECOVERY_CASES = [
    ("normal", (3.0, 0.5, 0.0)),
    ("uniform", (1.0, 4.0, 0.0)),
    ("exponential", (2.0, 0.0, 0.0)),
    ("lognormal", (0.5, 0.4, 0.0)),
    ("gamma", (3.0, 2.0, 0.0)),
    ("logistic", (1.0, 0.7, 0.0)),
    ("weibull", (2.0, 1.5, 0.0)),
]


@pytest.mark.parametrize("tname,params", RECOVERY_CASES)
def test_fit_recovers_generating_type_4way(tname, params):
    """Algorithm 3 over a candidate set containing the generator picks it (or
    an equivalent fit with error within noise of the generator's)."""
    types = d.TYPES_10
    v = d.sample(tname, params, KEY, (8, 4000))
    m = d.moments_from_values(v)
    r = fitting.compute_pdf_and_error(v, m, types, 32)
    gen_idx = d.type_index(types, tname)
    err_best = np.asarray(r.error)
    # compute the generator type's own error for comparison
    params_all = d.fit_all(types, m)
    from repro.core import pdf_error as pe

    edges = pe.interval_edges(m.vmin, m.vmax, 32)
    masses = pe.cdf_masses(types, params_all, edges)
    freq = pe.histogram(v, m.vmin, m.vmax, 32)
    errs = np.asarray(pe.pdf_error_from_freq(freq, masses))
    gen_err = errs[:, gen_idx]
    # best error can only be <= generator error; and must be close to it
    assert (err_best <= gen_err + 1e-6).all()
    assert (err_best >= gen_err - 0.15).all(), "picked a wildly better fit?"


@pytest.mark.parametrize("tname", d.TYPES_10)
def test_cdf_bounds_and_monotonicity(tname):
    params = {
        "normal": (0.0, 1.0, 0.0), "uniform": (-1.0, 1.0, 0.0),
        "exponential": (1.5, 0.0, 0.0), "lognormal": (0.0, 0.5, 0.0),
        "cauchy": (0.0, 1.0, 0.0), "gamma": (2.0, 1.0, 0.0),
        "geometric": (0.3, 0.0, 0.0), "logistic": (0.0, 1.0, 0.0),
        "student_t": (0.0, 1.0, 8.0), "weibull": (1.5, 1.0, 0.0),
    }[tname]
    p = jnp.asarray(params)
    x = jnp.linspace(-5.0, 10.0, 201)
    c = np.asarray(d.cdf(tname, p, x))
    assert np.isfinite(c).all()
    assert (c >= -1e-6).all() and (c <= 1 + 1e-6).all()
    assert (np.diff(c) >= -1e-5).all(), "CDF must be nondecreasing"


@settings(max_examples=20, deadline=None)
@given(
    mean=st.floats(-100, 100),
    std=st.floats(0.01, 50),
    n=st.integers(20, 200),
)
def test_moments_match_numpy(mean, std, n):
    rng = np.random.default_rng(42)
    v = (mean + std * rng.standard_normal((3, n))).astype(np.float32)
    m = d.moments_from_values(jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(m.mean), v.mean(1), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(m.var), v.var(1, ddof=1), rtol=2e-3, atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(m.vmin), v.min(1), rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(m.vmax), v.max(1), rtol=0, atol=0)


def test_fit_all_shape_and_finiteness():
    v = d.sample("normal", (10.0, 2.0, 0.0), KEY, (5, 300))
    m = d.moments_from_values(v)
    params = d.fit_all(d.TYPES_10, m)
    assert params.shape == (5, 10, 3)
    assert bool(jnp.isfinite(params).all())


def test_weibull_bisection_accuracy():
    # known k: CV^2 should invert back
    for k_true in [0.7, 1.0, 2.0, 5.0]:
        lam = 2.0
        v = d.sample("weibull", (k_true, lam, 0.0), KEY, (1, 200_000))
        m = d.moments_from_values(v)
        p = d.fit_weibull(m)
        assert abs(float(p[0, 0]) - k_true) / k_true < 0.1, (k_true, p[0])
