"""Sampling (Algorithm 5): estimators approach full-population features."""

import numpy as np
import pytest

from repro.core import distributions as d
from repro.core import ml_predict as mlp
from repro.core import sampling as smp


@pytest.fixture(scope="module")
def population():
    rng = np.random.default_rng(0)
    # two sub-populations with distinct (mu, sigma) signatures -> types 0/1
    mean = np.concatenate([rng.normal(0, 0.1, 600), rng.normal(5, 0.1, 400)])
    std = np.concatenate([rng.normal(1, 0.02, 600), rng.normal(3, 0.02, 400)])
    labels = np.concatenate([np.zeros(600, np.int32), np.ones(400, np.int32)])
    feats = np.stack([mean, std], 1).astype(np.float32)
    tree = mlp.train_tree(feats, labels, len(d.TYPES_4), depth=2, max_bins=16)
    return mean.astype(np.float32), std.astype(np.float32), labels, tree


def test_full_rate_recovers_exact_percentages(population):
    mean, std, labels, tree = population
    f = smp.slice_features_from_moments(mean, std, tree, d.TYPES_4, group_first=False)
    np.testing.assert_allclose(f.type_percentage[0], 0.6, atol=0.02)
    np.testing.assert_allclose(f.type_percentage[1], 0.4, atol=0.02)
    np.testing.assert_allclose(f.avg_mean, mean.mean(), rtol=1e-6)


def test_random_sampling_distance_shrinks_with_rate(population):
    mean, std, labels, tree = population
    full = smp.slice_features_from_moments(mean, std, tree, d.TYPES_4, group_first=False)
    dists = []
    for rate in [0.01, 0.1, 0.5]:
        idx = smp.sample_indices_random(len(mean), rate, seed=5)
        f = smp.slice_features_from_moments(
            mean[idx], std[idx], tree, d.TYPES_4, group_first=False
        )
        dists.append(smp.type_percentage_distance(f.type_percentage, full.type_percentage))
    assert dists[2] <= dists[0] + 0.05, dists  # fig 17's trend


def test_kmeans_sampling_selects_diverse_points(population):
    mean, std, _, _ = population
    feats = np.stack([mean, std], 1)
    idx = smp.sample_indices_kmeans(feats, 0.02, iters=5, seed=0)
    assert 1 <= len(idx) <= 0.03 * len(mean) + 2
    # diversity: both clusters represented
    assert (mean[idx] < 2.5).any() and (mean[idx] > 2.5).any()


def test_grouped_percentages_weight_by_points(population):
    """Percentages are per-point even when predictions run per-group."""
    mean, std, labels, tree = population
    a = smp.slice_features_from_moments(mean, std, tree, d.TYPES_4, group_first=False)
    b = smp.slice_features_from_moments(
        mean, std, tree, d.TYPES_4, group_first=True, group_tol=1e-6
    )
    np.testing.assert_allclose(a.type_percentage, b.type_percentage, atol=1e-9)


def test_sample_indices_random_properties():
    idx = smp.sample_indices_random(1000, 0.1, seed=1)
    assert len(idx) == 100
    assert len(np.unique(idx)) == 100
    assert idx.min() >= 0 and idx.max() < 1000
