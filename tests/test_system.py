"""System-level behaviour tests: the paper's headline claims, end to end.

These assert the *structural* versions of the paper's results (work
reduction, error bounds, method orderings) — wall-clock assertions are kept
coarse because the container CPU is shared.
"""

import numpy as np
import pytest

from repro.core import distributions as d
from repro.core import ml_predict as mlp
from repro.core.pipeline import PDFComputer, PDFConfig
from repro.core.regions import CubeGeometry
from repro.data.simulation import SeismicSimulation, SimulationConfig


@pytest.fixture(scope="module")
def sim():
    return SeismicSimulation(
        SimulationConfig(geometry=CubeGeometry(8, 12, 24), num_simulations=300)
    )


def _train_tree(sim, types):
    from repro.core.pipeline import train_type_tree

    return train_type_tree(sim, types=types)


@pytest.fixture(scope="module")
def tree(sim):
    return _train_tree(sim, d.TYPES_4)


def _fitted(res):
    return sum(s.num_fitted for s in res.stats)


def test_grouping_reduces_fit_work_without_extra_error(sim):
    """Paper §6: 'Grouping outperforms Baseline ... without introducing
    extra error' — work drops by the dedup factor, results identical."""
    rb = PDFComputer(PDFConfig(window_lines=4, method="baseline"), sim).run_slice(3)
    rg = PDFComputer(PDFConfig(window_lines=4, method="grouping"), sim).run_slice(3)
    assert _fitted(rg) <= _fitted(rb) / 4, (_fitted(rg), _fitted(rb))
    np.testing.assert_array_equal(rb.type_idx, rg.type_idx)
    assert abs(rb.avg_error - rg.avg_error) < 1e-6


def test_ml_small_error_penalty_10types(sim):
    """Algorithm 4 runs ONE Eq.-5 pass instead of T=10; its extra error must
    stay within the paper's observed band (<= 0.017 there; we allow 0.05)."""
    tree10 = _train_tree(sim, d.TYPES_10)
    rb = PDFComputer(
        PDFConfig(window_lines=4, method="baseline", mode="faithful",
                  types=d.TYPES_10), sim
    ).run_slice(3)
    rm = PDFComputer(
        PDFConfig(window_lines=4, method="ml", mode="faithful",
                  types=d.TYPES_10), sim, tree=tree10
    ).run_slice(3)
    assert _fitted(rm) == _fitted(rb)
    assert rm.avg_error <= rb.avg_error + 0.05


def test_grouping_ml_is_the_best_combination(sim, tree):
    """Paper: Grouping+ML up to 33x vs baseline at small node counts. We
    assert the structural version: it does the least total fit work."""
    fits = {}
    for method in ["baseline", "grouping", "ml", "grouping_ml"]:
        comp = PDFComputer(
            PDFConfig(window_lines=4, method=method), sim,
            tree=tree if "ml" in method else None,
        )
        fits[method] = _fitted(comp.run_slice(3))
    assert fits["grouping_ml"] <= fits["grouping"] <= fits["baseline"]
    assert fits["grouping_ml"] < fits["baseline"] / 4


def test_reuse_cache_carries_across_windows(sim):
    comp = PDFComputer(PDFConfig(window_lines=3, method="reuse"), sim)
    comp.run_slice(3)
    assert comp.cache.hits > 0
    assert comp.cache.hit_rate > 0.1, comp.cache.hit_rate


def test_bounded_error_constraint_flags(sim):
    ok = PDFComputer(
        PDFConfig(window_lines=4, method="baseline", error_bound=1.9), sim
    ).run_slice(1)
    tight = PDFComputer(
        PDFConfig(window_lines=4, method="baseline", error_bound=1e-6), sim
    ).run_slice(1)
    assert ok.error_bound_satisfied is True
    assert tight.error_bound_satisfied is False


def test_end_to_end_type_recovery(sim):
    """The full pipeline recovers the generator's dominant distribution type
    on most points of a slice (uncertainty quantification works)."""
    for slice_i in range(4):
        res = PDFComputer(PDFConfig(window_lines=4, method="grouping"), sim).run_slice(slice_i)
        want = sim.true_type_index(slice_i)
        frac = (res.type_idx == want).mean()
        assert frac > 0.5, (slice_i, want, frac)
