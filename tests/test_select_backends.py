"""Select-backend equivalence (the grouping-aware fused dispatch issue's
acceptance matrix): select_backend='device' must produce *bitwise-identical*
per-point (type, params, error) to the host Select path for every grouped
method on both candidate sets — the device hi/lo keys are exact splits of the
host f64 int64 keys, and every fit backend is row-deterministic, so moving
the dedup onto the device cannot change a single bit."""

import numpy as np
import pytest

from repro.core import distributions as d
from repro.core.executor import SELECT_BACKENDS
from repro.core.pipeline import PDFComputer, PDFConfig, train_type_tree
from repro.core.regions import CubeGeometry
from repro.data.simulation import SeismicSimulation, SimulationConfig

GROUPED_METHODS = ("grouping", "reuse", "grouping_ml", "reuse_ml")


@pytest.mark.parametrize("mag", [1e-3, 1.0, 3e3, 1e6, 1e9, -3e3, -1e9])
@pytest.mark.parametrize("tol", [1e-6, 3.7e-5, 1e-2])
def test_device_keys_bitexact_with_host_deterministic(mag, tol):
    """Deterministic twin of the hypothesis property test in
    tests/test_grouping.py (that module importorskips hypothesis, which the
    reduced container lacks — this version always runs): device hi/lo keys
    reassemble the host int64 keys exactly, and the two partitions agree,
    at seismic-scale magnitudes, negative means, std=0 and non-default tols.
    """
    import jax.numpy as jnp

    from repro.core import grouping as grp

    rng = np.random.default_rng(int(abs(mag)) % 997 + int(tol * 1e7) % 97)
    mean = rng.normal(mag, abs(mag) * 0.1 + 1e-3, 300).astype(np.float32)
    var = np.abs(rng.normal(100, 30, 300)).astype(np.float32)
    var[::3] = 0.0  # degenerate windows
    reps = rng.integers(0, 300, size=200)  # real duplicate groups
    mean = np.concatenate([mean, mean[reps]])
    var = np.concatenate([var, var[reps]])

    host_keys = grp.quantize_keys_host(mean, var, tol)
    dev_keys = np.asarray(grp.quantize_keys_from_var(mean, var, tol))
    np.testing.assert_array_equal(grp.keys_to_int64(dev_keys), host_keys)

    host = grp.group_host(host_keys)
    dev = grp.group_device(jnp.asarray(dev_keys))
    assert int(dev.num_groups) == host.num_groups
    np.testing.assert_array_equal(
        host.rep_indices[host.inverse], np.asarray(dev.rep_for_point)
    )


@pytest.fixture(scope="module")
def sim():
    return SeismicSimulation(
        SimulationConfig(geometry=CubeGeometry(8, 6, 10), num_simulations=200)
    )


@pytest.fixture(scope="module")
def trees(sim):
    return {
        len(types): train_type_tree(sim, types, window_lines=2)
        for types in (d.TYPES_4, d.TYPES_10)
    }


def test_registry_and_default():
    assert SELECT_BACKENDS == ("host", "device")
    assert PDFConfig().select_backend == "host"
    with pytest.raises(ValueError, match="select_backend"):
        PDFConfig(select_backend="gpu")
    with pytest.raises(ValueError, match="rep_bucket"):
        PDFConfig(rep_bucket=0)  # padded_size(g, 0) would never terminate


@pytest.mark.parametrize("types", [d.TYPES_4, d.TYPES_10], ids=["4types", "10types"])
@pytest.mark.parametrize("method", GROUPED_METHODS)
def test_device_select_bitwise_matches_host(sim, trees, method, types):
    tree = trees[len(types)] if "ml" in method else None
    res, fitted, hits = {}, {}, {}
    for backend in SELECT_BACKENDS:
        cfg = PDFConfig(
            types=types, window_lines=2, method=method, select_backend=backend
        )
        comp = PDFComputer(cfg, sim, tree=tree)
        res[backend] = comp.run_slice(4)
        fitted[backend] = [w.num_fitted for w in res[backend].stats]
        hits[backend] = [w.cache_hits for w in res[backend].stats]
    a, b = res["host"], res["device"]
    np.testing.assert_array_equal(a.type_idx, b.type_idx)
    np.testing.assert_array_equal(a.params, b.params)  # bitwise
    np.testing.assert_array_equal(a.error, b.error)  # bitwise
    np.testing.assert_array_equal(a.mean, b.mean)
    # the dedup bookkeeping agrees too: same per-window group counts, and
    # for the reuse methods the same cache hit trajectory
    assert fitted["host"] == fitted["device"]
    assert hits["host"] == hits["device"]


@pytest.mark.parametrize("fit_backend", ["reference", "fused"])
def test_device_select_across_fit_backends(sim, fit_backend):
    """The device Select path is fit-backend generic: the gather prologue
    feeds whichever backend the config selects."""
    res = {}
    for backend in SELECT_BACKENDS:
        cfg = PDFConfig(
            types=d.TYPES_4, window_lines=2, method="grouping",
            select_backend=backend, fit_backend=fit_backend,
        )
        res[backend] = PDFComputer(cfg, sim).run_slice(2)
    np.testing.assert_array_equal(res["host"].type_idx, res["device"].type_idx)
    np.testing.assert_array_equal(res["host"].params, res["device"].params)
    np.testing.assert_array_equal(res["host"].error, res["device"].error)


def test_device_select_nondefault_tol(sim):
    """group_tol threads through the device probe (the dry-run used to drop
    it): a loose tolerance must group more aggressively on both backends,
    identically."""
    fitted = {}
    for backend in SELECT_BACKENDS:
        for tol in (1e-6, 1e2):
            cfg = PDFConfig(
                types=d.TYPES_4, window_lines=2, method="grouping",
                select_backend=backend, group_tol=tol,
            )
            r = PDFComputer(cfg, sim).run_slice(3)
            fitted[(backend, tol)] = sum(w.num_fitted for w in r.stats)
    assert fitted[("host", 1e-6)] == fitted[("device", 1e-6)]
    assert fitted[("host", 1e2)] == fitted[("device", 1e2)]
    assert fitted[("device", 1e2)] <= fitted[("device", 1e-6)]
