# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device. Multi-device mesh behaviour is tested via
# subprocesses (test_mesh_multidevice.py / test_distributed.py) that set
# --xla_force_host_platform_device_count themselves.
import os
from datetime import timedelta

import numpy as np
import pytest

try:
    from hypothesis import settings as _hyp_settings
except ImportError:  # hypothesis is the optional 'test' extra
    pass
else:
    # Property suites inherit these unless a test's @settings overrides the
    # field: "ci" is derandomized (stable example schedules — a failure on
    # one machine reproduces on every machine) with an explicit per-example
    # deadline generous enough for a first-example JAX trace; "dev" keeps
    # fresh randomness for local exploration. Select with the
    # HYPOTHESIS_PROFILE env var (default: ci).
    _hyp_settings.register_profile(
        "ci", derandomize=True, deadline=timedelta(seconds=15),
        print_blob=True)
    _hyp_settings.register_profile(
        "dev", derandomize=False, deadline=timedelta(seconds=15))
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
