# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device. Multi-device mesh behaviour is tested via
# subprocesses (test_mesh_multidevice.py) that set
# --xla_force_host_platform_device_count themselves.
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
