"""Eq. 5 / Eq. 6 properties + fused-vs-faithful equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional 'test' extra")
from hypothesis import given, settings, strategies as st

from repro.core import distributions as d
from repro.core import fitting
from repro.core import pdf_error as pe

KEY = jax.random.PRNGKey(1)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(10, 500),
    num_bins=st.sampled_from([4, 16, 20, 64]),
    scale=st.floats(0.1, 1000),
)
def test_histogram_partitions_all_values(n, num_bins, scale):
    rng = np.random.default_rng(7)
    v = (scale * rng.standard_normal((4, n))).astype(np.float32)
    vmin, vmax = v.min(1), v.max(1)
    h = np.asarray(pe.histogram(jnp.asarray(v), jnp.asarray(vmin), jnp.asarray(vmax), num_bins))
    assert h.shape == (4, num_bins)
    np.testing.assert_array_equal(h.sum(1), np.full(4, n))
    assert (h >= 0).all()


def test_error_bounded_by_two():
    """|freq/N - mass| summed: freqs sum to 1, masses sum to <= 1 => e <= 2."""
    v = d.sample("normal", (0.0, 1.0, 0.0), KEY, (16, 500))
    m = d.moments_from_values(v)
    params = d.fit_all(d.TYPES_10, m)
    errs = np.asarray(pe.pdf_error(v, params, d.TYPES_10, 20, m))
    assert (errs >= 0).all() and (errs <= 2.0 + 1e-6).all()


def test_error_decreases_with_sample_size():
    """Eq.-5 error of the true type shrinks as n grows (KS-consistency)."""
    errs = []
    for n in [100, 1000, 10_000]:
        v = d.sample("normal", (5.0, 2.0, 0.0), KEY, (8, n))
        m = d.moments_from_values(v)
        r = fitting.compute_pdf_and_error(v, m, d.TYPES_4, 20)
        errs.append(float(np.asarray(r.error).mean()))
    assert errs[0] > errs[1] > errs[2], errs


def test_fused_equals_faithful():
    v = d.sample("lognormal", (0.2, 0.6, 0.0), KEY, (6, 400))
    m = d.moments_from_values(v)
    a = fitting.compute_pdf_and_error(v, m, d.TYPES_10, 32, mode="fused")
    b = fitting.compute_pdf_and_error(v, m, d.TYPES_10, 32, mode="faithful")
    np.testing.assert_array_equal(np.asarray(a.type_idx), np.asarray(b.type_idx))
    np.testing.assert_allclose(np.asarray(a.error), np.asarray(b.error), rtol=1e-6)


def test_predicted_type_path_matches_full_path_error():
    """Algorithm 4 with the *correct* prediction reproduces Algorithm 3's
    error for that type exactly."""
    v = d.sample("exponential", (1.0, 0.0, 0.0), KEY, (5, 800))
    m = d.moments_from_values(v)
    full = fitting.compute_pdf_and_error(v, m, d.TYPES_4, 20)
    pred = fitting.compute_pdf_with_predicted_type(
        v, m, full.type_idx, d.TYPES_4, 20
    )
    np.testing.assert_allclose(
        np.asarray(pred.error), np.asarray(full.error), rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(pred.params), np.asarray(full.params), rtol=1e-6
    )


def test_slice_average_error_masked():
    e = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    assert float(pe.slice_average_error(e)) == 2.5
    mask = jnp.asarray([True, True, False, False])
    assert float(pe.slice_average_error(e, mask)) == 1.5
