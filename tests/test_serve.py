"""PDFServer (repro/serve): the coalescing-equivalence contract and the
serving lifecycle.

The load-bearing guarantee is bitwise equality: every answer a server
produces — coalesced or naive, computed or served from the hot-window LRU
or the ResultCache, under concurrent clients — must match the batch
pipeline's arrays exactly. The rest covers the queue lifecycle: graceful
drain on close, loud failure propagation, submit validation."""

import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.api import (
    ComputeSpec,
    ExecSpec,
    MethodSpec,
    PDFSession,
    PipelineSpec,
    SourceSpec,
    build_source,
)
from repro.api.spec import ServeSpec
from repro.core import regions
from repro.core.executor import RESULT_FIELDS
from repro.serve import PDFServer, PointQuery, RegionQuery, WindowQuery

# lines_per_slice=10 with window_lines=3 leaves a 1-line tail window
# ([9, 10)) so span math is exercised off the aligned grid.
SOURCE = SourceSpec(num_slices=3, lines_per_slice=10, points_per_line=8,
                    observations=150)
PPL = SOURCE.points_per_line
WINDOW_LINES = 3


def make_spec(method="grouping", serve=ServeSpec(), **kw):
    return PipelineSpec(
        source=SOURCE,
        method=MethodSpec(name=method),
        compute=ComputeSpec(window_lines=WINDOW_LINES, num_bins=20),
        serve=serve,
        **kw,
    )


def reference(spec, slices):
    return PDFSession(spec).run_all(slices)


def assert_answer_matches(answer, ref_slice, lo, hi):
    for name in RESULT_FIELDS:
        np.testing.assert_array_equal(
            getattr(answer, name), getattr(ref_slice, name)[lo:hi],
            err_msg=name)


# -- bitwise equivalence vs the batch pipeline ---------------------------------


@pytest.mark.parametrize("method", ["baseline", "grouping", "reuse", "sampling"])
def test_answers_bitwise_equal_to_pipeline(method):
    """Point / unaligned-window / region answers are bitwise-identical to
    the serial batch pipeline, for every method family the executor has
    (sampling exercises the per-window dispatch fallback and the
    window-seeded sample draws)."""
    spec = make_spec(method)
    ref = reference(spec, [1, 2])
    with PDFServer(spec) as srv:
        a = srv.query(PointQuery(1, 4, 5))
        assert_answer_matches(a, ref[1], 4 * PPL + 5, 4 * PPL + 6)
        assert a.spec_hash == spec.content_hash()

        # span [2, 7) crosses windows [0,3) [3,6) [6,9) and is unaligned
        # on both edges
        a = srv.query(WindowQuery(1, 2, 7))
        assert_answer_matches(a, ref[1], 2 * PPL, 7 * PPL)

        # span reaching into the 1-line tail window [9, 10)
        a = srv.query(WindowQuery(2, 8, 10))
        assert_answer_matches(a, ref[2], 8 * PPL, 10 * PPL)

        a = srv.query(RegionQuery(2))
        assert_answer_matches(a, ref[2], 0, SOURCE.lines_per_slice * PPL)


@pytest.mark.parametrize("method", ["baseline", "grouping", "reuse"])
def test_run_window_batch_matches_serial_windows(method):
    """One batched dispatch over windows spanning slices (tail window
    included) returns bitwise what per-window serial dispatch returns —
    the executor-level contract the coalescing tick rests on."""
    windows = [
        regions.Window(0, 0, 3),
        regions.Window(0, 9, 10),  # tail
        regions.Window(1, 3, 6),
        regions.Window(2, 6, 9),
    ]
    spec = make_spec(method)
    # separate sessions: the reuse method's cache must not leak hits
    # between the two dispatch orders being compared
    batched = PDFSession(spec).executor(0).run_window_batch(windows)
    serial_ex = PDFSession(spec).executor(0)
    for w, br in zip(windows, batched):
        sr = serial_ex.run_window(w)
        assert br.window == w == sr.window
        for name in RESULT_FIELDS:
            np.testing.assert_array_equal(
                getattr(br, name), getattr(sr, name), err_msg=f"{w}/{name}")


def test_coalesced_equals_naive_server():
    """The same query set answered by a coalescing server and by the
    one-launch-per-query baseline is bitwise-identical — coalescing changes
    launch count, never results."""
    queries = [PointQuery(0, 1, 2), WindowQuery(0, 0, 5), RegionQuery(1),
               PointQuery(1, 9, 7), WindowQuery(2, 4, 10), PointQuery(0, 1, 2)]
    answers = {}
    for mode, serve in (
        ("coalesced", ServeSpec(coalesce=True)),
        ("naive", ServeSpec(coalesce=False, window_cache_entries=0,
                            tick_seconds=0.0)),
    ):
        with PDFServer(make_spec("grouping", serve=serve)) as srv:
            futures = [srv.submit(q) for q in queries]
            answers[mode] = [f.result(timeout=60) for f in futures]
    for qc, qn in zip(answers["coalesced"], answers["naive"]):
        assert qc.query == qn.query
        for name in RESULT_FIELDS:
            np.testing.assert_array_equal(
                getattr(qc, name), getattr(qn, name), err_msg=name)


def test_concurrent_clients_bitwise():
    """8 closed-loop clients hammering overlapping point/window queries all
    get bitwise-correct spans; the server coalesces the overlap (fewer
    windows computed than requested)."""
    spec = make_spec("grouping")
    ref = reference(spec, [0, 1, 2])
    errors: list[BaseException] = []

    def client(c: int) -> None:
        try:
            s = c % SOURCE.num_slices
            for i in range(6):
                line = (c + 2 * i) % SOURCE.lines_per_slice
                point = (3 * c + i) % PPL
                a = server.query(PointQuery(s, line, point))
                lo = line * PPL + point
                assert_answer_matches(a, ref[s], lo, lo + 1)
            a = server.query(WindowQuery(s, 1, 8))
            assert_answer_matches(a, ref[s], PPL, 8 * PPL)
        except BaseException as e:  # noqa: BLE001 — re-raised on main thread
            errors.append(e)

    with PDFServer(spec) as server:
        threads = [threading.Thread(target=client, args=(c,)) for c in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = server.stats()
    if errors:
        raise errors[0]
    assert stats.queries == 8 * 7
    assert stats.windows_computed <= 4 * SOURCE.num_slices  # each window once
    assert stats.windows_requested > stats.windows_computed
    assert stats.coalesce_ratio > 1.0


# -- cache layers --------------------------------------------------------------


def test_repeat_query_hits_memory_lru():
    spec = make_spec("grouping")
    with PDFServer(spec) as srv:
        first = srv.query(WindowQuery(0, 0, 6))
        again = srv.query(WindowQuery(0, 0, 6))
        stats = srv.stats()
    assert first.windows_computed == 2 and first.windows_from_memory == 0
    assert again.windows_from_memory == 2 and again.windows_computed == 0
    for name in RESULT_FIELDS:
        np.testing.assert_array_equal(
            getattr(first, name), getattr(again, name), err_msg=name)
    assert stats.windows_from_memory == 2


def test_lru_disabled_recomputes():
    serve = ServeSpec(window_cache_entries=0, tick_seconds=0.0)
    with PDFServer(make_spec("grouping", serve=serve)) as srv:
        srv.query(PointQuery(0, 0, 0))
        srv.query(PointQuery(0, 0, 0))
        stats = srv.stats()
    assert stats.windows_computed == 2 and stats.windows_from_memory == 0


def test_hot_path_from_result_cache_builds_nothing(tmp_path):
    """A server in front of a fully-populated ResultCache answers without
    ever building an executor or training a tree, and stores nothing new;
    a server that computes a full slice stores it back for the next one."""
    spec = make_spec(
        "grouping", execution=ExecSpec(cache_dir=str(tmp_path / "cache")))
    ref = reference(spec, [0])  # populates the cache for slice 0

    with PDFServer(spec) as srv:
        a = srv.query(RegionQuery(0))
        assert_answer_matches(a, ref[0], 0, SOURCE.lines_per_slice * PPL)
        assert a.windows_from_disk == 4 and a.windows_computed == 0
        # slice 1 is NOT cached: the server computes it window by window
        # and stores the completed slice back
        srv.query(RegionQuery(1))
        stats = srv.stats()
        assert not srv.session._executors or stats.windows_computed > 0
        assert stats.slices_stored == 1
    # fresh server, same cache dir: slice 1 now serves from disk too
    with PDFServer(spec) as srv2:
        b = srv2.query(RegionQuery(1))
        assert b.windows_from_disk == 4 and b.windows_computed == 0
        assert not srv2.session._executors  # pure cache read: no executor
        assert srv2.session._tree is None


# -- lifecycle -----------------------------------------------------------------


def test_graceful_drain_on_close():
    """Everything queued before close() is served to completion; submitting
    after close raises instead of silently dropping."""
    spec = make_spec("grouping")
    srv = PDFServer(spec).start()
    futures = [srv.submit(PointQuery(s, line, 0))
               for s in range(2) for line in range(0, 10, 3)]
    srv.close(timeout=120)
    for f in futures:
        assert f.done()
        answer = f.result(timeout=0)  # already resolved, never dropped
        assert answer.type_idx.shape == (1,)
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(PointQuery(0, 0, 0))


def test_submit_before_start_raises():
    srv = PDFServer(make_spec("grouping"))
    with pytest.raises(RuntimeError, match="not started"):
        srv.submit(PointQuery(0, 0, 0))


class _FailingSource:
    """Delegates everything to a real source but refuses to load."""

    def __init__(self, inner):
        self._inner = inner

    def load_window(self, w):
        raise RuntimeError("injected load failure")

    def __getattr__(self, name):
        return getattr(self._inner, name)


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_serving_thread_failure_is_loud():
    """A crash in the serving thread fails the in-flight future with the
    original error, poisons the server, and surfaces again on close()."""
    spec = make_spec("grouping")
    srv = PDFServer(spec, data_source=_FailingSource(build_source(SOURCE)))
    srv.start()
    fut = srv.submit(PointQuery(0, 0, 0))
    with pytest.raises(RuntimeError, match="injected load failure"):
        fut.result(timeout=60)
    srv._thread.join(timeout=60)
    with pytest.raises(RuntimeError, match="server thread failed"):
        srv.submit(PointQuery(0, 0, 0))
    with pytest.raises(RuntimeError, match="server thread failed"):
        srv.close()


def test_submit_validation():
    with PDFServer(make_spec("grouping")) as srv:
        with pytest.raises(ValueError, match="slice"):
            srv.submit(RegionQuery(99))
        with pytest.raises(ValueError, match="point"):
            srv.submit(PointQuery(0, 0, PPL))
        with pytest.raises(ValueError, match="line"):
            srv.submit(PointQuery(0, SOURCE.lines_per_slice, 0))
        with pytest.raises(ValueError, match="lines"):
            srv.submit(WindowQuery(0, 5, 5))  # empty span
        with pytest.raises(TypeError, match="unknown query"):
            srv.submit(("not", "a", "query"))


# -- observability -------------------------------------------------------------


def test_stats_and_stage_percentiles():
    spec = make_spec("grouping")
    with PDFServer(spec) as srv:
        srv.query(WindowQuery(0, 0, 6))
        srv.query(PointQuery(0, 1, 1))
        stats = srv.stats()
    assert stats.queries == 2
    assert stats.queries_by_kind == {"WindowQuery": 1, "PointQuery": 1}
    assert stats.launches >= 1 and stats.windows_computed == 2
    assert stats.batch_occupancy > 0
    assert set(stats.latency) == {"p50", "p99"}
    assert stats.latency["p99"] >= stats.latency["p50"] > 0
    assert set(stats.launch_latency) == {"p50", "p99"}
    # per-stage tails come from the session's executor monitors — the same
    # numbers PDFSession.report() now carries
    assert "compute" in stats.stage_percentiles
    assert stats.stage_percentiles["compute"]["p50"] > 0
    report = srv.session.report()
    assert report.stage_percentiles.keys() == stats.stage_percentiles.keys()


def test_serve_spec_excluded_from_content_hash():
    """ServeSpec is delivery policy, not result definition: any serve
    config maps to the same ResultCache entries."""
    base = make_spec("grouping")
    tweaked = make_spec("grouping", serve=ServeSpec(
        coalesce=False, tick_seconds=0.5, max_batch_windows=1,
        window_cache_entries=0))
    assert base.content_hash() == tweaked.content_hash()
