"""Staged executor: plans, bitwise equivalence vs the serial reference path,
prefetch overlap + error propagation, async persist / resume, slice
scheduling across shards."""

import numpy as np
import pytest

from repro.core import distributions as d
from repro.core.executor import ExecutorConfig, PDFConfig, StagedExecutor
from repro.core.pipeline import PDFComputer, train_type_tree
from repro.core.regions import CubeGeometry, WorkUnit, build_plan
from repro.data.loader import PrefetchError, ThrottledSource, WindowPrefetcher
from repro.data.simulation import SeismicSimulation, SimulationConfig
from repro.runtime.scheduler import SliceScheduler, assign_slices

# the pre-refactor strictly serial loop: the reference all staged
# configurations must match bitwise
SERIAL = ExecutorConfig(prefetch=False, async_persist=False)

RESULT_FIELDS = ("type_idx", "params", "error", "mean", "std", "skew", "kurt")


@pytest.fixture(scope="module")
def sim():
    return SeismicSimulation(
        SimulationConfig(geometry=CubeGeometry(8, 9, 12), num_simulations=250)
    )


@pytest.fixture(scope="module")
def tree(sim):
    return train_type_tree(sim, window_lines=3)


def assert_results_equal(a, b):
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), err_msg=f)
    assert a.avg_error == b.avg_error


# -- plans ---------------------------------------------------------------------


def test_build_plan_covers_slices_in_order():
    geom = CubeGeometry(4, 10, 7)
    plan = build_plan(geom, [2, 0], window_lines=4)
    assert plan.slices == (2, 0)
    assert [u.seq for u in plan.units] == list(range(len(plan)))
    # windows of each slice are disjoint, ordered, and cover all lines
    for s in (2, 0):
        ws = [u.window for u in plan.units_for_slice(s)]
        assert ws[0].line_start == 0 and ws[-1].line_end == 10
        for prev, nxt in zip(ws, ws[1:]):
            assert prev.line_end == nxt.line_start


def test_build_plan_start_lines_and_bounds():
    geom = CubeGeometry(4, 10, 7)
    plan = build_plan(geom, [1, 3], window_lines=5, start_lines={1: 5, 3: 10})
    assert [u.window.slice_i for u in plan.units] == [1]  # slice 3 complete
    assert plan.units[0].window.line_start == 5
    with pytest.raises(ValueError):
        build_plan(geom, [4], window_lines=5)


# -- equivalence ---------------------------------------------------------------


@pytest.mark.parametrize(
    "method", ["baseline", "grouping", "reuse", "ml", "grouping_ml", "reuse_ml"]
)
def test_methods_bitwise_identical_to_serial_path(sim, tree, method):
    cfg = PDFConfig(window_lines=3, method=method)
    t = tree if "ml" in method else None
    serial = PDFComputer(cfg, sim, tree=t, exec_config=SERIAL).run_slice(2)
    staged = PDFComputer(cfg, sim, tree=t).run_slice(2)  # prefetch + async
    assert_results_equal(serial, staged)


def test_multi_slice_plan_matches_sequential_slices(sim):
    """One plan spanning slices == consecutive run_slice calls on one
    computer (the reuse cache crosses slice boundaries identically)."""
    cfg = PDFConfig(window_lines=3, method="reuse")
    seq = PDFComputer(cfg, sim, exec_config=SERIAL)
    expected = {s: seq.run_slice(s) for s in (2, 3)}

    ex = StagedExecutor(cfg, sim)
    got = ex.run(build_plan(sim.geometry, [2, 3], 3))
    assert set(got) == {2, 3}
    for s in (2, 3):
        assert_results_equal(expected[s], got[s])
    assert ex.last_report is not None
    assert ex.last_report.units == len(got[2].stats) + len(got[3].stats)


# -- prefetcher ----------------------------------------------------------------


def test_prefetcher_preserves_order():
    pf = WindowPrefetcher(range(20), lambda i: i * i, depth=3)
    assert list(pf) == [i * i for i in range(20)]


def test_prefetcher_propagates_stage_errors():
    def boom(i):
        if i == 3:
            raise ValueError("bad window")
        return i

    pf = WindowPrefetcher(range(10), boom, depth=2)
    with pytest.raises(PrefetchError) as ei:
        list(pf)
    assert isinstance(ei.value.__cause__, ValueError)


def test_prefetcher_close_unblocks_producer():
    pf = WindowPrefetcher(range(1000), lambda i: i, depth=1)
    it = iter(pf)
    assert next(it) == 0
    pf.close()  # producer is blocked on the full queue; must not deadlock
    assert not pf._thread.is_alive()


def test_prefetch_overlaps_throttled_load(sim):
    """Through an NFS-modeled source, the compute stage must block on less
    than the full load time (the first window is never hidden, later ones
    are) — the 'device not blocked on load_window' property."""
    nfs = ThrottledSource(sim, bandwidth_bytes_per_s=4e6)  # ~3ms per window
    cfg = PDFConfig(window_lines=3, method="baseline")
    comp = PDFComputer(cfg, nfs)
    comp.run_slice(1)  # jit warmup
    res = comp.run_slice(2)
    rep = comp.last_report
    assert rep.load_seconds > 0
    assert res.total_wait_seconds < res.total_load_seconds
    assert rep.load_hidden_seconds > 0


def test_throttled_source_paces_reads(sim):
    import time

    w = build_plan(sim.geometry, [0], 3).units[0].window
    raw = sim.load_window(w)
    bw = raw.nbytes / 0.02  # ~20ms per window
    t0 = time.perf_counter()
    block = ThrottledSource(sim, bw).load_window(w)
    assert time.perf_counter() - t0 >= 0.015
    np.testing.assert_array_equal(block, raw)


# -- persist / resume ----------------------------------------------------------


def test_crash_mid_slice_resume_identical(sim, tmp_path):
    """Crash mid-slice, re-run with resume=True: results identical to an
    uninterrupted run, completed windows not re-done — through the fully
    staged pipeline (prefetch + async persist)."""
    cfg = PDFConfig(window_lines=3, method="grouping")
    full = PDFComputer(cfg, sim, out_dir=tmp_path / "full").run_slice(5)

    out = tmp_path / "crash"
    seen = 0

    class Crash(Exception):
        pass

    def crash_after_two(ws):
        nonlocal seen
        seen += 1
        if seen == 2:
            raise Crash()

    with pytest.raises(Crash):
        PDFComputer(cfg, sim, out_dir=out).run_slice(5, on_window=crash_after_two)

    resumed = PDFComputer(cfg, sim, out_dir=out).run_slice(5, resume=True)
    assert_results_equal(full, resumed)
    # the two completed windows were restored from .npz, not re-run
    assert len(resumed.stats) == len(full.stats) - 2


def test_async_persist_watermark_and_files_consistent(sim, tmp_path):
    cfg = PDFConfig(window_lines=4, method="baseline")
    comp = PDFComputer(cfg, sim, out_dir=tmp_path)
    res = comp.run_slice(3)
    assert comp._watermark(3) == sim.geometry.lines_per_slice
    files = sorted(tmp_path.glob("slice3_window_*.npz"))
    assert len(files) == len(res.stats)
    ppl = sim.geometry.points_per_line
    for f in files:
        z = np.load(f)
        lo, hi = int(z["line_start"]) * ppl, int(z["line_end"]) * ppl
        np.testing.assert_array_equal(z["error"], res.error[lo:hi])
        np.testing.assert_array_equal(z["type_idx"], res.type_idx[lo:hi])


def test_persist_failure_surfaces(sim, tmp_path):
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("file where the out_dir must go")
    comp = PDFComputer(PDFConfig(window_lines=4), sim, out_dir=blocker)
    with pytest.raises(RuntimeError, match="persist stage failed"):
        comp.run_slice(1)


# -- scheduler -----------------------------------------------------------------


def test_assign_slices_round_robin_balance():
    a = assign_slices(list(range(10)), 3)
    assert [x.slices for x in a] == [(0, 3, 6, 9), (1, 4, 7), (2, 5, 8)]
    sizes = [len(x.slices) for x in a]
    assert max(sizes) - min(sizes) <= 1
    with pytest.raises(ValueError):
        assign_slices([0], 0)


def test_scheduler_runs_all_shards_and_matches_direct(sim):
    cfg = PDFConfig(window_lines=3, method="grouping")
    direct = {
        s: PDFComputer(cfg, sim, exec_config=SERIAL).run_slice(s) for s in (1, 2, 3)
    }
    sched = SliceScheduler(num_shards=2)
    results = sched.run(
        lambda shard: StagedExecutor(cfg, sim), [1, 2, 3]
    )
    assert set(results) == {1, 2, 3}
    for s in (1, 2, 3):
        assert_results_equal(direct[s], results[s])
    assert set(sched.last_reports) == {0, 1}
    assert sched.window_monitor.completed == sum(len(r.stats) for r in results.values())


def test_scheduler_single_shard_mode(sim):
    cfg = PDFConfig(window_lines=3, method="baseline")
    sched = SliceScheduler(num_shards=2)
    results = sched.run(
        lambda shard: StagedExecutor(cfg, sim), [1, 2, 3, 4], shard=1
    )
    # shard 1 owns slices [2, 4] under round-robin of [1,2,3,4]
    assert set(results) == {2, 4}
