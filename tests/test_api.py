"""The public API (repro/api): spec round-trip + hashing + validation, CLI
generation, PDFComputer-shim bitwise equivalence, the sampling method, and
resume provenance checking."""

import argparse
import dataclasses
import json

import numpy as np
import pytest

from repro.api import (
    ComputeSpec,
    ExecSpec,
    MethodSpec,
    PDFSession,
    PipelineSpec,
    SourceSpec,
    TreeSpec,
    add_spec_args,
    build_source,
    source_spec_for,
    spec_from_args,
)
from repro.core import distributions as d
from repro.core import sampling as smp
from repro.core.executor import METHODS, RESULT_FIELDS, SAMPLERS, PDFConfig
from repro.core.pipeline import PDFComputer

SMALL_SOURCE = SourceSpec(num_slices=8, lines_per_slice=9, points_per_line=12,
                          observations=250)


@pytest.fixture(scope="module")
def sim():
    return build_source(SMALL_SOURCE)


# -- randomized valid specs (deterministic twin of the hypothesis test) --------


def random_spec(rng: np.random.Generator) -> PipelineSpec:
    num_slices = int(rng.integers(1, 20))
    if rng.random() < 0.5:
        slices = None
    else:
        k = int(rng.integers(1, num_slices + 1))
        slices = tuple(int(s) for s in rng.choice(num_slices, size=k, replace=False))
    shards = int(rng.integers(1, 5))
    return PipelineSpec(
        source=SourceSpec(
            kind="simulation",
            num_slices=num_slices,
            lines_per_slice=int(rng.integers(1, 40)),
            points_per_line=int(rng.integers(1, 40)),
            observations=int(rng.integers(1, 1000)),
            num_layers=int(rng.integers(1, 32)),
            base_vp=float(rng.uniform(1.0, 1e4)),
            quantize_decimals=int(rng.integers(0, 6)),
            group_block=int(rng.integers(1, 8)),
            line_block=int(rng.integers(1, 8)),
            seed=int(rng.integers(0, 2**31)),
            throttle_mb_s=None if rng.random() < 0.5 else float(rng.uniform(0.1, 1e3)),
        ),
        method=MethodSpec(
            name=str(rng.choice(METHODS)),
            group_tol=float(10.0 ** rng.uniform(-9, 2)),
            rep_bucket=int(rng.integers(1, 512)),
            error_bound=None if rng.random() < 0.5 else float(rng.uniform(0.01, 10)),
            sample_frac=float(rng.uniform(0.001, 1.0)),
            sampler=str(rng.choice(SAMPLERS)),
            kmeans_iters=int(rng.integers(1, 20)),
            sample_seed=int(rng.integers(0, 2**31)),
            tree=TreeSpec(
                depth=int(rng.integers(1, 8)),
                max_bins=int(rng.integers(2, 64)),
                train_slices=None if rng.random() < 0.5
                else tuple(int(s) for s in rng.choice(64, size=4, replace=False)),
                train_window_lines=int(rng.integers(1, 8)),
            ),
        ),
        compute=ComputeSpec(
            types=[d.TYPES_4, d.TYPES_10, ("normal", "uniform")][int(rng.integers(3))],
            num_bins=int(rng.integers(2, 128)),
            window_lines=int(rng.integers(1, 50)),
            mode=str(rng.choice(["faithful", "fused"])),
            fit_backend=str(rng.choice(["reference", "kernels", "fused"])),
            select_backend=str(rng.choice(["host", "device"])),
        ),
        execution=ExecSpec(
            slices=slices,
            shards=shards,
            shard=None if rng.random() < 0.5 else int(rng.integers(0, shards)),
            prefetch=bool(rng.random() < 0.5),
            prefetch_depth=int(rng.integers(1, 8)),
            async_persist=bool(rng.random() < 0.5),
            out_dir=None,
            resume=False,
        ),
    )


def test_json_roundtrip_randomized_specs():
    rng = np.random.default_rng(7)
    for _ in range(100):
        spec = random_spec(rng)
        back = PipelineSpec.from_json(spec.to_json())
        assert back == spec
        assert back.content_hash() == spec.content_hash()


def test_json_roundtrip_hypothesis():
    pytest.importorskip("hypothesis",
                        reason="property tests need the optional 'test' extra")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def specs(draw):
        num_slices = draw(st.integers(1, 20))
        slices = draw(st.one_of(
            st.none(),
            st.lists(st.integers(0, num_slices - 1), min_size=1, max_size=4,
                     unique=True).map(tuple),
        ))
        shards = draw(st.integers(1, 4))
        return PipelineSpec(
            source=SourceSpec(
                num_slices=num_slices,
                lines_per_slice=draw(st.integers(1, 40)),
                points_per_line=draw(st.integers(1, 40)),
                observations=draw(st.integers(1, 1000)),
                seed=draw(st.integers(0, 2**31 - 1)),
                throttle_mb_s=draw(st.one_of(
                    st.none(),
                    st.floats(0.1, 1e3, allow_nan=False, allow_infinity=False))),
            ),
            method=MethodSpec(
                name=draw(st.sampled_from(METHODS)),
                group_tol=draw(st.floats(1e-9, 1e2, allow_nan=False,
                                         allow_infinity=False, exclude_min=False)),
                rep_bucket=draw(st.integers(1, 512)),
                error_bound=draw(st.one_of(
                    st.none(),
                    st.floats(0.01, 10, allow_nan=False, allow_infinity=False))),
                sample_frac=draw(st.floats(0.001, 1.0, allow_nan=False)),
                sampler=draw(st.sampled_from(SAMPLERS)),
                kmeans_iters=draw(st.integers(1, 20)),
                tree=TreeSpec(depth=draw(st.integers(1, 8)),
                              max_bins=draw(st.integers(2, 64))),
            ),
            compute=ComputeSpec(
                types=draw(st.sampled_from([d.TYPES_4, d.TYPES_10])),
                num_bins=draw(st.integers(2, 128)),
                window_lines=draw(st.integers(1, 50)),
                mode=draw(st.sampled_from(["faithful", "fused"])),
                fit_backend=draw(st.sampled_from(["reference", "kernels", "fused"])),
                select_backend=draw(st.sampled_from(["host", "device"])),
            ),
            execution=ExecSpec(
                slices=slices,
                shards=shards,
                prefetch=draw(st.booleans()),
                prefetch_depth=draw(st.integers(1, 8)),
                async_persist=draw(st.booleans()),
            ),
        )

    @settings(max_examples=200)
    @given(specs())
    def inner(spec):
        back = PipelineSpec.from_json(spec.to_json())
        assert back == spec
        assert back.content_hash() == spec.content_hash()

    inner()


# -- hash semantics ------------------------------------------------------------


def test_hash_ignores_execution_but_not_method_or_compute():
    base = PipelineSpec()
    staged = dataclasses.replace(
        base, execution=ExecSpec(prefetch=False, shards=4, prefetch_depth=5))
    assert staged.content_hash() == base.content_hash()

    tol = dataclasses.replace(base, method=MethodSpec(group_tol=1e-3))
    bins = dataclasses.replace(base, compute=ComputeSpec(num_bins=20))
    seed = dataclasses.replace(base, source=SourceSpec(seed=1))
    assert len({base.content_hash(), tol.content_hash(), bins.content_hash(),
                seed.content_hash()}) == 4


def test_hash_ignores_nfs_throttle_model():
    # ThrottledSource only sleeps — a throttled benchmark run and its
    # unthrottled resume are the same computation
    base = PipelineSpec()
    throttled = dataclasses.replace(base, source=SourceSpec(throttle_mb_s=50.0))
    assert throttled.content_hash() == base.content_hash()


def test_shim_and_session_stamp_the_same_hash(sim):
    spec = PipelineSpec(source=SMALL_SOURCE,
                        method=MethodSpec(name="grouping"),
                        compute=ComputeSpec(window_lines=3))
    shim = PDFComputer(spec.pdf_config(), sim)
    assert shim.spec.content_hash() == spec.content_hash()
    assert PDFSession(spec, data_source=sim).spec_hash == spec.content_hash()


# -- validation ----------------------------------------------------------------


@pytest.mark.parametrize("build", [
    lambda: ComputeSpec(num_bins=1),
    lambda: ComputeSpec(window_lines=0),
    lambda: ComputeSpec(types=()),
    lambda: ComputeSpec(types=("nope",)),
    lambda: ComputeSpec(mode="turbo"),
    lambda: MethodSpec(name="magic"),
    lambda: MethodSpec(error_bound=0.0),
    lambda: MethodSpec(error_bound=-1.0),
    lambda: MethodSpec(group_tol=0.0),
    lambda: MethodSpec(rep_bucket=0),
    lambda: MethodSpec(sample_frac=0.0),
    lambda: MethodSpec(sample_frac=1.5),
    lambda: MethodSpec(sampler="sobol"),
    lambda: MethodSpec(kmeans_iters=0),
    lambda: TreeSpec(depth=0),
    lambda: TreeSpec(max_bins=1),
    lambda: TreeSpec(train_slices=()),
    lambda: SourceSpec(kind="parquet"),
    lambda: SourceSpec(num_slices=0),
    lambda: SourceSpec(observations=0),
    lambda: SourceSpec(throttle_mb_s=0.0),
    lambda: ExecSpec(shards=0),
    lambda: ExecSpec(shard=2, shards=2),
    lambda: ExecSpec(prefetch_depth=0),
    lambda: ExecSpec(resume=True),  # resume without out_dir
    lambda: PipelineSpec(version=99),
    lambda: PipelineSpec(source=SourceSpec(num_slices=2),
                         execution=ExecSpec(slices=(5,))),
])
def test_invalid_specs_rejected_at_construction(build):
    with pytest.raises(ValueError):
        build()


@pytest.mark.parametrize("kwargs", [
    dict(num_bins=1),
    dict(window_lines=0),
    dict(error_bound=0.0),
    dict(error_bound=-2.0),
    dict(sample_frac=0.0),
    dict(sampler="sobol"),
    dict(kmeans_iters=0),
])
def test_pdf_config_validation(kwargs):
    with pytest.raises(ValueError):
        PDFConfig(**kwargs)


def test_from_json_rejects_unknown_keys_and_versions():
    spec = PipelineSpec()
    payload = spec.to_dict()
    payload["method"]["group_tolerance"] = 1e-3  # typo'd knob must not pass
    with pytest.raises(ValueError, match="unknown spec.method keys"):
        PipelineSpec.from_dict(payload)
    payload = spec.to_dict()
    payload["extra"] = {}
    with pytest.raises(ValueError, match="unknown spec keys"):
        PipelineSpec.from_dict(payload)
    payload = spec.to_dict()
    payload["version"] = 999
    with pytest.raises(ValueError, match="version"):
        PipelineSpec.from_dict(payload)


# -- CLI generation ------------------------------------------------------------


def _parse(argv, base=None):
    ap = argparse.ArgumentParser()
    add_spec_args(ap)
    return spec_from_args(ap.parse_args(argv), base=base)


def test_cli_flags_override_defaults():
    spec = _parse(["--method", "grouping_ml", "--types", "10",
                   "--group-tol", "1e-4", "--window-lines", "9",
                   "--tree-depth", "6", "--slices", "0", "2", "--serial"])
    assert spec.method.name == "grouping_ml"
    assert spec.compute.types == d.TYPES_10
    assert spec.method.group_tol == 1e-4
    assert spec.compute.window_lines == 9
    assert spec.method.tree.depth == 6
    assert spec.execution.slices == (0, 2)
    assert spec.execution.prefetch is False and spec.execution.async_persist is False


def test_cli_base_defaults_survive_unless_overridden():
    base = PipelineSpec(compute=ComputeSpec(num_bins=20))
    assert _parse([], base=base).compute.num_bins == 20
    assert _parse(["--num-bins", "32"], base=base).compute.num_bins == 32


def test_cli_cache_dir_and_source_path_flags():
    spec = _parse(["--cache-dir", "/tmp/rc"])
    assert spec.execution.cache_dir == "/tmp/rc"
    spec = _parse(["--kind", "file", "--source-path", "/data/cube"])
    assert spec.source.kind == "file" and spec.source.path == "/data/cube"


def test_spec_reference_doc_is_in_sync():
    """docs/spec_reference.md is generated from the spec metadata
    (`python -m repro.api.cli --doc`); a spec-field change must ship its
    regenerated doc (CI's docs-sync job enforces the same invariant)."""
    from pathlib import Path

    from repro.api.cli import render_spec_reference

    doc = Path(__file__).resolve().parent.parent / "docs" / "spec_reference.md"
    assert doc.exists(), "docs/spec_reference.md missing — run " \
                         "python -m repro.api.cli --doc --out docs/spec_reference.md"
    assert doc.read_text() == render_spec_reference(), \
        "docs/spec_reference.md is stale — regenerate with " \
        "python -m repro.api.cli --doc --out docs/spec_reference.md"


def test_cli_spec_file_roundtrip(tmp_path):
    spec = PipelineSpec(source=SMALL_SOURCE, method=MethodSpec(name="reuse"),
                        compute=ComputeSpec(num_bins=24))
    f = tmp_path / "spec.json"
    f.write_text(spec.to_json())
    loaded = _parse(["--spec", str(f)])
    assert loaded == spec
    # explicit flags override the file
    assert _parse(["--spec", str(f), "--method", "baseline"]).method.name == "baseline"


def test_no_pipeline_flags_declared_outside_api_cli():
    """The acceptance grep, as a test: consumers must not hand-declare
    pipeline knobs — the spec is the single declaration site."""
    from pathlib import Path

    root = Path(__file__).resolve().parent.parent
    pipeline_flags = (
        "--method", "--group-tol", "--rep-bucket", "--window-lines",
        "--num-bins", "--types", "--fit-backend", "--select-backend",
        "--mode", "--slices", "--shards", "--shard", "--prefetch",
        "--obs", "--ppl", "--lines", "--num-slices", "--error-bound",
        "--sample-frac", "--sampler", "--resume", "--serial",
    )
    consumers = [
        *(root / "src" / "repro" / "launch").glob("*pdf*.py"),
        *(root / "benchmarks").glob("*.py"),
        *(root / "examples").glob("pdf*.py"),
        root / "examples" / "quickstart.py",
    ]
    offenders = []
    for path in consumers:
        text = path.read_text()
        for flag in pipeline_flags:
            if f'add_argument("{flag}"' in text or f"add_argument('{flag}'" in text:
                offenders.append(f"{path.name}: {flag}")
    assert not offenders, offenders


# -- source spec <-> live source ----------------------------------------------


def test_source_spec_describes_and_rebuilds_the_simulation(sim):
    spec = source_spec_for(sim)
    assert spec == SMALL_SOURCE
    rebuilt = build_source(spec)
    assert rebuilt.geometry == sim.geometry
    from repro.core.regions import Window

    w = Window(2, 0, 3)
    np.testing.assert_array_equal(rebuilt.load_window(w), sim.load_window(w))


def test_external_source_requires_object():
    with pytest.raises(ValueError, match="external"):
        build_source(SourceSpec(kind="external"))


def test_paper_workload_configs_lift_to_specs():
    from repro.configs.pdf_seismic import SET1, SET3, to_spec

    s1 = to_spec(SET1)
    assert s1.source.num_slices == 501 and s1.compute.window_lines == 25
    assert s1.execution.slices == (201,)
    assert PipelineSpec.from_json(s1.to_json()) == s1
    assert to_spec(SET3).content_hash() != s1.content_hash()


# -- session vs shim: bitwise equivalence --------------------------------------


@pytest.mark.parametrize("method", ["baseline", "grouping", "reuse"])
def test_session_matches_shim_bitwise(sim, method):
    spec = PipelineSpec(source=SMALL_SOURCE, method=MethodSpec(name=method),
                        compute=ComputeSpec(window_lines=3))
    shim_res = PDFComputer(spec.pdf_config(), sim).run_slice(2)
    sess_res = PDFSession(spec, data_source=sim).run_all([2])[2]
    for f in RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(shim_res, f), getattr(sess_res, f),
                                      err_msg=f)
    assert shim_res.avg_error == sess_res.avg_error
    assert shim_res.spec_hash == sess_res.spec_hash == spec.content_hash()


def test_session_streams_slices_in_order(sim):
    spec = PipelineSpec(source=SMALL_SOURCE, compute=ComputeSpec(window_lines=3),
                        execution=ExecSpec(slices=(3, 1, 2)))
    session = PDFSession(spec, data_source=sim)
    seen = [r.slice_i for r in session.run()]
    assert seen == [3, 1, 2]
    rep = session.report()
    assert rep.slices_done == 3
    assert rep.windows == 9  # 9 lines / 3-line windows x 3 slices
    assert rep.spec_hash == spec.content_hash()


# -- sampling as a first-class method ------------------------------------------


def test_sampling_full_fraction_matches_feature_helper(sim):
    spec = PipelineSpec(
        source=SMALL_SOURCE,
        method=MethodSpec(name="sampling", sample_frac=1.0),
        compute=ComputeSpec(window_lines=9),  # one window: same scope as helper
    )
    session = PDFSession(spec, data_source=sim)
    res = session.run_all([2])[2]
    assert (res.type_idx >= 0).all()  # frac=1.0 classifies every point
    assert sum(s.num_fitted for s in res.stats) == len(res.type_idx)
    got = res.features(spec.compute.types)

    ref = smp.slice_features_from_moments(
        res.mean, res.std, session.tree, spec.compute.types,
        group_tol=spec.method.group_tol, skew=res.skew, kurt=res.kurt,
    )
    np.testing.assert_array_equal(got.type_percentage, ref.type_percentage)
    assert got.num_sampled == ref.num_sampled
    assert got.avg_mean == pytest.approx(ref.avg_mean)
    assert got.avg_std == pytest.approx(ref.avg_std)


def test_sampling_partial_fraction_marks_unsampled(sim):
    spec = PipelineSpec(
        source=SMALL_SOURCE,
        method=MethodSpec(name="sampling", sample_frac=0.25, sample_seed=3),
        compute=ComputeSpec(window_lines=3),
    )
    res = PDFSession(spec, data_source=sim).run_all([2])[2]
    mask = res.type_idx >= 0
    frac = mask.mean()
    assert 0.2 <= frac <= 0.3
    assert res.avg_error == 0.0  # no Eq.-5 fitting at all
    # the random sampler subsets the window BEFORE the moments pass (§5.4's
    # cost falls with the rate): unsampled rows never got moments
    assert (res.mean[~mask] == 0).all()
    assert (np.abs(res.mean[mask]) > 0).all()
    # draw is seeded per (sample_seed, slice, line): a re-run reproduces it
    res2 = PDFSession(spec, data_source=sim).run_all([2])[2]
    np.testing.assert_array_equal(res.type_idx, res2.type_idx)
    np.testing.assert_array_equal(res.mean, res2.mean)


def test_sampling_kmeans_runs(sim):
    spec = PipelineSpec(
        source=SMALL_SOURCE,
        method=MethodSpec(name="sampling", sample_frac=0.2, sampler="kmeans",
                          kmeans_iters=3),
        compute=ComputeSpec(window_lines=9),
    )
    res = PDFSession(spec, data_source=sim).run_all([2])[2]
    mask = res.type_idx >= 0
    assert 0 < mask.sum() <= len(res.type_idx)


# -- resume provenance ---------------------------------------------------------


def test_resume_refuses_mismatched_spec(sim, tmp_path):
    out = str(tmp_path / "ckpt")
    spec = PipelineSpec(source=SMALL_SOURCE, method=MethodSpec(name="grouping"),
                        compute=ComputeSpec(window_lines=3),
                        execution=ExecSpec(out_dir=out))
    PDFSession(spec, data_source=sim).run_all([2])

    changed = dataclasses.replace(spec, method=MethodSpec(name="grouping",
                                                          group_tol=1e-3))
    with pytest.raises(ValueError, match="resume mismatch"):
        PDFSession(changed, data_source=sim).run_all([2], resume=True)

    # the matching spec resumes cleanly (and re-runs nothing)
    res = PDFSession(spec, data_source=sim).run_all([2], resume=True)[2]
    assert len(res.stats) == 0


def test_watermark_and_npz_carry_spec_hash(sim, tmp_path):
    out = tmp_path / "ckpt"
    spec = PipelineSpec(source=SMALL_SOURCE, compute=ComputeSpec(window_lines=3),
                        execution=ExecSpec(out_dir=str(out)))
    PDFSession(spec, data_source=sim).run_all([2])
    mark = json.loads((out / "slice2_watermark.json").read_text())
    assert mark["spec_hash"] == spec.content_hash()
    z = np.load(next(out.glob("slice2_window_*.npz")))
    assert str(z["spec_hash"]) == spec.content_hash()
