"""Executor-level backend equivalence: fit_backend='fused' (the default) vs
'reference' for every method on both candidate sets (the fused-fit issue's
acceptance matrix)."""

import numpy as np
import pytest

from repro.core import distributions as d
from repro.core.pipeline import METHODS, PDFComputer, PDFConfig, train_type_tree
from repro.core.regions import CubeGeometry
from repro.data.simulation import SeismicSimulation, SimulationConfig


@pytest.fixture(scope="module")
def sim():
    return SeismicSimulation(
        SimulationConfig(geometry=CubeGeometry(8, 6, 10), num_simulations=200)
    )


@pytest.fixture(scope="module")
def trees(sim):
    return {
        len(types): train_type_tree(sim, types, window_lines=2)
        for types in (d.TYPES_4, d.TYPES_10)
    }


def test_default_backend_is_fused():
    assert PDFConfig().fit_backend == "fused"


# method='sampling' is excluded: it never runs ComputePDF&Error, so there is
# no fit backend to compare (its cross-backend behaviour is covered by the
# moments tolerances asserted for every fitting method here, and by
# tests/test_api.py's sampling tests).
FIT_METHODS = tuple(m for m in METHODS if m != "sampling")


@pytest.mark.parametrize("types", [d.TYPES_4, d.TYPES_10], ids=["4types", "10types"])
@pytest.mark.parametrize("method", FIT_METHODS)
def test_fused_matches_reference(sim, trees, method, types):
    tree = trees[len(types)] if "ml" in method else None
    res = {}
    for backend in ("reference", "fused"):
        cfg = PDFConfig(
            types=types, window_lines=2, method=method, fit_backend=backend
        )
        res[backend] = PDFComputer(cfg, sim, tree=tree).run_slice(4)
    a, b = res["reference"], res["fused"]
    np.testing.assert_array_equal(a.type_idx, b.type_idx)
    np.testing.assert_allclose(a.error, b.error, atol=2e-3)
    np.testing.assert_allclose(a.params, b.params, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(a.mean, b.mean, rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(a.std, b.std, rtol=2e-2, atol=1e-2)
