"""Tier-1 coverage for the ``repro.analysis`` invariant checker.

Three layers:

* the *framework* — seeded-violation fixtures per rule (via the built-in
  self-check), inline suppression, baseline matching/staleness, the CLI
  exit-code contract;
* the *repo pin* — the shipped tree plus ``analysis_baseline.json`` must be
  clean (exit 0), and the baseline must stay within its ≤ 5-entry budget
  with a justification on every row;
* the *HASH ground truth* — the static rule only checks that ``hashed=``
  tags agree with the declarations in ``api.spec``; here we check the
  declarations agree with *runtime behavior*, by mutating every single spec
  field and asserting ``content_hash`` moves iff the field says it should.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import fields, replace
from pathlib import Path

import pytest

from repro.analysis import ALL_RULES
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.engine import (
    BaselineError,
    analyze_source,
    analyze_tree,
    apply_baseline,
    load_baseline,
)
from repro.analysis.selfcheck import FIXTURE_DIR, FIXTURES, run_self_check
from repro.api import spec as spec_mod
from repro.api.spec import (
    HASH_EXCLUDED_FIELDS,
    HASHED_SECTIONS,
    PipelineSpec,
)
from repro.core import distributions as dists

REPO_ROOT = Path(__file__).resolve().parent.parent
BASELINE = REPO_ROOT / "analysis_baseline.json"
PACKAGE_ROOT = Path(spec_mod.__file__).resolve().parent.parent


# -- the framework --------------------------------------------------------------


def test_self_check_is_clean():
    """Every rule finds exactly its fixture's ``# expect[RULE]`` lines —
    nothing more, nothing less — and honors the fixture's suppression."""
    assert run_self_check() == []


def test_every_rule_has_a_fixture():
    covered = {r.name for _, _, rules in FIXTURES for r in rules}
    assert covered == {r.name for r in ALL_RULES}


def test_fixtures_seed_findings_and_suppressions():
    """Each fixture actually produces findings for its rule (the checker is
    not vacuously green) and carries at least one exercised suppression."""
    for fname, relpath, rules in FIXTURES:
        src = (FIXTURE_DIR / fname).read_text()
        findings, suppressed = analyze_source(src, relpath, list(rules))
        assert findings, f"{fname} seeded no findings"
        assert suppressed >= 1, f"{fname} exercised no suppression"
        assert {f.rule for f in findings} == {r.name for r in rules}


DET_VIOLATION = "import time\n\n\ndef f():\n    return time.time()\n"


def test_inline_suppression_silences_a_finding():
    findings, suppressed = analyze_source(DET_VIOLATION, "core/x.py",
                                          list(ALL_RULES))
    assert [f.rule for f in findings] == ["DET"]
    silenced = DET_VIOLATION.replace(
        "time.time()", "time.time()  # repro: allow[DET]: test")
    findings, suppressed = analyze_source(silenced, "core/x.py",
                                          list(ALL_RULES))
    assert findings == [] and suppressed == 1


def test_wildcard_suppression():
    silenced = DET_VIOLATION.replace("time.time()",
                                     "time.time()  # repro: allow[*]")
    findings, suppressed = analyze_source(silenced, "core/x.py",
                                          list(ALL_RULES))
    assert findings == [] and suppressed == 1


def test_out_of_scope_paths_are_ignored():
    findings, _ = analyze_source(DET_VIOLATION, "benchmarks_glue/x.py",
                                 list(ALL_RULES))
    assert findings == []


def test_baseline_matches_by_snippet_not_line():
    findings, _ = analyze_source(DET_VIOLATION, "core/x.py", list(ALL_RULES))
    entry = {"rule": "DET", "path": "core/x.py",
             "snippet": "return time.time()", "justification": "test"}
    new, baselined, stale = apply_baseline(findings, [entry])
    assert new == [] and len(baselined) == 1 and stale == []
    # the same source shifted down two lines still matches (identity is the
    # stripped line, not its number) ...
    shifted, _ = analyze_source("\n\n" + DET_VIOLATION, "core/x.py",
                                list(ALL_RULES))
    new, baselined, stale = apply_baseline(shifted, [entry])
    assert new == [] and len(baselined) == 1
    # ... but once the offending line is gone the entry is stale.
    new, baselined, stale = apply_baseline([], [entry])
    assert stale == [entry]


def test_baseline_requires_justification(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"findings": [
        {"rule": "DET", "path": "core/x.py", "snippet": "x"}]}))
    with pytest.raises(BaselineError, match="justification"):
        load_baseline(p)


# -- the CLI exit-code contract -------------------------------------------------


def _seeded_tree(tmp_path: Path) -> Path:
    root = tmp_path / "pkg"
    (root / "core").mkdir(parents=True)
    (root / "core" / "bad.py").write_text(DET_VIOLATION)
    return root


def test_cli_flags_seeded_violation(tmp_path, capsys):
    root = _seeded_tree(tmp_path)
    assert analysis_main(["--root", str(root)]) == 1
    out = capsys.readouterr().out
    assert "core/bad.py" in out and "[DET]" in out


def test_cli_clean_tree_exits_zero(tmp_path, capsys):
    root = tmp_path / "pkg"
    (root / "core").mkdir(parents=True)
    (root / "core" / "ok.py").write_text("X = 1\n")
    assert analysis_main(["--root", str(root)]) == 0


def test_cli_stale_baseline_fails(tmp_path, capsys):
    root = tmp_path / "pkg"
    (root / "core").mkdir(parents=True)
    (root / "core" / "ok.py").write_text("X = 1\n")
    b = tmp_path / "b.json"
    b.write_text(json.dumps({"findings": [
        {"rule": "DET", "path": "core/ok.py", "snippet": "gone()",
         "justification": "was fixed"}]}))
    assert analysis_main(["--root", str(root), "--baseline", str(b)]) == 1
    assert "stale baseline entry" in capsys.readouterr().out


def test_cli_json_report(tmp_path, capsys):
    root = _seeded_tree(tmp_path)
    assert analysis_main(["--root", str(root), "--json"]) == 1
    report = json.loads(capsys.readouterr().out)
    assert report["files"] == 1
    assert [f["rule"] for f in report["new"]] == ["DET"]
    assert report["new"][0]["snippet"] == "return time.time()"


def test_cli_unknown_rule_is_usage_error(tmp_path, capsys):
    assert analysis_main(["--rules", "NOPE"]) == 2


def test_cli_rule_subset(tmp_path, capsys):
    root = _seeded_tree(tmp_path)
    # the violation is DET-only, so a SHAPE-only run is clean
    assert analysis_main(["--root", str(root), "--rules", "SHAPE"]) == 0
    assert analysis_main(["--root", str(root), "--rules", "DET"]) == 1


# -- the repo pin ---------------------------------------------------------------


def test_repo_tree_is_clean_under_baseline(capsys):
    """The acceptance gate, pinned as a test: the shipped tree plus the
    checked-in baseline is clean. CI runs the same command."""
    rc = analysis_main(["--root", str(PACKAGE_ROOT),
                        "--baseline", str(BASELINE)])
    assert rc == 0, capsys.readouterr().out


def test_repo_baseline_within_budget():
    entries = load_baseline(BASELINE)
    assert len(entries) <= 5, "baseline budget is 5 justified findings"
    for e in entries:
        assert e["justification"].strip()


# -- HASH ground truth: hashed= tags agree with content_hash behavior -----------

# One mutation per spec field, each producing a *valid* spec (post_init
# passes) that differs from the default in that field. A few knobs are only
# valid together (resume needs out_dir, ...) — those mutate as a dict whose
# fields must all carry the same hashed= tag.
_OTHER = lambda choices, cur: next(c for c in choices if c != cur)  # noqa: E731

MUTATIONS: dict[str, dict[str, object]] = {
    "source": {
        "kind": "external",
        "num_slices": 9, "lines_per_slice": 25, "points_per_line": 61,
        "observations": 301, "num_layers": 17, "base_vp": 3100.0,
        "quantize_decimals": 4, "group_block": 5, "line_block": 3,
        "seed": 1, "throttle_mb_s": 5.0,
    },
    "method": {
        "name": "grouping", "group_tol": 0.123, "rep_bucket": 65,
        "error_bound": 0.5, "sample_frac": 0.2, "sampler": "kmeans",
        "kmeans_iters": 11, "sample_seed": 1,
    },
    "method.tree": {
        "depth": 5, "max_bins": 33, "train_slices": (0, 1),
        "train_window_lines": 5,
    },
    "compute": {
        "types": dists.TYPES_10, "num_bins": 65, "window_lines": 7,
        "mode": "faithful",
        "fit_backend": "__other__", "select_backend": "__other__",
    },
    "execution": {
        "slices": (0,), "shards": 2, "shard": 0, "prefetch": False,
        "prefetch_depth": 3, "async_persist": False, "out_dir": "/tmp/x",
        "resume": {"resume": True, "out_dir": "/tmp/x"},
        "cache_dir": "/tmp/c",
        "cache_max_bytes": {"cache_max_bytes": 100, "cache_dir": "/tmp/c"},
        "max_retries": 3, "retry_backoff_s": 0.1, "speculate": False,
        "straggler_grace_s": 2.0, "degraded_mode": False,
        "fault_plan": "plan.json", "compile_cache_dir": "/tmp/cc",
    },
    "execution.placement": {
        "num_processes": 2, "process_id": 0,
        "coordinator": "127.0.0.1:23456", "distributed": False,
        "shard_devices": (0,), "redeal": False, "peer_timeout_s": 5.0,
    },
    "serve": {
        "tick_seconds": 0.002, "max_batch_windows": 16, "coalesce": False,
        "window_cache_entries": 0, "request_deadline_s": 1.0,
        "max_queue_depth": 4, "retry_transient": 3,
    },
    "stream": {
        "update_mode": "strict", "persist_stats": True, "incremental": False,
        "poll_interval_s": 7.5, "max_updates": 2,
    },
}

# Fields that cannot be mutated in isolation on a valid default spec:
# ``path``/``layout`` only mean anything for kind='file' (which hashes by
# manifest bytes, not by these fields). They must be tagged un-hashed AND
# appear in the source carve-out — asserted explicitly below.
UNMUTABLE = {("source", "path"), ("source", "layout")}


def _apply(spec: PipelineSpec, path: str, **mut) -> PipelineSpec:
    if path == "method.tree":
        return replace(spec, method=replace(
            spec.method, tree=replace(spec.method.tree, **mut)))
    if path == "execution.placement":
        pl = replace(spec.execution.placement, **mut)
        # num_processes > 1 is only valid with a shared out_dir (markers
        # and results live there); out_dir is un-hashed too, so supplying
        # one keeps the mutation's hash behavior attributable to ``mut``.
        out_dir = spec.execution.out_dir if pl.num_processes == 1 else "/tmp/x"
        return replace(spec, execution=replace(
            spec.execution, placement=pl, out_dir=out_dir))
    return replace(spec, **{path: replace(getattr(spec, path), **mut)})


def _resolve(value, fld):
    if value == "__other__":
        return _OTHER(fld.metadata["choices"], fld.default)
    return value


def _iter_spec_fields():
    for path, cls, _prefix in spec_mod._GROUPS:
        for fld in fields(cls):
            if path == "method" and fld.name == "tree":
                continue  # covered field-by-field via the method.tree group
            if path == "execution" and fld.name == "placement":
                continue  # covered via the execution.placement group
            yield path, fld


def test_every_field_declares_hashed():
    for path, fld in _iter_spec_fields():
        assert isinstance(fld.metadata.get("hashed"), bool), \
            f"{path}.{fld.name} has no machine-readable hashed= tag"


def test_every_field_has_mutation_coverage():
    """A new spec field must land in MUTATIONS (or the justified UNMUTABLE
    set) or this fails — metadata ↔ hash agreement stays total forever."""
    for path, fld in _iter_spec_fields():
        if (path, fld.name) in UNMUTABLE:
            continue
        assert fld.name in MUTATIONS[path], \
            f"no hash-behavior mutation for {path}.{fld.name}"


def test_hashed_tags_match_content_hash_behavior():
    base = PipelineSpec()
    base_hash = base.content_hash()
    for path, fld in _iter_spec_fields():
        if (path, fld.name) in UNMUTABLE:
            continue
        raw = _resolve(MUTATIONS[path][fld.name], fld)
        mut = raw if isinstance(raw, dict) else {fld.name: raw}
        changed = _apply(base, path, **mut).content_hash() != base_hash
        expect = fld.metadata["hashed"]
        assert changed == expect, (
            f"{path}.{fld.name}: hashed={expect} but mutating it "
            f"{'changed' if changed else 'did not change'} content_hash")


def test_unmutable_fields_are_carved_out():
    for path, name in UNMUTABLE:
        cls = dict((p, c) for p, c, _ in spec_mod._GROUPS)[path]
        fld = next(f for f in fields(cls) if f.name == name)
        assert fld.metadata["hashed"] is False
        assert name in HASH_EXCLUDED_FIELDS[path]


def test_declarations_cover_all_sections():
    spec_fields = {f.name for f in fields(PipelineSpec)} - {"version"}
    for s in HASHED_SECTIONS:
        assert s in spec_fields
    assert set(HASH_EXCLUDED_FIELDS) <= set(HASHED_SECTIONS)


def test_hash_pin():
    """The default spec's hash — BENCH ``__specs__`` rows and on-disk cache
    entries key on it; an unintended change here silently invalidates every
    existing cache. Bump deliberately, with a SPEC_VERSION bump."""
    assert PipelineSpec().content_hash() == "64aa94238649ed57"
