"""Seismic simulation generator: determinism, structure, type recovery."""

import numpy as np

from repro.core import distributions as d
from repro.core import fitting
from repro.core.regions import CubeGeometry, Window, iter_windows, num_windows
from repro.data.simulation import SeismicSimulation, SimulationConfig


def _sim(**kw):
    base = dict(geometry=CubeGeometry(8, 6, 12), num_simulations=400)
    base.update(kw)
    return SeismicSimulation(SimulationConfig(**base))


def test_deterministic_reload():
    sim = _sim()
    w = Window(3, 0, 2)
    a = sim.load_window(w)
    b = _sim().load_window(w)  # fresh instance, same seed
    np.testing.assert_array_equal(a, b)


def test_window_shapes():
    sim = _sim()
    w = Window(0, 1, 4)
    vals = sim.load_window(w)
    assert vals.shape == (3 * 12, 400)
    assert vals.dtype == np.float32
    assert np.isfinite(vals).all()


def test_grouping_redundancy_exists():
    """group_block points share a generator cell => exact (mu, sigma) dupes,
    the redundancy §5.2 exploits."""
    sim = _sim(group_block=4)
    vals = sim.load_window(Window(0, 0, 1))
    mu = vals.mean(1)
    uniq = len(np.unique(np.round(mu, 6)))
    assert uniq <= len(mu) / 2, (uniq, len(mu))


def test_fit_recovers_layer_type():
    """Points in a slice follow the dominant layer's distribution family."""
    import jax.numpy as jnp

    sim = _sim(num_simulations=2000)
    # pick a slice dominated by a normal layer (cycle index 0)
    for slice_i in range(8):
        if sim.true_type_index(slice_i) == 0:
            break
    vals = sim.load_window(Window(slice_i, 0, 1))
    v = jnp.asarray(vals[:8])
    m = d.moments_from_values(v)
    r = fitting.compute_pdf_and_error(v, m, d.TYPES_4, 20)
    picked = np.asarray(r.type_idx)
    # normal should dominate the picks (affine maps preserve the family)
    assert (picked == 0).mean() >= 0.7, picked


def test_iter_windows_partition():
    geom = CubeGeometry(4, 10, 5)
    ws = list(iter_windows(geom, 1, 3))
    assert num_windows(geom, 3) == len(ws) == 4
    covered = []
    for w in ws:
        covered.extend(range(w.line_start, w.line_end))
    assert covered == list(range(10))


def test_point_id_unique():
    geom = CubeGeometry(3, 4, 5)
    ids = {
        geom.point_id(s, l, p)
        for s in range(3)
        for l in range(4)
        for p in range(5)
    }
    assert len(ids) == geom.total_points


def test_nominal_bytes_set1_scale():
    from repro.configs.pdf_seismic import SET1, SET3

    sim1 = SeismicSimulation(
        SimulationConfig(geometry=SET1.geometry, num_simulations=SET1.num_simulations)
    )
    # Set1 in the paper is 235 GB of raw float data
    assert abs(sim1.nominal_bytes() / 1e9 - 251.9) < 260  # order-of-magnitude
    assert sim1.nominal_bytes() == 501 * 501 * 251 * 1000 * 4
