"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pdf_error as pe
from repro.kernels.hist import hist_ref, histogram
from repro.kernels.moments import moments, stats_ref

SHAPES = [(1, 64), (7, 100), (8, 512), (16, 1000), (3, 513), (32, 2048), (5, 1)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _f64_moments(v):
    v = np.asarray(v, np.float64)
    n = v.shape[1]
    mean = v.mean(1)
    c = v - mean[:, None]
    m2 = (c**2).mean(1)
    m3 = (c**3).mean(1)
    m4 = (c**4).mean(1)
    var = m2 * n / max(n - 1, 1)
    sig = np.sqrt(np.maximum(m2, 1e-12))
    return np.stack(
        [mean, var, m3 / sig**3, m4 / np.maximum(m2, 1e-12) ** 2 - 3, v.min(1), v.max(1)], 1
    )


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_moments_kernel_allclose(shape, dtype):
    rng = np.random.default_rng(hash(shape) % 2**31)
    v = (3000 + 10 * rng.standard_normal(shape)).astype(np.float32)
    vx = jnp.asarray(v, dtype)
    m = moments(vx)
    oracle = _f64_moments(np.asarray(vx, np.float32))
    got = np.stack([np.asarray(x, np.float64) for x in m], 1)
    tol = 1e-3 if dtype == jnp.float32 else 0.15
    # mean/min/max relative to value scale; var relative; skew/kurt absolute.
    np.testing.assert_allclose(got[:, 0], oracle[:, 0], rtol=tol, atol=tol)
    np.testing.assert_allclose(got[:, 1], oracle[:, 1], rtol=0.05 if dtype != jnp.float32 else 2e-3, atol=tol)
    np.testing.assert_allclose(got[:, 2], oracle[:, 2], atol=0.3 if dtype != jnp.float32 else 5e-3)
    np.testing.assert_allclose(got[:, 3], oracle[:, 3], atol=1.0 if dtype != jnp.float32 else 2e-2)
    np.testing.assert_allclose(got[:, 4], oracle[:, 4], rtol=tol, atol=tol)
    np.testing.assert_allclose(got[:, 5], oracle[:, 5], rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("num_bins", [8, 20, 64])
def test_hist_kernel_allclose(shape, num_bins):
    rng = np.random.default_rng(hash((shape, num_bins)) % 2**31)
    v = rng.standard_normal(shape).astype(np.float32)
    vx = jnp.asarray(v)
    vmin, vmax = vx.min(1), vx.max(1)
    got = np.asarray(histogram(vx, vmin, vmax, num_bins))
    ref = np.asarray(hist_ref(vx, vmin, vmax, num_bins))
    np.testing.assert_array_equal(got, ref)
    np.testing.assert_array_equal(got.sum(1), np.full(shape[0], shape[1]))


def test_hist_kernel_constant_rows():
    """All-equal rows (span ~0) must not NaN; everything lands in bin 0."""
    v = jnp.full((4, 100), 7.0)
    got = np.asarray(histogram(v, v.min(1), v.max(1), 16))
    assert got[:, 0].sum() == 4 * 100
    assert np.isfinite(got).all()


def test_kernels_compose_into_eq5():
    """Kernel-backed Eq. 5 == reference Eq. 5 (fitting.histogram_fn hook)."""
    from repro.core import distributions as d
    from repro.core import fitting

    v = d.sample("gamma", (2.0, 1.5, 0.0), jax.random.PRNGKey(3), (9, 700))
    m_ref = d.moments_from_values(v)
    a = fitting.compute_pdf_and_error(v, m_ref, d.TYPES_4, 20)
    m_k = moments(v)
    b = fitting.compute_pdf_and_error(v, m_k, d.TYPES_4, 20, histogram_fn=histogram)
    np.testing.assert_array_equal(np.asarray(a.type_idx), np.asarray(b.type_idx))
    np.testing.assert_allclose(np.asarray(a.error), np.asarray(b.error), atol=1e-3)
