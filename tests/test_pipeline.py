"""End-to-end pipeline (Algorithms 1-2): method equivalence, reuse, restart."""

import numpy as np
import pytest

from repro.core import distributions as d
from repro.core import ml_predict as mlp
from repro.core.pipeline import PDFComputer, PDFConfig
from repro.core.regions import CubeGeometry
from repro.data.simulation import SeismicSimulation, SimulationConfig


@pytest.fixture(scope="module")
def sim():
    return SeismicSimulation(
        SimulationConfig(geometry=CubeGeometry(8, 9, 12), num_simulations=300)
    )


@pytest.fixture(scope="module")
def tree(sim):
    """Train the type tree from 'previously generated output data'
    (baseline over slices 0-3, covering all four types; §5.3.1)."""
    from repro.core.pipeline import train_type_tree

    return train_type_tree(sim, window_lines=3)


def test_baseline_runs_and_bounds_error(sim):
    comp = PDFComputer(
        PDFConfig(window_lines=4, method="baseline", error_bound=1.0), sim
    )
    res = comp.run_slice(3)
    assert res.type_idx.shape == (9 * 12,)
    assert np.isfinite(res.error).all()
    assert res.error_bound_satisfied is True
    assert 0 <= res.avg_error <= 2


def test_grouping_matches_baseline_exactly(sim):
    """With exact keys, grouped PDFs == per-point PDFs (same mean/std => same
    observations in this generator)."""
    base = PDFComputer(PDFConfig(window_lines=3, method="baseline"), sim)
    grup = PDFComputer(PDFConfig(window_lines=3, method="grouping"), sim)
    rb = base.run_slice(2)
    rg = grup.run_slice(2)
    np.testing.assert_array_equal(rb.type_idx, rg.type_idx)
    np.testing.assert_allclose(rb.error, rg.error, rtol=1e-6)
    # grouping must actually reduce fitted points (generator has redundancy)
    assert sum(s.num_fitted for s in rg.stats) < sum(s.num_fitted for s in rb.stats)


def test_reuse_hits_across_windows(sim):
    comp = PDFComputer(PDFConfig(window_lines=3, method="reuse"), sim)
    res = comp.run_slice(2)
    assert comp.cache.hits > 0, "windows share (mu, sigma) keys in this generator"
    assert comp.cache.size > 0


def test_ml_method_small_extra_error(sim, tree):
    base = PDFComputer(PDFConfig(window_lines=3, method="baseline"), sim)
    ml = PDFComputer(PDFConfig(window_lines=3, method="ml"), sim, tree=tree)
    rb = base.run_slice(4)
    rm = ml.run_slice(4)
    # the paper: WithML error is slightly larger, bounded (<= 0.017 there).
    assert rm.avg_error <= rb.avg_error + 0.05
    agreement = (rm.type_idx == rb.type_idx).mean()
    assert agreement > 0.9, f"tree should usually predict argmin type ({agreement})"


def test_grouping_ml_combination(sim, tree):
    comp = PDFComputer(PDFConfig(window_lines=3, method="grouping_ml"), sim, tree=tree)
    res = comp.run_slice(4)
    assert np.isfinite(res.avg_error)
    assert sum(s.num_fitted for s in res.stats) < 9 * 12


def test_restart_from_watermark(sim, tmp_path):
    cfg = PDFConfig(window_lines=3, method="grouping")
    full = PDFComputer(cfg, sim, out_dir=tmp_path / "full").run_slice(5)

    out = tmp_path / "restart"
    partial = PDFComputer(cfg, sim, out_dir=out)
    windows_done = 0

    class Stop(Exception):
        pass

    def crash_after_one(ws):
        nonlocal windows_done
        windows_done += 1
        if windows_done == 1:
            raise Stop()

    with pytest.raises(Stop):
        partial.run_slice(5, on_window=crash_after_one)

    resumed = PDFComputer(cfg, sim, out_dir=out).run_slice(5, resume=True)
    np.testing.assert_array_equal(resumed.type_idx, full.type_idx)
    np.testing.assert_allclose(resumed.error, full.error, rtol=1e-6)
    # resumed run did fewer windows than the full run
    assert len(resumed.stats) < len(full.stats)


@pytest.mark.parametrize("backend", ["kernels", "fused"])
def test_kernel_backed_pipeline_matches_reference(sim, backend):
    a = PDFComputer(
        PDFConfig(window_lines=3, method="baseline", fit_backend="reference"), sim
    ).run_slice(1)
    b = PDFComputer(
        PDFConfig(window_lines=3, method="baseline", fit_backend=backend), sim
    ).run_slice(1)
    np.testing.assert_array_equal(a.type_idx, b.type_idx)
    np.testing.assert_allclose(a.error, b.error, atol=2e-3)


def test_unknown_fit_backend_rejected():
    with pytest.raises(ValueError, match="fit_backend"):
        PDFConfig(fit_backend="vectorized")
