"""Optimizer substrate: AdamW, Adafactor, schedule, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    AdamWConfig, adamw_init, adamw_update,
    adafactor_init, adafactor_update,
    compress_int8, decompress_int8, pod_allreduce_compressed,
    cosine_schedule,
)


def _quadratic_params():
    return {"a": jnp.asarray([3.0, -2.0]), "b": {"c": jnp.asarray([[1.5]])}}


def test_adamw_converges_on_quadratic():
    params = _quadratic_params()
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw_init(params, cfg)
    loss = lambda p: sum(jnp.sum(x**2) for x in jax.tree.leaves(p))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, gnorm = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-3
    assert int(state.step) == 200


def test_adamw_clips_global_norm():
    params = {"a": jnp.zeros(3)}
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    state = adamw_init(params, cfg)
    huge = {"a": jnp.asarray([1e6, 0.0, 0.0])}
    newp, state, gnorm = adamw_update(huge, state, params, cfg)
    assert float(gnorm) == 1e6
    assert np.isfinite(np.asarray(newp["a"])).all()
    # first-step Adam update magnitude is bounded by lr regardless of g scale
    assert float(jnp.abs(newp["a"]).max()) <= 1.0 + 1e-5


def test_adamw_bf16_moments():
    params = {"w": jnp.ones((8, 8))}
    cfg = AdamWConfig(moment_dtype=jnp.bfloat16)
    state = adamw_init(params, cfg)
    assert state.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((8, 8))}
    newp, state, _ = adamw_update(g, state, params, cfg)
    assert state.mu["w"].dtype == jnp.bfloat16
    assert newp["w"].dtype == params["w"].dtype


def test_adafactor_converges_and_is_factored():
    params = {"w": jnp.full((16, 4), 2.0)}
    state = adafactor_init(params)
    assert state.vr["w"].shape == (16,)
    assert state.vc["w"].shape == (4,)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = adafactor_update(g, state, params, lr=0.05)
    assert float(loss(params)) < 1e-2


def test_cosine_schedule_shape():
    s0 = float(cosine_schedule(0, warmup=10, total=100))
    s_w = float(cosine_schedule(10, warmup=10, total=100))
    s_end = float(cosine_schedule(100, warmup=10, total=100))
    assert s0 == 0.0
    assert abs(s_w - 1.0) < 1e-6
    assert abs(s_end - 0.1) < 1e-2
    mid = [float(cosine_schedule(t, 10, 100)) for t in range(10, 101, 10)]
    assert all(a >= b - 1e-9 for a, b in zip(mid, mid[1:])), "monotone decay"


def test_int8_compression_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q, scale = compress_int8(x)
    assert q.dtype == jnp.int8
    back = decompress_int8(q, scale)
    # max quantization error is scale/2 = max|x|/254
    assert float(jnp.abs(back - x).max()) <= float(scale) / 2 + 1e-7


def test_pod_allreduce_compressed_matches_mean():
    """shard_map over a fake 1-device axis: compressed allreduce == identity
    mean; multi-participant correctness is covered in the subprocess test."""
    mesh = jax.make_mesh((1,), ("pod",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    x = jnp.asarray(np.random.default_rng(1).normal(size=(8,)).astype(np.float32))
    f = shard_map(
        lambda v: pod_allreduce_compressed(v, "pod"),
        mesh=mesh, in_specs=P(), out_specs=P(),
    )
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x), atol=2e-2)
