"""Multi-device mesh behaviour via subprocesses (the parent process must keep
seeing exactly 1 CPU device, so each test spawns python with
--xla_force_host_platform_device_count set)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_runs():
    """Real sharded training step on a 2x4 mesh (reduced granite)."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry
        from repro.models import transformer as T, sharding as sh
        from repro.optim import AdamWConfig, adamw_init, adamw_update
        from repro.launch.mesh import make_mesh
        from jax.sharding import NamedSharding

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = registry.get("granite-3-8b").reduced().replace(
            d_model=64, d_ff=128, q_heads=8, kv_heads=4, vocab=512)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        shardings = sh.make_shardings(cfg, mesh, params)
        params = jax.tree.map(jax.device_put, params, shardings)
        opt = adamw_init(params)
        ocfg = AdamWConfig(lr=1e-3)
        toks = jax.device_put(
            jnp.zeros((4, 32), jnp.int32),
            NamedSharding(mesh, sh.batch_pspec(mesh)))

        @jax.jit
        def step(p, o, t):
            l, g = jax.value_and_grad(lambda q: T.loss_fn(q, t, t, cfg))(p)
            p, o, _ = adamw_update(g, o, p, ocfg)
            return p, o, l

        p2, o2, loss = step(params, opt, toks)
        assert jnp.isfinite(loss), loss
        print("LOSS", float(loss))
    """))


def test_elastic_checkpoint_reshard():
    """Save on a (4,2) mesh, restore onto (2,2) — the elastic-restart path."""
    print(run_py("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_mesh
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh_a = make_mesh((4, 2), ("data", "model"))
        w = jnp.arange(64.0).reshape(8, 8)
        wa = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(1, {"w": wa})
            devs = np.array(jax.devices()[:4]).reshape(2, 2)
            mesh_b = jax.sharding.Mesh(devs, ("data", "model"))
            sh_b = {"w": NamedSharding(mesh_b, P("model", "data"))}
            restored, manifest = mgr.restore_latest({"w": w}, shardings=sh_b)
            np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
            assert restored["w"].sharding == sh_b["w"]
            print("RESHARD OK")
    """))


def test_compressed_pod_allreduce_multiparticipant():
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.optim import pod_allreduce_compressed

        mesh = make_mesh((8,), ("pod",))
        x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 128)).astype(np.float32))
        f = jax.jit(shard_map(
            lambda v: pod_allreduce_compressed(v[0], "pod")[None],
            mesh=mesh, in_specs=P("pod"), out_specs=P("pod")))
        out = np.asarray(f(x))
        want = np.asarray(x).mean(0)
        for i in range(8):
            np.testing.assert_allclose(out[i], want, atol=0.05)
        print("COMPRESSED ALLREDUCE OK", float(np.abs(out[0]-want).max()))
    """))


def test_global_grouping_shard_map():
    """group_device_global: all_gather + dedup inside shard_map matches the
    single-shard result, and the DeviceGroups count contract holds —
    num_groups is global (identical on every shard) while num_groups_local
    (== the shard's sum(is_rep)) varies per shard and sums to the global."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core import grouping as grp
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4,), ("data",))
        rng = np.random.default_rng(0)
        keys = rng.integers(0, 5, size=(32, 2)).astype(np.int32)

        def shard_fn(k):
            g = grp.group_device_global(k, ("data",))
            # scalars ride out as per-shard length-1 rows so the test can see
            # every shard's value without relying on replication inference
            return (g.rep_for_point, g.is_rep,
                    g.num_groups[None], g.num_groups_local[None])

        f = jax.jit(shard_map(
            shard_fn, mesh=mesh, in_specs=P("data"),
            out_specs=(P("data"), P("data"), P("data"), P("data"))))
        rep, is_rep, n_glob, n_loc = (np.asarray(o) for o in f(jnp.asarray(keys)))
        rep_local = np.asarray(grp.group_device(jnp.asarray(keys)).rep_for_point)
        np.testing.assert_array_equal(rep, rep_local)
        n_global = len(np.unique(keys, axis=0))
        # num_groups is the *global* count, identical on every shard...
        np.testing.assert_array_equal(n_glob, n_global)
        # ...while num_groups_local is each shard's sum(is_rep) — generally
        # different from num_groups — and the locals sum to the global.
        for i in range(4):
            assert n_loc[i] == is_rep[i * 8:(i + 1) * 8].sum(), (i, n_loc)
        assert n_loc.sum() == n_global, (n_loc.tolist(), n_global)
        print("GLOBAL GROUPING OK, groups:", n_global,
              "per-shard:", n_loc.tolist())
    """))


def test_pipeline_parallel_ppermute():
    """2-stage GPipe over a 'pod' axis using shard_map + ppermute: outputs
    match the unpipelined reference."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.pipeline_pp import pipelined_forward
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2,), ("stage",))
        key = jax.random.PRNGKey(0)
        w1 = jax.random.normal(key, (16, 16)) * 0.3
        w2 = jax.random.normal(jax.random.fold_in(key, 1), (16, 16)) * 0.3
        x = jax.random.normal(jax.random.fold_in(key, 2), (8, 4, 16))  # (µbatches, b, d)
        ref = jnp.tanh(jnp.tanh(x @ w1) @ w2)
        out = pipelined_forward(mesh, "stage", [w1, w2], x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
        print("PP OK")
    """, devices=2))


def test_flash_decode_matches_plain():
    """flash_decode_attention (shard_map partial-KV) == plain decode
    attention on a 2x4 mesh."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ArchConfig
        from repro.models import layers as L
        from repro.launch.mesh import make_mesh
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = ArchConfig('t','dense',2,64,8,4,16,128,256,
                         param_dtype=jnp.float32, compute_dtype=jnp.float32,
                         remat='none')
        p = L.init_attention(jax.random.PRNGKey(0), cfg)
        B, S = 4, 32
        x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, 64))
        ck = jax.random.normal(jax.random.PRNGKey(2), (B, S, 4, 16))
        cv = jax.random.normal(jax.random.PRNGKey(3), (B, S, 4, 16))
        pos = 21
        ref, cref = L.decode_attention(p, x, {"k": ck, "v": cv}, pos, cfg=cfg)
        ckd = jax.device_put(ck, NamedSharding(mesh, P("data", "model", None, None)))
        cvd = jax.device_put(cv, NamedSharding(mesh, P("data", "model", None, None)))
        out, cfl = L.flash_decode_attention(
            p, x, {"k": ckd, "v": cvd}, pos, cfg=cfg, mesh=mesh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
        np.testing.assert_allclose(np.asarray(cfl["k"]), np.asarray(cref["k"]), atol=1e-6)
        print("FLASH DECODE OK", float(np.abs(np.asarray(out)-np.asarray(ref)).max()))
    """))
